"""Distributed-optimization tricks: compressed cross-pod psum under
shard_map, logical-axis constrained MoE dispatch, elastic remesh."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.optim.optimizer import compressed_psum


def test_compressed_psum_under_shard_map():
    """int8+error-feedback psum over a 1-device 'pod' axis: values match
    plain psum to quantization tolerance, residual returned."""
    mesh = jax.make_mesh((1,), ("pod",))
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)}
    e = {"w": jnp.zeros((16, 16), jnp.float32)}

    @functools.partial(
        shd.shard_map, mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()))
    def allreduce(g, e):
        return compressed_psum(g, "pod", e)

    summed, new_e = allreduce(g, e)
    # pod size 1: sum == dequantized value; error bounded by one step
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(summed["w"] - g["w"]))) <= scale
    np.testing.assert_allclose(
        np.asarray(new_e["w"]), np.asarray(g["w"] - summed["w"]),
        atol=1e-6)


def test_moe_sharded_dispatch_matches_dense():
    """moe_dispatch='sharded' only adds sharding constraints — numerics
    must be identical to the dense dispatch."""
    import dataclasses
    from repro.configs import get_smoke
    from repro.models import transformer as T

    cfg_d = get_smoke("qwen2-moe-a2.7b")
    cfg_s = dataclasses.replace(cfg_d, moe_dispatch="sharded")
    p = T.init_params(jax.random.key(0), cfg_d)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    l_d = jax.jit(lambda p, b: T.lm_loss(p, cfg_d, b))(p, batch)
    l_s = jax.jit(lambda p, b: T.lm_loss(p, cfg_s, b))(p, batch)
    np.testing.assert_allclose(float(l_d), float(l_s), rtol=1e-6)


def test_attn_sp_constraint_is_numeric_noop():
    import dataclasses
    from repro.configs import get_smoke
    from repro.models import transformer as T

    base = dataclasses.replace(get_smoke("qwen3-14b"),
                               attn_impl="chunked", attn_chunk=16)
    sp = dataclasses.replace(base, attn_sp=True)
    p = T.init_params(jax.random.key(1), base)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    l0 = jax.jit(lambda p, b: T.lm_loss(p, base, b))(p, batch)
    l1 = jax.jit(lambda p, b: T.lm_loss(p, sp, b))(p, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


def test_remesh_roundtrip_multidevice_spec():
    """Saving from one sharding and loading under another preserves
    values (1-device meshes stand in for re-scaled pods)."""
    from repro.train.fault import remesh_state

    mesh_a = jax.make_mesh((1,), ("data",))
    mesh_b = jax.make_mesh((1,), ("model",))
    x = jnp.arange(64.0).reshape(8, 8)
    sh_a = jax.sharding.NamedSharding(mesh_a, P("data", None))
    sh_b = jax.sharding.NamedSharding(mesh_b, P(None, "model"))
    xa = jax.device_put(x, sh_a)
    xb = remesh_state({"x": xa}, {"x": sh_b})["x"]
    assert xb.sharding.is_equivalent_to(sh_b, 2)
    np.testing.assert_array_equal(np.asarray(xb), np.asarray(x))
