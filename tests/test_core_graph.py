"""Region graphs (ordered dependences, F1/F2) + criticality planning (F5)."""
from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.criticality import (RegionCost, dedicated_efficiency,
                                    mxu_padded, plan_split)
from repro.core.dependence import OrderedDep, Region, RegionGraph, fuse_scan


def cholesky_graph():
    return RegionGraph(
        regions=[
            Region("point", fn=None, critical=False),
            Region("vector", fn=None, critical=False),
            Region("matrix", fn=None, critical=True),
        ],
        deps=[
            OrderedDep("point", "vector"),
            # inva consumed by the whole shrinking matrix region:
            OrderedDep("point", "matrix", cons_rate=Fraction(8),
                       cons_stretch=Fraction(-1)),
            OrderedDep("matrix", "point"),  # loop-carried
        ],
    )


def test_graph_validates():
    g = cholesky_graph()
    assert g.critical.name == "matrix"


def test_graph_rejects_unknown_region():
    with pytest.raises(ValueError):
        RegionGraph(regions=[Region("a", None, critical=True)],
                    deps=[OrderedDep("a", "zzz")])


def test_graph_requires_critical():
    with pytest.raises(ValueError):
        RegionGraph(regions=[Region("a", None)], deps=[])


def test_inductive_consumption_rate():
    d = OrderedDep("p", "m", cons_rate=Fraction(8),
                   cons_stretch=Fraction(-1))
    assert [d.consumptions_at(k) for k in range(10)] == \
        [8, 7, 6, 5, 4, 3, 2, 1, 0, 0]
    g = cholesky_graph()
    assert g.total_consumptions(g.deps[1], 8) == 36


def test_fuse_scan_is_scan():
    """The FIFO-as-carry fusion: a chain a->b->a computed in one scan
    equals the hand-unrolled loop."""

    def step(carry, x):
        inva = 1.0 / carry               # "point" region (non-critical)
        new = carry + inva * x           # "matrix" region consumes inva
        return new, inva

    xs = jnp.arange(1.0, 6.0)
    final, invas = fuse_scan(step, jnp.asarray(2.0), xs=xs)
    c = 2.0
    want = []
    for x in np.arange(1.0, 6.0):
        want.append(1.0 / c)
        c = c + (1.0 / c) * x
    np.testing.assert_allclose(np.asarray(invas), want, rtol=1e-6)
    np.testing.assert_allclose(float(final), c, rtol=1e-6)


# ---------------- criticality planning ----------------

def test_plan_split_cholesky_shape():
    regions = [
        RegionCost("point", 2.0, has_transcendental=True),   # sqrt+div
        RegionCost("vector", 10.0),
        RegionCost("matrix", 100.0),
    ]
    crit, non = plan_split(regions)
    assert "matrix" in crit
    assert "point" in non


def test_plan_split_always_one_critical():
    regions = [RegionCost("a", 1.0, has_transcendental=True),
               RegionCost("b", 1.0, has_transcendental=True)]
    crit, non = plan_split(regions)
    assert len(crit) == 1 and len(non) == 1


def test_mxu_padding_and_efficiency():
    assert mxu_padded(1) == 128
    assert mxu_padded(128) == 128
    assert mxu_padded(129) == 256
    # the paper's Q9 argument: point regions on MXU tiles are ~1% utilized
    assert dedicated_efficiency(1) == pytest.approx(1 / 128)
    assert dedicated_efficiency(128) == 1.0
