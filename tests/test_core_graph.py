"""Region graphs (ordered dependences, F1/F2) + criticality planning (F5)."""
from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.criticality import (RegionCost, dedicated_efficiency,
                                    mxu_padded, plan_split)
from repro.core.dependence import OrderedDep, Region, RegionGraph, fuse_scan


def cholesky_graph():
    return RegionGraph(
        regions=[
            Region("point", fn=None, critical=False),
            Region("vector", fn=None, critical=False),
            Region("matrix", fn=None, critical=True),
        ],
        deps=[
            OrderedDep("point", "vector"),
            # inva consumed by the whole shrinking matrix region:
            OrderedDep("point", "matrix", cons_rate=Fraction(8),
                       cons_stretch=Fraction(-1)),
            OrderedDep("matrix", "point"),  # loop-carried
        ],
    )


def test_graph_validates():
    g = cholesky_graph()
    assert g.critical.name == "matrix"


def test_graph_rejects_unknown_region():
    with pytest.raises(ValueError):
        RegionGraph(regions=[Region("a", None, critical=True)],
                    deps=[OrderedDep("a", "zzz")])


def test_graph_requires_critical():
    with pytest.raises(ValueError):
        RegionGraph(regions=[Region("a", None)], deps=[])


def test_inductive_consumption_rate():
    d = OrderedDep("p", "m", cons_rate=Fraction(8),
                   cons_stretch=Fraction(-1))
    assert [d.consumptions_at(k) for k in range(10)] == \
        [8, 7, 6, 5, 4, 3, 2, 1, 0, 0]
    g = cholesky_graph()
    assert g.total_consumptions(g.deps[1], 8) == 36


def test_fuse_scan_is_scan():
    """The FIFO-as-carry fusion: a chain a->b->a computed in one scan
    equals the hand-unrolled loop."""

    def step(carry, x):
        inva = 1.0 / carry               # "point" region (non-critical)
        new = carry + inva * x           # "matrix" region consumes inva
        return new, inva

    xs = jnp.arange(1.0, 6.0)
    final, invas = fuse_scan(step, jnp.asarray(2.0), xs=xs)
    c = 2.0
    want = []
    for x in np.arange(1.0, 6.0):
        want.append(1.0 / c)
        c = c + (1.0 / c) * x
    np.testing.assert_allclose(np.asarray(invas), want, rtol=1e-6)
    np.testing.assert_allclose(float(final), c, rtol=1e-6)


def test_fuse_scan_zero_length_is_identity():
    """Degenerate fusion: a zero-trip scan (the inner_base=0 stream
    case) returns the initial carry untouched and an empty FIFO trace —
    pinned so the fused kernels can rely on it for empty tail blocks."""
    step = lambda c, x: (c + 1.0, c)
    final, ys = fuse_scan(step, jnp.asarray(2.5), length=0)
    assert float(final) == 2.5
    assert np.asarray(ys).shape == (0,)
    final2, ys2 = fuse_scan(step, jnp.asarray(2.5),
                            xs=jnp.zeros((0,)))
    assert float(final2) == 2.5
    assert np.asarray(ys2).shape == (0,)


# ---------------- criticality planning ----------------

def test_plan_split_cholesky_shape():
    regions = [
        RegionCost("point", 2.0, has_transcendental=True),   # sqrt+div
        RegionCost("vector", 10.0),
        RegionCost("matrix", 100.0),
    ]
    crit, non = plan_split(regions)
    assert "matrix" in crit
    assert "point" in non


def test_plan_split_always_one_critical():
    regions = [RegionCost("a", 1.0, has_transcendental=True),
               RegionCost("b", 1.0, has_transcendental=True)]
    crit, non = plan_split(regions)
    assert len(crit) == 1 and len(non) == 1


@pytest.mark.parametrize("threshold", [0.1, 0.25, 0.5])
def test_plan_split_threshold_is_inclusive(threshold):
    """A region carrying EXACTLY `threshold` of the work is critical —
    the boundary is >=, which the served-DAG criticality knob
    (DagSpec.crit_threshold) relies on."""
    other = 1.0 / threshold - 1.0
    regions = [RegionCost("edge", 1.0), RegionCost("rest", other)]
    crit, _ = plan_split(regions, threshold=threshold)
    assert "edge" in crit


@pytest.mark.parametrize("threshold", [0.1, 0.25, 0.5])
def test_plan_split_just_below_threshold_is_slack(threshold):
    regions = [RegionCost("edge", 1.0 - 1e-6),
               RegionCost("rest", 1.0 / threshold - 1.0)]
    crit, non = plan_split(regions, threshold=threshold)
    assert "edge" in non and "rest" in crit


def test_plan_split_transcendental_excluded_even_when_dominant():
    """A sqrt/div-dominated region never joins the critical set on
    share alone (paper: sub-critical regions are the sqrt/div chains)."""
    regions = [RegionCost("sqrtchain", 90.0, has_transcendental=True),
               RegionCost("bulk", 30.0)]
    crit, non = plan_split(regions, threshold=0.25)
    assert crit == ["bulk"] and non == ["sqrtchain"]


def test_plan_split_biggest_wins_fallback():
    """When every region is excluded (all transcendental or all below
    threshold), the largest is critical by definition and everything
    else is slack."""
    regions = [RegionCost("a", 5.0, has_transcendental=True),
               RegionCost("b", 9.0, has_transcendental=True),
               RegionCost("c", 2.0, has_transcendental=True)]
    crit, non = plan_split(regions)
    assert crit == ["b"]
    assert sorted(non) == ["a", "c"]


def test_plan_split_zero_total_work():
    """All-zero work estimates must not divide by zero; the fallback
    still nominates exactly one critical region."""
    regions = [RegionCost("a", 0.0), RegionCost("b", 0.0)]
    crit, non = plan_split(regions)
    assert len(crit) == 1 and len(non) == 1
    assert set(crit) | set(non) == {"a", "b"}


def test_region_graph_critical_selects_first_marked():
    g = RegionGraph(
        regions=[Region("a", None), Region("b", None, critical=True),
                 Region("c", None, critical=True)],
        deps=[OrderedDep("a", "b"), OrderedDep("b", "c")])
    assert g.critical.name == "b"


def test_dag_spec_criticality_uses_plan_split():
    """The served-DAG layer's stage criticality is plan_split over the
    stages' modeled FLOPs: the PUSCH channel estimate is critical, the
    transcendental FFT and the small equalize tail are slack."""
    from repro import kernels as K
    spec = K.get_dag("pusch_receive")
    shapes = tuple(np.shape(a)
                   for a in spec.make_case(np.random.default_rng(0), 8))
    crit, slack = spec.criticality(shapes)
    assert crit == ["chanest"]
    assert sorted(slack) == ["equalize", "fft"]
    crit_c, slack_c = spec.criticality(shapes, chained=True)
    assert crit_c == ["chain"] and slack_c == ["fft"]


def test_mxu_padding_and_efficiency():
    assert mxu_padded(1) == 128
    assert mxu_padded(128) == 128
    assert mxu_padded(129) == 256
    # the paper's Q9 argument: point regions on MXU tiles are ~1% utilized
    assert dedicated_efficiency(1) == pytest.approx(1 / 128)
    assert dedicated_efficiency(128) == 1.0
