"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracle in
ref.py, swept over shapes and dtypes.  These are the paper's seven DSP
workloads + the two LM-side kernels (flash attention, SSM scan).

Kernels are fetched from the registry (repro.kernels.get) — the single
enumeration point — instead of a hand-maintained import list; the
registry-driven auto-discovery sweep lives in test_pipelines.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ref as ref
from repro import kernels as K

from conftest import assert_close

cholesky_pallas = K.get("cholesky").pallas
trisolve_pallas = K.get("trisolve").pallas
qr_pallas = K.get("qr").pallas
svd_pallas = K.get("svd").pallas
gemm_pallas = K.get("gemm").pallas
fir_pallas = K.get("fir").pallas
fft_pallas = K.get("fft").pallas
flash_attention_pallas = K.get("flash_attention").pallas
ssm_scan_pallas = K.get("ssm_scan").pallas

RNG = np.random.default_rng(1234)


def spd(b, n, dtype=np.float32):
    a = RNG.standard_normal((b, n, n)).astype(dtype)
    return a @ a.swapaxes(-1, -2) + n * np.eye(n, dtype=dtype)


# ---------------- cholesky ----------------

@pytest.mark.parametrize("n", [8, 12, 16, 24, 32])
@pytest.mark.parametrize("b", [1, 3])
def test_cholesky_sizes(n, b):
    """Paper's data sizes 12..32 (non-power-of-two included)."""
    a = spd(b, n)
    got = cholesky_pallas(a, interpret=True)
    assert_close(got, ref.cholesky(a), rtol=1e-4, name=f"chol{n}")


def test_cholesky_reconstruction():
    a = spd(2, 16)
    l = np.asarray(cholesky_pallas(a, interpret=True))
    assert_close(l @ l.swapaxes(-1, -2), a, rtol=1e-4, name="LL^T")
    # strictly lower-triangular output
    assert np.allclose(np.triu(l, 1), 0.0)


# ---------------- trisolve ----------------

@pytest.mark.parametrize("n,m", [(8, 1), (12, 4), (16, 8), (32, 2)])
def test_trisolve_sizes(n, m):
    a = spd(2, n)
    l = np.linalg.cholesky(a)
    b = RNG.standard_normal((2, n, m)).astype(np.float32)
    got = trisolve_pallas(l, b, interpret=True)
    assert_close(got, ref.trisolve(l, b), rtol=1e-3, name=f"tri{n}x{m}")
    # residual check: L @ x == b
    assert_close(l @ np.asarray(got), b, rtol=1e-3, name="residual")


# ---------------- QR ----------------

@pytest.mark.parametrize("m,n", [(12, 12), (16, 12), (24, 16), (32, 32)])
def test_qr_sizes(m, n):
    a = RNG.standard_normal((2, m, n)).astype(np.float32)
    q, r = qr_pallas(a, interpret=True)
    q, r = np.asarray(q), np.asarray(r)
    assert_close(q @ r, a, rtol=1e-4, name="QR=A")
    eye = np.broadcast_to(np.eye(m, dtype=np.float32), (2, m, m))
    assert_close(q @ q.swapaxes(-1, -2), eye, rtol=1e-4, name="QQ^T")
    # R upper triangular
    assert np.allclose(np.tril(r[:, :, :], -1), 0.0, atol=1e-4)


# ---------------- SVD ----------------

@pytest.mark.parametrize("m,n", [(12, 12), (16, 12), (32, 24)])
def test_svd_singular_values(m, n):
    a = RNG.standard_normal((2, m, n)).astype(np.float32)
    u, s, v = svd_pallas(a, sweeps=14, interpret=True)
    want = np.linalg.svd(a, compute_uv=False)
    got = np.sort(np.asarray(s), axis=-1)[:, ::-1]
    assert_close(got, want, rtol=1e-3, name="sigma")


def test_svd_reconstruction():
    a = RNG.standard_normal((1, 16, 12)).astype(np.float32)
    u, s, v = svd_pallas(a, sweeps=14, interpret=True)
    u, s, v = np.asarray(u), np.asarray(s), np.asarray(v)
    assert_close((u * s[:, None, :]) @ v.swapaxes(-1, -2), a, rtol=1e-3,
                 name="USV^T")


# ---------------- GEMM ----------------

@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 64, 64, 64, 64, 64),
    (128, 64, 128, 64, 128, 64),
    (128, 128, 128, 128, 128, 128),
    (256, 128, 128, 128, 128, 128),
])
def test_gemm_blocks(m, k, n, bm, bn, bk):
    x = RNG.standard_normal((m, k)).astype(np.float32)
    y = RNG.standard_normal((k, n)).astype(np.float32)
    got = gemm_pallas(jnp.asarray(x), jnp.asarray(y), bm=bm, bn=bn, bk=bk,
                      interpret=True)
    assert_close(got, x @ y, rtol=1e-4, name="gemm")


def test_gemm_bf16():
    x = RNG.standard_normal((64, 64)).astype(np.float32)
    y = RNG.standard_normal((64, 64)).astype(np.float32)
    got = gemm_pallas(jnp.asarray(x, jnp.bfloat16),
                      jnp.asarray(y, jnp.bfloat16),
                      bm=64, bn=64, bk=64, interpret=True)
    assert_close(np.asarray(got, np.float32), x @ y, rtol=5e-2,
                 name="gemm-bf16")


# ---------------- FIR ----------------

@pytest.mark.parametrize("n,m", [(128, 9), (256, 31), (512, 65)])
def test_fir_centro_symmetric(n, m):
    x = RNG.standard_normal((n,)).astype(np.float32)
    h = RNG.standard_normal((m,)).astype(np.float32)
    h = (h + h[::-1]) / 2          # centro-symmetric taps (paper workload)
    out = n - m + 1
    got = fir_pallas(jnp.asarray(x), jnp.asarray(h), bo=out,
                     interpret=True)
    assert_close(got[:out], ref.fir(x, h), rtol=1e-4, name=f"fir{n},{m}")


# ---------------- FFT ----------------

@pytest.mark.parametrize("n", [64, 128, 1024])
def test_fft_sizes(n):
    """Paper's FFT sizes 64/128/1024."""
    xr = RNG.standard_normal((2, n)).astype(np.float32)
    xi = RNG.standard_normal((2, n)).astype(np.float32)
    fre, fim = fft_pallas(xr, xi, interpret=True)
    wre, wim = ref.fft(xr, xi)
    assert_close(np.stack([np.asarray(fre), np.asarray(fim)]),
                 np.stack([np.asarray(wre), np.asarray(wim)]),
                 rtol=1e-3, name=f"fft{n}")


def test_fft_matches_numpy():
    xr = RNG.standard_normal((1, 256)).astype(np.float32)
    xi = np.zeros((1, 256), np.float32)
    fre, fim = fft_pallas(xr, xi, interpret=True)
    want = np.fft.fft(xr[0])
    assert_close(np.asarray(fre)[0], want.real, rtol=1e-3, name="fft-re")
    assert_close(np.asarray(fim)[0], want.imag, rtol=1e-3, name="fft-im")


# ---------------- flash attention (inductive RI stream) ----------------

@pytest.mark.parametrize("s,dh,causal", [
    (128, 64, True), (256, 64, True), (128, 128, True), (128, 64, False),
])
def test_flash_attention(s, dh, causal):
    q = (RNG.standard_normal((2, 2, s, dh)) * 0.3).astype(np.float32)
    k = (RNG.standard_normal((2, 2, s, dh)) * 0.3).astype(np.float32)
    v = RNG.standard_normal((2, 2, s, dh)).astype(np.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    assert_close(got, ref.mha(q, k, v, causal=causal), rtol=1e-3,
                 name="flash")


def test_flash_attention_bf16():
    s, dh = 128, 64
    q = (RNG.standard_normal((1, 2, s, dh)) * 0.3).astype(np.float32)
    k = (RNG.standard_normal((1, 2, s, dh)) * 0.3).astype(np.float32)
    v = RNG.standard_normal((1, 2, s, dh)).astype(np.float32)
    got = flash_attention_pallas(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
        jnp.asarray(v, jnp.bfloat16), causal=True, interpret=True)
    assert_close(np.asarray(got, np.float32),
                 ref.mha(q, k, v, causal=True), rtol=5e-2,
                 name="flash-bf16")


def test_flash_attention_small_blocks():
    """Block sizes smaller than seq exercise the inductive kv trip count
    (kv blocks visited = q_block + 1 — the RI stream)."""
    s, dh = 256, 64
    q = (RNG.standard_normal((1, 1, s, dh)) * 0.3).astype(np.float32)
    k = (RNG.standard_normal((1, 1, s, dh)) * 0.3).astype(np.float32)
    v = RNG.standard_normal((1, 1, s, dh)).astype(np.float32)
    got = flash_attention_pallas(q, k, v, causal=True, bq=64, bkv=64,
                                 interpret=True)
    assert_close(got, ref.mha(q, k, v, causal=True), rtol=1e-3,
                 name="flash-blk")


# ---------------- SSM chunked scan (ordered inter-chunk dep) ----------

@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (128, 128)])
def test_ssm_scan_shared_bc(s, chunk):
    b, h, n, p = 1, 4, 8, 8
    x = RNG.standard_normal((b, h, s, p)).astype(np.float32)
    a = RNG.uniform(0.8, 0.999, (b, h, s)).astype(np.float32)
    bb = RNG.standard_normal((b, s, n)).astype(np.float32)
    cc = RNG.standard_normal((b, s, n)).astype(np.float32)
    y, hf = ssm_scan_pallas(x, a, bb, cc, chunk=chunk, interpret=True)
    yw, hw = ref.ssm_scan(np.moveaxis(x, 1, 2), np.moveaxis(a, 1, 2),
                          bb, cc)
    assert_close(np.moveaxis(np.asarray(y), 1, 2), yw, rtol=1e-3,
                 name="ssm-y")
    assert_close(hf, hw, rtol=1e-3, name="ssm-h")


def test_ssm_scan_per_head_bc():
    b, h, s, n, p = 1, 2, 64, 8, 4
    x = RNG.standard_normal((b, h, s, p)).astype(np.float32)
    a = RNG.uniform(0.8, 0.999, (b, h, s)).astype(np.float32)
    bb = RNG.standard_normal((b, h, s, n)).astype(np.float32)
    cc = RNG.standard_normal((b, h, s, n)).astype(np.float32)
    y, hf = ssm_scan_pallas(x, a, bb, cc, chunk=16, interpret=True)
    yw, hw = ref.ssm_scan(np.moveaxis(x, 1, 2), np.moveaxis(a, 1, 2),
                          np.moveaxis(bb, 1, 2), np.moveaxis(cc, 1, 2))
    assert_close(np.moveaxis(np.asarray(y), 1, 2), yw, rtol=1e-3,
                 name="ssm-y-ph")
    assert_close(hf, hw, rtol=1e-3, name="ssm-h-ph")


def test_ssm_scan_chunk_invariance():
    """The ordered inter-chunk dependence must make the result independent
    of the chunk size (paper F1: ordering is what guarantees correctness)."""
    b, h, s, n, p = 1, 2, 128, 4, 4
    x = RNG.standard_normal((b, h, s, p)).astype(np.float32)
    a = RNG.uniform(0.9, 0.999, (b, h, s)).astype(np.float32)
    bb = RNG.standard_normal((b, s, n)).astype(np.float32)
    cc = RNG.standard_normal((b, s, n)).astype(np.float32)
    y16, _ = ssm_scan_pallas(x, a, bb, cc, chunk=16, interpret=True)
    y64, _ = ssm_scan_pallas(x, a, bb, cc, chunk=64, interpret=True)
    assert_close(y16, y64, rtol=1e-4, name="chunk-invariance")
