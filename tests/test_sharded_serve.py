"""Mesh-sharded SolverMux (multi-device lane pools).

Pins the properties the mesh path promises:

  * ``mesh_size=1`` is bit-for-bit the single-device scheduler — same
    events, same outputs (the golden-trace replay in test_overload pins
    the event stream against the committed file; here we pin explicit
    mesh_size=1 against the default construction).
  * a mesh-spanning ``shard_map`` launch returns bit-identical results
    to the plain jit'd launch on the same batch (lanes are independent),
    so serving the same traffic at mesh > 1 yields numerically equal
    job outputs.
  * hot buckets split across shards only when the cost model says the
    sharded flush beats the serial local launches (``steal_ratio``
    gate), flushes place on the least-loaded shard, and the metrics
    snapshot reports per-shard utilization + imbalance.
  * the sharded overload replay scales: mesh=4 aggregate throughput at
    least 3x mesh=1 on the committed deterministic trace (the
    acceptance floor check_bench_json also gates in CI).

The suite session forces 8 virtual CPU devices (conftest), so every
mesh size swept here exists.
"""
import math

import jax
import numpy as np
import pytest

from repro import kernels as K
from repro import pipelines as pp
from repro.launch.serve_solvers import (OVERLOAD_TICK, job_args,
                                        overload_trace,
                                        run_sharded_overload)
from repro.serve import (CostModel, LaneShards, ManualClock,
                         OverloadPolicy, SolverMux)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="mesh tests need the 8-virtual-device session (conftest)")


def _run(mesh_size, *, lanes=4, ticks=3, steal_ratio=None):
    """Replay the committed overload trace (policy on, the usual
    2-launch budget scaled by the mesh) through one mux; returns the
    mux and the submitted jobs.  ``mesh_size=None`` exercises the
    default construction path."""
    cm = CostModel()
    spec = K.get("mmse_equalize")
    unit = cm.launch_cost("mmse_equalize", spec.base,
                          ((12, 8), (12, 2)), lanes)
    scale = mesh_size if mesh_size else 1
    pol = OverloadPolicy(budget=2.0 * scale * unit, cost_model=cm)
    clock = ManualClock()
    mux = SolverMux(lanes=lanes, clock=clock, pressure=2 * lanes,
                    policy=pol, mesh_size=mesh_size)
    if steal_ratio is not None:
        mux._steal_ratio = steal_ratio
    jobs, by_tick = [], {}
    for e in overload_trace(ticks, lanes):
        by_tick.setdefault(e["tick"], []).append(e)
    for t in range(2 * ticks):
        for e in by_tick.get(t, ()):
            jobs.append(mux.submit(
                e["pipeline"],
                *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                deadline=clock() + e["deadline_ticks"] * OVERLOAD_TICK,
                priority=e["priority"]))
        mux.poll()
        clock.advance(OVERLOAD_TICK)
    mux.run()
    return mux, jobs


# ---------------- mesh=1 degradation ----------------

def test_mesh1_bit_identical_to_default_path():
    """Explicit mesh_size=1 builds no mesh and replays the overload
    trace with the exact event stream and outputs of the default mux —
    the degradation guarantee CI asserts alongside the golden trace."""
    mux_a, jobs_a = _run(None)
    mux_b, jobs_b = _run(1)
    assert mux_b.shards is None and mux_b.total_lanes == mux_b.lanes
    assert mux_a.events == mux_b.events
    assert len(jobs_a) == len(jobs_b)
    for a, b in zip(jobs_a, jobs_b):
        assert a.state == b.state and a.seq == b.seq
        if a.state == "done":
            np.testing.assert_array_equal(np.asarray(a.out),
                                          np.asarray(b.out))
    # no mesh fields leak into single-device events (golden-trace shape)
    for ev in mux_b.events:
        assert "mesh" not in ev and "shard" not in ev


def test_mesh1_launch_records_carry_defaults():
    mux, _ = _run(1)
    for rec in mux.metrics().launches:
        assert rec.mesh == 1 and rec.shard == 0


# ---------------- mesh-spanning numerical equality ----------------

def test_shard_map_wrap_bit_identical_to_jit():
    """A LaneShards-wrapped pipeline entry point equals the plain jit'd
    one bit-for-bit — the lane axis is embarrassingly parallel."""
    shards = LaneShards.build(4)
    rng = np.random.default_rng(0)
    h = rng.standard_normal((8, 12, 8)).astype(np.float32)
    y = rng.standard_normal((8, 12, 2)).astype(np.float32)
    got = jax.jit(shards.wrap(pp.mmse_equalize_pallas, 2))(h, y)
    want = jax.jit(pp.mmse_equalize_pallas)(h, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mesh2_outputs_match_mesh1():
    """Serving the same trace at mesh=2 completes every hard job with
    outputs numerically identical to the mesh=1 run, and actually uses
    mesh-spanning launches to do it."""
    _, jobs1 = _run(1)
    mux2, jobs2 = _run(2)
    snap = mux2.metrics()
    assert any(rec.mesh == 2 for rec in snap.launches), \
        "mesh=2 run never spanned the mesh"
    assert any(ev["event"] == "shard_split" for ev in mux2.events)
    by_seq = {j.seq: j for j in jobs1}
    compared = 0
    for j in jobs2:
        other = by_seq[j.seq]
        if j.state == "done" and other.state == "done":
            np.testing.assert_array_equal(np.asarray(j.out),
                                          np.asarray(other.out))
            compared += 1
    assert compared >= 10


# ---------------- balancing / splitting ----------------

def test_local_launches_balance_across_shards():
    """Back-to-back full lane groups place on alternating shards (least
    accumulated load wins)."""
    clock = ManualClock()
    mux = SolverMux(lanes=4, clock=clock, mesh_size=2)
    for i in range(4):
        mux.submit("cholesky_solve", *job_args("cholesky_solve", 8, 2, i))
    mux.poll()
    for i in range(4, 8):
        mux.submit("cholesky_solve", *job_args("cholesky_solve", 8, 2, i))
    mux.poll()
    shards_used = [rec.shard for rec in mux.metrics().launches]
    assert sorted(shards_used) == [0, 1]


def test_flush_bucket_drains_spanning_first():
    """A backlog of lanes*mesh bucket-mates drains as ONE mesh-spanning
    launch on the non-policy path."""
    clock = ManualClock()
    mux = SolverMux(lanes=4, clock=clock, mesh_size=2)
    jobs = [mux.submit("cholesky_solve",
                       *job_args("cholesky_solve", 8, 2, i))
            for i in range(8)]
    done = mux.poll()
    assert len(done) == 8 and all(j.state == "done" for j in jobs)
    recs = mux.metrics().launches
    assert len(recs) == 1 and recs[0].mesh == 2 and recs[0].shard == -1


def test_steal_ratio_gates_splitting():
    """With an absurd steal_ratio the cost comparison always favors
    local launches: the policy logs shard_reject and never splits."""
    mux, _ = _run(2, steal_ratio=1e9)
    assert any(ev["event"] == "shard_reject" for ev in mux.events)
    assert not any(ev["event"] == "shard_split" for ev in mux.events)


def test_shard_metrics_reported():
    mux, _ = _run(2)
    snap = mux.metrics()
    assert set(snap.shards) == {0, 1}
    for st in snap.shards.values():
        assert 0.0 <= st.utilization <= 1.0
        assert st.launches > 0
    assert math.isfinite(snap.shard_imbalance)
    assert snap.shard_imbalance >= 1.0
    spanning = [rec for rec in snap.launches if rec.mesh > 1]
    assert spanning and all(rec.shard == -1 for rec in spanning)


def test_lane_shards_accounting():
    shards = LaneShards.build(2)
    assert shards.size == 2
    assert math.isnan(shards.imbalance())
    assert shards.pick() == 0                 # tie -> lowest index
    shards.note(0, 1.0)
    assert shards.pick() == 1                 # least load
    assert shards.pick([10.0, 0.0]) == 0      # budget outranks load
    shards.note(1, 3.0)
    assert shards.imbalance() == pytest.approx(1.5)
    shards.note_all(1.0)
    assert shards.load == [2.0, 4.0]


def test_mesh_size_validation():
    with pytest.raises(ValueError):
        SolverMux(lanes=2, mesh_size=0)
    with pytest.raises(ValueError):
        LaneShards.build(jax.device_count() + 1)


# ---------------- cost model: per-mesh pricing ----------------

def test_launch_cost_mesh_pricing():
    """mesh=1 keeps the exact legacy expression; mesh>1 prices
    overhead(mesh) + ceil(lanes/mesh) per-shard lane time."""
    cm = CostModel()
    spec = K.get("mmse_equalize")
    shapes = ((12, 8), (12, 2))
    legacy = cm.launch_cost("mmse_equalize", spec.base, shapes, 8)
    assert legacy == cm.launch_cost("mmse_equalize", spec.base, shapes,
                                    8, mesh=1)
    lane = cm.lane_cost("mmse_equalize", spec.base, shapes)
    sharded = cm.launch_cost("mmse_equalize", spec.base, shapes, 8,
                             mesh=4)
    assert sharded == pytest.approx(cm.overhead(4) + 2 * lane)
    # a spanning flush of a full mesh-wide group beats the serial
    # launches it replaces (the split decision's whole premise)
    assert sharded < 4 * cm.launch_cost("mmse_equalize", spec.base,
                                        shapes, 2)


def test_overhead_monotone_in_mesh():
    cm = CostModel()
    assert cm.overhead(1) == cm.launch_overhead
    assert cm.overhead(2) > cm.overhead(1)
    assert cm.overhead(4) > cm.overhead(2)


def test_from_bench_json_calibrates_mesh_overhead(tmp_path):
    """Sharded bench rows re-fit per-mesh launch overheads: residual =
    wall - ceil(lanes/mesh) * lane_time at the calibrated rate."""
    rate = 2e-9
    flops = 1e6
    lane = flops * rate
    payload = {
        "schema": 1,
        "rows": [],
        "variants": [{"pipeline": "mmse_equalize", "variant": "base",
                      "n": 8, "dispatches": 3, "model_flops": flops,
                      "wall_us": lane * 1e6}],
        "dispatch_counts": {},
        "sharded": [{"pipeline": "mmse_equalize", "variant": "base",
                     "mesh": 4, "lanes": 16,
                     "wall_us": (3e-4 + 4 * lane) * 1e6,
                     "model_flops": flops}],
    }
    path = tmp_path / "bench.json"
    import json
    path.write_text(json.dumps(payload))
    cm = CostModel.from_bench_json(str(path))
    assert 4 in cm.mesh_overhead
    assert cm.overhead(4) == pytest.approx(3e-4, rel=0.05)


# ---------------- scaling acceptance ----------------

def test_sharded_overload_mesh4_scales_3x():
    """The acceptance floor: on the committed deterministic overload
    trace (fixed virtual window, no drain), mesh=4 aggregate lane
    throughput is at least 3x mesh=1, with per-shard utilization
    reported for every shard."""
    s1 = run_sharded_overload(1, ticks=3)
    s4 = run_sharded_overload(4, ticks=3)
    assert s1["jobs"] == s4["jobs"]           # identical offered load
    assert s4["throughput"] >= 3.0 * s1["throughput"]
    assert set(s4["shard_util"]) == {0, 1, 2, 3}
    assert s4["spanning"] > 0
    assert s4["attainment_hard"] >= s1["attainment_hard"]
