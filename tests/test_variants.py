"""Performance variants + registry dispatch: split-complex MMSE vs the
complex oracle (property-tested, hypothesis-fuzzed when available),
blocked Cholesky/QR equality against the unblocked fused kernels across
block-size/shape sweeps, the model-FLOP win of the split path (HLO
dot-flops counter), and dispatch routing through registry, engine, and
mux (a mixed-size trace must land each bucket on the expected variant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.kernels import ref
from repro.kernels.common import sample_spd
from repro.pipelines import (cholesky_solve_blocked, cholesky_solve_pallas,
                             expand_complex_channel, mmse_equalize_pallas,
                             mmse_equalize_split_pallas, qr_solve_blocked,
                             qr_solve_pallas)
from repro.roofline.hlo_costs import analyze_hlo
from repro.serve import ManualClock, PipelineEngine, SolveJob, SolverMux

from conftest import assert_close
from strategies import channel_planes, floats, fuzzed, integers, spd_system

RNG = np.random.default_rng(777)


# ---------------- split-complex MMSE vs the complex oracle ----------------
# Property: for ANY complex system (any m >= n, k, sigma2), the split
# re/im kernel matches the complex64 jnp oracle to fp32 tolerance.  The
# deterministic grid always runs; hypothesis widens the shape/sigma space.

def _check_split_matches_complex_oracle(n, m_extra, k, sigma2, seed):
    hr, hi, yr, yi = [jnp.asarray(p) for p in channel_planes(
        seed, 2, n + m_extra, n, k=k)]
    got = mmse_equalize_split_pallas(hr, hi, yr, yi, sigma2=sigma2)
    want = ref.mmse_equalize_split(hr, hi, yr, yi, sigma2=sigma2)
    assert_close(got, want, rtol=1e-3,
                 name=f"split-mmse n={n} m={n + m_extra} k={k} s={sigma2}")


@pytest.mark.parametrize("n,m_extra,k", [(2, 0, 1), (8, 4, 2), (12, 4, 1),
                                         (16, 0, 3), (24, 8, 2)])
@pytest.mark.parametrize("sigma2", [1e-3, 0.1, 1.0])
def test_split_mmse_matches_complex_oracle(n, m_extra, k, sigma2):
    _check_split_matches_complex_oracle(n, m_extra, k, sigma2, seed=n + k)


@fuzzed(max_examples=10, n=integers(2, 10), m_extra=integers(0, 6),
        k=integers(1, 3), sigma2=floats(1e-3, 2.0),
        seed=integers(0, 2 ** 16))
def test_split_mmse_matches_complex_oracle_fuzzed(n, m_extra, k,
                                                  sigma2, seed):
    _check_split_matches_complex_oracle(n, m_extra, k, sigma2, seed)


def test_split_mmse_equals_expansion_path():
    """The split kernel assembles the SAME real-embedded 2n x 2n system
    the [[Re,-Im],[Im,Re]] expansion builds — answers agree to rounding."""
    b, m, n, k = 3, 20, 16, 2
    hr, hi = [jnp.asarray(RNG.standard_normal((b, m, n))
                          .astype(np.float32)) for _ in range(2)]
    yr, yi = [jnp.asarray(RNG.standard_normal((b, m, k))
                          .astype(np.float32)) for _ in range(2)]
    h, y = expand_complex_channel(hr, hi, yr, yi)
    split = mmse_equalize_split_pallas(hr, hi, yr, yi, sigma2=0.1)
    expanded = mmse_equalize_pallas(h, y, sigma2=0.1)
    assert_close(split, expanded, rtol=1e-4, name="split-vs-expansion")


def test_split_mmse_zero_channel_stays_finite():
    hr = jnp.zeros((1, 16, 12), jnp.float32)
    yr = jnp.asarray(RNG.standard_normal((1, 16, 1)).astype(np.float32))
    x = np.asarray(mmse_equalize_split_pallas(hr, hr, yr, yr, sigma2=0.1))
    assert np.isfinite(x).all()
    assert np.abs(x).max() < 1e-5


# ---------------- split-complex model-FLOP acceptance ----------------

def test_split_mmse_halves_model_flops():
    """Acceptance: at equal (m, n, k) the split kernel performs <= 0.55x
    the model FLOPs of the real-expansion kernel, measured by the HLO
    dot-flops counter on the LOWERED Pallas kernels themselves (the
    fused solve chain contributes no dot ops in either, so this isolates
    the Gram + matched-filter GEMM work: 6mn^2+8mnk vs 16mn^2+8mnk)."""
    from functools import partial
    for m, n, k in [(20, 16, 2), (36, 32, 1)]:
        hr, hi = [jnp.asarray(RNG.standard_normal((2, m, n))
                              .astype(np.float32)) for _ in range(2)]
        yr, yi = [jnp.asarray(RNG.standard_normal((2, m, k))
                              .astype(np.float32)) for _ in range(2)]
        h, y = expand_complex_channel(hr, hi, yr, yi)
        split_flops = analyze_hlo(
            jax.jit(partial(mmse_equalize_split_pallas, sigma2=0.1,
                            interpret=True))
            .lower(hr, hi, yr, yi).compile().as_text())["flops"]
        exp_flops = analyze_hlo(
            jax.jit(partial(mmse_equalize_pallas, sigma2=0.1,
                            interpret=True))
            .lower(h, y).compile().as_text())["flops"]
        assert split_flops > 0 and exp_flops > 0
        ratio = split_flops / exp_flops
        assert ratio <= 0.55, (m, n, k, ratio)
        # and the counter sees exactly the kernels' model dot counts
        assert split_flops == 2 * (6 * m * n * n + 8 * m * n * k)
        assert exp_flops == 2 * (16 * m * n * n + 8 * m * n * k)


# ---------------- blocked Cholesky: equality sweeps ----------------

def _check_blocked_chol_equals_unblocked(n, bs, rhs, seed):
    a, b = [jnp.asarray(p) for p in spd_system(seed, 2, n, k=rhs)]
    blocked = cholesky_solve_blocked(a, b, bs=bs)
    unblocked = cholesky_solve_pallas(a, b)
    assert_close(blocked, unblocked, rtol=1e-4,
                 name=f"chol-blocked n={n} bs={bs}")


@pytest.mark.parametrize("bs", [32, 64])
@pytest.mark.parametrize("n", [128, 256])
def test_blocked_cholesky_equals_unblocked(n, bs):
    """Acceptance sweep: blocking is a schedule change, not a numeric
    one — n=256 with bs in {32, 64} must match the fused kernel."""
    _check_blocked_chol_equals_unblocked(n, bs, rhs=3, seed=n + bs)


@pytest.mark.parametrize("rhs", [1, 5])
def test_blocked_cholesky_rhs_widths(rhs):
    _check_blocked_chol_equals_unblocked(128, 32, rhs=rhs, seed=rhs)


def test_blocked_cholesky_matches_oracle():
    a = jnp.asarray(sample_spd(RNG, 2, 128))
    b = jnp.asarray(RNG.standard_normal((2, 128, 2)).astype(np.float32))
    got = cholesky_solve_blocked(a, b)
    assert_close(got, ref.cholesky_solve(a, b), rtol=1e-3,
                 name="chol-blocked-oracle")


def test_blocked_cholesky_singular_stays_finite():
    """The eps pivot guard must survive blocking: a rank-deficient SPD
    matrix keeps every lane finite."""
    v = RNG.standard_normal((1, 128, 5)).astype(np.float32)
    a = jnp.asarray(v @ v.swapaxes(-1, -2))          # rank 5 << 128
    b = jnp.asarray(RNG.standard_normal((1, 128, 2)).astype(np.float32))
    x = np.asarray(cholesky_solve_blocked(a, b, bs=32))
    assert np.isfinite(x).all()


# ---------------- blocked QR: equality sweeps ----------------

def _check_blocked_qr_equals_unblocked(m, n, bs, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((2, m, n)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((2, m, 2)).astype(np.float32))
    blocked = qr_solve_blocked(a, b, bs=bs)
    unblocked = qr_solve_pallas(a, b)
    assert_close(blocked, unblocked, rtol=1e-3,
                 name=f"qr-blocked m={m} n={n} bs={bs}")


@pytest.mark.parametrize("bs", [32, 64])
@pytest.mark.parametrize("m,n", [(132, 128), (160, 128)])
def test_blocked_qr_equals_unblocked(m, n, bs):
    _check_blocked_qr_equals_unblocked(m, n, bs, seed=m + bs)


def test_blocked_qr_256_least_squares_residual():
    """n=256: the blocked solution's residual is orthogonal to range(A)
    (the defining property of the least-squares answer)."""
    a = RNG.standard_normal((1, 260, 256)).astype(np.float32)
    b = RNG.standard_normal((1, 260, 1)).astype(np.float32)
    x = np.asarray(qr_solve_blocked(jnp.asarray(a), jnp.asarray(b), bs=64))
    resid = a @ x - b
    corr = np.abs(np.einsum("bmn,bmk->bnk", a, resid)).max()
    assert corr / np.abs(b).max() < 2e-2            # fp32, n=256 scale


# ---------------- registry dispatch ----------------

def test_dispatch_routes_by_shape_and_arity():
    spec = K.get("cholesky_solve")
    small = spec.make_case(np.random.default_rng(0), 16)
    assert spec.dispatch(*small).name == "base"
    big = spec.make_case(np.random.default_rng(0), 256)
    assert spec.dispatch(*big).name == "blocked"
    # non-tiling sizes stay on base (the blocked panels need n % 32 == 0)
    odd = spec.make_case(np.random.default_rng(0), 136)
    assert spec.dispatch(*odd).name == "base"

    mmse = K.get("mmse_equalize")
    h, y = mmse.make_case(np.random.default_rng(0), 12)
    assert mmse.dispatch(h, y).name == "base"
    hr, hi, yr, yi = (np.asarray(h),) * 2 + (np.asarray(y),) * 2
    assert mmse.dispatch(hr, hi, yr, yi).name == "split_complex"


@pytest.mark.parametrize("name,variant", [
    (spec.name, v.name)
    for spec in K.specs(kind="pipeline") for v in spec.variants])
def test_registry_variant_matches_oracle(name, variant):
    """Auto-discovered: every registered variant checks against its own
    oracle (or the spec's) over its declared sizes, with dispatch
    actually selecting it — adding a variant adds it here with no
    edits."""
    spec = K.get(name)
    var = next(v for v in spec.variants if v.name == variant)
    rng = np.random.default_rng(321)
    make = var.make_case or spec.make_case
    oracle = var.oracle or spec.run_oracle
    for n in (var.sizes or spec.sizes[:1]):
        args = make(rng, n)
        assert spec.dispatch(*args).name == variant, (name, variant, n)
        got = jax.tree.leaves(var.fn(*args))
        want = jax.tree.leaves(oracle(*args))
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert_close(np.asarray(g, np.float32), w, rtol=1e-3,
                         name=f"{name}/{variant}@{n}")


def test_kernels_without_variants_dispatch_to_base():
    spec = K.get("gemm")
    args = spec.make_case(np.random.default_rng(0), 16)
    v = spec.dispatch(*args)
    assert v.name == "base" and v.fn is spec.pallas


# ---------------- serving: buckets land on the expected variant ----------

def test_mux_mixed_trace_dispatches_each_bucket_to_expected_variant():
    """A mixed-size, mixed-arity trace through the SolverMux: the n=8
    bucket serves from base, the n=128 bucket from blocked, 4-plane MMSE
    jobs from split_complex — per-launch variant records and the
    dispatch_counts metric prove it, and every answer still matches the
    dispatch-aware registry oracle."""
    rng = np.random.default_rng(11)
    mux = SolverMux(lanes=2, clock=ManualClock())
    jobs = []
    for _ in range(2):
        jobs.append(mux.submit("cholesky_solve",
                               sample_spd(rng, 1, 8)[0],
                               rng.standard_normal((8, 2))
                               .astype(np.float32)))
        jobs.append(mux.submit("cholesky_solve",
                               sample_spd(rng, 1, 128)[0],
                               rng.standard_normal((128, 2))
                               .astype(np.float32)))
        m, n = 16, 12
        jobs.append(mux.submit("mmse_equalize",
                               *[rng.standard_normal(s)
                                 .astype(np.float32)
                                 for s in ((m, n), (m, n), (m, 1),
                                           (m, 1))]))
        jobs.append(mux.submit("qr_solve",
                               rng.standard_normal((132, 128))
                               .astype(np.float32),
                               rng.standard_normal((132, 1))
                               .astype(np.float32)))
    done = mux.run()
    assert len(done) == len(jobs)
    for job in jobs:
        want = K.get(job.pipeline).run_oracle_lane(*job.args)
        assert_close(job.out, want, rtol=2e-3,
                     name=f"mux-{job.pipeline}-{job.args[0].shape}")

    by_shape = {(l.pipeline, l.shape[0][0]): l.variant
                for l in mux.metrics().launches}
    assert by_shape[("cholesky_solve", (8, 8))] == "base"
    assert by_shape[("cholesky_solve", (128, 128))] == "blocked"
    assert by_shape[("mmse_equalize", (16, 12))] == "split_complex"
    assert by_shape[("qr_solve", (132, 128))] == "blocked"

    snap = mux.metrics()
    assert snap["cholesky_solve"].dispatch_counts == {"base": 1,
                                                      "blocked": 1}
    assert snap["mmse_equalize"].dispatch_counts == {"split_complex": 1}
    assert snap["qr_solve"].dispatch_counts == {"blocked": 1}


def test_mux_pads_split_complex_bucket_from_variant_filler():
    """A partial split-complex bucket pads from the VARIANT's declared
    4-plane filler (the spec's 2-arg filler cannot describe it)."""
    rng = np.random.default_rng(12)
    mux = SolverMux(lanes=4, clock=ManualClock())
    m, n = 12, 8
    job = mux.submit("mmse_equalize",
                     *[rng.standard_normal(s).astype(np.float32)
                       for s in ((m, n), (m, n), (m, 2), (m, 2))])
    mux.run()
    launch = mux.metrics().launches[0]
    assert launch.padded == 3 and launch.variant == "split_complex"
    want = K.get("mmse_equalize").run_oracle_lane(*job.args)
    assert_close(job.out, want, rtol=1e-3, name="split-padded")


def test_pipeline_engine_dispatches_blocked():
    eng = PipelineEngine("cholesky_solve", lanes=2)
    rng = np.random.default_rng(13)
    jobs = [eng.submit(SolveJob(args=(
        sample_spd(rng, 1, 128)[0],
        rng.standard_normal((128, 2)).astype(np.float32))))
        for _ in range(2)]
    eng.run()
    assert eng.metrics()["cholesky_solve"].dispatch_counts == \
        {"blocked": 1}
    for j in jobs:
        want = K.get("cholesky_solve").run_oracle_lane(*j.args)
        assert_close(j.out, want, rtol=1e-3, name="engine-blocked")


# ---------------- FFT chunked twiddle table ----------------

def test_fft_chunked_twiddles_match_dense_layout():
    """The compact table packs stage s at offset 2**s - 1 with exactly
    the w_span^off values the old dense (stages x n/2) layout repeated."""
    from repro.kernels.fft import fft_tables
    n = 64
    rev, wre, wim = fft_tables(n)
    assert wre.shape == (n - 1,)
    for s in range(int(np.log2(n))):
        half = 1 << s
        for off in range(half):
            ang = -2.0 * np.pi * off / (half << 1)
            assert np.isclose(wre[half - 1 + off], np.cos(ang))
            assert np.isclose(wim[half - 1 + off], np.sin(ang))
    # bit-reversal unchanged
    assert rev[1] == n // 2 and rev[n - 1] == n - 1


def test_fft_1024_point_matches_oracle():
    """The paper's 1024-point size, unlocked by the chunked table."""
    from repro.kernels.fft import fft_pallas
    xr = jnp.asarray(RNG.standard_normal((2, 1024)).astype(np.float32))
    xi = jnp.asarray(RNG.standard_normal((2, 1024)).astype(np.float32))
    gr, gi = fft_pallas(xr, xi)
    wr, wi = ref.fft(xr, xi)
    assert_close(gr, wr, rtol=1e-3, name="fft1024-re")
    assert_close(gi, wi, rtol=1e-3, name="fft1024-im")
