"""Served pipeline DAG invariants: golden staged-scheduling trace +
property suite.

Three layers of proof for the DAG subsystem (``SolverMux.submit_dag``):

* **golden replay** — the committed ``tests/data/pusch_trace.json``
  replayed on a virtual clock must reproduce
  ``tests/data/pusch_golden.json`` byte for byte.  The event stream
  pins stage ordering, criticality-first admission (the equal-deadline
  rank inversion at t=2.0), and the deterministic end-to-end latency.
  Regenerate with ``tests/data/regen_pusch_golden.py`` after any
  INTENTIONAL scheduling change and review the diff.

* **fuzzed properties** (hypothesis; deterministic grid fallback) —
  for random DAG traces: every submitted DAG reaches a terminal state
  with every stage accounted (terminal job or explicit cancellation —
  no orphans, also under injected faults and preemption pressure);
  stage outputs are bit-identical to standalone runs of the same
  pipeline; the flush order never violates the DAG's topological
  order.

* **mid-DAG fault containment** — a stage that fails mid-DAG retries
  through launch supervision and the DAG completes (or cascades
  cleanly); hard DAGs are never silently lost.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np
import pytest

from repro import kernels as K
from repro.launch.serve_solvers import (dag_hard_lost, pusch_trace,
                                        replay_pusch, run_pusch)
from repro.serve import FaultInjector

from strategies import dag_traces, fault_streams, fuzzed, integers

DATA = pathlib.Path(__file__).parent / "data"

# canned entries for the deterministic grid (same tuple layout as the
# dag_traces() strategy): (dag, n, priority, deadline_ticks, gap, chained)
GRID_TRACES = [
    [("pusch_receive", 8, "hard", 8, 1, False),
     ("pusch_receive", 8, "hard", 7, 1, False),
     ("svd_solve", 8, "best_effort", 0, 0, False)],
    [("pusch_receive", 8, "hard", 4, 0, True),
     ("pusch_receive", 12, "best_effort", 0, 1, True),
     ("svd_solve", 12, "hard", 6, 0, False)],
    [("svd_solve", 8, "hard", 1, 0, False),
     ("pusch_receive", 8, "best_effort", 0, 0, False)],
]


def _trace_dicts(entries) -> list[dict]:
    """dag_traces() tuples -> the committed-trace dict schema that
    ``replay_pusch`` consumes.  ``chained`` only sticks on DAGs that
    declare a fused chain; ``deadline_ticks == 0`` means no deadline."""
    trace, tick = [], 0
    for i, (dag, n, priority, deadline, gap, chained) in enumerate(entries):
        spec = K.get_dag(dag)
        trace.append(dict(tick=tick, dag=dag, n=n, priority=priority,
                          deadline_ticks=deadline or None,
                          chained=chained and bool(spec.chained),
                          seed=1000 + i))
        tick += gap
    return trace


def _replay(entries, injector=None):
    return replay_pusch(_trace_dicts(entries), injector=injector)


# ---------------- invariant checkers ----------------

TERMINAL = ("done", "failed", "dropped")


def _check_accounting(mux, dags) -> None:
    """Every DAG terminal; every stage of its (chained-aware) stage list
    accounted — a terminal stage job or an explicit cancellation."""
    assert mux.pending() == 0, "mux left stage jobs queued after drain"
    for dj in dags:
        assert dj.state in TERMINAL, (dj.dag, dj.state)
        stages = dj.spec.stage_list(chained=dj.chained)
        for stage in stages:
            sj = dj.stages.get(stage.name)
            if dj.state == "done":
                assert sj is not None and sj != "cancelled", \
                    f"{dj.dag}:{stage.name} missing from a done DAG"
                assert sj.state == "done", (stage.name, sj.state)
            else:
                # failed/dropped DAG: stage either ran to a terminal
                # state or was explicitly cancelled — never orphaned
                assert sj == "cancelled" or sj is None or \
                    sj.state in TERMINAL, (stage.name, sj.state)
                assert sj is not None, \
                    f"{dj.dag}:{stage.name} neither run nor cancelled"
        if dj.state == "done":
            assert dj.out is not None


def _check_bit_identity(dags) -> None:
    """Every done stage job's served output equals a standalone run of
    the dispatched variant on the same (singleton-batch) arguments —
    batching + benign padding lanes must not perturb a single bit."""
    checked = 0
    for dj in dags:
        for name, sj in dj.stages.items():
            if sj == "cancelled" or sj.state != "done":
                continue
            spec = K.get(sj.pipeline)
            variant = spec.dispatch_key(
                tuple(np.shape(a) for a in sj.args),
                tuple(np.asarray(a).dtype for a in sj.args))
            alone = np.asarray(
                variant.fn(*[np.asarray(a)[None] for a in sj.args]))[0]
            assert np.array_equal(np.asarray(sj.out), alone), \
                f"{dj.dag}:{name} served output != standalone run"
            checked += 1
    assert checked > 0


def _check_topological(mux_events, dags) -> None:
    """The flush order of stage jobs never violates a DAG's
    producer->consumer edges (derived from each stage's ``consumes``,
    chained-aware)."""
    stage_of = {}   # job seq -> (dag seq, stage name)
    for e in mux_events:
        if e["event"] == "dag_stage":
            stage_of[e["job"]] = (e["seq"], e["stage"])
    first_flush = {}  # job seq -> event index of its (first) flush
    for i, e in enumerate(mux_events):
        if e["event"] != "flush":
            continue
        for seq in list(e.get("jobs", ())) + list(e.get("coalesced", ())):
            first_flush.setdefault(seq, i)
    for dj in dags:
        flushed = {}  # stage name -> flush index
        for seq, (dseq, sname) in stage_of.items():
            if dseq == dj.seq and seq in first_flush:
                flushed[sname] = first_flush[seq]
        for stage in dj.spec.stage_list(chained=dj.chained):
            for producer in stage.consumes:
                if stage.name in flushed and producer in flushed:
                    assert flushed[producer] < flushed[stage.name], \
                        (dj.dag, producer, stage.name)


# ---------------- golden replay ----------------

def test_golden_pusch_replay_event_sequence():
    """Byte-for-byte: the committed DAG trace replayed on the virtual
    clock reproduces the committed golden event stream."""
    trace = json.loads((DATA / "pusch_trace.json").read_text())
    mux, dags = replay_pusch(trace)
    got = json.dumps(mux.drain_events(), indent=1) + "\n"
    assert got == (DATA / "pusch_golden.json").read_text(), \
        "DAG scheduling decisions drifted from the golden trace; if " \
        "intentional, regenerate via tests/data/regen_pusch_golden.py"
    assert all(d.state == "done" for d in dags)


def test_golden_trace_matches_generator():
    """The committed trace file IS pusch_trace(4, seed=0) — the regen
    script and the golden test stay in lockstep."""
    committed = json.loads((DATA / "pusch_trace.json").read_text())
    assert committed == pusch_trace(4, seed=0)


def test_criticality_rank_admits_critical_stage_first():
    """The staggered-deadline window in the golden trace: at t=2.0 the
    earlier DAG's slack equalize stage (lower job seq) and the later
    DAG's critical channel-estimate stage (higher job seq) hold EQUAL
    absolute deadlines, so plain seq order would flush equalize first —
    the criticality rank must invert that and admit chanest ahead."""
    events = json.loads((DATA / "pusch_golden.json").read_text())
    stage_of = {e["job"]: (e["stage"], e["critical"])
                for e in events if e["event"] == "dag_stage"}
    flushed = []
    for e in events:
        if e["event"] == "flush" and e["t"] == 2.0:
            for seq in e["jobs"]:
                if seq in stage_of:
                    flushed.append((seq, *stage_of[seq]))
    names = [name for _, name, _ in flushed]
    assert "chanest" in names and "equalize" in names, flushed
    i_crit = names.index("chanest")
    i_slack = names.index("equalize")
    assert i_crit < i_slack, \
        f"critical stage not admitted first at t=2.0: {flushed}"
    # ... and it won on rank, not on arrival order: the critical job
    # was submitted AFTER the slack one (higher seq)
    assert flushed[i_crit][0] > flushed[i_slack][0], flushed
    assert flushed[i_crit][2] is True and flushed[i_slack][2] is False


def test_chained_e2e_latency_beats_staged():
    """Fusing the channel-estimate->equalize tail lane-resident removes
    one full scheduling round trip: chained e2e p50 must be strictly
    below stage-independent at the same budget/trace."""
    staged = run_pusch(False, ticks=4)
    chained = run_pusch(True, ticks=4)
    assert staged["done"] == staged["dags"]
    assert chained["done"] == chained["dags"]
    assert chained["e2e_p50"] < staged["e2e_p50"], \
        (chained["e2e_p50"], staged["e2e_p50"])
    assert chained["launches"] < staged["launches"]


# ---------------- mid-DAG fault containment ----------------

def test_mid_dag_stage_fault_contained():
    """A targeted mid-DAG stage fault (channel estimate raises twice)
    is absorbed by launch supervision: the stage retries, the DAG
    completes, zero hard DAGs lost."""
    s = run_pusch(False, ticks=4,
                  fault_trace=str(DATA / "pusch_fault_trace.json"))
    assert s["retries"] >= 1, "fault trace did not fire"
    assert s["hard_lost"] == 0
    assert s["done"] == s["dags"]
    assert s["failed_jobs"] == 0


def test_mid_dag_fault_beyond_retries_cascades_cleanly():
    """When retries exhaust, the failed stage ends the DAG and cancels
    the unreachable downstream stages — terminal, never orphaned."""
    injector = FaultInjector({"target": [
        {"pipeline": "pusch_chanest", "variant": "base",
         "kind": "raise", "count": 50}]}, seed=0)
    mux, dags = _replay(GRID_TRACES[0], injector=injector)
    _check_accounting(mux, dags)
    pusch = [d for d in dags if d.dag == "pusch_receive"]
    assert all(d.state == "failed" for d in pusch)
    for d in pusch:
        assert d.reason.startswith("stage:chanest:")
        assert d.stages["equalize"] == "cancelled"
    # the svd DAG shares the mux and is untouched by the cascade
    assert all(d.state == "done" for d in dags if d.dag == "svd_solve")


# ---------------- deterministic grid + fuzzed properties ----------------

@pytest.mark.parametrize("idx", range(len(GRID_TRACES)))
def test_dag_invariants_grid(idx):
    mux, dags = _replay(GRID_TRACES[idx])
    events = mux.drain_events()
    _check_accounting(mux, dags)
    _check_bit_identity(dags)
    _check_topological(events, dags)


@fuzzed(max_examples=15, trace=dag_traces())
def test_dag_terminal_accounting_fuzzed(trace):
    mux, dags = _replay(trace)
    _check_accounting(mux, dags)


@fuzzed(max_examples=10, trace=dag_traces())
def test_dag_stage_outputs_match_standalone_fuzzed(trace):
    _, dags = _replay(trace)
    _check_bit_identity(dags)


@fuzzed(max_examples=15, trace=dag_traces())
def test_dag_topological_order_fuzzed(trace):
    mux, dags = _replay(trace)
    _check_topological(mux.drain_events(), dags)


@fuzzed(max_examples=10, trace=dag_traces(), faults=fault_streams(),
        fault_seed=integers(0, 2 ** 8))
def test_dag_faults_never_orphan_fuzzed(trace, faults, fault_seed):
    """Under seeded fault injection every DAG still reaches a terminal
    state with all stages accounted, and hard DAGs are never silently
    lost (cascade or complete — no limbo)."""
    injector = FaultInjector(faults, seed=fault_seed)
    mux, dags = _replay(trace, injector=injector)
    events = mux.drain_events()
    _check_accounting(mux, dags)
    _check_topological(events, dags)
    assert dag_hard_lost(dags) == 0
