"""Self-tuning cost model: offline calibration fixes, the online
predict -> measure -> re-fit loop, drift observability, and the adaptive
flush-threshold tuner.

Layers covered:

* ``_median`` / ``from_bench_json`` — the true-median fix (the old
  ``sorted(v)[len(v)//2]`` picked the UPPER middle element for
  even-length lists) and the malformed-baseline failure modes (missing
  file, invalid JSON, malformed rows, empty payload — all fall back to
  defaults with a logged warning instead of raising).
* ``RobustEstimator`` — warmup discipline, the observed-sample envelope
  property (every warmed value is a convex combination of window
  medians of floored samples), positivity floors.
* ``CostModel.observe`` — drift tracking for non-adaptive models,
  coordinate-descent re-fitting for adaptive ones, bad-measurement
  rejection, calibrated-vs-default source surfacing.
* **closed-loop convergence** — the committed deterministic overload
  trace replayed with a synthetic wall model and ``launch_overhead``
  seeded 10x wrong: predictions converge to within +-20% of measured
  and hard-deadline SLO attainment matches the correctly-seeded run.
* ``BucketTuner`` — warmup defaults, inter-arrival-driven ``max_wait``,
  launch-cost-driven pressure, clamps.
* ``Recorder.snapshot`` — the zero-width-window throughput fix (NaN =
  unknown, 0.0 = genuinely empty).

The ``*_fuzzed`` properties randomize measured-cost streams through the
estimator and the full observe loop (hypothesis-optional via
tests/strategies.py; the deterministic tests above carry the coverage
without it).
"""
from __future__ import annotations

import json
import logging
import math

import numpy as np
import pytest

from repro import kernels as K
from repro.launch.serve_solvers import (hard_attainment, job_args,
                                        overload_trace)
from repro.serve import (CostModel, ManualClock, OverloadPolicy,
                         Recorder, ServeConfig, SolverMux)
from repro.serve.cost import (DEFAULT_LAUNCH_OVERHEAD,
                              DEFAULT_SEC_PER_FLOP, RobustEstimator,
                              _median)
from repro.serve.tuning import BucketTuner

from strategies import cost_streams, fuzzed


def fast_config(window: int = 1, warmup: int = 1,
                alpha: float = 0.5) -> ServeConfig:
    """A ServeConfig with small calibration windows so deterministic
    tests converge in a handful of observations."""
    cfg = ServeConfig()
    cfg.calibration_window = window
    cfg.calibration_warmup = warmup
    cfg.calibration_alpha = alpha
    return cfg


# ---------------- the median fix (satellite: from_bench_json) ----------

def test_median_true_median_for_even_lists():
    # 4-sample pin: the old sorted(v)[len(v)//2] returned 3.0 (the upper
    # middle element), biasing every calibrated rate upward
    assert _median([1.0, 2.0, 3.0, 4.0]) == 2.5
    assert _median([4.0, 1.0, 3.0, 2.0]) == 2.5
    assert _median([1.0, 2.0, 3.0]) == 2.0
    assert _median([7.0]) == 7.0


def test_from_bench_json_uses_true_median(tmp_path):
    # 4 measured sizes for one pair -> rate must be the average of the
    # two middle per-size rates, not the upper one
    flops = 1000.0
    walls_us = [1.0, 2.0, 3.0, 4.0]
    payload = {"variants": [
        {"pipeline": "p", "variant": "base", "n": 8 + i,
         "model_flops": flops, "wall_us": w, "dispatches": 4}
        for i, w in enumerate(walls_us)]}
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(payload))
    cm = CostModel.from_bench_json(str(path))
    want = 2.5 * 1e-6 / flops
    assert cm.table[("p", "base")] == pytest.approx(want, rel=1e-12)
    assert cm.source("p", "base") == "bench"
    assert cm.source("p", "other") == "default"


# ---------------- failure modes (satellite: fallback + warning) --------

def _assert_fallback(cm, caplog):
    assert cm.table == {}
    assert cm.sec_per_flop == DEFAULT_SEC_PER_FLOP
    assert cm.launch_overhead == DEFAULT_LAUNCH_OVERHEAD
    assert any("falling back to uncalibrated defaults" in r.message
               or "no usable" in r.message for r in caplog.records)


def test_from_bench_json_missing_file_falls_back(tmp_path, caplog):
    with caplog.at_level(logging.WARNING, logger="repro.serve.cost"):
        cm = CostModel.from_bench_json(str(tmp_path / "nope.json"))
    _assert_fallback(cm, caplog)
    # "calibrated vs default" is visible per pair in the drift metrics
    assert all(st.source == "default" for st in cm.drift().values())


def test_from_bench_json_invalid_json_falls_back(tmp_path, caplog):
    path = tmp_path / "garbage.json"
    path.write_text("{not json")
    with caplog.at_level(logging.WARNING, logger="repro.serve.cost"):
        cm = CostModel.from_bench_json(str(path))
    _assert_fallback(cm, caplog)


def test_from_bench_json_malformed_rows_fall_back(tmp_path, caplog):
    path = tmp_path / "malformed.json"
    path.write_text(json.dumps({"variants": [
        {"model_flops": 10.0, "wall_us": 5.0}]}))   # no pipeline/variant
    with caplog.at_level(logging.WARNING, logger="repro.serve.cost"):
        cm = CostModel.from_bench_json(str(path))
    _assert_fallback(cm, caplog)


def test_from_bench_json_empty_payload_falls_back(tmp_path, caplog):
    path = tmp_path / "empty.json"
    path.write_text(json.dumps({"variants": []}))
    with caplog.at_level(logging.WARNING, logger="repro.serve.cost"):
        cm = CostModel.from_bench_json(str(path))
    _assert_fallback(cm, caplog)


def test_calibrated_pair_reported_in_drift_without_traffic(tmp_path):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps({"variants": [
        {"pipeline": "p", "variant": "blocked", "n": 128,
         "model_flops": 100.0, "wall_us": 3.0, "dispatches": 2}]}))
    cm = CostModel.from_bench_json(str(path))
    drift = cm.drift()
    st = drift["p/blocked"]
    assert st.source == "bench" and st.updates == 0
    assert math.isnan(st.ratio) and not st.alert


# ---------------- RobustEstimator ----------------

def test_estimator_holds_initial_through_warmup():
    est = RobustEstimator(5e-4, alpha=0.5, window=2, warmup=2,
                          floor=1e-9)
    samples = [1e-5, 2e-5, 3e-5, 4e-5]
    for i, s in enumerate(samples[:-1]):
        est.observe(s)
        if est.updates < 2:
            assert est.value == 5e-4, f"moved early at sample {i}"
    est.observe(samples[-1])
    assert est.warmed
    # warmed value is a convex combination of window medians -> inside
    # the observed envelope, nowhere near the bad seed
    assert min(samples) <= est.value <= max(samples)


def test_estimator_first_median_replaces_seed():
    # the seeded value must not blend into the estimate: one window in,
    # the estimate IS that window's median
    est = RobustEstimator(1.0, alpha=0.25, window=3, warmup=1,
                          floor=1e-9)
    for s in (2.0, 4.0, 3.0):
        est.observe(s)
    assert est.value == 3.0


def test_estimator_floor_clamps_adversarial_samples():
    est = RobustEstimator(1e-4, alpha=0.5, window=1, warmup=1,
                          floor=1e-9)
    for s in (-1.0, -5.0, 0.0):
        est.observe(s)
    assert est.value == 1e-9


def test_estimator_median_rejects_window_outliers():
    est = RobustEstimator(1e-4, alpha=1.0, window=5, warmup=1,
                          floor=1e-12)
    # 2 outliers out of 5 cannot move the window median
    for s in (1.0, 1.0, 1.0, 1e6, 1e6):
        est.observe(s)
    assert est.value == 1.0


# ---------------- CostModel.observe ----------------

def _mmse():
    spec = K.get("mmse_equalize")
    shapes = ((12, 8), (12, 2))
    return spec, spec.base, shapes


def test_observe_ignores_bad_measurements():
    spec, variant, shapes = _mmse()
    cm = CostModel(adaptive=True, config=fast_config())
    for bad in (math.nan, math.inf, -math.inf, 0.0, -1.0, None):
        cm.observe(spec.name, variant, shapes, 4, bad)
    assert cm.calibration_updates()["overhead"] == 0
    assert all(st.updates == 0 for st in cm.drift().values())


def test_non_adaptive_model_tracks_drift_but_never_refits():
    spec, variant, shapes = _mmse()
    cm = CostModel()
    assert not cm.adaptive
    oh0, rate0 = cm.launch_overhead, cm.rate(spec.name, variant.name)
    truth = 3.0 * cm.launch_cost(spec.name, variant, shapes, 4)
    for _ in range(20):
        cm.observe(spec.name, variant, shapes, 4, truth)
    assert cm.launch_overhead == oh0
    assert cm.rate(spec.name, variant.name) == rate0
    st = cm.drift()[f"{spec.name}/{variant.name}"]
    assert st.updates == 20
    assert st.ratio == pytest.approx(1.0 / 3.0, rel=1e-9)
    assert st.source == "default"


def test_observe_refits_mispriced_overhead():
    # launch_overhead seeded 10x wrong, rate correct: the overhead
    # residual stream sees the true overhead exactly, and predictions
    # converge onto measurements
    spec, variant, shapes = _mmse()
    cm = CostModel(launch_overhead=10 * DEFAULT_LAUNCH_OVERHEAD,
                   adaptive=True, config=fast_config())
    lanes = 4
    truth = (DEFAULT_LAUNCH_OVERHEAD
             + lanes * variant.model_flops(shapes) * DEFAULT_SEC_PER_FLOP)
    for _ in range(8):
        cm.observe(spec.name, variant, shapes, lanes, truth)
    predicted = cm.launch_cost(spec.name, variant, shapes, lanes)
    assert 0.8 <= predicted / truth <= 1.25
    assert cm.source(spec.name, variant.name) == "online"
    ups = cm.calibration_updates()
    assert ups["overhead"] > 0
    assert ups[f"{spec.name}/{variant.name}"] > 0


def test_drift_alert_flags_mispriced_pair():
    spec, variant, shapes = _mmse()
    cm = CostModel()          # frozen: predictions never improve
    truth = 10.0 * cm.launch_cost(spec.name, variant, shapes, 4)
    for _ in range(6):
        cm.observe(spec.name, variant, shapes, 4, truth)
    st = cm.drift()[f"{spec.name}/{variant.name}"]
    assert st.alert
    worst = cm.worst_drift()
    assert worst is not None and worst.key == st.key


def test_config_env_overrides_master_switch(monkeypatch):
    monkeypatch.setenv("REPRO_SERVE_CALIBRATE", "1")
    monkeypatch.setenv("REPRO_SERVE_CALIBRATION_WINDOW", "2")
    cfg = ServeConfig()
    assert cfg.calibrate and cfg.calibration_window == 2
    assert CostModel(config=cfg).adaptive
    monkeypatch.setenv("REPRO_SERVE_CALIBRATE", "0")
    assert not CostModel(config=cfg.reload()).adaptive


# ---------------- closed-loop convergence (acceptance) -----------------

OH_TRUE = DEFAULT_LAUNCH_OVERHEAD
RATE_TRUE = DEFAULT_SEC_PER_FLOP


class SyntheticMux(SolverMux):
    """SolverMux whose calibration loop is fed a deterministic wall
    model — ``measured = OH_TRUE + lanes * flops * RATE_TRUE`` — instead
    of real (noisy, interpret-mode) timings, so the convergence test is
    exact and replayable."""

    def observe_launch(self, spec, variant, key, lanes, measured):
        v = variant if variant is not None else spec.base
        shapes = tuple(shape for shape, _ in key)
        synth = OH_TRUE + lanes * v.model_flops(shapes) * RATE_TRUE
        super().observe_launch(spec, variant, key, lanes, synth)


def _replay_overload(cm, *, ticks=8, lanes=4):
    """The committed deterministic overload trace through a SyntheticMux
    with ``cm`` pricing the policy.  Budget comes from a correctly
    seeded reference model in every run, so only the *pricing* model
    under test differs between runs."""
    ref = CostModel()
    spec = K.get("mmse_equalize")
    unit = ref.launch_cost("mmse_equalize", spec.base,
                           ((12, 8), (12, 2)), lanes)
    pol = OverloadPolicy(budget=2.0 * unit, cost_model=cm)
    clock = ManualClock()
    mux = SyntheticMux(lanes=lanes, clock=clock, pressure=2 * lanes,
                       policy=pol)
    by_tick: dict[int, list[dict]] = {}
    for entry in overload_trace(ticks, lanes, 0):
        by_tick.setdefault(entry["tick"], []).append(entry)
    jobs = []
    for t in range(2 * ticks):
        for e in by_tick.get(t, ()):
            jobs.append(mux.submit(
                e["pipeline"],
                *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                deadline=clock() + e["deadline_ticks"],
                priority=e["priority"]))
        mux.poll()
        clock.advance(1.0)
    mux.run()
    return hard_attainment(jobs), mux


def test_overload_convergence_from_mispriced_overhead():
    """The acceptance scenario: ``launch_overhead`` seeded 10x wrong,
    the online loop replays the committed overload trace, and (a) the
    re-fit model prices every trafficked variant within +-20% of
    measured, (b) once the loop has closed over the trace, hard-deadline
    SLO attainment is restored to the correctly seeded run's level (the
    cold mis-seeded pass pays a bounded early-deadline cost while
    admission is overpriced — the aged-voucher path keeps it serving
    until the model corrects)."""
    att_ok, _ = _replay_overload(CostModel())
    cm_bad = CostModel(launch_overhead=10 * OH_TRUE, adaptive=True,
                       config=fast_config())
    att_cold, _ = _replay_overload(cm_bad)
    assert att_cold >= 0.9 * att_ok, (
        f"mis-seeded cold start collapsed: {att_cold:.3f} vs "
        f"correct-seed {att_ok:.3f}")

    # second pass with the now-converged model: attainment must match
    # the correctly seeded run exactly
    att_warm, mux = _replay_overload(cm_bad)
    assert att_warm == pytest.approx(att_ok), (
        f"attainment not restored after convergence: {att_warm:.3f} vs "
        f"correct-seed {att_ok:.3f}")

    checked = 0
    for st in cm_bad.drift().values():
        if st.updates < 3:
            continue
        assert 0.8 <= st.last <= 1.25, (
            f"{st.key}: last predicted/measured {st.last:.3f} "
            f"outside +-20% after {st.updates} observations")
        checked += 1
    assert checked, "no trafficked pair accumulated 3+ observations"

    # and the SLO surface carries the whole story
    snap = mux.metrics()
    assert snap.drift and snap.calibration_updates["overhead"] > 0
    assert snap.worst_drift is not None


def test_metrics_snapshot_carries_drift_without_policy():
    cm = CostModel(adaptive=True, config=fast_config())
    clock = ManualClock()
    mux = SyntheticMux(lanes=4, clock=clock, cost_model=cm)
    rng = np.random.default_rng(0)
    for i in range(8):
        a = rng.standard_normal((12, 8)).astype(np.float32)
        b = rng.standard_normal((12, 2)).astype(np.float32)
        mux.submit("mmse_equalize", a, b)
    mux.run()
    snap = mux.metrics()
    assert "mmse_equalize/base" in snap.drift
    assert snap.drift["mmse_equalize/base"].updates > 0
    assert snap.calibration_updates["overhead"] >= 0
    # measured wall-clock is stamped on every launch record
    assert all(math.isfinite(l.measured) and l.measured > 0
               for l in snap.launches)


def test_mux_rejects_cost_model_next_to_policy():
    with pytest.raises(ValueError):
        SolverMux(lanes=4, policy=OverloadPolicy(),
                  cost_model=CostModel())


# ---------------- BucketTuner ----------------

def _tuner_config():
    cfg = ServeConfig()
    cfg.calibration_warmup = 2
    cfg.interarrival_alpha = 0.5
    return cfg


def test_tuner_returns_defaults_until_warm():
    cfg = _tuner_config()
    tuner = BucketTuner(4, config=cfg)
    key = ((8, 8), "float32")
    assert tuner.max_wait("p", key, 1, 7e-3) == 7e-3
    assert tuner.pressure("p", 16) == 16
    tuner.note_arrival("p", key, 0.0)
    tuner.note_arrival("p", key, 1e-4)       # one gap: still cold
    assert tuner.max_wait("p", key, 1, 7e-3) == 7e-3


def test_tuner_max_wait_tracks_interarrival_and_clamps():
    cfg = _tuner_config()
    cfg.wait_cap = 5e-3
    cfg.wait_floor = 1e-5
    tuner = BucketTuner(4, config=cfg)
    key = ((8, 8), "float32")
    for i in range(4):                       # steady 0.1 ms arrivals
        tuner.note_arrival("p", key, i * 1e-4)
    # 1 job queued -> 3 missing lanes -> expected fill 3 * 0.1 ms
    assert tuner.max_wait("p", key, 1, None) == pytest.approx(3e-4)
    # fuller bucket -> shorter wait (monotone in queued)
    assert tuner.max_wait("p", key, 3, None) == pytest.approx(1e-4)
    # cap: a dried-up stream cannot hold jobs hostage
    slow = BucketTuner(4, config=cfg)
    for i in range(4):
        slow.note_arrival("p", key, i * 10.0)
    assert slow.max_wait("p", key, 1, None) == cfg.wait_cap
    # explicit constructor max_wait lowers the cap further
    assert slow.max_wait("p", key, 1, 1e-3) == 1e-3


def test_tuner_pressure_amortizes_overhead_and_clamps():
    cfg = _tuner_config()
    cfg.pressure_gain = 8.0
    cfg.pressure_cap_lanes = 8
    cm = CostModel()                          # overhead 5e-5
    tuner = BucketTuner(4, config=cfg, cost_model=cm)
    for _ in range(3):                        # lane cost 5e-5 -> want 8
        tuner.note_launch("p", 1, 5e-5)
    assert tuner.pressure("p", 16) == 8
    # expensive lanes -> clamps at one pool width
    costly = BucketTuner(4, config=cfg, cost_model=cm)
    for _ in range(3):
        costly.note_launch("p", 1, 1.0)
    assert costly.pressure("p", 16) == 4
    # near-free lanes -> clamps at cap_lanes * lanes
    cheap = BucketTuner(4, config=cfg, cost_model=cm)
    for _ in range(3):
        cheap.note_launch("p", 1, 1e-12)
    assert cheap.pressure("p", 16) == 32


# ---------------- throughput window fix (satellite) --------------------

def test_zero_width_window_throughput_is_nan_not_zero():
    rec = Recorder()
    rec.record_job("p", 1.0, 1.0)            # one instantaneous batch
    rec.record_job("p", 1.0, 1.0)
    st = rec.snapshot()["p"]
    assert st.jobs == 2 and math.isnan(st.throughput)


def test_empty_pipeline_throughput_is_zero():
    rec = Recorder()
    rec.record_launch("p", ((8, 8),), 0, 4, 1.0)   # launch, no jobs
    assert rec.snapshot()["p"].throughput == 0.0


def test_positive_window_throughput_unchanged():
    rec = Recorder()
    rec.record_job("p", 0.0, 1.0)
    rec.record_job("p", 1.0, 2.0)
    assert rec.snapshot()["p"].throughput == pytest.approx(1.0)


# ---------------- fuzzed properties ----------------

@fuzzed(max_examples=40, stream=cost_streams(48, 1e-9, 10.0))
def test_estimator_envelope_fuzzed(stream):
    """Any positive measured-cost stream: once warmed, the estimate lies
    within the observed sample envelope (it is a convex combination of
    window medians) and is never non-positive."""
    est = RobustEstimator(123.0, alpha=0.35, window=3, warmup=2,
                          floor=1e-12)
    for s in stream:
        est.observe(s)
        assert est.value > 0.0
    if est.warmed:
        clamped = [max(1e-12, s) for s in stream]
        assert min(clamped) <= est.value <= max(clamped)
    else:
        assert est.value == 123.0


@fuzzed(max_examples=25, stream=cost_streams(32, -5.0, 5.0))
def test_observe_keeps_model_positive_fuzzed(stream):
    """Adversarial measured streams (negatives, zeros, outliers) through
    the full observe loop: rates and overhead stay positive and every
    prediction stays finite and positive."""
    spec, variant, shapes = _mmse()
    cm = CostModel(adaptive=True, config=fast_config(window=2, warmup=1))
    for s in stream:
        cm.observe(spec.name, variant, shapes, 4, s)
        assert cm.launch_overhead > 0.0
        assert cm.rate(spec.name, variant.name) > 0.0
        predicted = cm.launch_cost(spec.name, variant, shapes, 4)
        assert math.isfinite(predicted) and predicted > 0.0
