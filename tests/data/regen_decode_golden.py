"""Regenerate ``decode_trace.json`` + ``decode_golden.json``.

Run after any INTENTIONAL continuous-batching change (slot insertion
order, decode admission pricing, decode event schema), then review the
golden diff like any other code change:

  PYTHONPATH=src python tests/data/regen_decode_golden.py

The replay parameters here must stay in sync with
``tests/test_decode_serve.py::test_golden_decode_replay_event_sequence``.
The golden event stream is the proof artifact for mux-owned token
traffic: it pins the interleaving of solver flushes with decode
insert/step/done decisions, slot reuse order, and budget-priced decode
admission on the virtual clock.  The replay engine uses ``eos_id=-1``
so the sequence depends only on the trace's prompt/output lengths,
never on model floating point — the file is platform-independent.
"""
import json
import pathlib

from repro.launch.serve_solvers import decode_trace, replay_decode

DATA = pathlib.Path(__file__).parent

def main():
    trace = decode_trace(4, seed=0)
    (DATA / "decode_trace.json").write_text(
        json.dumps(trace, indent=1) + "\n")
    mux, engine, requests, jobs = replay_decode(trace)
    events = mux.drain_events()
    out = DATA / "decode_golden.json"
    out.write_text(json.dumps(events, indent=1) + "\n")
    kinds = sorted({e["event"] for e in events})
    print(f"wrote {out}: {len(events)} events, kinds={kinds}, "
          f"requests done={sum(r.done for r in requests)}/{len(requests)}, "
          f"solver done={sum(j.state == 'done' for j in jobs)}/{len(jobs)}")

if __name__ == "__main__":
    main()
