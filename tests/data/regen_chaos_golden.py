"""Regenerate ``chaos_golden.json`` from ``fault_trace.json``.

Run after any INTENTIONAL supervision/fault-handling change, then
review the golden diff like any other code change:

  PYTHONPATH=src python tests/data/regen_chaos_golden.py

The replay parameters here must stay in sync with
``tests/test_faults.py::test_golden_chaos_replay_event_sequence``.
"""
import json
import pathlib

from repro.launch.serve_solvers import run_chaos

DATA = pathlib.Path(__file__).parent

def main():
    summary = run_chaos(DATA / "fault_trace.json")
    out = DATA / "chaos_golden.json"
    out.write_text(json.dumps(summary["events"], indent=1) + "\n")
    kinds = sorted({e["event"] for e in summary["events"]})
    print(f"wrote {out}: {len(summary['events'])} events, kinds={kinds}")

if __name__ == "__main__":
    main()
