"""Regenerate ``pusch_trace.json`` + ``pusch_golden.json``.

Run after any INTENTIONAL DAG-scheduling change (stage admission
order, criticality ranking, DAG event schema), then review the golden
diff like any other code change:

  PYTHONPATH=src python tests/data/regen_pusch_golden.py

The replay parameters here must stay in sync with
``tests/test_dag_serve.py::test_golden_pusch_replay_event_sequence``.
The golden event stream is the proof artifact for staged scheduling:
it pins stage ordering (topological), criticality-first admission (the
equal-deadline rank inversion at t=2.0), and the deterministic
end-to-end DAG latency under the virtual clock.
"""
import json
import pathlib

from repro.launch.serve_solvers import pusch_trace, replay_pusch

DATA = pathlib.Path(__file__).parent

def main():
    trace = pusch_trace(4, seed=0)
    (DATA / "pusch_trace.json").write_text(
        json.dumps(trace, indent=1) + "\n")
    mux, dags = replay_pusch(trace)
    events = mux.drain_events()
    out = DATA / "pusch_golden.json"
    out.write_text(json.dumps(events, indent=1) + "\n")
    kinds = sorted({e["event"] for e in events})
    states = sorted({d.state for d in dags})
    print(f"wrote {out}: {len(events)} events, kinds={kinds}, "
          f"dag states={states}")

if __name__ == "__main__":
    main()
