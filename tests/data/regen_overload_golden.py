"""Regenerate ``overload_golden.json`` from ``overload_trace.json``.

Run after any INTENTIONAL overload-policy change, then review the
golden diff like any other code change:

  PYTHONPATH=src python tests/data/regen_overload_golden.py

The replay parameters here must stay in sync with
``tests/test_overload.py::test_golden_trace_replay_event_sequence``.
"""
import json
import pathlib

from repro.launch.serve_solvers import load_trace, replay_trace
from repro.serve import CostModel, OverloadPolicy

DATA = pathlib.Path(__file__).parent

def main():
    trace = load_trace(DATA / "overload_trace.json")
    mux = replay_trace(trace, lanes=2, policy=OverloadPolicy(
        budget=6.5e-5, cost_model=CostModel()), pressure=4)
    out = DATA / "overload_golden.json"
    out.write_text(json.dumps(mux.events, indent=1) + "\n")
    kinds = sorted({e["event"] for e in mux.events})
    print(f"wrote {out}: {len(mux.events)} events, kinds={kinds}")

if __name__ == "__main__":
    main()
