"""Logical-axis sharding rules + mesh construction (distribution layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh


def mesh1d():
    return jax.make_mesh((1,), ("data",))


def test_resolve_without_context_is_noop():
    assert shd.resolve("batch", "seq") == P()
    x = jnp.ones((2, 2))
    assert shd.constrain(x, "batch", None) is x


def test_resolve_with_mesh_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(mesh):
        assert shd.resolve("batch", "seq", "embed") == P("data", None, None)
        assert shd.resolve("batch", None, "heads") == P("data", None,
                                                        "model")
        assert shd.resolve("fsdp", "model") == P("data", "model")


def test_resolve_multi_axis_batch():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    with shd.axis_rules(mesh):
        spec = shd.resolve("batch")
        assert spec == P(("pod", "data"))


def test_serve_rules_disable_fsdp():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(mesh, shd.SERVE_RULES):
        assert shd.resolve("fsdp") == P(None)
        assert shd.resolve("batch") == P("data")


def test_named_safe_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("data",))
    with shd.axis_rules(mesh):
        # vocab=7 on a 1-way axis always divides; use a fake 2-way rule via
        # named_safe's divisibility math directly
        s = shd.named_safe(P("batch"), (4,))
        assert isinstance(s, jax.sharding.NamedSharding)


def test_param_spec_policy():
    ps = shd.param_spec(("layers", "attn", "wq"), (8, 64, 64))
    assert ps == P(None, "fsdp", "model")       # stacked layer dim first
    ps = shd.param_spec(("layers", "attn", "wo"), (8, 64, 64))
    assert ps == P(None, "model", "fsdp")
    ps = shd.param_spec(("embed",), (1000, 64))
    assert ps == P("vocab", "fsdp")
    ps = shd.param_spec(("lm_head",), (64, 1000))
    assert ps == P("fsdp", "vocab")
    # MoE expert tensors: experts on their own axis
    ps = shd.param_spec(("layers", "moe", "wi"), (8, 16, 64, 128))
    assert ps == P(None, "experts", "fsdp", None)
    # 1-D scales replicated
    ps = shd.param_spec(("layers", "ln1"), (8, 64))
    assert ps == P(None, None)


def test_constrain_under_mesh_runs():
    mesh = jax.make_mesh((1,), ("data",))
    with shd.axis_rules(mesh):
        f = jax.jit(lambda x: shd.constrain(x * 2, "batch", None))
        out = f(jnp.ones((2, 3)))
        np.testing.assert_allclose(np.asarray(out), 2.0)


def test_make_production_mesh_requires_devices():
    """On this 1-device container the 256/512-chip meshes must be built in
    a subprocess with placeholder devices (launch/dryrun.py does this);
    here we assert the constructor shape logic via the error path."""
    with pytest.raises(ValueError):
        make_production_mesh()            # 256 devices unavailable
    with pytest.raises(ValueError):
        make_production_mesh(multi_pod=True)
