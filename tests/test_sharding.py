"""Logical-axis sharding rules + mesh construction (distribution layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh


def mesh1d():
    return jax.make_mesh((1,), ("data",))


def test_resolve_without_context_is_noop():
    assert shd.resolve("batch", "seq") == P()
    x = jnp.ones((2, 2))
    assert shd.constrain(x, "batch", None) is x


def test_resolve_with_mesh_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(mesh):
        assert shd.resolve("batch", "seq", "embed") == P("data", None, None)
        assert shd.resolve("batch", None, "heads") == P("data", None,
                                                        "model")
        assert shd.resolve("fsdp", "model") == P("data", "model")


def test_resolve_multi_axis_batch():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    with shd.axis_rules(mesh):
        spec = shd.resolve("batch")
        assert spec == P(("pod", "data"))


def test_serve_rules_disable_fsdp():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(mesh, shd.SERVE_RULES):
        assert shd.resolve("fsdp") == P(None)
        assert shd.resolve("batch") == P("data")


def test_named_safe_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("data",))
    with shd.axis_rules(mesh):
        # vocab=7 on a 1-way axis always divides; use a fake 2-way rule via
        # named_safe's divisibility math directly
        s = shd.named_safe(P("batch"), (4,))
        assert isinstance(s, jax.sharding.NamedSharding)


def test_param_spec_policy():
    ps = shd.param_spec(("layers", "attn", "wq"), (8, 64, 64))
    assert ps == P(None, "fsdp", "model")       # stacked layer dim first
    ps = shd.param_spec(("layers", "attn", "wo"), (8, 64, 64))
    assert ps == P(None, "model", "fsdp")
    ps = shd.param_spec(("embed",), (1000, 64))
    assert ps == P("vocab", "fsdp")
    ps = shd.param_spec(("lm_head",), (64, 1000))
    assert ps == P("fsdp", "vocab")
    # MoE expert tensors: experts on their own axis
    ps = shd.param_spec(("layers", "moe", "wi"), (8, 16, 64, 128))
    assert ps == P(None, "experts", "fsdp", None)
    # 1-D scales replicated
    ps = shd.param_spec(("layers", "ln1"), (8, 64))
    assert ps == P(None, None)


def test_constrain_under_mesh_runs():
    mesh = jax.make_mesh((1,), ("data",))
    with shd.axis_rules(mesh):
        f = jax.jit(lambda x: shd.constrain(x * 2, "batch", None))
        out = f(jnp.ones((2, 3)))
        np.testing.assert_allclose(np.asarray(out), 2.0)


def test_make_production_mesh_requires_devices():
    """The suite session exposes 8 virtual CPU devices (conftest) — far
    short of the 256/512-chip production meshes, which must be built in
    a subprocess with placeholder devices (launch/dryrun.py does this);
    here we assert the constructor shape logic via the error path."""
    with pytest.raises(ValueError):
        make_production_mesh()            # 256 devices unavailable
    with pytest.raises(ValueError):
        make_production_mesh(multi_pod=True)


# ---------------- version-portable shard_map shim ----------------

def test_shard_map_shim_prefers_new_api(monkeypatch):
    """When ``jax.shard_map`` exists (newer releases) the shim must call
    it — forwarding the ``check_vma`` knob under its NEW name, never the
    legacy ``check_rep``."""
    seen = {}

    def fake(f, *, mesh, in_specs, out_specs, **kw):
        seen.update(kw, mesh=mesh)
        return f

    monkeypatch.setattr(jax, "shard_map", fake, raising=False)
    mesh = jax.make_mesh((2,), ("data",))
    out = shd.shard_map(lambda x: x, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"), check_vma=True)
    assert out(7) == 7
    assert seen == {"check_vma": True, "mesh": mesh}
    assert "check_rep" not in seen


def test_shard_map_shim_experimental_fallback(monkeypatch):
    """Without ``jax.shard_map`` the shim must fall back to
    ``jax.experimental.shard_map`` (``check_rep`` spelling) and still
    produce a working mesh program — bit-identical to the unsharded
    computation."""
    monkeypatch.delattr(jax, "shard_map", raising=False)
    mesh = jax.make_mesh((2,), ("data",))
    x = np.arange(8, dtype=np.float32).reshape(2, 4)
    fn = shd.shard_map(lambda a: a * 2.0, mesh=mesh, in_specs=P("data"),
                       out_specs=P("data"))
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(x)), x * 2.0)


def test_shard_map_shim_executes_on_data_mesh():
    """Whichever branch is live in this jax version, the shim's output
    matches the plain computation exactly on a real 2-device mesh."""
    mesh = jax.make_mesh((2,), ("data",))
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, 6)).astype(np.float32)
    fn = shd.shard_map(jnp.tanh, mesh=mesh, in_specs=P("data"),
                       out_specs=P("data"))
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(x)),
                                  np.asarray(jax.jit(jnp.tanh)(x)))


# ---------------- rule resolution vs missing mesh axes ----------------

def test_resolve_missing_candidate_axis_is_unconstrained():
    """A mesh lacking every candidate axis of a rule resolves to the
    unconstrained spec — same as an empty rule — while rules whose axis
    IS present still bind."""
    mesh = jax.make_mesh((1,), ("model",))
    with shd.axis_rules(mesh):
        assert shd.resolve("batch") == P(None)   # candidates (pod, data) absent
        assert shd.resolve("seq") == P(None)     # empty rule
        assert shd.resolve("heads") == P("model")
        assert shd.resolve("batch", "heads") == P(None, "model")


def test_lane_mesh_bounds_and_axis():
    from repro.launch.mesh import make_lane_mesh
    with pytest.raises(ValueError):
        make_lane_mesh(0)
    with pytest.raises(ValueError):
        make_lane_mesh(jax.device_count() + 1)
    mesh = make_lane_mesh(2)
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == 2
