"""SolverMux: registry-driven multi-pipeline serving — mixed-type
routing, shape-bucket grouping, deadline-aware flush ordering,
timeout/pressure partial flushes, registry-filler padding (every
registered pipeline, no contamination of real lanes), and the SLO
metrics snapshot."""
import numpy as np
import pytest

from repro import kernels as K
from repro.kernels import ref
from repro.kernels.common import sample_spd
from repro.serve import ManualClock, SolverMux, pad_group

from conftest import assert_close

RNG = np.random.default_rng(1234)


def chol_args(n, rng=RNG):
    return (sample_spd(rng, 1, n)[0],
            rng.standard_normal((n, 2)).astype(np.float32))


def tall_args(n, k=2, rng=RNG):
    """(m, n) tall matrix + (m, k) rhs — qr_solve / mmse_equalize shape."""
    m = n + 4
    return (rng.standard_normal((m, n)).astype(np.float32),
            rng.standard_normal((m, k)).astype(np.float32))


def oracle_of(job):
    """Single-lane registry-oracle answer for a SolveJob."""
    return K.get(job.pipeline).run_oracle_lane(*job.args)


# ---------------- mixed routing + batching (acceptance) ----------------

def test_mux_mixed_stream_batches_and_matches_oracles():
    """Interleaved cholesky/qr/mmse jobs at >= 2 distinct shapes each,
    one run(): every job gets its own oracle-matching answer, and
    batching actually happens (fewer grid launches than jobs)."""
    mux = SolverMux(lanes=4, clock=ManualClock())
    jobs = []
    for _ in range(4):                       # interleaved, never grouped
        for n in (8, 12):
            jobs.append(mux.submit("cholesky_solve", *chol_args(n)))
            jobs.append(mux.submit("qr_solve", *tall_args(n)))
            jobs.append(mux.submit("mmse_equalize", *tall_args(n)))
    done = mux.run()
    assert len(done) == len(jobs) == 24
    assert mux.pending() == 0
    for job in jobs:
        assert_close(job.out, oracle_of(job), rtol=1e-3,
                     name=f"mux-{job.pipeline}")
    snap = mux.metrics()
    assert snap.total_launches < snap.total_jobs == 24
    # 3 pipelines x 2 shapes x 4 jobs -> ceil(4/4) = 1 launch per bucket
    assert snap.total_launches == 6


def test_mux_routes_by_pipeline():
    mux = SolverMux(lanes=4, clock=ManualClock())
    j1 = mux.submit("cholesky_solve", *chol_args(8))
    j2 = mux.submit("qr_solve", *tall_args(8))
    mux.run()
    assert j1.pipeline == "cholesky_solve" and j2.pipeline == "qr_solve"
    per = mux.metrics().pipelines
    assert per["cholesky_solve"].jobs == 1
    assert per["qr_solve"].jobs == 1


def test_mux_rejects_non_pipeline_and_unknown():
    mux = SolverMux(lanes=4)
    with pytest.raises(ValueError):
        mux.submit("gemm", np.eye(8, dtype=np.float32))
    with pytest.raises(KeyError):
        mux.submit("no_such_pipeline", np.eye(8, dtype=np.float32))


def test_mux_options_bound_per_pipeline():
    """Per-pipeline options reach the served kernel (sigma2 here)."""
    mux = SolverMux(lanes=2, clock=ManualClock(),
                    options={"mmse_equalize": {"sigma2": 0.05}})
    h, y = tall_args(8)
    job = mux.submit("mmse_equalize", h, y)
    mux.run()
    want = np.asarray(ref.mmse_equalize(h[None], y[None], sigma2=0.05))[0]
    assert_close(job.out, want, rtol=1e-3, name="mmse-sigma2-option")


# ---------------- shape buckets ----------------

def test_mux_shape_buckets_never_mix():
    """Jobs of different shapes never share a grid launch; same-shape
    jobs do."""
    mux = SolverMux(lanes=4, clock=ManualClock())
    for _ in range(4):
        mux.submit("cholesky_solve", *chol_args(8))
    for _ in range(3):
        mux.submit("cholesky_solve", *chol_args(12))
    mux.run()
    snap = mux.metrics()
    assert snap.total_launches == 2
    by_shape = {l.shape: l for l in snap.launches}
    assert len(by_shape) == 2                 # one launch per shape bucket
    reals = sorted(l.real for l in snap.launches)
    assert reals == [3, 4]


def test_mux_rhs_width_is_part_of_bucket_key():
    """Same matrix size, different rhs width -> different buckets."""
    mux = SolverMux(lanes=4, clock=ManualClock())
    mux.submit("cholesky_solve", *chol_args(8))
    a, _ = chol_args(8)
    mux.submit("cholesky_solve", a,
               RNG.standard_normal((8, 5)).astype(np.float32))
    done = mux.run()
    assert mux.metrics().total_launches == 2
    for job in done:
        assert_close(job.out, oracle_of(job), rtol=1e-3, name="rhs-width")


# ---------------- deadline-aware flush policy ----------------

def test_mux_deadline_flush_ordering():
    """run() flushes the oldest-deadline bucket first; a no-deadline
    bucket goes last regardless of submission order."""
    mux = SolverMux(lanes=4, clock=ManualClock())
    mux.submit("qr_solve", *tall_args(8))                    # no deadline
    mux.submit("cholesky_solve", *chol_args(8), deadline=3.0)
    mux.submit("mmse_equalize", *tall_args(8), deadline=1.0)
    mux.submit("cholesky_solve", *chol_args(12), deadline=2.0)
    mux.run()
    order = [l.pipeline for l in mux.metrics().launches]
    assert order == ["mmse_equalize", "cholesky_solve",
                     "cholesky_solve", "qr_solve"]


def test_mux_poll_dispatches_full_groups_holds_partials():
    """poll(): a full lane group goes out immediately; a partial bucket
    with no expired deadline stays queued until run() drains it."""
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk)
    full = [mux.submit("cholesky_solve", *chol_args(8)) for _ in range(4)]
    part = mux.submit("cholesky_solve", *chol_args(12))
    done = mux.poll()
    assert sorted(id(j) for j in done) == sorted(id(j) for j in full)
    assert mux.pending() == 1 and part.out is None
    clk.advance(100.0)                 # no max_wait, no deadline: holds
    assert mux.poll() == []
    assert mux.run() == [part]
    assert_close(part.out, oracle_of(part), rtol=1e-3, name="partial")


def test_mux_remainder_reranks_behind_older_bucket():
    """A bucket whose oldest jobs were chunked away must re-rank by its
    remaining jobs: the leftover (newer) job flushes AFTER an older
    bucket submitted in between."""
    mux = SolverMux(lanes=2, clock=ManualClock())
    for _ in range(2):                          # bucket A: full group
        mux.submit("cholesky_solve", *chol_args(8))
    older = mux.submit("cholesky_solve", *chol_args(12))   # bucket B
    leftover = mux.submit("cholesky_solve", *chol_args(8))  # A again
    mux.poll()                                  # dispatches A's full pair
    assert mux.pending() == 2
    done = mux.run()
    assert [j.seq for j in done] == [older.seq, leftover.seq]


def test_mux_poll_flushes_expired_deadline():
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk)
    job = mux.submit("mmse_equalize", *tall_args(8), deadline=1.0)
    assert mux.poll() == []                      # deadline not reached
    clk.advance(1.5)
    assert mux.poll() == [job]
    assert job.out is not None


def test_mux_poll_flushes_aged_partials_after_max_wait():
    clk = ManualClock()
    mux = SolverMux(lanes=4, max_wait=0.010, clock=clk)
    job = mux.submit("qr_solve", *tall_args(8))
    clk.advance(0.005)
    assert mux.poll() == []                      # younger than max_wait
    clk.advance(0.006)
    assert mux.poll() == [job]


def test_mux_pressure_flushes_oldest_bucket_first():
    """Pool pressure flushes partial buckets (oldest deadline/arrival
    first) until the pool drops below the threshold."""
    clk = ManualClock()
    mux = SolverMux(lanes=8, pressure=4, clock=clk)
    older = [mux.submit("cholesky_solve", *chol_args(8))
             for _ in range(3)]
    newer = [mux.submit("cholesky_solve", *chol_args(12))
             for _ in range(2)]
    done = mux.poll()                  # queued 5 >= 4: flush oldest bucket
    assert sorted(id(j) for j in done) == sorted(id(j) for j in older)
    assert mux.pending() == 2          # relieved: newer bucket survives
    assert all(j.out is None for j in newer)
    mux.run()


# ---------------- registry-filler padding ----------------

@pytest.mark.parametrize("name", sorted(K.names(kind="pipeline")))
def test_mux_padded_lanes_never_contaminate(name):
    """EVERY registered pipeline: a 3-job group padded to the 4-lane pool
    via the spec's declared filler returns real-lane results identical to
    the oracle — the padding lane is benign by construction."""
    spec = K.get(name)
    assert spec.filler is not None, f"{name} must declare a filler"
    rng = np.random.default_rng(5)
    n = spec.sizes[0]
    batched = [np.asarray(a) for a in spec.make_case(rng, n)]
    extra = [np.asarray(a) for a in spec.make_case(rng, n)]
    mux = SolverMux(lanes=4, clock=ManualClock())
    jobs = [mux.submit(name, *[a[i] for a in batched]) for i in range(2)]
    jobs.append(mux.submit(name, *[a[0] for a in extra]))
    mux.run()
    launches = mux.metrics().launches
    assert len(launches) == 1 and launches[0].padded == 1
    for job in jobs:
        assert_close(job.out, oracle_of(job), rtol=spec.rtol,
                     name=f"pad-{name}")


def test_mux_pads_square_rhs_qr_without_corruption():
    """Acceptance check for the removed shape heuristic: a qr_solve batch
    whose rhs is SQUARE (m x m) — ambiguous under the old 'square 3-D arg
    => add identity' rule — pads cleanly from the registry filler."""
    rng = np.random.default_rng(6)
    n, m = 8, 12
    mux = SolverMux(lanes=4, clock=ManualClock())
    jobs = []
    for _ in range(3):                          # 3 jobs -> 1 padded lane
        a = rng.standard_normal((m, n)).astype(np.float32)
        b = rng.standard_normal((m, m)).astype(np.float32)   # square rhs
        jobs.append(mux.submit("qr_solve", a, b))
    mux.run()
    assert mux.metrics().launches[0].padded == 1
    for job in jobs:
        a, b = job.args
        want = np.asarray(ref.qr_solve(a[None], b[None]))[0]
        assert_close(job.out, want, rtol=1e-3, name="square-rhs-pad")


def test_pad_group_requires_declared_filler():
    """No filler declared -> padding is an error, never a guess."""
    stacked = [np.zeros((3, 8, 8), np.float32)]
    with pytest.raises(ValueError, match="filler"):
        pad_group(K.get("gemm"), stacked, lanes=4)


# ---------------- SLO metrics snapshot ----------------

def test_mux_metrics_snapshot_deterministic():
    """On a manual clock the whole snapshot is exact: counts, lane
    utilization/waste, p50/p99 latency, and windowed throughput."""
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk)
    mux.submit("cholesky_solve", *chol_args(8))
    clk.advance(0.25)
    mux.submit("cholesky_solve", *chol_args(8))
    clk.advance(0.25)                  # latencies: 0.5 and 0.25 s
    mux.run()
    st = mux.metrics()["cholesky_solve"]
    assert st.jobs == 2 and st.launches == 1
    assert st.lanes_dispatched == 4 and st.lanes_padded == 2
    assert st.lane_utilization == pytest.approx(0.5)
    assert st.padded_lane_waste == pytest.approx(0.5)
    assert st.latency.count == 2
    assert st.latency.max == pytest.approx(0.5)
    assert st.latency.p50 == pytest.approx(0.375)   # midpoint of 2 samples
    assert st.latency.p99 == pytest.approx(0.4975, rel=1e-3)
    # window = first submit (t=0) .. last finish (t=0.5) -> 2 jobs / 0.5 s
    assert st.throughput == pytest.approx(4.0)


def test_mux_metrics_reset():
    mux = SolverMux(lanes=2, clock=ManualClock())
    mux.submit("cholesky_solve", *chol_args(8))
    mux.run()
    assert mux.metrics().total_jobs == 1
    mux.reset_metrics()
    snap = mux.metrics()
    assert snap.total_jobs == 0 and snap.total_launches == 0


def test_engine_shim_exports_mux_and_deprecates():
    """The legacy repro.serve.engine import path serves the new API but
    warns: new code should import from repro.serve."""
    import importlib
    import sys
    import warnings

    sys.modules.pop("repro.serve.engine", None)
    with pytest.warns(DeprecationWarning, match="repro.serve"):
        engine = importlib.import_module("repro.serve.engine")
    assert engine.SolverMux is SolverMux
    for name in ("DecodeEngine", "PipelineEngine", "Request", "SolveJob"):
        assert hasattr(engine, name)
    # re-import of the cached module is silent (module-level warning)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        from repro.serve.engine import PipelineEngine  # noqa: F401
    # the launch-supervision seam rides along: the shim's SolverMux
    # accepts an injector, and importing the faults module directly
    # (as mux.py now does) never trips the deprecation warning
    from repro.serve import FaultInjector
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        from repro.serve.faults import FaultInjector as direct
    assert direct is FaultInjector
    mux = engine.SolverMux(lanes=2, clock=ManualClock(),
                           injector=FaultInjector({}))
    assert isinstance(mux.injector, FaultInjector)
