"""Attention train-path implementations must agree (xla / chunked /
banded, with and without sequence-parallel constraints, GQA grouping)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ref as ref
from repro.configs import get_smoke
from repro.models import attention as attn

from conftest import assert_close

CFG = get_smoke("qwen3-14b")
B, S = 2, 64


def qkv(seed=0):
    rng = np.random.default_rng(seed)
    h, kv, dh = CFG.n_heads, CFG.n_kv, CFG.d_head
    q = jnp.asarray(rng.standard_normal((B, S, h, dh)) * .3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, kv, dh)) * .3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, kv, dh)), jnp.float32)
    return q, k, v


def gqa_ref(q, k, v, causal=True):
    """Expand kv heads to q heads and run the plain oracle."""
    g = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k, g, axis=2)
    vf = jnp.repeat(v, g, axis=2)
    o = ref.mha(jnp.moveaxis(q, 2, 1), jnp.moveaxis(kf, 2, 1),
                jnp.moveaxis(vf, 2, 1), causal=causal)
    return jnp.moveaxis(o, 1, 2)


@pytest.mark.parametrize("impl,extra", [
    ("xla", {}),
    ("chunked", {"attn_chunk": 16}),
    ("chunked", {"attn_chunk": 64}),
    ("banded", {"attn_bands": 4}),
    ("banded", {"attn_bands": 8}),
    ("chunked", {"attn_chunk": 16, "attn_sp": True}),
    ("banded", {"attn_bands": 4, "attn_sp": True}),
    ("banded", {"attn_bands": 4, "attn_chunk": 8}),   # inner chunking
    ("banded", {"attn_bands": 2, "attn_chunk": 8}),
])
def test_attend_train_impl_equivalence(impl, extra):
    cfg = dataclasses.replace(CFG, attn_impl=impl, **extra)
    q, k, v = qkv()
    got = jax.jit(lambda q, k, v: attn.attend_train(q, k, v, cfg))(q, k, v)
    assert_close(got, gqa_ref(q, k, v), rtol=1e-4, name=impl)


def test_attend_non_causal():
    cfg = dataclasses.replace(CFG, attn_impl="xla")
    q, k, v = qkv(1)
    got = attn.attend_train(q, k, v, cfg, causal=False)
    assert_close(got, gqa_ref(q, k, v, causal=False), rtol=1e-4)


def test_chunked_non_divisible_seq():
    """VLM prefix can make S a non-power-of-two: the chunk picker must
    find a divisor (regression for the internvl2 dry-run failure)."""
    cfg = dataclasses.replace(CFG, attn_impl="chunked", attn_chunk=48)
    rng = np.random.default_rng(2)
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    s = 68   # 4 + 64, like prefix+tokens; divisors <= 48: 34
    q = jnp.asarray(rng.standard_normal((B, s, h, dh)) * .3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, s, kv, dh)) * .3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, s, kv, dh)), jnp.float32)
    got = attn.attend_train(q, k, v, cfg)
    assert_close(got, gqa_ref(q, k, v), rtol=1e-4, name="nondiv")


def test_decode_matches_train_row():
    """attention_decode at position p equals row p of the train path."""
    cfg = dataclasses.replace(CFG, attn_impl="xla")
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((B, 8, cfg.d_model)) * .1,
                    jnp.float32)
    p = attn.init_attention(jax.random.key(0), cfg)
    pos = jnp.broadcast_to(jnp.arange(8), (B, 8))
    want = attn.attention_train(p, cfg, x, pos)           # (B,8,D)

    ck = jnp.zeros((B, 8, cfg.n_kv, cfg.d_head), jnp.float32)
    cv = jnp.zeros_like(ck)
    outs = []
    for j in range(8):
        o, ck, cv = attn.attention_decode(
            p, cfg, x[:, j:j + 1], ck, cv, jnp.full((B,), j, jnp.int32))
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    assert_close(got, want, rtol=1e-3, name="decode-vs-train")
