"""HBM-scale tiled solver variants: equality-vs-oracle sweeps, VMEM
working-set accounting (per-cell O(n*bs), never O(n^2)), rank-deficiency
pivot-guard behavior at tile boundaries, F4 masking (NaN-poisoned upper
triangle), dispatch routing at registry and mux level, and hypothesis
fuzzing via the shared strategies harness.

The n in {512, 1024} x bs in {64, 128} interpret-mode sweeps are marked
``slow`` (the scheduled CI job runs them); tier-1 keeps the midrange
shapes plus the no-compute dispatch assertions for the big buckets.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.kernels import ref
from repro.pipelines import (cholesky_solve_pallas, cholesky_solve_tiled,
                             mmse_equalize_blocked, mmse_equalize_tiled,
                             mmse_tiled_vmem_floats, qr_solve_pallas,
                             qr_solve_tiled, qr_tiled_vmem_floats,
                             tiled_vmem_floats)
from repro.serve import ManualClock, SolverMux

from conftest import assert_close
from strategies import fuzzed, integers, sampled, spd_system, tall_system

PIPELINES = ("cholesky_solve", "qr_solve", "mmse_equalize")


def _tiled_case(name, seed, n, bs_k=2):
    if name == "cholesky_solve":
        return spd_system(seed, 1, n, k=bs_k)
    return tall_system(seed, 1, n + 16, n, k=bs_k)


def _run_tiled(name, a, b, bs):
    fn = {"cholesky_solve": cholesky_solve_tiled,
          "qr_solve": qr_solve_tiled,
          "mmse_equalize": mmse_equalize_tiled}[name]
    return fn(jnp.asarray(a), jnp.asarray(b), bs=bs)


def _oracle(name, a, b):
    fn = {"cholesky_solve": ref.cholesky_solve,
          "qr_solve": ref.qr_solve,
          "mmse_equalize": ref.mmse_equalize}[name]
    return fn(jnp.asarray(a), jnp.asarray(b))


# ---------------- equality vs oracle ----------------

@pytest.mark.parametrize("name", PIPELINES)
@pytest.mark.parametrize("n,bs", [(128, 32), (256, 64)])
def test_tiled_matches_oracle_midrange(name, n, bs):
    """Tier-1 shapes: data-tiling is a schedule/residency change, not a
    numeric one — the tiled chain matches the jnp oracle."""
    a, b = _tiled_case(name, seed=n + bs, n=n)
    got = _run_tiled(name, a, b, bs=bs)
    assert_close(got, _oracle(name, a, b), rtol=1e-3,
                 name=f"tiled-{name} n={n} bs={bs}")


@pytest.mark.slow
@pytest.mark.parametrize("name", PIPELINES)
@pytest.mark.parametrize("n", [512, 1024])
@pytest.mark.parametrize("bs", [64, 128])
def test_tiled_matches_oracle_large(name, n, bs):
    """The HBM-scale sweep (scheduled CI): n in {512, 1024} x bs in
    {64, 128}, every pipeline, interpret mode."""
    a, b = _tiled_case(name, seed=n + bs, n=n)
    got = _run_tiled(name, a, b, bs=bs)
    assert_close(got, _oracle(name, a, b), rtol=2e-3,
                 name=f"tiled-{name} n={n} bs={bs}")


@fuzzed(max_examples=6, n_tiles=integers(2, 4), bs=sampled(32, 64),
        seed=integers(0, 2 ** 16))
def test_tiled_cholesky_fuzzed(n_tiles, bs, seed):
    """Property: for ANY tiling (tile count, block size, seed) the tiled
    solve matches the single-block fused kernel."""
    n = n_tiles * bs
    a, b = spd_system(seed, 1, n, k=2)
    got = cholesky_solve_tiled(jnp.asarray(a), jnp.asarray(b), bs=bs)
    want = cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(b))
    assert_close(got, want, rtol=1e-3, name=f"fuzz n={n} bs={bs}")


@fuzzed(max_examples=4, n_tiles=integers(2, 3), bs=sampled(32, 64),
        seed=integers(0, 2 ** 16))
def test_tiled_qr_fuzzed(n_tiles, bs, seed):
    n = n_tiles * bs
    a, b = tall_system(seed, 1, n + 8, n, k=2)
    got = qr_solve_tiled(jnp.asarray(a), jnp.asarray(b), bs=bs)
    want = qr_solve_pallas(jnp.asarray(a), jnp.asarray(b))
    assert_close(got, want, rtol=2e-3, name=f"fuzz-qr n={n} bs={bs}")


# ---------------- F4 masking: only the lower triangle is read ----------

def test_tiled_cholesky_ignores_poisoned_upper_triangle():
    """NaN-poisoning the strict upper triangle must not change the
    answer: the tiled chain, like the fused kernel, only ever reads the
    lower triangle (paper Feature 4's implicit masking)."""
    n = 256
    a, b = spd_system(5, 1, n, k=2)
    want = cholesky_solve_tiled(jnp.asarray(a), jnp.asarray(b), bs=64)
    ap = a.copy()
    ap[0][np.triu_indices(n, 1)] = np.nan
    got = cholesky_solve_tiled(jnp.asarray(ap), jnp.asarray(b), bs=64)
    assert np.isfinite(np.asarray(got)).all()
    assert_close(got, want, rtol=1e-6, name="poisoned-upper")


# ---------------- VMEM working set: O(n*bs), not O(n^2) ----------------

def test_tiled_vmem_working_set_is_linear_in_n():
    """Doubling n at fixed bs doubles (not quadruples) the per-cell
    working set, and at n = 1024 the per-cell footprint is far below the
    O(n^2) a whole-matrix block would need — the declared scratch/block
    accounting the kernels enforce at call time."""
    for fn, args_small, args_big in [
            (tiled_vmem_floats, (512, 128, 2), (1024, 128, 2)),
            (qr_tiled_vmem_floats, (528, 512, 128, 2),
             (1040, 1024, 128, 2)),
            (mmse_tiled_vmem_floats, (528, 512, 128, 2),
             (1040, 1024, 128, 2))]:
        small, big = fn(*args_small), fn(*args_big)
        assert big <= 2.1 * small, (fn.__name__, small, big)
    n = 1024
    whole_matrix = n * n                       # the blocked kernels' cost
    assert tiled_vmem_floats(n, 128, 2) < 0.4 * whole_matrix
    assert mmse_tiled_vmem_floats(n + 16, n, 128, 2) < 0.7 * whole_matrix


def test_tiled_rejects_over_budget_shapes():
    """The call-time VMEM guard is real: a shape whose slabs alone
    exceed the budget is refused instead of silently compiled.  The
    guard fires on static shapes, so eval_shape exercises it without
    materializing the gigabyte-scale operands."""
    import functools
    import jax
    huge = 16384                               # 3*n*bs*4B > 14 MiB
    a = jax.ShapeDtypeStruct((1, huge, huge), jnp.float32)
    b = jax.ShapeDtypeStruct((1, huge, 2), jnp.float32)
    with pytest.raises(AssertionError):
        jax.eval_shape(functools.partial(cholesky_solve_tiled, bs=128),
                       a, b)


# ---------------- pivot guards at tile boundaries ----------------

@pytest.mark.parametrize("rank", [40, 100, 129])
def test_tiled_cholesky_deficiency_across_tile_boundaries(rank):
    """Rank-deficient SPD input whose numerical rank ends inside the
    first, second, and third tile (bs=64): every lane stays finite, and
    for a CONSISTENT right-hand side (b in range(A)) the guarded solve
    still satisfies A x ~= b — the solution on the deficient subspace is
    not unique, so elementwise equality with the fused kernel is not a
    property; the residual is."""
    n = 256
    a, _ = spd_system(rank, 1, n, k=2, rank=rank)
    rng = np.random.default_rng(rank + 1)
    b = (a @ rng.standard_normal((1, n, 2))).astype(np.float32)
    got = np.asarray(cholesky_solve_tiled(jnp.asarray(a),
                                          jnp.asarray(b), bs=64))
    assert np.isfinite(got).all()
    resid = np.abs(a @ got - b).max() / np.abs(b).max()
    assert resid < 1e-3, (rank, resid)


@pytest.mark.parametrize("col", [10, 70, 130])
def test_tiled_qr_deficient_column_in_any_panel(col):
    """A zeroed (numerically dependent) column inside panel 0, 1, and 2
    (bs=64): tau=0 reflector + zeroed solution component keep the tiled
    solve finite, matching the unblocked kernel's guard."""
    n = 192
    a, b = tall_system(col, 1, n + 8, n, k=2, deficient_col=col)
    got = qr_solve_tiled(jnp.asarray(a), jnp.asarray(b), bs=64)
    assert np.isfinite(np.asarray(got)).all()
    want = qr_solve_pallas(jnp.asarray(a), jnp.asarray(b))
    assert_close(got, want, rtol=2e-3, name=f"qr-deficient-col{col}")
    assert abs(np.asarray(got)[0, col]).max() < 1e-5


# ---------------- dispatch routing ----------------

@pytest.mark.parametrize("name", PIPELINES)
@pytest.mark.parametrize("n", [512, 1024, 1888, 2048])
def test_dispatcher_picks_tiled_for_hbm_buckets(name, n):
    """Registry routing for the n >= 512 shape buckets (no kernel runs:
    this is the pure dispatch decision serving uses per bucket).
    n = 1888 (% 64 != 0 but % 32 == 0) must route to tiled too — any
    n % 32 == 0 shape falling back to a whole-matrix VMEM kernel at
    this scale would OOM a real core."""
    spec = K.get(name)
    mat = (n, n) if name == "cholesky_solve" else (n + 16, n)
    key = (mat, (mat[0], 2))
    v = spec.dispatch_key(key, (np.float32, np.float32))
    assert v.name == "tiled", (name, n, v.name)
    from repro.pipelines.cholesky_solve import tiled_block_size
    assert n % tiled_block_size(n) == 0    # the wrapper can tile it
    # and the midrange/base buckets are untouched by the new variant
    small = ((24, 24), (24, 2)) if name == "cholesky_solve" \
        else ((28, 24), (28, 2))
    assert spec.dispatch_key(small, (np.float32,) * 2).name == "base"


def test_mmse_blocked_alias_is_tiled():
    """The ROADMAP's 'Blocked MMSE Gram' name resolves to the shipped
    tiled kernel."""
    assert mmse_equalize_blocked is mmse_equalize_tiled


@pytest.mark.slow
def test_mux_serves_hbm_bucket_from_tiled_variant():
    """End to end through the SolverMux: n=512 jobs of all three
    pipelines land on the tiled variant (dispatch_counts + per-launch
    variant records prove it) and still match the registry oracle."""
    mux = SolverMux(lanes=2, clock=ManualClock())
    jobs = []
    a, b = spd_system(0, 1, 512, k=2)
    jobs.append(mux.submit("cholesky_solve", a[0], b[0]))
    a, b = tall_system(1, 1, 528, 512, k=2)
    jobs.append(mux.submit("qr_solve", a[0], b[0]))
    h, y = tall_system(2, 1, 528, 512, k=2)
    jobs.append(mux.submit("mmse_equalize", h[0], y[0]))
    done = mux.run()
    assert len(done) == len(jobs)
    snap = mux.metrics()
    for name in PIPELINES:
        assert snap[name].dispatch_counts == {"tiled": 1}, (
            name, snap[name].dispatch_counts)
    for job in jobs:
        want = K.get(job.pipeline).run_oracle_lane(*job.args)
        assert_close(job.out, want, rtol=2e-3,
                     name=f"mux-tiled-{job.pipeline}")
