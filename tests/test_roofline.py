"""Roofline machinery: HLO roll-up parser (scan trip counts, dot flops,
collective bytes) validated against known-cost jitted programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (Roofline, collective_bytes,
                                     model_flops_decode, model_flops_train)
from repro.roofline.hlo_costs import analyze_hlo
from repro.configs import get_config


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    txt = compiled_text(lambda a, b: a @ b, a, b)
    got = analyze_hlo(txt)
    want = 2 * 64 * 128 * 32
    assert got["flops"] == pytest.approx(want, rel=0.01)


def test_scan_trip_count_multiplies():
    """cost_analysis visits a while body once; the roll-up must multiply
    by the trip count (this is why the parser exists)."""
    a = jnp.zeros((32, 32), jnp.float32)
    n_steps = 11

    def f(a):
        def step(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(step, a, None, length=n_steps)
        return out

    got = analyze_hlo(compiled_text(f, a))
    want = 2 * 32 * 32 * 32 * n_steps
    assert got["flops"] == pytest.approx(want, rel=0.05)
    assert n_steps in got["trips"].values()


def test_bytes_nonzero_and_bounded():
    a = jnp.zeros((256, 256), jnp.float32)
    got = analyze_hlo(compiled_text(lambda a: a + 1.0, a))
    nbytes = 256 * 256 * 4
    assert got["bytes"] >= 2 * nbytes * 0.9        # read + write
    assert got["bytes"] <= 6 * nbytes              # fused: no blowup


def test_collective_bytes_parser():
    hlo = """
ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256] parameter(0)
  %ag = f32[256,256] all-gather(%p), dimensions={0}
  %ar = f32[128,256] all-reduce(%p), to_apply=%add
  %cp = f32[128,256] collective-permute(%p), source_target_pairs={{0,1}}
}
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 256 * 256 * 4
    assert got["all-reduce"] == 2 * 128 * 256 * 4   # 2x ring factor
    assert got["collective-permute"] == 128 * 256 * 4


def test_analyze_hlo_collectives_roll_up():
    hlo = """
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64] parameter(0)
  ROOT %ar = f32[64] all-reduce(%p), to_apply=%add
}
"""
    got = analyze_hlo(hlo)
    assert got["collectives"]["all-reduce"] == 2 * 64 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="x", shape="y", mesh="single", chips=1,
                 hlo_flops=197e12, hlo_bytes=819e9 * 2,
                 coll_bytes=50e9 * 0.5, coll_breakdown={},
                 model_flops=98.5e12)
    r.finish()
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.step_time == pytest.approx(2.0)
    assert r.mfu == pytest.approx(98.5e12 / (197e12 * 2.0))


def test_model_flops_formulas():
    cfg = get_config("phi4-mini-3.8b")
    n = cfg.active_param_count()
    assert model_flops_train(cfg, 1000) == pytest.approx(6.0 * n * 1000)
    d = model_flops_decode(cfg, batch=8, ctx=4096)
    assert d > 2.0 * n * 8                       # attention term added
    # MoE: active (not total) params enter the formula
    moe = get_config("dbrx-132b")
    assert model_flops_train(moe, 1) < 6.0 * moe.param_count()


def test_rollup_vs_cost_analysis_on_scanned_model():
    """End-to-end: the roll-up flops for a scanned 2-layer MLP are ~2x the
    single-layer flops, while naive cost_analysis undercounts."""
    w = jnp.zeros((2, 64, 64), jnp.float32)   # 2 stacked layers
    x = jnp.zeros((8, 64), jnp.float32)

    def f(w, x):
        def step(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(step, x, w)
        return h

    per_layer = 2 * 8 * 64 * 64
    got = analyze_hlo(compiled_text(f, w, x))
    assert got["flops"] == pytest.approx(2 * per_layer, rel=0.05)
