"""Continuous-batching decode serving: characterization + golden replay.

Four layers, mirroring tests/test_dag_serve.py:

* **engine characterization** — greedy determinism across pool
  compositions, per-request EOS / ``max_new`` stops, paged-slot cache
  non-contamination, and single-request bit-identity between the
  preserved lockstep path and the continuous per-slot path.
* **per-slot sampling regression** — pins BOTH sides of the historical
  bug: under ``run_lockstep`` one sampling pool mate switches the whole
  pool to a shared categorical stream (a co-batched greedy request's
  output changes); under the continuous path greedy slots never touch
  RNG and are bit-identical solo or co-batched.
* **mux integration** — decode admission through ``SolverMux``
  (attach/submit validation, expired best-effort shedding, hard never
  shed) plus the golden mixed solver+decode trace replayed byte-for-byte
  on the virtual clock, and the committed-trace throughput gate:
  continuous batching strictly beats lockstep tokens/step at equal
  budget with zero hard jobs lost.
* **fuzzed properties** (hypothesis-optional) — random decode traffic:
  every request reaches a terminal state with clean slot accounting,
  greedy outputs are independent of co-batched traffic, and hard
  requests are never lost through the mux.
"""
import json
import pathlib

import jax
import pytest

from repro.launch.serve_solvers import (decode_model, decode_prompt,
                                        decode_trace, replay_decode,
                                        run_decode_serve)
from repro.serve import CostModel, ManualClock, OverloadPolicy, SolverMux
from repro.serve.decode import DecodeEngine, Request
from strategies import decode_traffic, fuzzed, integers

DATA = pathlib.Path(__file__).parent / "data"


@pytest.fixture(scope="module")
def engine():
    """Shared standalone engine: ``eos_id=-1`` (token ids are
    non-negative, so EOS never fires) makes every request run exactly
    ``max_new`` steps — tests that need EOS semantics override
    ``engine.eos`` in place (it is only read host-side)."""
    cfg, params = decode_model()
    return DecodeEngine(cfg, params, batch=4, max_len=64, eos_id=-1)


def _solo(engine, prompt, max_new=5, temperature=0.0):
    r = engine.submit(Request(prompt=list(prompt), max_new=max_new,
                              temperature=temperature))
    engine.run()
    return r.out


# ---------------- engine characterization ----------------

def test_greedy_deterministic_across_pool_compositions(engine):
    """A greedy request's output is a function of its prompt alone —
    identical solo, co-batched with other greedy traffic, and co-batched
    with SAMPLING traffic (per-slot RNG keys leave greedy slots
    untouched)."""
    alone = _solo(engine, [9, 8, 7, 6])
    r1 = engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    engine.submit(Request(prompt=[30, 31, 32], max_new=4))
    engine.submit(Request(prompt=[40], max_new=6))
    engine.run()
    r2 = engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    engine.submit(Request(prompt=[3, 4], max_new=6, temperature=1.0))
    engine.submit(Request(prompt=[5], max_new=6, temperature=0.7))
    engine.run()
    assert alone == r1.out == r2.out


def test_eos_stops_generation_per_request(engine):
    """EOS is honored per request: pick a token the model actually
    generates, declare it EOS, and the request stops there — pool mates
    stop on their OWN terms (their own EOS draw or max_new)."""
    base = _solo(engine, [11, 12, 13], max_new=6)
    assert len(base) == 6              # eos=-1 never fires
    engine.eos = base[2]
    try:
        r = engine.submit(Request(prompt=[11, 12, 13], max_new=6))
        mate = engine.submit(Request(prompt=[40], max_new=4))
        engine.run()
        assert r.out == base[:3]       # stopped AT the eos token
        assert r.done and mate.done
        assert len(mate.out) == 4 or mate.out[-1] == engine.eos
    finally:
        engine.eos = -1


def test_max_new_honored_per_request(engine):
    reqs = [engine.submit(Request(prompt=[2 + i], max_new=1 + i))
            for i in range(6)]
    engine.run()
    assert [len(r.out) for r in reqs] == [1, 2, 3, 4, 5, 6]
    assert all(r.done for r in reqs)


def test_max_new_clamped_to_cache(engine):
    r = engine.submit(Request(prompt=[7, 8], max_new=10_000))
    assert r.max_new == engine.max_len - 2
    engine._queue.remove(r)            # don't actually run 62 steps
    with pytest.raises(ValueError):
        engine.submit(Request(prompt=[], max_new=1))
    with pytest.raises(ValueError):
        engine.submit(Request(prompt=[1] * 64, max_new=1))


def test_paged_slot_reuse_does_not_contaminate(engine):
    """Slot reuse never re-reads stale cache pages: after DEEP pool
    traffic leaves long stale tails in every slot's cache, a fresh
    request reusing a slot (position reset to 0, no cache zeroing)
    produces the same output as before the pollution."""
    before = _solo(engine, [21, 22], max_new=4)
    for i in range(5):                 # deep, slot-reusing pollution
        engine.submit(Request(prompt=[3 + i] * 8, max_new=12))
    engine.run()
    assert _solo(engine, [21, 22], max_new=4) == before


def test_single_request_lockstep_bit_identity(engine):
    """One greedy request: the continuous per-slot path and the
    preserved lockstep pool path are bit-identical."""
    cont = _solo(engine, [9, 4, 2], max_new=5)
    r = engine.submit(Request(prompt=[9, 4, 2], max_new=5))
    engine.run_lockstep()
    assert r.out == cont


def test_continuous_retires_heterogeneous_batch_in_fewer_steps(engine):
    """The tentpole economics on one pool: with more heterogeneous
    requests than slots, the lockstep path pays for every generation's
    longest member plus the pool barrier, while the continuous path
    backfills freed slots mid-flight."""
    mk = lambda: [Request(prompt=[2 + i] * (1 + i % 4),
                          max_new=1 + 2 * (i % 4)) for i in range(8)]
    engine.steps = 0
    for r in mk():
        engine.submit(r)
    engine.run()
    cont_steps = engine.steps
    engine.steps = 0
    for r in mk():
        engine.submit(r)
    engine.run_lockstep()
    assert cont_steps < engine.steps


# ---------------- per-slot sampling regression ----------------

def test_lockstep_pool_sampling_regression(engine):
    """The OLD failure mode, pinned: under ``run_lockstep`` a single
    sampling pool mate switches the WHOLE pool to one shared categorical
    stream, changing a co-batched greedy request's output.  The
    continuous path fixes this (greedy slots select argmax per slot, no
    RNG consumed) — pinned in
    test_greedy_deterministic_across_pool_compositions above."""
    engine.key = jax.random.PRNGKey(0)
    solo = engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    engine.run_lockstep()
    engine.key = jax.random.PRNGKey(0)
    greedy = engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    engine.submit(Request(prompt=[3, 4], max_new=5, temperature=1.0))
    engine.run_lockstep()
    assert greedy.out != solo.out      # the bug: pool-wide sampling


def test_sampling_requests_have_private_streams(engine):
    """Two identical sampling requests draw from per-request RNG streams
    (seq folded into the key), so co-batching them yields independent
    draws — while re-running the SAME request seq reproduces its
    stream."""
    a = engine.submit(Request(prompt=[5, 6], max_new=8, temperature=1.0))
    b = engine.submit(Request(prompt=[5, 6], max_new=8, temperature=1.0))
    engine.run()
    assert a.out != b.out              # private streams, not shared
    replay = Request(prompt=[5, 6], max_new=8, temperature=1.0)
    replay.seq = a.seq                 # pin the stream explicitly
    engine.submit(replay)
    engine.run()
    assert replay.out == a.out         # same seq -> same draws


# ---------------- slot accounting ----------------

def test_slot_accounting_never_leaks(engine):
    """After any drain: no request holds a slot, the queue is empty,
    and every submitted request reached a terminal state."""
    reqs = [engine.submit(Request(prompt=[2 + i], max_new=i % 3))
            for i in range(9)]         # includes max_new=0 requests
    done = engine.run()
    assert engine.occupied() == 0 and engine.pending() == 0
    assert not engine.has_work()
    assert all(r is None for r in engine._slot_req)
    assert sorted(r.seq for r in done) == sorted(r.seq for r in reqs)
    assert all(r.done and not r.dropped for r in reqs)


def test_shed_expired_drops_only_queued_best_effort(engine):
    """Expired best-effort requests are shed from the QUEUE only: hard
    requests and requests already holding a slot are never shed."""
    hard = engine.submit(Request(prompt=[2], max_new=2, priority="hard",
                                 deadline=-1.0))
    engine.step()                      # hard takes a slot
    in_slot = engine.submit(Request(prompt=[3], max_new=2,
                                     deadline=-1.0))
    engine.step()                      # expired best-effort in a slot
    queued = engine.submit(Request(prompt=[4], max_new=2, deadline=-1.0))
    live = engine.submit(Request(prompt=[5], max_new=2, deadline=1e9))
    shed = engine.shed_expired(engine.clock())
    assert shed == [queued] and queued.dropped
    engine.run()
    assert hard.done and in_slot.done and live.done and not queued.done


# ---------------- mux integration ----------------

def _mux(engine, budget=None):
    clock = ManualClock()
    engine.clock = clock
    mux = SolverMux(lanes=4, max_wait=0.0, clock=clock,
                    policy=OverloadPolicy(budget=budget,
                                          cost_model=CostModel()))
    mux.attach_decode(engine)
    return mux, clock


def _fresh_engine(batch=4):
    cfg, params = decode_model()
    return DecodeEngine(cfg, params, batch=batch, max_len=64, eos_id=-1)


def test_mux_decode_admission_validation(engine):
    mux = SolverMux(lanes=4)
    with pytest.raises(RuntimeError):
        mux.submit_decode(Request(prompt=[2]))
    eng = _fresh_engine()
    mux.attach_decode(eng)
    with pytest.raises(ValueError):
        mux.submit_decode(Request(prompt=[2]), priority="urgent")
    with pytest.raises(ValueError):
        mux.attach_decode(eng)         # double attach


def test_mux_serves_decode_alongside_solvers():
    """One poll loop serves lane traffic AND token traffic: solver jobs
    flush, decode requests stream through slots, and both land in the
    same snapshot with decode per-phase latency populated."""
    eng = _fresh_engine()
    mux, clock = _mux(eng)
    from repro.launch.serve_solvers import job_args
    jobs = [mux.submit("mmse_equalize", *job_args("mmse_equalize", 8, 2, i))
            for i in range(2)]
    reqs = [mux.submit_decode(Request(prompt=[2 + i], max_new=3),
                              priority="hard")
            for i in range(2)]
    for _ in range(4):
        mux.poll()
        clock.advance(1.0)
    mux.run()
    assert all(j.state == "done" for j in jobs)
    assert all(r.done for r in reqs)
    snap = mux.metrics()
    assert snap.decode.requests == 2 and snap.decode.tokens == 6
    assert snap.decode.insert.count == 2
    assert snap.decode.prefill.count == 2
    assert snap.decode.generate.count == 2
    assert snap.decode.tokens_per_step > 0
    kinds = {e["event"] for e in mux.drain_events()}
    assert {"decode_attach", "decode_insert", "decode_step",
            "decode_done", "flush"} <= kinds


def test_mux_sheds_expired_best_effort_decode_never_hard():
    """Deadline admission matches the solver rules: queued best-effort
    decode past its deadline is shed (recorded + evented); hard decode
    is admitted even when the per-poll budget is exhausted."""
    eng = _fresh_engine(batch=1)       # 1 slot forces queueing
    mux, clock = _mux(eng, budget=1e-12)   # budget never covers a step
    # long enough to hold the slot through the first poll's step
    # allowance, so the stale request is still queued when it expires
    blocker = mux.submit_decode(Request(prompt=[2], max_new=8),
                                priority="hard")
    stale = mux.submit_decode(Request(prompt=[3], max_new=2),
                              deadline=0.5)
    hard = mux.submit_decode(Request(prompt=[4], max_new=2),
                             priority="hard", deadline=0.5)
    for _ in range(8):
        mux.poll()
        clock.advance(1.0)
    assert stale.dropped and not stale.done
    assert blocker.done and hard.done  # hard overrode the zero budget
    snap = mux.metrics()
    assert snap.decode.shed == 1
    assert snap["decode"].dropped == 1
    events = mux.drain_events()
    assert any(e["event"] == "drop" and e.get("pipeline") == "decode"
               for e in events)
    assert mux.pending() == 0


def test_mux_budget_defers_best_effort_decode():
    eng = _fresh_engine()
    mux, clock = _mux(eng, budget=1e-12)
    r = mux.submit_decode(Request(prompt=[2], max_new=2))
    mux.poll()                         # deferred: budget exhausted
    assert not r.done
    events = mux.drain_events()
    assert any(e["event"] == "decode_defer" for e in events)
    mux.run()                          # drain ignores the poll budget
    assert r.done


# ---------------- golden mixed solver+decode replay ----------------

def test_golden_trace_matches_generator():
    committed = json.loads((DATA / "decode_trace.json").read_text())
    assert committed == decode_trace(4, seed=0)


def test_golden_decode_replay_event_sequence():
    """Replay the committed mixed trace on the virtual clock and compare
    the full mux event stream byte-for-byte: solver flushes interleaved
    with decode insert/step/done decisions, slot reuse order and priced
    decode admission are all pinned.  (eos_id=-1 in the replay keeps the
    sequence independent of model floating point.)"""
    trace = json.loads((DATA / "decode_trace.json").read_text())
    mux, eng, requests, jobs = replay_decode(trace)
    assert all(r.done for r in requests)
    assert all(j.state == "done" for j in jobs)
    assert mux.pending() == 0
    got = json.dumps(mux.drain_events(), indent=1) + "\n"
    assert got == (DATA / "decode_golden.json").read_text(), \
        "decode event stream diverged; if intentional, run " \
        "tests/data/regen_decode_golden.py and review the diff"


def test_continuous_beats_lockstep_on_committed_trace():
    """The acceptance gate, as a test: on the committed trace the
    continuous path serves the SAME tokens in strictly fewer SPMD steps
    than the lockstep baseline, with zero hard jobs/requests lost."""
    cont = run_decode_serve(True, ticks=4)
    base = run_decode_serve(False, ticks=4)
    assert cont["hard_lost"] == 0 and base["hard_lost"] == 0
    assert cont["tokens"] == base["tokens"] > 0
    assert cont["steps"] < base["steps"]
    assert cont["tokens_per_step"] > base["tokens_per_step"]
    assert cont["slot_reuses"] > 0
    assert cont["pending"] == 0


# ---------------- fuzzed properties ----------------

def _traffic_requests(entries):
    return [Request(prompt=decode_prompt(plen, 17 * i), max_new=max_new,
                    temperature=t10 / 10)
            for i, (plen, max_new, t10, _gap) in enumerate(entries)]


GRID_TRAFFIC = [
    [(1, 0, 0, 0)],
    [(3, 2, 0, 1), (1, 5, 13, 0), (2, 0, 7, 2), (6, 3, 0, 0)],
    [(2, 4, 0, 0)] * 5,
]


def _check_terminal(engine, entries):
    reqs = _traffic_requests(entries)
    for r, (_, _, _, gap) in zip(reqs, entries):
        engine.submit(r)
        for _ in range(gap):
            engine.step()
    engine.run()
    assert all(r.done and not r.dropped for r in reqs)
    assert [len(r.out) for r in reqs] == [e[1] for e in entries]
    assert engine.occupied() == 0 and engine.pending() == 0
    assert all(s is None for s in engine._slot_req)


@pytest.mark.parametrize("entries", GRID_TRAFFIC)
def test_traffic_terminal_grid(engine, entries):
    _check_terminal(engine, entries)


@fuzzed(max_examples=10, entries=decode_traffic())
def test_traffic_terminal_fuzzed(engine, entries):
    """Every request reaches a terminal state with exactly ``max_new``
    tokens (eos=-1) and slot accounting never leaks, for ANY arrival
    pattern — including max_new=0 requests and mid-stream arrivals."""
    _check_terminal(engine, entries)


def _check_greedy_independent(engine, entries):
    solo = {}
    for i, (plen, max_new, t10, _gap) in enumerate(entries):
        if t10 == 0 and max_new > 0:
            solo[i] = _solo(engine, decode_prompt(plen, 17 * i), max_new)
    reqs = _traffic_requests(entries)
    for r, (_, _, _, gap) in zip(reqs, entries):
        engine.submit(r)
        for _ in range(gap):
            engine.step()
    engine.run()
    for i, out in solo.items():
        assert reqs[i].out == out


@pytest.mark.parametrize("entries", GRID_TRAFFIC[1:])
def test_traffic_greedy_independent_grid(engine, entries):
    _check_greedy_independent(engine, entries)


@fuzzed(max_examples=6, entries=decode_traffic(max_len=5))
def test_traffic_greedy_independent_fuzzed(engine, entries):
    """A greedy request's output is independent of whatever traffic it
    is co-batched with — random prompts, sampling neighbors, arrival
    gaps.  (This is the per-slot sampling fix as a property.)"""
    _check_greedy_independent(engine, entries)


def _check_mux_hard_never_lost(entries, budget_steps):
    eng = _fresh_engine()
    mux, clock = _mux(eng, budget=budget_steps * 1e-4 or 1e-12)
    reqs = []
    for i, (plen, max_new, t10, gap) in enumerate(entries):
        r = Request(prompt=decode_prompt(plen, 17 * i), max_new=max_new,
                    temperature=t10 / 10)
        pri = "hard" if i % 2 == 0 else "best_effort"
        mux.submit_decode(r, priority=pri,
                          deadline=clock() + (2.0 if gap else 6.0))
        reqs.append(r)
        mux.poll()
        clock.advance(1.0)
    for _ in range(4):
        mux.poll()
        clock.advance(1.0)
    mux.run()
    for i, r in enumerate(reqs):
        assert r.done or r.dropped
        if i % 2 == 0:
            assert r.done and not r.dropped
    assert mux.pending() == 0 and eng.occupied() == 0


@pytest.mark.parametrize("entries,budget_steps",
                         [(GRID_TRAFFIC[1], 0), (GRID_TRAFFIC[2], 2)])
def test_mux_hard_decode_never_lost_grid(entries, budget_steps):
    _check_mux_hard_never_lost(entries, budget_steps)


@fuzzed(max_examples=6, entries=decode_traffic(), budget_steps=integers(0, 3))
def test_mux_hard_decode_never_lost_fuzzed(entries, budget_steps):
    """Through the mux under an arbitrary (possibly zero) budget, hard
    decode requests are never shed and always finish; best-effort is
    only ever dropped from the queue, already-terminal either way."""
    _check_mux_hard_never_lost(entries, budget_steps)
