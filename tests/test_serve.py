"""Decode engine: batched generation, slot padding, greedy determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serve import DecodeEngine, Request


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("phi4-mini-3.8b")
    return cfg, T.init_params(jax.random.key(0), cfg)


@pytest.fixture(scope="module")
def engine(model):
    cfg, params = model
    return DecodeEngine(cfg, params, batch=4, max_len=64, eos_id=1)


def test_engine_single_request(engine):
    engine.submit(Request(prompt=[5, 6, 7], max_new=4))
    done = engine.run()
    assert len(done) == 1
    r = done[0]
    assert 1 <= len(r.out) <= 4
    assert all(0 <= t < engine.cfg.vocab for t in r.out)


def test_engine_batched_requests(engine):
    for i in range(6):   # more requests than the 4-slot pool
        engine.submit(Request(prompt=[2 + i, 3, 4], max_new=3))
    done = engine.run()
    assert len(done) == 6
    assert all(1 <= len(r.out) <= 3 for r in done)


def test_engine_greedy_deterministic(engine):
    outs = []
    for _ in range(2):
        engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
        outs.append(engine.run()[0].out)
    assert outs[0] == outs[1]


def test_engine_isolation_across_slots(engine):
    """A request's output depends on its own prompt, not on pool mates.
    (run() returns requests in COMPLETION order — shorter pool mates
    finish first under continuous batching — so track the object.)"""
    engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    alone = engine.run()[0].out
    r = engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    engine.submit(Request(prompt=[30, 31, 32], max_new=5))
    engine.submit(Request(prompt=[40], max_new=5))
    engine.run()
    assert alone == r.out


def test_engine_sampled_mode(engine):
    engine.submit(Request(prompt=[3, 4, 5], max_new=4, temperature=1.0))
    done = engine.run()
    assert len(done[0].out) >= 1


def test_engine_shares_core_metrics(model):
    """DecodeEngine rides the same EngineCore accounting as the solver
    engines — per-step launches and request latencies land in the
    snapshot — plus the continuous-batching view: per-phase samples,
    token/step counters and slot reuse.  A fresh engine with
    ``eos_id=-1`` (never generated) makes the step counts exact."""
    cfg, params = model
    engine = DecodeEngine(cfg, params, batch=4, max_len=64, eos_id=-1)
    for i in range(6):                 # 6 requests, 4-slot pool
        engine.submit(Request(prompt=[2 + i, 3], max_new=2))
    engine.run()
    snap = engine.metrics()
    st = snap["decode"]
    # each request needs 3 SPMD steps (2 prompt feeds overlapping the
    # first output + 1 generate); requests 5-6 reuse freed slots, so the
    # whole batch retires in 6 steps instead of the lockstep path's 2
    # pool generations
    assert st.jobs == 6
    assert st.launches == 6
    assert st.lanes_dispatched == 24 and st.lanes_padded == 6
    assert st.lane_utilization == pytest.approx(18 / 24)
    assert st.latency.count == 6 and st.latency.p50 >= 0.0
    d = snap.decode
    assert d.requests == 6 and d.tokens == 12 and d.steps == 6
    assert d.tokens_per_step == pytest.approx(2.0)
    assert d.slot_reuses == 2
    assert d.insert.count == d.prefill.count == d.generate.count == 6
