"""Decode engine: batched generation, slot padding, greedy determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serve import DecodeEngine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke("phi4-mini-3.8b")
    params = T.init_params(jax.random.key(0), cfg)
    return DecodeEngine(cfg, params, batch=4, max_len=64, eos_id=1)


def test_engine_single_request(engine):
    engine.submit(Request(prompt=[5, 6, 7], max_new=4))
    done = engine.run()
    assert len(done) == 1
    r = done[0]
    assert 1 <= len(r.out) <= 4
    assert all(0 <= t < engine.cfg.vocab for t in r.out)


def test_engine_batched_requests(engine):
    for i in range(6):   # more requests than the 4-slot pool
        engine.submit(Request(prompt=[2 + i, 3, 4], max_new=3))
    done = engine.run()
    assert len(done) == 6
    assert all(1 <= len(r.out) <= 3 for r in done)


def test_engine_greedy_deterministic(engine):
    outs = []
    for _ in range(2):
        engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
        outs.append(engine.run()[0].out)
    assert outs[0] == outs[1]


def test_engine_isolation_across_slots(engine):
    """A request's output depends on its own prompt, not on pool mates."""
    engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    alone = engine.run()[0].out
    engine.submit(Request(prompt=[9, 8, 7, 6], max_new=5))
    engine.submit(Request(prompt=[30, 31, 32], max_new=5))
    engine.submit(Request(prompt=[40], max_new=5))
    together = engine.run()[0].out
    assert alone == together


def test_engine_sampled_mode(engine):
    engine.submit(Request(prompt=[3, 4, 5], max_new=4, temperature=1.0))
    done = engine.run()
    assert len(done[0].out) >= 1


def test_engine_shares_core_metrics(engine):
    """DecodeEngine rides the same EngineCore accounting as the solver
    engines: pool launches and request latencies land in the snapshot."""
    engine.reset_metrics()
    for i in range(6):                 # 6 requests, 4-slot pool
        engine.submit(Request(prompt=[2 + i, 3], max_new=2))
    engine.run()
    st = engine.metrics()["decode"]
    assert st.jobs == 6
    assert st.launches == 2            # two pool generations
    assert st.lanes_dispatched == 8 and st.lanes_padded == 2
    assert st.lane_utilization == pytest.approx(6 / 8)
    assert st.latency.count == 6 and st.latency.p50 >= 0.0
