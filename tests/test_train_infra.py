"""Training infrastructure: optimizer math, checkpointing (atomic, keep-k,
mesh-agnostic), fault tolerance (retry, straggler), data pipeline
determinism, trainer resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, compress_int8,
                                   decompress_int8, global_norm,
                                   init_opt_state, lr_at)
from repro.train import checkpoint as ckpt
from repro.train.fault import RetryPolicy, StragglerMonitor, remesh_state


# ---------------- optimizer ----------------

def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, s)) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert abs(lrs[10] - cfg.lr) / cfg.lr < 0.2  # peak near lr
    assert lrs[-1] < lrs[20]                     # cosine decays
    assert lrs[-1] >= cfg.lr * cfg.min_lr_frac * 0.99


def test_global_norm_and_clip():
    g = {"a": jnp.full((3,), 3.0), "b": jnp.full((4,), 2.0)}
    want = np.sqrt(9 * 3 + 4 * 4)
    assert float(global_norm(g)) == pytest.approx(want, rel=1e-6)
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(want, rel=1e-6)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below the threshold: untouched
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_adamw_matches_reference():
    """One AdamW step against a hand-computed update."""
    cfg = OptConfig(lr=1e-2, warmup=1, b1=0.9, b2=0.95, eps=1e-8,
                    weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, 2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, -0.2]], jnp.float32)}
    st = init_opt_state(p)
    p2, st2, _ = adamw_update(cfg, p, g, st)
    m = 0.1 * np.array([0.1, -0.2])
    v = 0.05 * np.array([0.1, -0.2]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    lr0 = float(lr_at(cfg, 0))
    want = np.array([1.0, 2.0]) - lr0 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"])[0], want, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_weight_decay_matrices_only():
    cfg = OptConfig(lr=1e-2, warmup=1, weight_decay=0.1, clip_norm=1e9)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    p2, _, _ = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0   # decayed
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)  # not decayed


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q, scale = compress_int8(g)
    assert q.dtype == jnp.int8
    deq = decompress_int8(q, scale)
    err = float(jnp.max(jnp.abs(deq - g)))
    assert err <= float(scale) * 0.5 + 1e-7   # quantization bound


def test_int8_error_feedback_converges():
    """With error feedback, the *accumulated* compressed sum tracks the
    true sum (residual stays bounded, bias does not accumulate)."""
    rng = np.random.default_rng(1)
    e = jnp.zeros((32,), jnp.float32)
    tot_true = np.zeros((32,))
    tot_comp = np.zeros((32,))
    for i in range(50):
        g = jnp.asarray(rng.standard_normal((32,)) * 0.1, jnp.float32)
        g32 = g + e
        q, s = compress_int8(g32)
        deq = decompress_int8(q, s)
        e = g32 - deq
        tot_true += np.asarray(g)
        tot_comp += np.asarray(deq)
    # residual is bounded by one quantization step
    assert np.max(np.abs(tot_true - tot_comp)) < 0.05


# ---------------- checkpointing ----------------

def tree_eq(a, b):
    return all(np.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": {"w": jnp.zeros((2, 3))},
                     "step": jnp.asarray(7, jnp.int32)}}
    ckpt.save(str(tmp_path), 7, state)
    step, loaded = ckpt.load(str(tmp_path))
    assert step == 7
    assert tree_eq(state, loaded)


def test_checkpoint_keep_k(tmp_path):
    for s in range(6):
        ckpt.save(str(tmp_path), s, {"x": jnp.zeros(1)}, keep=3)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_atomic_no_partial(tmp_path):
    """A tmp dir from a 'crashed' writer is never visible as a step."""
    os.makedirs(tmp_path / ".tmp_step_9_999")
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones(2)})
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_async(tmp_path):
    import time
    ckpt.save(str(tmp_path), 3, {"x": jnp.ones(4)}, blocking=False)
    for _ in range(100):
        if ckpt.latest_step(str(tmp_path)) == 3:
            break
        time.sleep(0.05)
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_remesh(tmp_path):
    """Elastic re-mesh: load under explicit (single-device) shardings."""
    state = {"w": jnp.arange(8.0).reshape(2, 4)}
    ckpt.save(str(tmp_path), 0, state)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    _, loaded = ckpt.load(str(tmp_path), shardings=sh)
    assert tree_eq(state, loaded)
    re = remesh_state(loaded, sh)
    assert tree_eq(state, re)


# ---------------- fault tolerance ----------------

def test_retry_policy_recovers():
    calls = {"n": 0, "fixed": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("device lost")
        return "ok"

    def on_failure(_e):
        calls["fixed"] += 1

    rp = RetryPolicy(max_retries=3, backoff_s=0.0)
    assert rp.run(flaky, on_failure=on_failure) == "ok"
    assert calls["fixed"] == 2


def test_retry_policy_exhausts():
    rp = RetryPolicy(max_retries=2, backoff_s=0.0)
    with pytest.raises(RuntimeError):
        rp.run(lambda: (_ for _ in ()).throw(RuntimeError("always")))


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0, alpha=0.5)
    assert not m.observe(0, 1.0)
    assert not m.observe(1, 1.1)
    assert m.observe(2, 10.0)          # 10x slower -> flagged
    assert m.flagged_steps == [2]


# ---------------- data pipeline ----------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=42)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)          # a "restarted" pipeline
    for step in (0, 5, 1000):
        b1, b2 = p1.batch(step), p2.batch(step)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert np.array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(p1.batch(0)["tokens"],
                              p1.batch(1)["tokens"])


def test_pipeline_host_sharding():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=7)
    hosts = [TokenPipeline(cfg, process_index=i, process_count=4)
             for i in range(4)]
    batches = [h.batch(3)["tokens"] for h in hosts]
    assert all(b.shape == (2, 8) for b in batches)
    # different hosts draw disjoint streams
    assert not np.array_equal(batches[0], batches[1])


def test_pipeline_labels_shifted():
    cfg = DataConfig(vocab=50, seq_len=12, global_batch=2, seed=0)
    b = TokenPipeline(cfg).batch(0)
    # autoregressive contract: labels are the next token
    raw = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    assert np.array_equal(raw[:, 1:], b["labels"])


# ---------------- trainer resume (integration) ----------------

def test_trainer_checkpoint_resume(tmp_path):
    from repro.configs import get_smoke
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_smoke("xlstm-125m")
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2, seed=0)
    tc = TrainConfig(steps=4, ckpt_dir=str(tmp_path), ckpt_every=2,
                     log_every=100, opt=OptConfig(lr=1e-3, warmup=1))
    t1 = Trainer(cfg, tc, TokenPipeline(dc))
    r1 = t1.run()
    assert ckpt.latest_step(str(tmp_path)) == 4

    # a "crashed and restarted" trainer resumes from step 4 — and running
    # to the same target is a no-op returning immediately
    t2 = Trainer(cfg, tc, TokenPipeline(dc))
    assert t2.start_step == 4
    assert tree_eq(t2.params, t1.params)

    # extending the run continues from the checkpoint
    tc2 = TrainConfig(steps=6, ckpt_dir=str(tmp_path), ckpt_every=2,
                      log_every=100, opt=OptConfig(lr=1e-3, warmup=1))
    t3 = Trainer(cfg, tc2, TokenPipeline(dc))
    r3 = t3.run()
    assert len(r3["losses"]) == 2
    assert np.isfinite(r3["final_loss"])
