"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
repro.launch.dryrun (run as a subprocess) uses 512 placeholder devices."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_close(got, want, rtol=2e-2, atol=1e-5, name=""):
    got = np.asarray(got, dtype=np.float64)
    want = np.asarray(want, dtype=np.float64)
    assert got.shape == want.shape, f"{name}: {got.shape} vs {want.shape}"
    denom = np.max(np.abs(want)) + 1e-12
    err = np.max(np.abs(got - want)) / denom
    assert err < rtol, f"{name}: max rel err {err:.3e} >= {rtol}"
