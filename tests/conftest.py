"""Shared fixtures + session-wide XLA device environment.

The whole suite runs on 8 virtual CPU devices
(``--xla_force_host_platform_device_count=8``, merged into any existing
``XLA_FLAGS`` before the first jax import) so mesh/sharding tests —
distributed collectives, the mesh-sharded SolverMux — exercise real
multi-device programs.  Single-device tests are unaffected: jax still
places unsharded work on device 0.  An explicit device count already in
``XLA_FLAGS`` is respected, not clobbered (``repro.launch.xla_env``);
only repro.launch.dryrun (run as a subprocess) uses 512 placeholder
devices."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.launch.xla_env import force_host_device_count

force_host_device_count(8)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_close(got, want, rtol=2e-2, atol=None, name=""):
    """Mixed absolute/relative closeness: elementwise
    ``|got - want| <= atol + rtol * |want|`` (np.allclose semantics).

    ``atol=None`` (the default) resolves to ``rtol * max|want| + 1e-12``
    — a scale-relative floor so near-zero entries of an otherwise large
    solution are judged against the problem's scale rather than their
    own magnitude.  NOTE: every element then gets the old normalized
    budget PLUS its own ``rtol * |want|`` term, i.e. up to 2x the old
    bound at the dominant element — a deliberate additive-mixed
    semantics, not a claim of bit-identical gating.  Pass ``atol``
    explicitly for a true elementwise-relative check with an absolute
    floor you choose (it is honored, not ignored).
    """
    got = np.asarray(got, dtype=np.float64)
    want = np.asarray(want, dtype=np.float64)
    assert got.shape == want.shape, f"{name}: {got.shape} vs {want.shape}"
    if atol is None:
        atol = rtol * np.max(np.abs(want)) + 1e-12
    err = np.abs(got - want)
    tol = atol + rtol * np.abs(want)
    bad = ~(err <= tol)                   # catches NaN/inf too
    if bad.any():
        worst = np.unravel_index(np.argmax(err - tol), err.shape)
        raise AssertionError(
            f"{name}: {bad.sum()}/{err.size} elements outside "
            f"atol={atol:.3e} + rtol={rtol:.3e}*|want|; worst at "
            f"{worst}: got {got[worst]:.6e} want {want[worst]:.6e} "
            f"(|diff| {err[worst]:.3e} > tol {tol[worst]:.3e})")
