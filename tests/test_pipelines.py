"""Fused solver pipelines vs oracles (shape/dtype/batch sweeps incl.
non-power-of-two partial-vector tails, paper Feature 3), registry-driven
auto-discovery checks, degenerate-input guard paths, inductive-domain
masking (no garbage-lane reads), and the PipelineEngine service."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ref as ref
from repro import kernels as K
from repro.kernels.common import sample_spd
from repro.pipelines import (cholesky_solve_pallas, cholesky_solve_unfused,
                             expand_complex_channel, mmse_equalize_composed,
                             mmse_equalize_pallas, qr_solve_pallas,
                             qr_solve_unfused)
from repro.serve import PipelineEngine, SolveJob

from conftest import assert_close

RNG = np.random.default_rng(4321)

# paper data sizes 8..32, non-power-of-two included (partial vector tails)
SIZES = [8, 12, 16, 24, 32]


def spd(b, n, dtype=np.float32):
    return sample_spd(RNG, b, n).astype(dtype)


# ---------------- cholesky_solve ----------------

@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("b", [1, 3])
def test_cholesky_solve_sizes(n, b):
    a = spd(b, n)
    rhs = RNG.standard_normal((b, n, 4)).astype(np.float32)
    got = cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(rhs))
    assert_close(got, ref.cholesky_solve(a, rhs), rtol=1e-4,
                 name=f"chol_solve{n}")


@pytest.mark.parametrize("m", [1, 2, 8])
def test_cholesky_solve_rhs_widths(m):
    a = spd(2, 16)
    rhs = RNG.standard_normal((2, 16, m)).astype(np.float32)
    got = cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(rhs))
    assert_close(got, ref.cholesky_solve(a, rhs), rtol=1e-4, name=f"rhs{m}")


def test_cholesky_solve_returns_factor():
    a = spd(2, 12)
    rhs = RNG.standard_normal((2, 12, 1)).astype(np.float32)
    x, l = cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(rhs),
                                 return_l=True)
    l = np.asarray(l)
    assert_close(l @ l.swapaxes(-1, -2), a, rtol=1e-4, name="LL^T")
    assert np.allclose(np.triu(l, 1), 0.0)


def test_cholesky_solve_fused_matches_unfused():
    """Fusion is a scheduling change, not a numeric one."""
    a = spd(3, 24)
    rhs = RNG.standard_normal((3, 24, 2)).astype(np.float32)
    fused = cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(rhs))
    unfused = cholesky_solve_unfused(jnp.asarray(a), jnp.asarray(rhs))
    assert_close(fused, unfused, rtol=1e-4, name="fused-vs-unfused")


def test_cholesky_solve_bf16():
    a = spd(2, 16)
    rhs = RNG.standard_normal((2, 16, 2)).astype(np.float32)
    got = cholesky_solve_pallas(jnp.asarray(a, jnp.bfloat16),
                                jnp.asarray(rhs, jnp.bfloat16))
    assert_close(np.asarray(got, np.float32), ref.cholesky_solve(a, rhs),
                 rtol=8e-2, name="chol_solve-bf16")


# ---------------- qr_solve ----------------

@pytest.mark.parametrize("m,n", [(8, 8), (12, 8), (16, 12), (24, 16),
                                 (32, 32), (36, 24)])
def test_qr_solve_sizes(m, n):
    a = RNG.standard_normal((2, m, n)).astype(np.float32)
    b = RNG.standard_normal((2, m, 3)).astype(np.float32)
    got = qr_solve_pallas(jnp.asarray(a), jnp.asarray(b))
    assert_close(got, ref.qr_solve(a, b), rtol=1e-4, name=f"qr{m}x{n}")


def test_qr_solve_least_squares_residual():
    """For tall systems the residual must be orthogonal to range(A)."""
    a = RNG.standard_normal((2, 24, 12)).astype(np.float32)
    b = RNG.standard_normal((2, 24, 1)).astype(np.float32)
    x = np.asarray(qr_solve_pallas(jnp.asarray(a), jnp.asarray(b)))
    resid = a @ x - b
    assert np.abs(np.einsum("bmn,bmk->bnk", a, resid)).max() < 1e-3


def test_qr_solve_fused_matches_unfused():
    a = RNG.standard_normal((2, 20, 16)).astype(np.float32)
    b = RNG.standard_normal((2, 20, 2)).astype(np.float32)
    fused = qr_solve_pallas(jnp.asarray(a), jnp.asarray(b))
    unfused = qr_solve_unfused(jnp.asarray(a), jnp.asarray(b))
    assert_close(fused, unfused, rtol=1e-3, name="qr-fused-vs-unfused")


# ---------------- mmse_equalize ----------------

@pytest.mark.parametrize("n", SIZES)
def test_mmse_sizes(n):
    m = n + 4
    h = RNG.standard_normal((2, m, n)).astype(np.float32)
    y = RNG.standard_normal((2, m, 2)).astype(np.float32)
    got = mmse_equalize_pallas(jnp.asarray(h), jnp.asarray(y))
    assert_close(got, ref.mmse_equalize(h, y), rtol=1e-4, name=f"mmse{n}")


@pytest.mark.parametrize("batch", [1, 5, 8])
def test_mmse_batches(batch):
    h = RNG.standard_normal((batch, 16, 12)).astype(np.float32)
    y = RNG.standard_normal((batch, 16, 1)).astype(np.float32)
    got = mmse_equalize_pallas(jnp.asarray(h), jnp.asarray(y))
    assert_close(got, ref.mmse_equalize(h, y), rtol=1e-4,
                 name=f"mmse-b{batch}")


def test_mmse_fused_matches_composed():
    h = RNG.standard_normal((3, 20, 16)).astype(np.float32)
    y = RNG.standard_normal((3, 20, 2)).astype(np.float32)
    fused = mmse_equalize_pallas(jnp.asarray(h), jnp.asarray(y))
    composed = mmse_equalize_composed(jnp.asarray(h), jnp.asarray(y))
    assert_close(fused, composed, rtol=1e-4, name="mmse-fused-vs-composed")


def test_mmse_complex_expansion_recovers_symbols():
    """End-to-end 5G shape: noiseless complex channel, equalizer must
    invert it (sigma2 -> tiny regularization only)."""
    b, m, n = 4, 16, 12
    hr = RNG.standard_normal((b, m, n)).astype(np.float32)
    hi = RNG.standard_normal((b, m, n)).astype(np.float32)
    xr = RNG.standard_normal((b, n, 1)).astype(np.float32)
    xi = RNG.standard_normal((b, n, 1)).astype(np.float32)
    yr = hr @ xr - hi @ xi
    yi = hr @ xi + hi @ xr
    h, y = expand_complex_channel(jnp.asarray(hr), jnp.asarray(hi),
                                  jnp.asarray(yr), jnp.asarray(yi))
    xhat = np.asarray(mmse_equalize_pallas(h, y, sigma2=1e-6))
    want = np.concatenate([xr, xi], axis=1)
    assert_close(xhat, want, rtol=1e-3, name="complex-recovery")


# ---------------- registry-driven auto-discovery ----------------

def test_registry_has_kernels_and_pipelines():
    assert set(K.names(kind="pipeline")) == {"cholesky_solve", "qr_solve",
                                             "mmse_equalize", "pusch_fft",
                                             "pusch_chanest", "pusch_chain",
                                             "svd_factor", "svd_apply"}
    assert set(K.dag_names()) == {"pusch_receive", "svd_solve"}
    # every seed kernel is registered — the registry IS the import list
    assert {"cholesky", "trisolve", "qr", "svd", "gemm", "fir", "fft",
            "flash_attention", "ssm_scan"} <= set(K.names(kind="kernel"))


@pytest.mark.parametrize("name", sorted(K.names()))
def test_registry_kernel_matches_oracle(name):
    """Auto-discovered: every registered kernel/pipeline checks against
    its own oracle over its declared size sweep — adding a kernel to the
    registry adds it to this test with no edits here."""
    spec = K.get(name)
    rng = np.random.default_rng(99)
    for n in spec.sizes:
        args = spec.make_case(rng, n)
        got = jax.tree.leaves(spec.run_pallas(*args))
        want = jax.tree.leaves(spec.run_oracle(*args))
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert_close(np.asarray(g, np.float32), w, rtol=spec.rtol,
                         name=f"{name}@{n}")


def test_registry_streams_classify():
    """Stream descriptors attached to the registry reproduce the paper's
    capability classification: solver-family kernels are inductive (RI),
    dense/regular kernels rectangular (paper Q10)."""
    for name in ("cholesky", "trisolve", "qr", "cholesky_solve",
                 "qr_solve", "mmse_equalize"):
        s = K.get(name).stream(16)
        assert "I" in s.capability, name
        assert s.length() > 0
    for name in ("gemm", "fir", "fft", "ssm_scan"):
        assert set(K.get(name).stream(16).capability) == {"R"}, name


# ---------------- degenerate inputs (guard paths) ----------------

def test_cholesky_solve_singular_stays_finite():
    """Rank-deficient SPD (outer product): the eps pivot guard must keep
    every lane finite instead of spraying NaNs."""
    v = RNG.standard_normal((2, 16, 2)).astype(np.float32)
    a = v @ v.swapaxes(-1, -2)                   # rank 2 << 16
    rhs = RNG.standard_normal((2, 16, 3)).astype(np.float32)
    x = np.asarray(cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(rhs)))
    assert np.isfinite(x).all()


def test_cholesky_solve_ill_conditioned_accuracy():
    """cond ~ 1e4 (above the deficiency threshold): still solves to loose
    tolerance (float32 limit)."""
    q, _ = np.linalg.qr(RNG.standard_normal((16, 16)))
    eig = np.geomspace(1.0, 1e-4, 16).astype(np.float32)
    a = (q * eig) @ q.T
    a = a[None].astype(np.float32)
    rhs = RNG.standard_normal((1, 16, 1)).astype(np.float32)
    x = np.asarray(cholesky_solve_pallas(jnp.asarray(a), jnp.asarray(rhs)))
    assert np.isfinite(x).all()
    assert_close(a @ x, rhs, rtol=2e-2, name="illcond-residual")


def test_qr_solve_rank_deficient_stays_finite():
    """Duplicate columns -> zero householder norm + zero R diagonal: both
    the tau=0 and the clamped-denominator guards fire."""
    col = RNG.standard_normal((2, 16, 1)).astype(np.float32)
    a = np.repeat(col, 8, axis=2)                # rank 1
    b = RNG.standard_normal((2, 16, 2)).astype(np.float32)
    x = np.asarray(qr_solve_pallas(jnp.asarray(a), jnp.asarray(b)))
    assert np.isfinite(x).all()


def test_qr_solve_exact_zero_pivot_stays_finite():
    """R with a hard-zero diagonal entry ([[0,1],[0,0]] pattern): the
    deficient component must be ZEROED, not divided by a clamped tiny
    pivot (which cascades to inf through the remaining rows)."""
    a = np.array([[[0.0, 1.0], [0.0, 0.0], [0.0, 0.0]]], np.float32)
    b = np.ones((1, 3, 1), np.float32)
    x = np.asarray(qr_solve_pallas(jnp.asarray(a), jnp.asarray(b)))
    assert np.isfinite(x).all()


def test_qr_solve_zero_matrix_stays_finite():
    a = np.zeros((1, 12, 8), np.float32)
    b = RNG.standard_normal((1, 12, 1)).astype(np.float32)
    x = np.asarray(qr_solve_pallas(jnp.asarray(a), jnp.asarray(b)))
    assert np.isfinite(x).all()


def test_mmse_zero_channel_stays_finite():
    """All-zero channel: G = sigma2 I, x = 0 — regularization only."""
    h = np.zeros((1, 16, 12), np.float32)
    y = RNG.standard_normal((1, 16, 1)).astype(np.float32)
    x = np.asarray(mmse_equalize_pallas(jnp.asarray(h), jnp.asarray(y)))
    assert np.isfinite(x).all()
    assert np.abs(x).max() < 1e-5


# ---------------- inductive-domain masking (paper F4) ----------------

def test_cholesky_solve_ignores_upper_triangle_garbage():
    """The fused solve reads ONLY the lower triangle (the inductive
    domain): NaN-poisoning the strict upper half must not change x."""
    a = spd(2, 16)
    rhs = RNG.standard_normal((2, 16, 2)).astype(np.float32)
    clean = np.asarray(cholesky_solve_pallas(jnp.asarray(a),
                                             jnp.asarray(rhs)))
    poisoned = a.copy()
    iu = np.triu_indices(16, k=1)
    poisoned[:, iu[0], iu[1]] = np.nan
    got = np.asarray(cholesky_solve_pallas(jnp.asarray(poisoned),
                                           jnp.asarray(rhs)))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, clean, rtol=0, atol=0)


def test_trisolve_masked_lanes_never_read_garbage():
    """The seed trisolve kernel's masked AXPY: NaNs planted in the strict
    upper triangle of L (outside the inductive domain) must not leak."""
    from repro.kernels.trisolve import trisolve_pallas
    a = spd(2, 12)
    l = np.linalg.cholesky(a)
    b = RNG.standard_normal((2, 12, 2)).astype(np.float32)
    clean = np.asarray(trisolve_pallas(jnp.asarray(l), jnp.asarray(b)))
    lp = l.copy()
    iu = np.triu_indices(12, k=1)
    lp[:, iu[0], iu[1]] = 1e30            # garbage (inf-adjacent) lanes
    got = np.asarray(trisolve_pallas(jnp.asarray(lp), jnp.asarray(b)))
    np.testing.assert_allclose(got, clean, rtol=0, atol=0)


# ---------------- serving ----------------

def test_pipeline_engine_serves_and_pads():
    """Jobs of mixed shapes, lane-pool padding: every job gets its own
    answer; padded identity lanes never contaminate real ones."""
    eng = PipelineEngine("cholesky_solve", lanes=4)
    jobs = []
    for n in (8, 8, 12):                  # 2 groups; both need padding
        a = spd(1, n)[0]
        b = RNG.standard_normal((n, 2)).astype(np.float32)
        jobs.append(eng.submit(SolveJob(args=(a, b))))
    done = eng.run()
    assert len(done) == 3 and not eng._queue
    for j in jobs:
        a, b = j.args
        want = np.asarray(ref.cholesky_solve(a[None], b[None]))[0]
        assert_close(j.out, want, rtol=1e-4, name="engine-job")


def test_pipeline_engine_matches_direct_batch():
    """One full lane group == a direct pallas call on the same stack."""
    eng = PipelineEngine("mmse_equalize", lanes=4)
    h = RNG.standard_normal((4, 16, 12)).astype(np.float32)
    y = RNG.standard_normal((4, 16, 1)).astype(np.float32)
    jobs = [eng.submit(SolveJob(args=(h[i], y[i]))) for i in range(4)]
    eng.run()
    direct = np.asarray(mmse_equalize_pallas(jnp.asarray(h),
                                             jnp.asarray(y)))
    np.testing.assert_allclose(np.stack([j.out for j in jobs]), direct,
                               rtol=0, atol=0)


def test_pipeline_engine_rejects_non_pipeline():
    with pytest.raises(ValueError):
        PipelineEngine("gemm")
