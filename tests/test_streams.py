"""Stream-descriptor IR: paper-claim checks (Figs. 10/11/21/22) and
property tests on the executable semantics.

hypothesis is optional (see tests/strategies.py): each property runs
over a deterministic parametrized grid, and the ``@fuzzed`` variants
widen the space when hypothesis is installed."""
from fractions import Fraction

import numpy as np
import pytest

from repro.core.streams import (StreamDescriptor, StreamDim,
                                average_stream_length, command_count,
                                commands_per_iteration, inductive, rect)

from strategies import fuzzed, integers, sampled


# ---------------- constructors / classification ----------------

def test_rect_capability():
    assert rect(4).capability == "R"
    assert rect(4, 8).capability == "RR"
    assert rect(2, 3, 4).capability == "RRR"


def test_inductive_capability():
    s = inductive(outer_trip=8, inner_base=8, inner_stretch=-1)
    assert s.capability == "RI"
    assert s.dims[1].is_inductive


def test_rect_row_major_addresses():
    s = rect(3, 4)
    assert list(s.addresses()) == list(range(12))


def test_inductive_triangular_length():
    # inner trip = n - j  (upper-triangular domain), n = 8
    n = 8
    s = inductive(outer_trip=n, inner_base=n, inner_stretch=-1)
    assert s.length() == n * (n + 1) // 2
    assert s.trip_counts() == [n - j for j in range(n)]


def test_trip_clamps_at_zero():
    s = inductive(outer_trip=6, inner_base=2, inner_stretch=-1)
    # trips 2,1,0,0,0,0 -> never negative
    assert s.trip_counts() == [2, 1, 0, 0, 0, 0]
    assert s.length() == 3


def test_fractional_stretch():
    # vectorized-by-4 triangular stream: trip = ceil((8 - j)/1)/4 pattern
    s = StreamDescriptor(dims=(
        StreamDim(Fraction(4)),
        StreamDim(Fraction(2), 1, (Fraction(-1, 2),)),
    ))
    assert s.trip_counts() == [2, 2, 1, 1]


# ---------------- paper Fig. 11: solver command counts ----------------

def solver_streams(n: int):
    """The three inductive access streams of the triangular solver
    (paper Fig. 11): reads of b, the inductive matrix walk of a, and the
    inductive reuse of the divide output."""
    a = inductive(outer_trip=n, inner_base=n - 1, inner_stretch=-1,
                  outer_stride=n + 1, inner_stride=1, name="a")
    b = rect(n, name="b")
    x = inductive(outer_trip=n, inner_base=n - 1, inner_stretch=-1,
                  name="x-reuse")
    return [a, b, x]


@pytest.mark.parametrize("n", [4, 8, 12, 16, 32])
def test_solver_commands_ri_constant(n):
    """RI capability: each solver stream is ONE command -> the paper's
    '8 total' (3 streams + 5 fixed config/barrier commands) vs '3+5n'."""
    streams = solver_streams(n)
    ri = sum(command_count(s, "RI") for s in streams)
    assert ri == 3                              # one command per stream
    rr = sum(command_count(s, "RR") for s in streams)
    assert rr == 2 * n + 1                      # inductive ones decompose
    # paper's totals: fixed overhead of 5 commands either way
    assert ri + 5 == 8
    assert rr + 5 == 5 + 1 + 2 * n              # O(n) control insts


@pytest.mark.parametrize("n", [8, 16, 32, 128])
def test_ri_below_one_command_per_iter(n):
    """Paper Fig. 22: RI always achieves < 1 control inst/iteration on the
    FGOP (triangular) patterns."""
    tri = inductive(outer_trip=n, inner_base=n, inner_stretch=-1)
    assert commands_per_iteration(tri, "RI") < 1.0
    assert commands_per_iteration(tri, "RI") <= \
        commands_per_iteration(tri, "RR")
    assert commands_per_iteration(tri, "RR") <= \
        commands_per_iteration(tri, "V")


@pytest.mark.parametrize("n", [16, 32, 128])
def test_stream_length_ordering(n):
    """Paper Fig. 21: average stream length grows with capability, and
    inductive capability is what unlocks long streams on FGOP patterns."""
    tri = inductive(outer_trip=n, inner_base=n, inner_stretch=-1)
    lv = average_stream_length(tri, "V")
    lr = average_stream_length(tri, "R")
    lri = average_stream_length(tri, "RI")
    assert lv <= lr <= lri
    assert lri == tri.length()          # one command covers everything


def test_gemm_rect_needs_no_induction():
    """Regular workloads (GEMM) are fully served by RR (paper Q10)."""
    g = rect(12, 64)
    assert command_count(g, "RR") == 1
    assert command_count(g, "RI") == 1


# ---------------- property tests ----------------
# Each property lives in a _check_* helper; a deterministic parametrized
# grid always runs it, and (when hypothesis is installed) a fuzzed
# variant widens the coverage.

def _check_rect_length_product(nj, ni):
    s = rect(nj, ni)
    assert s.length() == nj * ni
    assert len(s.addresses()) == nj * ni


def _check_inductive_length_matches_sum(n, stretch, base):
    s = inductive(outer_trip=n, inner_base=base, inner_stretch=stretch)
    want = sum(max(0, base + stretch * j) for j in range(n))
    assert s.length() == want


def _check_decomposition_preserves_coverage(n, stretch, base, cap):
    """Whatever the capability, the commands issued must cover exactly the
    pattern's iteration space (command_count * avg length == length)."""
    s = inductive(outer_trip=n, inner_base=base, inner_stretch=stretch)
    c = command_count(s, cap)
    if s.length() == 0:
        # degenerate pattern (zero iterations anywhere, e.g. inner_base=0
        # with non-positive stretch): no commands at any capability
        assert c == 0
        return
    assert c >= 1
    # RI expresses any 2D inductive pattern in one command
    if cap == "RI":
        assert c == 1
    # decomposed commands can never be fewer than the RI command
    assert c >= command_count(s, "RI")


def _check_addresses_unique_for_unit_stride_triangle(n):
    """The triangular row-walk a[j*(n+1) + i] touches distinct addresses."""
    s = inductive(outer_trip=n, inner_base=n, inner_stretch=-1,
                  outer_stride=n + 1, inner_stride=1)
    addrs = s.addresses()
    assert len(np.unique(addrs)) == len(addrs)


@pytest.mark.parametrize("nj,ni", [(1, 1), (1, 12), (3, 4), (7, 5),
                                   (12, 12)])
def test_rect_length_product(nj, ni):
    _check_rect_length_product(nj, ni)


@pytest.mark.parametrize("n", [1, 2, 5, 16])
@pytest.mark.parametrize("stretch", [-3, -1, 0, 1, 3])
@pytest.mark.parametrize("base", [0, 1, 7, 16])
def test_inductive_length_matches_sum(n, stretch, base):
    _check_inductive_length_matches_sum(n, stretch, base)


@pytest.mark.parametrize("n", [1, 3, 10])
@pytest.mark.parametrize("stretch", [-2, -1, 0, 1, 2])
@pytest.mark.parametrize("base", [0, 1, 4, 10])
@pytest.mark.parametrize("cap", ["R", "RR", "RI"])
def test_decomposition_preserves_coverage(n, stretch, base, cap):
    _check_decomposition_preserves_coverage(n, stretch, base, cap)


# ---------------- degenerate (zero-length) streams ----------------
# An inductive stream whose inner trips start at zero (inner_base=0) is
# legal — StreamDim.trip clamps at zero — but the control-overhead model
# used to charge >=1 command for patterns with NO iterations.  These pins
# hold the guarded behavior.

@pytest.mark.parametrize("cap", ["V", "R", "RR", "RI"])
def test_zero_length_stream_needs_no_commands(cap):
    empty = inductive(outer_trip=4, inner_base=0, inner_stretch=0)
    assert empty.length() == 0
    assert empty.trip_counts() == [0, 0, 0, 0]
    assert command_count(empty, cap) == 0
    assert commands_per_iteration(empty, cap) == 0.0
    assert average_stream_length(empty, cap) == 0.0


@pytest.mark.parametrize("cap", ["V", "R", "RR", "RI"])
def test_zero_trip_rect_needs_no_commands(cap):
    assert command_count(rect(0, 8), cap) == 0
    assert command_count(rect(8, 0), cap) == 0


def test_inner_base_zero_growing_stream_counts_all_rows():
    """inner_base=0 with positive stretch: row j=0 is empty but the
    pattern is NOT degenerate — RI takes one command, and decomposed R
    commands issue one per outer row (the empty row's command is issued
    before its zero trip count is known: the paper's 3+5n accounting)."""
    s = inductive(outer_trip=4, inner_base=0, inner_stretch=2)
    assert s.trip_counts() == [0, 2, 4, 6]
    assert s.length() == 12
    assert command_count(s, "RI") == 1
    assert command_count(s, "R") == 4  # one per outer row, empty included
    assert average_stream_length(s, "R") == pytest.approx(3.0)


@pytest.mark.parametrize("n", [2, 3, 5, 8, 12])
def test_addresses_unique_for_unit_stride_triangle(n):
    _check_addresses_unique_for_unit_stride_triangle(n)


@fuzzed(max_examples=50, nj=integers(1, 12), ni=integers(1, 12))
def test_rect_length_product_fuzzed(nj, ni):
    _check_rect_length_product(nj, ni)


@fuzzed(max_examples=80, n=integers(1, 16), stretch=integers(-3, 3),
        base=integers(0, 16))
def test_inductive_length_matches_sum_fuzzed(n, stretch, base):
    _check_inductive_length_matches_sum(n, stretch, base)


@fuzzed(max_examples=80, n=integers(1, 10), stretch=integers(-2, 2),
        base=integers(0, 10), cap=sampled("R", "RR", "RI"))
def test_decomposition_preserves_coverage_fuzzed(n, stretch, base, cap):
    _check_decomposition_preserves_coverage(n, stretch, base, cap)


@fuzzed(max_examples=30, n=integers(2, 12))
def test_addresses_unique_for_unit_stride_triangle_fuzzed(n):
    _check_addresses_unique_for_unit_stride_triangle(n)
