"""Overload-aware scheduling in SolverMux: variant-cost admission
control, priority preemption, and cross-shape coalescing.

Deterministic ManualClock scenario tests for every policy edge —
shed-on-expiry, budget admission + preemption ordering, coalescing
applicability (predicate/compatibility re-check at the padded shape,
filler correctness, cost refusal), no-starvation of best-effort traffic,
per-pool pressure boundaries, metrics-counter accounting — plus the
hypothesis-fuzzed scheduler invariants (no hard-deadline job is ever
dropped; coalesced results are BIT-identical to un-coalesced solves)
and the golden trace-replay regression pinning the exact
flush/drop/preempt/coalesce event sequence.
"""
import json
import pathlib

import numpy as np
import pytest

from repro import kernels as K
from repro.kernels.common import sample_spd
from repro.launch.serve_solvers import (job_args, load_trace,
                                        replay_trace, run_overload)
from repro.serve import (CostModel, ManualClock, OverloadPolicy,
                         SolverMux, VariantDispatcher)

from conftest import assert_close
from strategies import fuzzed, traces

DATA = pathlib.Path(__file__).parent / "data"
RNG = np.random.default_rng(42)


def chol_args(n, k=2, rng=RNG):
    return (sample_spd(rng, 1, n)[0],
            rng.standard_normal((n, k)).astype(np.float32))


def tall_args(n, k=2, rng=RNG):
    m = n + 4
    return (rng.standard_normal((m, n)).astype(np.float32),
            rng.standard_normal((m, k)).astype(np.float32))


def events_of(mux, *kinds):
    return [e for e in mux.events if e["event"] in kinds]


# ---------------- cost model ----------------

def test_variant_model_flops_and_fallback():
    spec = K.get("cholesky_solve")
    shapes = ((8, 8), (8, 2))
    want = 8 ** 3 / 3.0 + 2.0 * 8 * 8 * 2
    assert spec.base.model_flops(shapes) == pytest.approx(want)
    assert spec.model_flops(shapes, (np.float32, np.float32)) == \
        pytest.approx(want)
    # a variant without a flops model falls back to first-arg volume
    noflops = K.Variant(name="x", fn=None, when=lambda s, d: True)
    assert noflops.model_flops(((4, 6), (4, 2))) == 24.0


def test_cost_model_orders_by_shape_and_overhead():
    cm = CostModel()
    spec = K.get("cholesky_solve")
    small = cm.launch_cost(spec.name, spec.base, ((8, 8), (8, 2)), 4)
    big = cm.launch_cost(spec.name, spec.base, ((12, 12), (12, 2)), 4)
    assert 0 < small < big
    # overhead is per launch: one 8-lane launch beats two 4-lane ones
    one = cm.launch_cost(spec.name, spec.base, ((8, 8), (8, 2)), 8)
    two = 2 * cm.launch_cost(spec.name, spec.base, ((8, 8), (8, 2)), 4)
    assert one < two


def test_cost_model_calibrates_from_committed_baseline():
    cm = CostModel.from_bench_json(
        pathlib.Path(__file__).parent.parent / "BENCH_pipelines.json")
    assert cm.table, "committed baseline produced no calibration rates"
    for (pipeline, variant), rate in cm.table.items():
        assert rate > 0, (pipeline, variant)
    # calibrated pairs price through the table, others through default
    assert cm.rate("cholesky_solve", "base") != \
        pytest.approx(cm.sec_per_flop) or \
        ("cholesky_solve", "base") not in cm.table


def test_dispatcher_price_routes_through_dispatch():
    spec = K.get("cholesky_solve")
    disp = VariantDispatcher(spec, cost_model=CostModel())
    key8 = ((((8, 8)), "float32"), (((8, 2)), "float32"))
    key12 = ((((12, 12)), "float32"), (((12, 2)), "float32"))
    assert 0 < disp.price(key8, lanes=4) < disp.price(key12, lanes=4)


# ---------------- shedding (admission control) ----------------

def test_shed_drops_expired_best_effort_only():
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, policy=OverloadPolicy())
    be = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    hard = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0,
                      priority="hard")
    clk.advance(2.0)
    done = mux.poll()
    assert be.state == "dropped" and be.out is None
    assert hard.state == "done" and any(j is hard for j in done)
    drops = events_of(mux, "drop")
    assert len(drops) == 1 and drops[0]["seq"] == be.seq
    st = mux.metrics()["cholesky_solve"]
    assert st.dropped == 1
    assert st.latency_by_priority["hard"].count == 1
    assert "best_effort" not in st.latency_by_priority


def test_shed_boundary_at_exact_deadline():
    """deadline == now is still servable ON time — only deadline < now
    sheds."""
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, policy=OverloadPolicy())
    job = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    clk.advance(1.0)
    done = mux.poll()
    assert job.state == "done" and any(j is job for j in done)
    assert job.finished_at <= job.deadline


def test_policy_none_never_drops():
    """Without a policy the legacy behavior is untouched: expired
    best-effort jobs are served late, never dropped."""
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk)
    job = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    clk.advance(100.0)
    done = mux.poll()
    assert job.state == "done" and any(j is job for j in done)
    assert not events_of(mux, "drop", "preempt", "coalesce", "defer")


def test_submit_rejects_unknown_priority():
    mux = SolverMux(lanes=2)
    with pytest.raises(ValueError, match="priority"):
        mux.submit("cholesky_solve", *chol_args(8), priority="urgent")


# ---------------- budgeted admission + preemption ----------------

def _prices(lanes):
    cm = CostModel()
    spec = K.get("cholesky_solve")
    return cm, {n: cm.launch_cost("cholesky_solve", spec.base,
                                  ((n, n), (n, 2)), lanes)
                for n in (8, 12, 16)}


def test_budget_defers_cheapest_last_candidate():
    """Budget for one launch: the earliest-deadline bucket flushes, the
    other defers with a priced event."""
    cm, p = _prices(4)
    clk = ManualClock()
    pol = OverloadPolicy(budget=p[8] * 1.05, coalesce=False,
                         cost_model=cm)
    mux = SolverMux(lanes=4, clock=clk, pressure=1, policy=pol)
    first = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    later = mux.submit("cholesky_solve", *chol_args(12), deadline=2.0)
    done = mux.poll(0.5)
    assert done == [first]
    assert later.state == "queued"
    defers = events_of(mux, "defer")
    assert len(defers) == 1 and defers[0]["jobs"] == [later.seq]
    assert defers[0]["price"] == pytest.approx(p[12], rel=1e-4)


def test_preemption_abandons_cheapest_best_effort_first():
    """A pending hard-deadline bucket preempts admitted best-effort
    flushes cheapest-to-abandon first: with budget = p8 + p12 and a hard
    n=16 candidate last in deadline order, BOTH best-effort buckets are
    abandoned (cheapest first: n=8 then n=12) because freeing only n=8
    does not fit p16."""
    cm, p = _prices(4)
    clk = ManualClock()
    pol = OverloadPolicy(budget=p[8] + p[12], coalesce=False,
                         cost_model=cm)
    mux = SolverMux(lanes=4, clock=clk, pressure=1, policy=pol)
    be_cheap = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    be_costly = [mux.submit("cholesky_solve", *chol_args(12),
                            deadline=1.1) for _ in range(2)]
    hard = mux.submit("cholesky_solve", *chol_args(16), deadline=2.0,
                      priority="hard")
    done = mux.poll(0.5)
    assert done == [hard]
    assert be_cheap.state == "queued"
    assert all(j.state == "queued" for j in be_costly)
    pre = events_of(mux, "preempt")
    assert [e["jobs"] for e in pre] == [[be_cheap.seq],
                                        [j.seq for j in be_costly]]
    assert pre[0]["cost"] <= pre[1]["cost"]       # cheapest abandoned 1st
    assert all(e["for_pipeline"] == "cholesky_solve" for e in pre)
    snap = mux.metrics()
    assert snap.total_preempted == 3
    assert snap["cholesky_solve"].preempted == 3


def test_preemption_skips_when_freeing_cannot_fit():
    """If abandoning every best-effort flush still cannot fit the hard
    candidate, nothing is preempted — the hard bucket defers instead."""
    cm, p = _prices(4)
    clk = ManualClock()
    # budget fits only the n=8 launch; freeing it cannot fit p16
    pol = OverloadPolicy(budget=p[8] * 1.05, coalesce=False,
                         cost_model=cm)
    mux = SolverMux(lanes=4, clock=clk, pressure=1, policy=pol)
    be = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    hard = mux.submit("cholesky_solve", *chol_args(16), deadline=2.0,
                      priority="hard")
    done = mux.poll(0.5)
    assert done == [be]
    assert hard.state == "queued"
    assert not events_of(mux, "preempt")
    assert len(events_of(mux, "defer")) == 1


def test_preempted_bucket_is_served_on_a_later_poll():
    cm, p = _prices(4)
    clk = ManualClock()
    pol = OverloadPolicy(budget=p[12] * 1.05, coalesce=False,
                         cost_model=cm)
    mux = SolverMux(lanes=4, clock=clk, pressure=1, policy=pol)
    be = mux.submit("cholesky_solve", *chol_args(8), deadline=1.0)
    hard = mux.submit("cholesky_solve", *chol_args(12), deadline=2.0,
                      priority="hard")
    assert mux.poll(0.5) == [hard]                # be preempted
    assert be.state == "queued"
    assert mux.poll(0.6) == [be]                  # re-admitted next round
    assert be.state == "done"
    assert_close(be.out, K.get("cholesky_solve").run_oracle_lane(*be.args),
                 rtol=1e-3, name="preempted-then-served")


def test_no_starvation_aged_bucket_bypasses_budget():
    """A due best-effort bucket deferred ``max_defer`` times is admitted
    ahead of a perpetual hard-deadline flood on the next poll — and only
    ONE aged bucket may borrow past the budget per poll (no avalanche)."""
    cm, _ = _prices(2)
    spec = K.get("cholesky_solve")
    p8 = cm.launch_cost("cholesky_solve", spec.base, ((8, 8), (8, 2)), 2)
    clk = ManualClock()
    pol = OverloadPolicy(budget=p8 * 1.05, coalesce=False, max_defer=3,
                         cost_model=cm)
    mux = SolverMux(lanes=2, clock=clk, pressure=1, policy=pol)
    be = mux.submit("cholesky_solve", *chol_args(12), deadline=100.0)
    served_at = None
    for tick in range(6):
        for i in range(2):
            mux.submit("cholesky_solve", *chol_args(8),
                       deadline=clk() + 0.1, priority="hard")
        done = mux.poll()
        assert len(done) <= 4, "aged bypass must not avalanche"
        if any(j is be for j in done):
            served_at = tick
            break
        clk.advance(1.0)
    assert served_at == pol.max_defer
    assert be.state == "done"


# ---------------- cross-shape coalescing ----------------

def test_coalescing_merges_small_bucket_into_big_partial():
    """Under pool pressure, a small bucket rides a bigger compatible
    bucket's free lanes: ONE launch, rider results BIT-identical to the
    un-coalesced pallas solve, counters and events accounted."""
    def run(coalesce):
        clk = ManualClock()
        mux = SolverMux(lanes=4, clock=clk, pressure=3,
                        policy=OverloadPolicy(coalesce=coalesce))
        big = [mux.submit("cholesky_solve", *job_args(
            "cholesky_solve", 12, 2, 100 + i)) for i in range(2)]
        small = [mux.submit("cholesky_solve", *job_args(
            "cholesky_solve", 8, 2, 200 + i)) for i in range(2)]
        mux.poll()
        mux.run()
        return mux, big, small

    mux_on, big_on, small_on = run(True)
    mux_off, big_off, small_off = run(False)
    snap = mux_on.metrics()
    assert snap.total_launches == 1 and snap.total_coalesced == 2
    assert mux_off.metrics().total_launches == 2
    launch = snap.launches[0]
    assert launch.real == 4 and launch.coalesced == 2 and launch.padded == 0
    coal = events_of(mux_on, "coalesce")
    assert len(coal) == 1
    assert coal[0]["jobs"] == [j.seq for j in small_on]
    assert coal[0]["ride_cost"] < coal[0]["own_cost"]
    for a, b in zip(big_on + small_on, big_off + small_off):
        assert b.state == a.state == "done"
        assert np.array_equal(a.out, b.out), \
            "coalesced result must be bit-identical to the solo solve"
        assert a.out.shape == b.out.shape     # extracted to small shape


def test_coalescing_fills_remaining_lanes_with_filler():
    """Riders and declared filler coexist: 1 host job + 1 rider + 2
    filler lanes in a 4-lane launch, every real result exact."""
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, pressure=2,
                    policy=OverloadPolicy())
    host = mux.submit("qr_solve", *job_args("qr_solve", 12, 2, 7))
    rider = mux.submit("qr_solve", *job_args("qr_solve", 8, 2, 8))
    mux.poll()
    launch = mux.metrics().launches[0]
    assert (launch.real, launch.coalesced, launch.padded) == (2, 1, 2)
    spec = K.get("qr_solve")
    assert_close(host.out, spec.run_oracle_lane(*host.args), rtol=1e-3,
                 name="coalesce-host")
    assert_close(rider.out, spec.run_oracle_lane(*rider.args), rtol=1e-3,
                 name="coalesce-rider")
    assert rider.out.shape == (8, 2)


def test_coalescing_applicability_is_declared_not_guessed():
    compat = K.get("mmse_equalize").coalesce.compatible
    k = lambda *pairs: tuple((shape, dt) for shape, dt in pairs)
    two8 = k(((12, 8), "float32"), ((12, 2), "float32"))
    two12 = k(((16, 12), "float32"), ((16, 2), "float32"))
    four = k(((12, 8), "float32"), ((12, 8), "float32"),
             ((12, 2), "float32"), ((12, 2), "float32"))
    assert compat(two8, two12)
    assert not compat(two12, two8)          # big cannot ride small
    assert not compat(two8, two8)           # same bucket is not a ride
    assert not compat(four, two12)          # split-complex arity differs
    assert not compat(two8, four)
    # dtype must match exactly
    two12_f64 = k(((16, 12), "float64"), ((16, 2), "float64"))
    assert not compat(two8, two12_f64)
    # rhs wider than the host's cannot be embedded
    wide = k(((12, 8), "float32"), ((12, 5), "float32"))
    assert not compat(wide, two12)
    # identity block must fit below the small rows: M - ms >= N - ns
    squat = k(((16, 8), "float32"), ((16, 2), "float32"))
    assert not compat(squat, two12)         # 16-16 < 12-8


def test_split_complex_bucket_never_coalesces_with_two_arg():
    """Integration: a 4-plane split-complex MMSE partial and a 2-arg
    MMSE partial under pressure flush as separate launches — arity makes
    them incompatible in both directions."""
    rng = np.random.default_rng(3)
    m, n = 12, 8
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, pressure=2,
                    policy=OverloadPolicy())
    mux.submit("mmse_equalize",
               rng.standard_normal((m, n)).astype(np.float32),
               rng.standard_normal((m, n)).astype(np.float32),
               rng.standard_normal((m, 2)).astype(np.float32),
               rng.standard_normal((m, 2)).astype(np.float32))
    mux.submit("mmse_equalize", *tall_args(n))
    mux.poll()
    snap = mux.metrics()
    assert snap.total_launches == 2 and snap.total_coalesced == 0
    assert not events_of(mux, "coalesce")
    counts = snap["mmse_equalize"].dispatch_counts
    assert counts == {"split_complex": 1, "base": 1}


def test_coalescing_rejects_nonconforming_embed():
    """A Coalescer.embed that does not produce lanes at exactly the host
    bucket's shapes/dtypes is an error at launch, never a silent
    mis-stack — the applicability contract is enforced at the padded
    shape."""
    import dataclasses

    spec = K.get("cholesky_solve")
    broken = dataclasses.replace(spec, coalesce=K.Coalescer(
        compatible=spec.coalesce.compatible,
        embed=lambda args, big_shapes: args,       # wrong (small) shapes
        extract=spec.coalesce.extract))
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, pressure=2,
                    policy=OverloadPolicy())
    mux._pool("cholesky_solve").spec = broken
    big = mux.submit("cholesky_solve", *chol_args(12))
    small = mux.submit("cholesky_solve", *chol_args(8))
    with pytest.raises(ValueError, match="coalesce.embed"):
        mux.poll()
    # the failed launch must not strand anything: both jobs are still
    # queued (launch happens BEFORE dequeue) and servable once the
    # coalescer is fixed
    assert mux.pending() == 2
    assert big.state == small.state == "queued"
    mux._pool("cholesky_solve").spec = spec
    mux.run()
    assert big.state == small.state == "done"


def test_absorbed_launch_budget_is_refunded_to_deferred():
    """Absorbing an admitted smaller launch refunds its budget: a
    deferred bucket in ANOTHER pool (so it cannot simply ride along) is
    readmitted and flushes in the SAME poll instead of aging toward the
    voucher.  A flat cost model (overhead-only) makes every launch cost
    the same, so the refunded launch exactly covers the deferred one."""
    cm = CostModel(sec_per_flop=0.0, launch_overhead=1e-3)
    clk = ManualClock()
    pol = OverloadPolicy(budget=2.05e-3, cost_model=cm)
    mux = SolverMux(lanes=4, clock=clk, pressure=100, policy=pol)
    host = [mux.submit("cholesky_solve", *chol_args(16), deadline=1.0,
                       priority="hard") for _ in range(2)]
    donor = mux.submit("cholesky_solve", *chol_args(12), deadline=1.1,
                       priority="hard")
    third = mux.submit("qr_solve", *tall_args(8), deadline=1.2,
                       priority="hard")
    done = mux.poll(1.25)              # all three buckets due
    # admission: host + donor fit the 2-launch budget, third defers; the
    # donor then rides the host's free lanes and its refund readmits
    # the qr bucket (a different pool — pass-2 coalescing cannot reach it)
    assert {j.seq for j in done} == \
        {j.seq for j in host} | {donor.seq, third.seq}
    assert mux.metrics().total_launches == 2       # merged + readmitted
    readmits = events_of(mux, "readmit")
    assert len(readmits) == 1 and readmits[0]["jobs"] == [third.seq]
    assert len(events_of(mux, "defer")) == 1       # deferred, then saved
    assert len(events_of(mux, "coalesce")) == 1


def test_coalescing_refused_when_ride_costs_more_than_launch():
    """With zero launch overhead the cost model scores riding as pure
    padded-lane waste — the policy must refuse and log both prices."""
    cm = CostModel(launch_overhead=0.0)
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, pressure=2,
                    policy=OverloadPolicy(cost_model=cm))
    mux.submit("cholesky_solve", *chol_args(12))
    mux.submit("cholesky_solve", *chol_args(8))
    mux.poll()
    snap = mux.metrics()
    assert snap.total_launches == 2 and snap.total_coalesced == 0
    rejects = events_of(mux, "coalesce_reject")
    assert rejects and all(e["ride_cost"] >= e["own_cost"]
                           for e in rejects)


# ---------------- per-pool pressure (satellite fix) ----------------

@pytest.mark.parametrize("with_policy", [False, True])
def test_pressure_is_per_pool_not_global(with_policy):
    """Backlogs in other pools must not flush this pool's partials: two
    pools each one job below the threshold stay queued even though the
    mux-wide total is far above it."""
    clk = ManualClock()
    mux = SolverMux(lanes=8, pressure=4, clock=clk,
                    policy=OverloadPolicy() if with_policy else None)
    for _ in range(3):
        mux.submit("cholesky_solve", *chol_args(8))
        mux.submit("qr_solve", *tall_args(8))
    assert mux.pending() == 6          # total 6 >= 4, per pool 3 < 4
    assert mux.poll() == []
    mux.run()


@pytest.mark.parametrize("with_policy", [False, True])
def test_pressure_boundary_is_inclusive(with_policy):
    """The documented boundary is ``queued >= pressure``: exactly at the
    threshold flushes, one below holds."""
    clk = ManualClock()
    mux = SolverMux(lanes=8, pressure=4, clock=clk,
                    policy=OverloadPolicy() if with_policy else None)
    jobs = [mux.submit("cholesky_solve", *chol_args(8)) for _ in range(3)]
    assert mux.poll() == []            # 3 < 4: holds
    jobs.append(mux.submit("cholesky_solve", *chol_args(8)))
    done = mux.poll()                  # 4 == 4: flushes
    assert sorted(j.seq for j in done) == [j.seq for j in jobs]


# ---------------- accounting ----------------

def test_metrics_accounting_submitted_equals_terminal():
    clk = ManualClock()
    mux = SolverMux(lanes=4, clock=clk, policy=OverloadPolicy())
    jobs = []
    for i in range(3):
        jobs.append(mux.submit("cholesky_solve", *chol_args(8),
                               deadline=1.0))
        jobs.append(mux.submit("qr_solve", *tall_args(8),
                               deadline=5.0, priority="hard"))
    clk.advance(2.0)                   # best-effort chol expired
    mux.poll()
    mux.run()
    done = [j for j in jobs if j.state == "done"]
    dropped = [j for j in jobs if j.state == "dropped"]
    assert len(done) + len(dropped) == len(jobs)
    assert {j.pipeline for j in dropped} == {"cholesky_solve"}
    snap = mux.metrics()
    assert snap.total_jobs == len(done)
    assert snap.total_dropped == len(dropped) == 3
    assert snap["cholesky_solve"].dropped == 3
    assert snap["qr_solve"].latency_by_priority["hard"].count == 3
    assert len(events_of(mux, "drop")) == 3
    flushed = sum(len(e["jobs"]) + len(e["coalesced"])
                  for e in events_of(mux, "flush"))
    assert flushed == len(done)


# ---------------- SLO attainment acceptance ----------------

def test_overload_policy_strictly_improves_hard_attainment():
    """Acceptance: on the synthetic 2x-overload mixed-priority trace the
    policy run must strictly beat the same-budget baseline on
    hard-deadline SLO attainment, with ZERO hard-deadline drops and the
    shed/preempt/coalesce machinery demonstrably active."""
    on = run_overload(True)
    off = run_overload(False)
    assert on["attainment_hard"] > off["attainment_hard"]
    assert on["hard_dropped"] == 0 and off["hard_dropped"] == 0
    assert on["dropped"] > 0 and on["preempted"] > 0 \
        and on["coalesced"] > 0
    assert off["dropped"] == off["preempted"] == off["coalesced"] == 0
    assert on["launches"] < off["launches"]


# ---------------- golden trace replay ----------------

def test_golden_trace_replay_event_sequence():
    """Replay the committed overload trace on a virtual clock and pin
    the EXACT scheduling-decision sequence — any policy change shows up
    as a reviewable golden-file diff (regenerate with
    `python tests/data/regen_overload_golden.py`)."""
    trace = load_trace(DATA / "overload_trace.json")
    mux = replay_trace(trace, lanes=2, policy=OverloadPolicy(
        budget=6.5e-5, cost_model=CostModel()), pressure=4)
    got = json.loads(json.dumps(mux.events))
    want = json.loads((DATA / "overload_golden.json").read_text())
    assert got == want
    # sanity: the committed trace exercises every decision kind
    kinds = {e["event"] for e in got}
    assert {"flush", "drop", "defer", "preempt", "coalesce"} <= kinds


# ---------------- fuzzed scheduler invariants ----------------

def _replay(trace, policy, seed_base=0):
    clk = ManualClock()
    mux = SolverMux(lanes=2, clock=clk, pressure=4, policy=policy)
    jobs = []
    for i, (pipeline, n, priority, dl, gap) in enumerate(trace):
        jobs.append(mux.submit(
            pipeline, *job_args(pipeline, n, 2, seed_base + i),
            deadline=None if dl == 0 else clk() + float(dl),
            priority=priority))
        mux.poll()
        clk.advance(float(gap))
    for _ in range(3):
        clk.advance(1.0)
        mux.poll()
    mux.run()
    return mux, jobs


@fuzzed(max_examples=25, trace=traces(max_len=12))
def test_overload_invariants_fuzzed(trace):
    """Random priority/deadline/shape traces: hard-deadline jobs are
    NEVER dropped (while any capacity exists — budget is unlimited
    here, so a hard drop is an outright bug), every job reaches a
    terminal state, and the metrics counters account for all of them."""
    mux, jobs = _replay(trace, OverloadPolicy())
    assert all(j.state in ("done", "dropped") for j in jobs)
    assert not any(j.state == "dropped" for j in jobs
                   if j.priority == "hard")
    for j in jobs:
        assert (j.out is not None) == (j.state == "done")
    snap = mux.metrics()
    done = sum(1 for j in jobs if j.state == "done")
    assert snap.total_jobs == done
    assert snap.total_dropped == len(jobs) - done
    assert mux.pending() == 0


@fuzzed(max_examples=20, trace=traces(max_len=12))
def test_coalesced_results_bit_identical_fuzzed(trace):
    """The same trace served with and without coalescing (shedding and
    budget off, so both runs serve every job) must produce BIT-identical
    outputs — the block-diagonal embedding is exact, not approximate."""
    base = dict(shed=False, preempt=False, budget=None)
    mux_on, jobs_on = _replay(trace, OverloadPolicy(coalesce=True, **base))
    mux_off, jobs_off = _replay(trace,
                                OverloadPolicy(coalesce=False, **base))
    assert all(j.state == "done" for j in jobs_on + jobs_off)
    for a, b in zip(jobs_on, jobs_off):
        assert a.out.shape == b.out.shape
        assert np.array_equal(a.out, b.out)


@fuzzed(max_examples=15, trace=traces(max_len=10))
def test_budgeted_admission_never_drops_hard_fuzzed(trace):
    """Even under a starvation-tight budget the policy may only shed
    expired best-effort work: hard jobs always terminate 'done'."""
    cm = CostModel()
    mux, jobs = _replay(trace, OverloadPolicy(budget=6e-5, cost_model=cm))
    assert all(j.state == "done" for j in jobs if j.priority == "hard")
    for e in events_of(mux, "drop"):
        assert e["deadline"] < e["t"]      # only truly expired work shed
