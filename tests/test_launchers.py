"""Launcher entrypoints: distributed train (mesh+shardings+resume) and
serve (TP rules) on a 1x1 mesh."""
import types

import numpy as np
import pytest

from repro.launch import train as LT


def _args(tmp_path, steps):
    return types.SimpleNamespace(
        arch="xlstm-125m", smoke=True, mesh="1x1", steps=steps,
        seq=32, batch=4, lr=1e-3, seed=0, ckpt=str(tmp_path),
        ckpt_every=4)


def test_launch_train_runs_and_resumes(tmp_path):
    out = LT.run(_args(tmp_path, 4))
    assert len(out["losses"]) == 4
    assert np.isfinite(out["losses"]).all()
    # resume: extending to 6 steps only runs the remaining 2
    out2 = LT.run(_args(tmp_path, 6))
    assert len(out2["losses"]) == 2


def test_launch_mesh_parse():
    mesh = LT.make_mesh("1x1")
    assert mesh.axis_names == ("data", "model")
