"""Shared property-test harness: the hypothesis-optional pattern plus
well-conditioned matrix generators, extracted from the copies that lived
in test_streams / test_masking / test_variants.

Two layers:

* **fuzzed** — decorator implementing the repo's hypothesis-optional
  contract: when hypothesis is installed the test is fuzzed over the
  declared strategy space (CI asserts these ``*_fuzzed`` variants
  collect); without it the test still collects but is skipped, and the
  deterministic parametrized grid next to it carries the coverage.
  Strategies are declared with the lazy spec constructors below
  (``integers``/``floats``/``sampled``) so importing this module never
  requires hypothesis.

      @fuzzed(max_examples=30, n=integers(2, 12))
      def test_foo_fuzzed(n):
          _check_foo(n)

* **case generators** — deterministic, seed-keyed problem builders for
  the solver pipelines (fuzz the scalars, build the arrays
  reproducibly): ``spd_system`` (well-conditioned SPD + rhs),
  ``tall_system`` (full-rank least-squares), ``channel_planes``
  (split re/im complex MIMO channel).

* **scheduler traces** — the ``traces()`` lazy spec generates random
  priority/deadline/shape job traces for the SolverMux overload-policy
  invariants (tests/test_overload.py): each entry is
  ``(pipeline, n, priority, deadline_ticks, gap_ticks)`` where
  ``deadline_ticks == 0`` means no deadline and ``gap_ticks`` is the
  virtual-clock gap before the next arrival.  Arrays are built
  deterministically from the entry index, so a failing trace shrinks to
  a reproducible scenario.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as _st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    given = settings = _st = None

__all__ = [
    "HAVE_HYPOTHESIS", "fuzzed", "integers", "floats", "sampled",
    "traces", "dag_traces", "decode_traffic", "cost_streams",
    "fault_streams",
    "TRACE_PIPELINES", "TRACE_SIZES",
    "spd_system", "tall_system", "channel_planes",
]

TRACE_PIPELINES = ("cholesky_solve", "qr_solve", "mmse_equalize")
TRACE_SIZES = (8, 12)


# ---------------- lazy strategy specs ----------------
# Plain descriptors resolved to hypothesis strategies only inside
# ``fuzzed`` (and only when hypothesis is importable).

def integers(lo: int, hi: int):
    return ("integers", lo, hi)


def floats(lo: float, hi: float):
    return ("floats", lo, hi)


def sampled(*choices):
    return ("sampled", choices)


def traces(max_len: int = 16):
    """Random scheduler traces: lists of
    ``(pipeline, n, priority, deadline_ticks, gap_ticks)`` entries (see
    module docstring)."""
    return ("traces", max_len)


def dag_traces(max_len: int = 6):
    """Random served-DAG traces for the staged-scheduling invariants
    (tests/test_dag_serve.py): lists of
    ``(dag, n, priority, deadline_ticks, gap_ticks, chained)`` entries
    replayed through ``SolverMux.submit_dag`` on a virtual clock.
    ``deadline_ticks == 0`` means no deadline; ``chained`` only takes
    effect on DAGs that declare a fused stage chain.  Problem arrays are
    built deterministically from the entry index, so a failing trace
    shrinks to a reproducible scenario."""
    return ("dag_traces", max_len)


def decode_traffic(max_len: int = 8):
    """Random decode request traffic for the continuous-batching
    invariants (tests/test_decode_serve.py): lists of
    ``(prompt_len, max_new, temp_scaled, gap_ticks)`` entries where
    ``temp_scaled / 10`` is the sampling temperature (0 = greedy) and
    ``gap_ticks`` is the virtual-clock gap before the next arrival.
    Prompt tokens are built deterministically from the entry index, so
    a failing traffic pattern shrinks to a reproducible scenario."""
    return ("decode_traffic", max_len)


def cost_streams(max_len: int = 64, lo: float = 1e-9, hi: float = 10.0):
    """Random measured-launch-cost streams for the cost-model
    calibration properties (tests/test_cost_adaptive.py): non-empty
    lists of positive finite seconds spanning ns..10 s — wide enough to
    include pathological outliers the robust estimator must shrug off."""
    return ("cost_streams", max_len, lo, hi)


def fault_streams(max_fail: float = 0.3, max_nan: float = 0.2):
    """Random small fault traces for the launch-supervision
    no-silent-loss property (tests/test_faults.py): rate-based launch
    failures + NaN output lanes plus an optional one-shard blackhole
    window.  The trace dict feeds a seed-keyed
    :class:`repro.serve.faults.FaultInjector`, so a failing example
    shrinks to a fully reproducible chaos scenario."""
    return ("fault_streams", max_fail, max_nan)


def _resolve(spec):
    kind = spec[0]
    if kind == "integers":
        return _st.integers(min_value=spec[1], max_value=spec[2])
    if kind == "floats":
        return _st.floats(min_value=spec[1], max_value=spec[2])
    if kind == "sampled":
        return _st.sampled_from(list(spec[1]))
    if kind == "traces":
        entry = _st.tuples(
            _st.sampled_from(TRACE_PIPELINES),
            _st.sampled_from(TRACE_SIZES),
            _st.sampled_from(("hard", "best_effort")),
            _st.integers(min_value=0, max_value=4),   # 0 = no deadline
            _st.integers(min_value=0, max_value=2))   # arrival gap
        return _st.lists(entry, min_size=1, max_size=spec[1])
    if kind == "dag_traces":
        entry = _st.tuples(
            _st.sampled_from(("pusch_receive", "svd_solve")),
            _st.sampled_from(TRACE_SIZES),
            _st.sampled_from(("hard", "best_effort")),
            _st.integers(min_value=0, max_value=8),   # 0 = no deadline
            _st.integers(min_value=0, max_value=2),   # arrival gap
            _st.booleans())                           # chained
        return _st.lists(entry, min_size=1, max_size=spec[1])
    if kind == "decode_traffic":
        entry = _st.tuples(
            _st.integers(min_value=1, max_value=6),   # prompt_len
            _st.integers(min_value=0, max_value=8),   # max_new
            _st.sampled_from((0, 0, 7, 13)),          # temperature * 10
            _st.integers(min_value=0, max_value=2))   # arrival gap
        return _st.lists(entry, min_size=1, max_size=spec[1])
    if kind == "fault_streams":
        blackhole = _st.lists(_st.fixed_dictionaries({
            "shard": _st.integers(min_value=0, max_value=1),
            "from_t": _st.floats(min_value=0.0, max_value=1.0,
                                 allow_nan=False),
            "until_t": _st.floats(min_value=1.0, max_value=4.0,
                                  allow_nan=False),
        }), max_size=1)
        return _st.fixed_dictionaries({
            "seed": _st.integers(min_value=0, max_value=2 ** 16),
            "launch_fail_rate": _st.floats(min_value=0.0,
                                           max_value=spec[1],
                                           allow_nan=False),
            "nan_rate": _st.floats(min_value=0.0, max_value=spec[2],
                                   allow_nan=False),
            "nan_lanes": _st.integers(min_value=1, max_value=2),
            "blackhole": blackhole,
        })
    if kind == "cost_streams":
        sample = _st.floats(min_value=spec[2], max_value=spec[3],
                            allow_nan=False, allow_infinity=False)
        return _st.lists(sample, min_size=1, max_size=spec[1])
    raise ValueError(f"unknown strategy spec: {spec!r}")


def fuzzed(max_examples: int = 50, **strategy_specs):
    """Hypothesis-optional fuzzing decorator (see module docstring).

    With hypothesis: ``@settings(max_examples=..., deadline=None)`` +
    ``@given`` over the resolved strategies.  Without: the test is
    collected but skipped — tier-1 gating falls to the deterministic
    grid variant that every fuzzed property pairs with.
    """
    def deco(fn):
        if not HAVE_HYPOTHESIS:
            return pytest.mark.skip(
                reason="hypothesis not installed; deterministic grid "
                       "variant carries the coverage")(fn)
        resolved = {k: _resolve(v) for k, v in strategy_specs.items()}
        return settings(max_examples=max_examples,
                        deadline=None)(given(**resolved)(fn))
    return deco


# ---------------- deterministic case generators ----------------

def spd_system(seed: int, bsz: int, n: int, k: int = 2,
               rank: int | None = None):
    """Well-conditioned SPD system (a, b): a from the repo-wide
    ``sample_spd`` recipe (X X^T + n*I — the same matrices registry
    cases and benchmarks exercise) plus a Gaussian rhs.  ``rank`` builds
    a deliberately rank-deficient a = X_r X_r^T instead (no regularizing
    ridge) for pivot-guard tests."""
    from repro.kernels.common import sample_spd
    rng = np.random.default_rng(seed)
    if rank is None:
        a = sample_spd(rng, bsz, n)
    else:
        x = rng.standard_normal((bsz, n, rank)).astype(np.float32)
        a = x @ x.swapaxes(-1, -2)
    b = rng.standard_normal((bsz, n, k)).astype(np.float32)
    return a, b


def tall_system(seed: int, bsz: int, m: int, n: int, k: int = 2,
                deficient_col: int | None = None):
    """Full-rank tall least-squares case (a (B,M,N), b (B,M,K)), M >= N.
    i.i.d. Gaussian tall matrices are well-conditioned with overwhelming
    probability.  ``deficient_col`` zeroes one column (a numerically
    dependent direction) for rank-deficiency tests."""
    assert m >= n, (m, n)
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((bsz, m, n)).astype(np.float32)
    if deficient_col is not None:
        a[:, :, deficient_col] = 0.0
    b = rng.standard_normal((bsz, m, k)).astype(np.float32)
    return a, b


def channel_planes(seed: int, bsz: int, m: int, n: int, k: int = 2):
    """Split re/im complex MIMO channel case (hr, hi, yr, yi) for the
    split-complex MMSE path; Gaussian planes keep H^H H + sigma^2 I
    well-conditioned for any sigma2 > 0."""
    assert m >= n, (m, n)
    rng = np.random.default_rng(seed)
    mk = lambda *s: rng.standard_normal(s).astype(np.float32)
    return (mk(bsz, m, n), mk(bsz, m, n),
            mk(bsz, m, k), mk(bsz, m, k))
