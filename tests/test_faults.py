"""Fault-tolerant serving: seeded injection, launch supervision, and
graceful degradation.

Deterministic ManualClock scenarios for every containment path the
supervision machinery promises:

  * injector determinism (same trace + seed => identical fault stream)
    and the disabled/default-off paths that keep golden traces pinned;
  * transient launch failure -> bounded retry -> success, with the
    backoff charged as admission debt rather than wall-clock;
  * exhausted retries -> bisect isolates the poisoned job, cohort
    results stay BIT-identical to a fault-free run;
  * persistent NaN lane -> exactly that job fails (``nonfinite_output``),
    the healthy lanes are served;
  * admission-time validation: non-finite inputs are rejected at
    ``submit`` and never contaminate a lane group;
  * blackholed shard -> quarantine (capacity shrinks) -> probe ->
    reinstatement, on the mesh;
  * repeated variant failure -> demotion down the ladder
    (blocked -> base) with event + alert;
  * predicted-cost watchdog (opt-in) flags stalled launches;
  * the bounded event ring buffer reports drops instead of growing
    without bound;

plus the golden chaos-replay regression (committed fault trace ->
pinned event stream), the end-to-end chaos acceptance scenario
(no hard job silently lost, quarantine + reinstate + demote all
observed, hard attainment >= 80% of fault-free), and the
hypothesis-fuzzed no-silent-loss property over random fault streams.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.launch.serve_solvers import job_args, run_chaos
from repro.serve import (CostModel, FaultInjector, InjectedLaunchError,
                         ManualClock, SolverMux, global_config)

from conftest import assert_close
from strategies import fault_streams, fuzzed

DATA = pathlib.Path(__file__).parent / "data"

mesh_ok = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="mesh tests need the 8-virtual-device session (conftest)")


def chol_args(n=8, seed=0):
    return job_args("cholesky_solve", n, 3, seed)


def mk_mux(lanes=2, mesh_size=None, trace=None, cost_model=None,
           fault_seed=0):
    clock = ManualClock()
    injector = FaultInjector(trace, seed=fault_seed) \
        if trace is not None else None
    mux = SolverMux(lanes=lanes, clock=clock, mesh_size=mesh_size,
                    cost_model=cost_model, injector=injector)
    return mux, clock


def events_of(mux, *kinds):
    return [e for e in mux.events if e["event"] in kinds]


def reference_outputs(n_jobs, n=8):
    """Outputs of the same jobs through a fault-free mux — the
    bit-identical baseline degraded runs are judged against."""
    mux, _ = mk_mux()
    jobs = [mux.submit("cholesky_solve", *chol_args(n, seed=i))
            for i in range(n_jobs)]
    mux.run()
    assert all(j.state == "done" for j in jobs)
    return [j.out for j in jobs]


# ---------------- injector ----------------

def test_injector_deterministic_stream():
    trace = {"launch_fail_rate": 0.3, "nan_rate": 0.2, "stall_rate": 0.1}
    ctx = {"pipeline": "cholesky_solve", "variant": "base", "width": 4,
           "mesh": 1, "shard": None, "t": 0.0}
    def stream(seed):
        inj = FaultInjector(trace, seed=seed)
        return [inj.draw(ctx) for _ in range(64)]

    draws = stream(7)
    assert draws == stream(7)
    assert draws != stream(8)
    kinds = {f.kind for f in draws if f is not None}
    assert kinds == {"raise", "nan", "stall"}
    # a seed inside the trace wins over the constructor seed
    inj = FaultInjector({**trace, "seed": 7}, seed=99)
    assert [inj.draw(ctx) for _ in range(64)] == draws


def test_injector_disabled_and_default_off():
    trace = {"launch_fail_rate": 1.0}
    off = FaultInjector(trace, enabled=False)
    ctx = {"pipeline": "x", "variant": "base", "width": 2, "mesh": 1,
           "shard": None, "t": 0.0}
    assert all(off.draw(ctx) is None for _ in range(8))
    # no REPRO_SERVE_FAULT_TRACE -> no injector at all: the serving
    # stack is bit-identical to the pre-fault-injection code
    assert FaultInjector.from_config() is None
    mux, _ = mk_mux()
    assert mux.injector is None


def test_injector_targeted_counts_down():
    inj = FaultInjector({"target": [{"pipeline": "p", "variant": "v",
                                     "kind": "raise", "count": 2}]})
    hit = {"pipeline": "p", "variant": "v", "width": 2, "mesh": 1,
           "shard": None, "t": 0.0}
    miss = {**hit, "variant": "other"}
    assert inj.draw(miss) is None
    assert inj.draw(hit).reason == "targeted_fault"
    assert inj.draw(hit).reason == "targeted_fault"
    assert inj.draw(hit) is None          # count exhausted


# ---------------- retry / containment ----------------

def test_transient_fault_retried_then_served():
    trace = {"target": [{"pipeline": "cholesky_solve", "kind": "raise",
                         "count": 1}]}
    mux, _ = mk_mux(trace=trace)
    jobs = [mux.submit("cholesky_solve", *chol_args(seed=i))
            for i in range(2)]
    mux.poll()
    assert all(j.state == "done" for j in jobs)
    retries = events_of(mux, "retry")
    assert len(retries) == 1
    assert retries[0]["attempt"] == 1
    assert retries[0]["reason"] == "targeted_fault"
    assert retries[0]["backoff"] > 0
    snap = mux.metrics()
    assert snap.total_retries == 1
    assert snap.faults.retries == 1
    assert snap.faults.failed_jobs == 0
    # retried results are bit-identical to a fault-free run
    for job, want in zip(jobs, reference_outputs(2)):
        np.testing.assert_array_equal(np.asarray(job.out),
                                      np.asarray(want))


def test_exhausted_retries_bisect_isolates_poisoned_job():
    mux, _ = mk_mux(trace={"raise_on_nonfinite_input": True})
    good = mux.submit("cholesky_solve", *chol_args(seed=0))
    bad = mux.submit("cholesky_solve", *chol_args(seed=1))
    # corrupt AFTER admission: models data poisoned in flight, which
    # submit-time validation cannot see
    np.asarray(bad.args[0])[0, 0] = np.nan
    mux.poll()
    assert good.state == "done"
    assert bad.state == "failed"
    assert bad.reason == "nonfinite_input_crash"
    assert bad.finished_at is not None
    assert any(e.get("action") == "bisect"
               for e in events_of(mux, "retry"))
    fails = events_of(mux, "fail")
    assert [e["seq"] for e in fails] == [bad.seq]
    # the survivor is served bit-identical to a fault-free run
    np.testing.assert_array_equal(np.asarray(good.out),
                                  np.asarray(reference_outputs(1)[0]))
    assert mux.metrics().faults.failed_jobs == 1


def test_persistent_nan_lane_fails_only_that_job():
    # count=3 poisons lane 1 on every attempt (1 try + 2 retries), so
    # retries exhaust with the same sick lane -> lane isolation
    trace = {"target": [{"pipeline": "cholesky_solve", "kind": "nan",
                         "lane": 1, "count": 3}]}
    mux, _ = mk_mux(trace=trace)
    jobs = [mux.submit("cholesky_solve", *chol_args(seed=i))
            for i in range(2)]
    mux.poll()
    assert jobs[0].state == "done"
    assert jobs[1].state == "failed"
    assert jobs[1].reason == "nonfinite_output"
    assert all(e["reason"] == "nonfinite_output"
               for e in events_of(mux, "retry"))
    np.testing.assert_array_equal(np.asarray(jobs[0].out),
                                  np.asarray(reference_outputs(1)[0]))
    snap = mux.metrics()
    assert snap.total_failed == 1
    assert snap.faults.retries == 2


def test_submit_rejects_nonfinite_input_cohort_clean():
    mux, _ = mk_mux()
    a, b = chol_args(seed=0)
    a = np.array(a)
    a[0, 0] = np.inf
    poisoned = mux.submit("cholesky_solve", a, b)
    assert poisoned.state == "failed"
    assert poisoned.reason == "nonfinite_input"
    assert mux.pending() == 0             # never enqueued
    assert [e["reason"] for e in events_of(mux, "fail")] == \
        ["nonfinite_input"]
    # the cohort it would have shared a group with is untouched
    jobs = [mux.submit("cholesky_solve", *chol_args(seed=10 + i))
            for i in range(2)]
    mux.run()
    assert all(j.state == "done" for j in jobs)
    assert mux.metrics().total_failed == 1


# ---------------- shard health / degradation ----------------

@mesh_ok
def test_blackholed_shard_quarantined_then_reinstated():
    trace = {"blackhole": [{"shard": 0, "from_t": 0.0, "until_t": 3.0}]}
    mux, clock = mk_mux(mesh_size=4, trace=trace)
    assert mux.total_lanes == 8
    jobs = []
    for t in range(3):                    # failures at t = 0, 1, 2
        jobs += [mux.submit("cholesky_solve", *chol_args(seed=8 * t + i))
                 for i in range(2)]
        mux.poll()
        clock.advance(1.0)
    # every launch placed on shard 0 failed (blackhole), retries
    # re-placed it on a healthy shard -> no job was lost
    assert all(j.state == "done" for j in jobs)
    quar = events_of(mux, "quarantine")
    assert [e["shard"] for e in quar] == [0]
    assert quar[0]["reason"] == "blackhole"
    assert mux.total_lanes == 6           # capacity visibly shrinks
    snap = mux.metrics()
    assert snap.faults.quarantines == 1
    assert snap.faults.quarantined_shards == (0,)
    # quarantined at t=2, probe due at t=5 (probe_after=3.0); the
    # blackhole window ended at t=3, so the probe launch survives
    clock.advance(2.0)
    probe_jobs = [mux.submit("cholesky_solve", *chol_args(seed=90 + i))
                  for i in range(2)]
    mux.poll()
    assert all(j.state == "done" for j in probe_jobs)
    rein = events_of(mux, "reinstate")
    assert [e["shard"] for e in rein] == [0]
    assert rein[0]["downtime"] == pytest.approx(3.0)
    assert mux.total_lanes == 8
    snap = mux.metrics()
    assert snap.faults.reinstatements == 1
    assert snap.faults.quarantined_shards == ()
    assert snap.faults.time_to_recover == pytest.approx(3.0)


def test_repeated_variant_failure_demotes_down_ladder():
    # n=128 resolves the blocked cholesky variant; failing it twice
    # (demote_after=2) demotes the bucket to base mid-supervision, and
    # the third attempt succeeds on base
    trace = {"target": [{"pipeline": "cholesky_solve",
                         "variant": "blocked", "kind": "raise",
                         "count": 2}]}
    mux, _ = mk_mux(trace=trace)
    jobs = [mux.submit("cholesky_solve", *chol_args(n=128, seed=i))
            for i in range(2)]
    mux.poll()
    assert all(j.state == "done" for j in jobs)
    demotes = events_of(mux, "demote")
    assert len(demotes) == 1
    assert demotes[0]["from_variant"] == "blocked"
    assert demotes[0]["to_variant"] == "base"
    assert [e["variant"] for e in events_of(mux, "flush")] == ["base"]
    snap = mux.metrics()
    assert snap.faults.demotions == 1
    assert snap.faults.alerts == ("demote:cholesky_solve:blocked->base",)
    # the demotion sticks: later traffic on the bucket launches base
    more = [mux.submit("cholesky_solve", *chol_args(n=128, seed=9 + i))
            for i in range(2)]
    mux.poll()
    assert all(j.state == "done" for j in more)
    assert [e["variant"] for e in events_of(mux, "flush")] == \
        ["base", "base"]


def test_watchdog_flags_stalled_launch(monkeypatch):
    monkeypatch.setattr(global_config, "watchdog_ratio", 5.0)
    # every launch's measured wall-clock is inflated by 10 s — far
    # beyond 5x any predicted cost — but the jobs still complete
    trace = {"stall_rate": 1.0, "stall_s": 10.0}
    mux, _ = mk_mux(trace=trace, cost_model=CostModel())
    jobs = [mux.submit("cholesky_solve", *chol_args(seed=i))
            for i in range(2)]
    mux.poll()
    assert all(j.state == "done" for j in jobs)
    flags = events_of(mux, "watchdog")
    assert len(flags) == 1
    assert flags[0]["measured"] > flags[0]["predicted"]
    assert mux.metrics().faults.watchdog_flags == 1


def test_watchdog_off_by_default():
    trace = {"stall_rate": 1.0, "stall_s": 10.0}
    mux, _ = mk_mux(trace=trace, cost_model=CostModel())
    jobs = [mux.submit("cholesky_solve", *chol_args(seed=i))
            for i in range(2)]
    mux.poll()
    assert all(j.state == "done" for j in jobs)
    assert events_of(mux, "watchdog") == []
    assert mux.metrics().faults.watchdog_flags == 0


# ---------------- event ring buffer ----------------

def test_event_buffer_bounded_and_drops_reported(monkeypatch):
    monkeypatch.setattr(global_config, "event_cap", 5)
    mux, _ = mk_mux()
    for i in range(16):                   # 8 flush events > cap
        mux.submit("cholesky_solve", *chol_args(seed=i))
        if i % 2 == 1:
            mux.poll()
    assert len(mux.events) == 5
    drained = mux.drain_events()
    assert drained[0]["event"] == "events_dropped"
    assert drained[0]["count"] == 3
    assert len(drained) == 6
    # the drop counter resets with the drain: no stale re-reporting
    mux.submit("cholesky_solve", *chol_args(seed=99))
    mux.run()
    again = mux.drain_events()
    assert [e["event"] for e in again] == ["flush"]


# ---------------- chaos replay ----------------

@pytest.fixture(scope="module")
def chaos():
    faulted = run_chaos(str(DATA / "fault_trace.json"))
    clean = run_chaos(None)
    return faulted, clean


@mesh_ok
def test_golden_chaos_replay_event_sequence(chaos):
    """The committed fault trace replays to the committed event stream,
    byte for byte.  Regenerate INTENTIONAL changes with
    tests/data/regen_chaos_golden.py and review the diff."""
    faulted, _ = chaos
    golden = json.loads((DATA / "chaos_golden.json").read_text())
    assert json.loads(json.dumps(faulted["events"])) == golden


@mesh_ok
def test_chaos_acceptance(chaos):
    """The ISSUE acceptance scenario: ~10% launch failures + NaN lanes
    + one blackholed shard at mesh=4.  No hard job is silently lost,
    the dead shard is quarantined and later reinstated, at least one
    variant demotion fires, and hard-SLO attainment stays >= 80% of the
    fault-free run."""
    faulted, clean = chaos
    assert faulted["faulted"] and not clean["faulted"]
    assert faulted["hard_lost"] == 0
    assert faulted["pending"] == 0
    assert faulted["retries"] > 0
    assert faulted["quarantines"] >= 1
    assert faulted["reinstatements"] >= 1
    assert faulted["demotions"] >= 1
    assert np.isfinite(faulted["time_to_recover"])
    assert any(a.startswith("demote:") for a in faulted["alerts"])
    assert clean["failed"] == 0 and clean["retries"] == 0
    assert faulted["attainment_hard"] >= 0.8 * clean["attainment_hard"]
    # every submitted job reached a terminal state
    assert faulted["done"] + faulted["failed"] + faulted["dropped"] == \
        faulted["jobs"]


# ---------------- fuzzed invariant ----------------

def _check_no_silent_loss(trace):
    clock = ManualClock()
    mux = SolverMux(lanes=2, clock=clock, mesh_size=2,
                    injector=FaultInjector(trace))
    jobs = []
    for t in range(5):
        if t < 4:
            jobs.append(mux.submit(
                "cholesky_solve", *chol_args(seed=2 * t),
                deadline=clock() + 2.0, priority="hard"))
            jobs.append(mux.submit(
                "cholesky_solve", *chol_args(seed=2 * t + 1),
                priority="best_effort"))
        mux.poll()
        clock.advance(1.0)
    mux.run()
    assert mux.pending() == 0
    for j in jobs:
        assert j.state in ("done", "failed"), j.state
        if j.state == "failed":
            assert j.reason, "failed without a structured reason"
            assert j.finished_at is not None


@mesh_ok
@fuzzed(max_examples=15, trace=fault_streams())
def test_fault_streams_no_silent_loss_fuzzed(trace):
    """Under ANY random fault stream — launch failures, NaN lanes, a
    blackholed shard — every job reaches a terminal state and every
    failure carries a structured reason: faults degrade service, they
    never lose work silently."""
    _check_no_silent_loss(trace)


@pytest.mark.parametrize("trace", [
    {},
    {"launch_fail_rate": 0.25, "seed": 3},
    {"nan_rate": 0.2, "nan_lanes": 2, "seed": 5},
    {"launch_fail_rate": 0.15, "nan_rate": 0.1,
     "blackhole": [{"shard": 1, "from_t": 0.0, "until_t": 3.0}]},
])
@mesh_ok
def test_fault_streams_no_silent_loss_grid(trace):
    """Deterministic grid twin of the fuzzed property (carries the
    coverage when hypothesis is absent)."""
    _check_no_silent_loss(trace)


def test_injected_error_is_runtime_error():
    # supervision catches it specifically; callers outside the mux see
    # a plain RuntimeError subclass
    assert issubclass(InjectedLaunchError, RuntimeError)
