"""Implicit vector masking (paper F4): mask generators agree with the
stream-descriptor semantics, and the utilization model matches brute force.

hypothesis is optional (see tests/strategies.py): the properties always
run over a deterministic parametrized grid; the ``@fuzzed`` variants
widen the space when hypothesis is installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masking import (lane_mask, masked_fill, tail_mask, tri_mask,
                                vector_utilization)
from repro.core.streams import inductive

from strategies import fuzzed, integers, sampled


def test_lane_mask_basic():
    m = np.asarray(lane_mask(5, 8))
    assert m.tolist() == [1, 1, 1, 1, 1, 0, 0, 0]


def test_lane_mask_traced():
    f = jax.jit(lambda n: lane_mask(n, 8))
    assert np.asarray(f(3)).sum() == 3


def test_tail_mask_axis():
    m = np.asarray(tail_mask((2, 6), axis=1, length=4))
    assert m[:, :4].all() and not m[:, 4:].any()


@pytest.mark.parametrize("lower", [True, False])
def test_tri_mask_matches_numpy(lower):
    m = np.asarray(tri_mask((8, 8), 0, 1, lower=lower))
    want = np.tril(np.ones((8, 8), bool)) if lower \
        else np.triu(np.ones((8, 8), bool))
    assert (m == want).all()


def test_tri_mask_row_offset():
    """row_offset shifts the diagonal — the per-tile view of a global
    triangular domain (tile r starts at global row r*bm)."""
    m = np.asarray(tri_mask((4, 8), 0, 1, row_offset=4))
    for r in range(4):
        for c in range(8):
            assert m[r, c] == (c <= r + 4)


def test_masked_fill():
    x = jnp.ones((4, 4))
    out = np.asarray(masked_fill(x, tri_mask((4, 4), 0, 1), fill=-1.0))
    assert out[0, 0] == 1 and out[0, 1] == -1


# ---------------- utilization model (paper Fig. 2c,d) ----------------

def test_vector_utilization_full():
    assert vector_utilization([8, 8, 8], 8) == 1.0


def test_vector_utilization_triangular():
    """n=4 triangle at width 4: trips 4,3,2,1 -> 10 useful / 16 issued."""
    assert vector_utilization([4, 3, 2, 1], 4) == pytest.approx(10 / 16)


def _check_utilization_matches_bruteforce(n, w):
    tri = inductive(outer_trip=n, inner_base=n, inner_stretch=-1)
    trips = tri.trip_counts()
    got = vector_utilization(trips, w)
    useful = sum(trips)
    issued = sum(-(-t // w) * w for t in trips)
    assert got == pytest.approx(useful / issued if issued else 1.0)
    assert 0.0 < got <= 1.0


def _check_masking_beats_padding_scalarization(n, w):
    """Masked execution issues ceil(t/w)*w lanes; scalar fallback issues
    t*w lane-slots (1 useful lane per issue).  Masking is never worse."""
    tri = inductive(outer_trip=n, inner_base=n, inner_stretch=-1)
    trips = tri.trip_counts()
    masked_issued = sum(-(-t // w) * w for t in trips)
    scalar_issued = sum(t * w for t in trips)
    assert masked_issued <= scalar_issued


@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 13, 21, 32])
@pytest.mark.parametrize("w", [2, 4, 8, 16])
def test_utilization_matches_bruteforce(n, w):
    _check_utilization_matches_bruteforce(n, w)


@pytest.mark.parametrize("n", [1, 2, 5, 9, 16])
@pytest.mark.parametrize("w", [4, 8])
def test_masking_beats_padding_scalarization(n, w):
    _check_masking_beats_padding_scalarization(n, w)


@fuzzed(max_examples=60, n=integers(1, 32), w=sampled(2, 4, 8, 16))
def test_utilization_matches_bruteforce_fuzzed(n, w):
    _check_utilization_matches_bruteforce(n, w)


@fuzzed(max_examples=40, n=integers(1, 16), w=sampled(4, 8))
def test_masking_beats_padding_scalarization_fuzzed(n, w):
    _check_masking_beats_padding_scalarization(n, w)
