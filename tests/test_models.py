"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.optim.optimizer import OptConfig, init_opt_state
from repro.train.trainer import make_train_step

B, S = 2, 32


def make_batch(cfg: ArchConfig, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_prefix, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.frontend == "audio":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((b, 16, cfg.d_model)) * 0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    p = T.init_params(jax.random.key(0), cfg)
    loss = jax.jit(lambda p, b: T.lm_loss(p, cfg, b))(p, make_batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # random-init loss should be ~ log(vocab)
    assert float(loss) < np.log(cfg.vocab) + 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    p = T.init_params(jax.random.key(0), cfg)
    opt = init_opt_state(p)
    step = jax.jit(make_train_step(cfg, OptConfig(warmup=1, lr=1e-3)))
    batch = make_batch(cfg)
    p2, opt2, metrics = step(p, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert int(opt2["step"]) == 1
    # params must actually move
    moved = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b_: (a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)), p, p2), 0.0)
    assert moved > 0.0
    # pytree structure preserved (donation / checkpoint contract)
    assert jax.tree.structure(p) == jax.tree.structure(p2)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_shape(arch):
    cfg = get_smoke(arch)
    p = T.init_params(jax.random.key(0), cfg)
    logits = jax.jit(lambda p, b: T.prefill(p, cfg, b))(p, make_batch(cfg))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    p = T.init_params(jax.random.key(0), cfg)
    cache = D.init_cache(cfg, B, max_len=S, src_len=16)
    if cfg.family == "audio":
        cache = D.warm_cache_audio(
            p, cfg, cache, make_batch(cfg)["src_embeds"])
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t, q: D.decode_step(p, cfg, c, t, q))(p, cache, toks,
                                                           pos)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
    # cache shapes stable across steps (jit cache reuse contract)
    for a, b_ in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.shape == b_.shape and a.dtype == b_.dtype


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "zamba2-2.7b",
                                  "xlstm-125m", "seamless-m4t-large-v2"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode must reproduce the parallel (train-path)
    forward — the KV cache / state recurrence is exact, not approximate."""
    cfg = get_smoke(arch)
    p = T.init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(3)
    s = 8
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, s)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.frontend == "audio":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)) * 0.02, jnp.float32)
    want = T.prefill(p, cfg, batch)                      # (B, V)

    cache = D.init_cache(cfg, B, max_len=s, src_len=16)
    if cfg.family == "audio":
        cache = D.warm_cache_audio(p, cfg, cache, batch["src_embeds"])
    step = jax.jit(lambda p, c, t, q: D.decode_step(p, cfg, c, t, q))
    logits = None
    for j in range(s):
        logits, cache = step(p, cache, toks[:, j:j + 1],
                             jnp.full((B,), j, jnp.int32))
    got, want = np.asarray(logits), np.asarray(want)
    err = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 5e-2, f"decode diverges from prefill: rel err {err:.3e}"
    # the *ranking* must agree (greedy decode equivalence)
    assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.5


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    }
    for arch, (L, d, H, kv, dff, vocab) in spec.items():
        cfg = get_config(arch)
        if arch == "seamless-m4t-large-v2":
            # 24L interpreted as 24 enc + 24 dec (DESIGN.md assumption)
            assert cfg.enc_layers == 24 and cfg.dec_layers == 24
        else:
            assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv == kv, arch
        assert cfg.vocab == vocab, arch
        if cfg.family == "moe":
            assert cfg.moe.d_ff_expert == dff, arch
        elif arch != "xlstm-125m":
            assert cfg.d_ff == dff, arch
    # MoE structure
    assert get_config("dbrx-132b").moe.n_experts == 16
    assert get_config("dbrx-132b").moe.top_k == 4
    assert get_config("qwen2-moe-a2.7b").moe.n_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe.top_k == 4
    assert get_config("qwen2-moe-a2.7b").moe.n_shared == 4
    assert get_config("qwen3-14b").qk_norm
    assert get_config("nemotron-4-15b").act == "sq_relu"
    assert get_config("zamba2-2.7b").ssm.state == 64
    assert get_config("seamless-m4t-large-v2").is_encdec


def test_param_counts_in_range():
    """Analytic parameter counts should land near the advertised sizes."""
    expect = {
        "phi3-medium-14b": (12e9, 16e9),
        "qwen3-14b": (13e9, 16.5e9),
        "nemotron-4-15b": (14e9, 17e9),
        "phi4-mini-3.8b": (3.2e9, 4.6e9),
        "zamba2-2.7b": (2.2e9, 3.3e9),
        "xlstm-125m": (1.0e8, 1.7e8),
        "dbrx-132b": (1.1e11, 1.45e11),
        "internvl2-76b": (6.5e10, 8.5e10),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
    # MoE active < total
    for arch in ("dbrx-132b", "qwen2-moe-a2.7b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_loss_decreases_tiny_lm():
    """A few steps on the synthetic markov stream must reduce loss —
    end-to-end learning sanity for the train path."""
    cfg = get_smoke("phi4-mini-3.8b")
    from repro.data.pipeline import DataConfig, TokenPipeline
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=8, seed=0))
    p = T.init_params(jax.random.key(0), cfg)
    opt = init_opt_state(p)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-2, warmup=5)))
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        p, opt, m = step(p, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::3]
