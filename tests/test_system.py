"""System-level behaviour: shape-cell policy, abstract specs, and a
subprocess SPMD lower+compile on a small placeholder mesh (the same code
path the 256/512-chip dry-run uses, scaled down to stay fast)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import shapes as shp

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_shape_cells_cover_assignment():
    assert set(shp.SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                               "long_500k"}
    assert shp.SHAPES["train_4k"] == dict(kind="train", seq=4096,
                                          batch=256)
    assert shp.SHAPES["long_500k"] == dict(kind="decode", seq=524288,
                                           batch=1)


def test_long_context_applicability():
    """long_500k only for sub-quadratic archs (DESIGN.md §Arch-applic.)."""
    runs = {a for a in ARCHS
            if shp.cell_applicable(get_config(a), "long_500k")[0]}
    assert runs == {"zamba2-2.7b", "xlstm-125m"}
    # every other (arch, shape) cell is applicable
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shp.cell_applicable(get_config(a), s)[0]


def test_abstract_params_no_allocation():
    """ShapeDtypeStruct stand-ins: full 132B config stays abstract."""
    cfg = get_config("dbrx-132b")
    p = shp.abstract_params(cfg)
    leaves = jax.tree.leaves(p)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    assert total > 1e11          # it really is the 132B model


def test_batch_specs_families():
    cfg = get_config("internvl2-76b")
    b = shp.batch_specs(cfg, 4096, 256, labels=True)
    assert b["tokens"].shape == (256, 4096)
    assert "vision_embeds" in b
    cfg = get_config("seamless-m4t-large-v2")
    b = shp.batch_specs(cfg, 32768, 32, labels=False)
    assert "src_embeds" in b and "labels" not in b


def test_abstract_cache_decode_shapes():
    cfg = get_config("qwen3-14b")
    c = shp.abstract_cache(cfg, 128, 32768)
    assert c["k"].shape == (40, 128, 32768, 8, 128)
    cfg = get_config("xlstm-125m")
    c = shp.abstract_cache(cfg, 1, 524288)
    # O(1) state: no sequence-length dimension anywhere
    assert all(524288 not in l.shape for l in jax.tree.leaves(c))


@pytest.mark.slow
def test_spmd_lower_compile_small_mesh():
    """The production sharding rules compile under SPMD on an 8-device
    placeholder mesh (subprocess so the 1-device test session is safe)."""
    prog = textwrap.dedent("""
        from repro.launch.xla_env import force_host_device_count
        force_host_device_count(8)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_smoke
        from repro.distributed import sharding as shd
        from repro.launch import shapes as shp
        from repro.optim.optimizer import OptConfig
        from repro.train.trainer import make_train_step

        cfg = get_smoke("qwen3-14b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.axis_rules(mesh):
            p_abs = shp.abstract_params(cfg)
            import jax.tree_util as jtu
            p_sh = jtu.tree_map_with_path(
                lambda path, l: shd.named_safe(
                    shd.param_spec(tuple(getattr(k, "key", str(k))
                                         for k in path), l.shape), l.shape),
                p_abs)
            b_abs = shp.batch_specs(cfg, 64, 8, labels=True)
            b_sh = jax.tree.map(
                lambda l: shd.named_safe(
                    P("data", *([None] * (len(l.shape) - 1))), l.shape),
                b_abs)
            opt_abs = {"m": p_abs, "v": p_abs,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)}
            opt_sh = {"m": p_sh, "v": p_sh, "step": shd.named(P())}
            fn = make_train_step(cfg, OptConfig())
            comp = jax.jit(fn, in_shardings=(p_sh, opt_sh, b_sh),
                           out_shardings=(p_sh, opt_sh, None)) \\
                .lower(p_abs, opt_abs, b_abs).compile()
            m = comp.memory_analysis()
            print("OK", m.temp_size_in_bytes >= 0)
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
