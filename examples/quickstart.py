"""Quickstart: the FGOP abstractions in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# 1. Stream descriptors — the paper's R/RR/RI IR --------------------------
from repro.core.streams import (command_count, commands_per_iteration,
                                inductive, rect)

n = 16
tri = inductive(outer_trip=n, inner_base=n, inner_stretch=-1)
print(f"triangular stream, n={n}: capability={tri.capability}, "
      f"{tri.length()} iterations")
for cap in ("V", "RR", "RI"):
    print(f"  {cap:3s}: {command_count(tri, cap):4d} control commands "
          f"({commands_per_iteration(tri, cap):.3f} / iteration)")

# 2. Implicit vector masking ----------------------------------------------
from repro.core.masking import tri_mask, vector_utilization

print(f"\nvector utilization of the triangle at width 8: "
      f"{vector_utilization(tri.trip_counts(), 8):.1%} "
      f"(no scalar leftover iterations — masked, per paper Fig. 2)")

# 3. A Pallas kernel with an inductive (RI) iteration domain --------------
from repro.kernels.cholesky import cholesky_pallas

rng = np.random.default_rng(0)
a = rng.standard_normal((2, n, n)).astype(np.float32)
spd = a @ a.swapaxes(-1, -2) + n * np.eye(n, dtype=np.float32)
l = cholesky_pallas(spd, interpret=True)   # interpret=True: CPU validation
err = np.abs(np.asarray(l) @ np.asarray(l).swapaxes(-1, -2) - spd).max()
print(f"\ncholesky_pallas: |LL^T - A|_max = {err:.2e}")

# 4. An LM architecture with the FGOP kernels integrated ------------------
from repro.configs import get_smoke
from repro.models import transformer as T

cfg = get_smoke("qwen3-14b")
params = T.init_params(jax.random.key(0), cfg)
batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
         "labels": jnp.zeros((2, 32), jnp.int32)}
loss = jax.jit(lambda p, b: T.lm_loss(p, cfg, b))(params, batch)
print(f"\n{cfg.name}: one forward, loss={float(loss):.4f} "
      f"(~ln(vocab)={np.log(cfg.vocab):.4f})")
print("done.")
