"""End-to-end training driver: train a ~100M-parameter LM for a few
hundred steps with the full production stack — data pipeline, AdamW,
checkpoint/restore, straggler monitoring, retry-on-failure.

Default: the assigned xlstm-125m architecture (125M params) on the
synthetic token stream.  On this CPU container use a shorter sequence:

  PYTHONPATH=src python examples/train_lm.py \
      --arch xlstm-125m --steps 300 --seq 256 --batch 8

On a TPU pod the same driver runs the full config under pjit: pass
--mesh to shard (see repro/launch/dryrun.py for the production meshes).
Interrupting and re-running resumes from the last checkpoint.
"""
import argparse
import dataclasses
import time

from repro.configs import get_config, get_smoke
from repro.models.config import ArchConfig

# ~102M-parameter dense LM (CPU-trainable end-to-end driver config):
# 2*50304*512 embeds + 12 * (4*512^2 attn + 3*512*2048 ffn)
LM100M = ArchConfig(
    name="lm-100m", family="dense",
    n_layers=12, d_model=512, n_heads=8, n_kv=8, d_head=64,
    d_ff=2048, vocab=50304, act="swiglu", remat="none",
    compute_dtype="float32",
)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CI-speed)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.arch == "lm-100m":
        cfg = LM100M
    else:
        cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    n_params = cfg.param_count()
    print(f"arch={cfg.name}  params~{n_params / 1e6:.1f}M  "
          f"steps={args.steps}  tokens/step={args.batch * args.seq}")

    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=0, n_prefix=cfg.n_prefix if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model,
        src_len=64 if cfg.frontend == "audio" else 0))

    tc = TrainConfig(
        steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        keep=3, log_every=10,
        opt=OptConfig(lr=args.lr, warmup=min(50, args.steps // 5),
                      total_steps=args.steps))

    t0 = time.time()
    trainer = Trainer(cfg, tc, pipe)
    if trainer.start_step:
        print(f"resumed from checkpoint at step {trainer.start_step}")
    result = trainer.run()
    dt = time.time() - t0

    losses = result["losses"]
    if losses:
        k = max(1, len(losses) // 10)
        first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
        print(f"\nloss {first:.4f} -> {last:.4f} "
              f"({len(losses)} steps, {dt / max(len(losses), 1):.2f}s/step)")
        print(f"stragglers flagged: {result['stragglers']}")
        assert last < first, "loss did not decrease"
    print("done; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
