"""MMSE equalizer demo — the paper's 5G motivation served end to end.

A batch of per-subcarrier complex MIMO channels is equalized with the
FUSED mmse_equalize pipeline (GEMM + Cholesky + two substitutions in one
kernel launch per lane), via the real expansion [[Re,-Im],[Im,Re]], and
again via the split re/im fast path (``mmse_equalize_split`` — same
answer at ~0.4x the GEMM flops).  The same traffic is then pushed
through serve.PipelineEngine the way a baseband service would: jobs in,
lane-pooled grid launches, jobs out — split-plane jobs transparently
dispatch to the ``split_complex`` registry variant.

Run:  PYTHONPATH=src python examples/mmse_equalizer.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.pipelines import (expand_complex_channel, mmse_equalize,
                             mmse_equalize_split)
from repro.serve import PipelineEngine, SolveJob

ANTENNAS = 16        # receive antennas (paper sizes 12..32)
STREAMS = 12         # spatial streams
SUBCARRIERS = 24     # one pallas lane per subcarrier
SNR_DB = 10.0


def main():
    rng = np.random.default_rng(0)
    sigma2 = 10 ** (-SNR_DB / 10)
    print(f"MMSE equalizer: {ANTENNAS}x{STREAMS} MIMO, "
          f"{SUBCARRIERS} subcarriers, SNR {SNR_DB:.0f} dB")

    # per-subcarrier complex channel + transmitted symbols
    hr = rng.standard_normal((SUBCARRIERS, ANTENNAS, STREAMS)) \
        .astype(np.float32)
    hi = rng.standard_normal((SUBCARRIERS, ANTENNAS, STREAMS)) \
        .astype(np.float32)
    xr = rng.standard_normal((SUBCARRIERS, STREAMS, 1)).astype(np.float32)
    xi = rng.standard_normal((SUBCARRIERS, STREAMS, 1)).astype(np.float32)

    # y = H x + noise (complex, expanded to real)
    yr = hr @ xr - hi @ xi + np.sqrt(sigma2) * rng.standard_normal(
        (SUBCARRIERS, ANTENNAS, 1)).astype(np.float32)
    yi = hr @ xi + hi @ xr + np.sqrt(sigma2) * rng.standard_normal(
        (SUBCARRIERS, ANTENNAS, 1)).astype(np.float32)

    h, y = expand_complex_channel(jnp.asarray(hr), jnp.asarray(hi),
                                  jnp.asarray(yr), jnp.asarray(yi))

    t0 = time.perf_counter()
    xhat = mmse_equalize(h, y, sigma2=sigma2)
    jax.block_until_ready(xhat)
    dt = time.perf_counter() - t0
    xhat = np.asarray(xhat)
    xhat_r, xhat_i = xhat[:, :STREAMS], xhat[:, STREAMS:]
    nmse = ((np.linalg.norm(xhat_r - xr) ** 2
             + np.linalg.norm(xhat_i - xi) ** 2)
            / (np.linalg.norm(xr) ** 2 + np.linalg.norm(xi) ** 2))
    print(f"  direct call: {SUBCARRIERS} subcarriers in "
          f"{dt * 1e3:.2f} ms (incl. compile), NMSE={nmse:.3e}")

    # --- the split re/im fast path: same answer, ~0.4x the GEMM flops ---
    t0 = time.perf_counter()
    xsplit = mmse_equalize_split(jnp.asarray(hr), jnp.asarray(hi),
                                 jnp.asarray(yr), jnp.asarray(yi),
                                 sigma2=sigma2)
    jax.block_until_ready(xsplit)
    dt = time.perf_counter() - t0
    print(f"  split-complex path: {dt * 1e3:.2f} ms (incl. compile), "
          f"max |expansion - split| = "
          f"{np.abs(np.asarray(xsplit) - xhat).max():.2e}")

    # --- the same traffic through the serving engine ---
    eng = PipelineEngine("mmse_equalize", lanes=8, sigma2=sigma2)
    jobs = [eng.submit(SolveJob(args=(np.asarray(h[i]), np.asarray(y[i]))))
            for i in range(SUBCARRIERS)]
    # split-plane jobs ride the SAME pipeline; the registry dispatcher
    # routes their 4-arg shape bucket to the split_complex variant
    split_jobs = [eng.submit(SolveJob(args=(hr[i], hi[i], yr[i], yi[i])))
                  for i in range(SUBCARRIERS)]
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    served = np.stack([j.out for j in jobs])
    served_split = np.stack([j.out for j in split_jobs])
    counts = eng.metrics()["mmse_equalize"].dispatch_counts
    print(f"  PipelineEngine: {len(jobs) + len(split_jobs)} jobs in "
          f"{dt * 1e3:.2f} ms, dispatch={counts}, "
          f"max |direct - served| = {np.abs(served - xhat).max():.2e}, "
          f"max |split - served| = "
          f"{np.abs(served_split - np.asarray(xsplit)).max():.2e}")
    print("equalizer OK.")


if __name__ == "__main__":
    main()
