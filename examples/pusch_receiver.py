"""End-to-end 5G PUSCH receiver served as a pipeline DAG.

The flagship ordered-parallelism scenario: the uplink receive chain

  FFT demod -> channel estimate (pilots) -> MMSE Gram/equalize

is registered as the ``pusch_receive`` DAG (``repro.kernels``), whose
stages the ``SolverMux`` schedules as ordinary lane-pool jobs with the
producer->consumer edges enforced by the DAG frontier: a stage is
submitted the moment every stage it consumes has produced its output
buffer.  Criticality ordering comes from ``core/criticality.plan_split``
over the stages' modeled FLOPs — at equal deadline the critical channel
estimate is admitted ahead of slack stages regardless of arrival order.

Two execution shapes of the same DAG:

* **staged** — three launches with stage-output buffer handoffs;
* **chained** — the channel-estimate -> equalize tail fused
  lane-resident in one ``pallas_call`` (VMEM handoff, one scheduling
  round trip saved), declared via ``DagSpec.chained``.

Also runs the non-wireless ``svd_solve`` DAG (SVD factor -> apply) to
show the same machinery on a generic multi-stage workload, and replays
the committed mid-DAG fault trace to show a failing stage retrying
through launch supervision without orphaning its downstream stages.

Run:  PYTHONPATH=src python examples/pusch_receiver.py
"""
import pathlib

import numpy as np

from repro import kernels as K
from repro.launch.xla_env import force_host_device_count

force_host_device_count(8)

from repro.launch.serve_solvers import run_pusch  # noqa: E402
from repro.serve import CostModel, ManualClock, OverloadPolicy, \
    SolverMux  # noqa: E402

FAULT_TRACE = (pathlib.Path(__file__).parent.parent
               / "tests" / "data" / "pusch_fault_trace.json")


def one_dag_walkthrough():
    """Submit a single PUSCH DAG and narrate its stage schedule."""
    spec = K.get_dag("pusch_receive")
    print(f"DAG {spec.name}: stages "
          f"{[s.name for s in spec.stage_list()]}")
    args = spec.make_case(np.random.default_rng(0), 8)
    crit, slack = spec.criticality(tuple(np.shape(a) for a in args))
    print(f"  criticality (plan_split @ {spec.crit_threshold}): "
          f"critical={crit} slack={slack}")

    clock = ManualClock()
    mux = SolverMux(lanes=4, max_wait=0.0, clock=clock,
                    policy=OverloadPolicy(budget=None,
                                          cost_model=CostModel()))
    dag = mux.submit_dag("pusch_receive", *args, priority="hard",
                         deadline=clock() + 8.0)
    while dag.state in ("queued", "running"):
        mux.poll()
        clock.advance(1.0)
    mux.run()
    print(f"  -> {dag.state} in {dag.finished_at - dag.submitted_at:.0f} "
          f"virtual ticks")
    for e in mux.drain_events():
        if e["event"].startswith("dag"):
            extra = e.get("stage") or e.get("latency") or ""
            print(f"     t={e['t']:>4} {e['event']:<10} {extra}")
    # the served end-to-end output equals the composed reference chain
    want = spec.oracle(*args)
    err = np.max(np.abs(np.asarray(dag.out) - want)) \
        / (np.max(np.abs(want)) + 1e-12)
    print(f"  e2e rel err vs composed oracle: {err:.2e}")


def main():
    one_dag_walkthrough()

    print("\ncanonical trace, stage-independent vs stage-chained:")
    staged = run_pusch(False, ticks=4)
    chained = run_pusch(True, ticks=4)
    for s in (staged, chained):
        mode = "chained" if s["chained"] else "staged"
        print(f"  [{mode}] dags={s['dags']} done={s['done']} "
              f"e2e p50={s['e2e_p50']:.1f} ticks "
              f"launches={s['launches']}")
    print(f"  stage-chained speedup: "
          f"{staged['e2e_p50'] / chained['e2e_p50']:.2f}x e2e p50")

    print("\nmid-DAG stage fault (channel estimate raises twice):")
    faulted = run_pusch(False, ticks=4, fault_trace=str(FAULT_TRACE))
    print(f"  retries={faulted['retries']} done={faulted['done']}/"
          f"{faulted['dags']} hard_lost={faulted['hard_lost']}")


if __name__ == "__main__":
    main()
