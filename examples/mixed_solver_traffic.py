"""Mixed solver traffic through the registry-driven SolverMux.

Submits an interleaved stream of cholesky_solve, qr_solve, and
mmse_equalize jobs at two problem sizes each — the PUSCH-style mix the
ROADMAP's serve-multiplexing item describes — and shows the three layers
of the mux at work: per-pipeline routing via the kernel registry, shape
bucketing inside each lane pool, and deadline-aware continuous batching
(full lane groups dispatch on poll; stragglers flush when their deadline
or age expires).  Results are checked against the registry oracles and
the per-pipeline SLO metrics printed.

  PYTHONPATH=src python examples/mixed_solver_traffic.py
"""
import argparse

import numpy as np

from repro import kernels as K
from repro.kernels.common import sample_spd
from repro.serve import ManualClock, SolverMux


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--jobs", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    clock = ManualClock()
    mux = SolverMux(lanes=args.lanes, max_wait=2e-3, clock=clock)

    def make(pipeline, n):
        m = n + 4
        if pipeline == "cholesky_solve":
            return (sample_spd(rng, 1, n)[0],
                    rng.standard_normal((n, 2)).astype(np.float32))
        return (rng.standard_normal((m, n)).astype(np.float32),
                rng.standard_normal((m, 2)).astype(np.float32))

    pipelines = K.names(kind="pipeline")
    sizes = (8, 12)
    print(f"pipelines from registry: {pipelines}; sizes {sizes}; "
          f"lanes={args.lanes}")

    # interleaved arrivals, 1 job / 0.25 ms, deadline 1.5 ms after arrival
    jobs = []
    for i in range(args.jobs):
        pipeline = pipelines[i % len(pipelines)]
        n = sizes[(i // len(pipelines)) % len(sizes)]
        jobs.append(mux.submit(pipeline, *make(pipeline, n),
                               deadline=clock() + 1.5e-3))
        done = mux.poll()              # full lane groups dispatch here
        if done:
            print(f"  t={clock() * 1e3:5.2f}ms poll dispatched "
                  f"{len(done):2d} jobs ({mux.pending()} still queued)")
        clock.advance(0.25e-3)
    rest = mux.run()                   # drain stragglers (partial pads)
    print(f"  t={clock() * 1e3:5.2f}ms drain dispatched {len(rest)} jobs")

    # every job got its own oracle-checked answer
    for job in jobs:
        want = K.get(job.pipeline).run_oracle_lane(*job.args)
        err = (np.max(np.abs(job.out - want))
               / (np.max(np.abs(want)) + 1e-12))
        assert err < 1e-3, (job.pipeline, err)
    print(f"all {len(jobs)} results match registry oracles\n")

    snap = mux.metrics()
    print(f"{'pipeline':<16} {'jobs':>4} {'launches':>8} {'util':>6} "
          f"{'waste':>6} {'p50_ms':>7} {'p99_ms':>7}")
    for name, st in sorted(snap.pipelines.items()):
        print(f"{name:<16} {st.jobs:>4} {st.launches:>8} "
              f"{st.lane_utilization:>6.2f} {st.padded_lane_waste:>6.2f} "
              f"{st.latency.p50 * 1e3:>7.3f} {st.latency.p99 * 1e3:>7.3f}")
    print(f"\n{snap.total_jobs} jobs in {snap.total_launches} grid "
          f"launches (batching: {snap.total_jobs / snap.total_launches:.1f} "
          f"jobs/launch)")


if __name__ == "__main__":
    main()
