"""Mixed solver traffic through the registry-driven SolverMux.

Submits an interleaved stream of cholesky_solve, qr_solve, and
mmse_equalize jobs at two problem sizes each — the PUSCH-style mix the
ROADMAP's serve-multiplexing item describes — and shows the layers of
the mux at work: per-pipeline routing via the kernel registry, shape
bucketing inside each lane pool, deadline-aware continuous batching
(full lane groups dispatch on poll; stragglers flush when their deadline
or age expires), and — with ``--policy`` — the overload policy: jobs
carry a priority class (every third job is a hard-deadline control-path
solve), expired best-effort work is shed, and small jobs coalesce into
larger buckets' free lanes.  Results are checked against the registry
oracles and the per-pipeline SLO metrics printed, including the
dropped/preempted/coalesced counters and per-priority p99.

With ``--adapt`` the cost model's online calibration loop runs too:
every launch is measured, sec/FLOP and launch overhead re-fit, and the
per-variant predicted/measured drift printed at the end.

With ``--mesh N`` the mux pools lanes over N local devices (on CPU the
script forces 8 virtual devices): full lane groups place on the
least-loaded shard, hot buckets flush as one mesh-spanning shard_map
launch, and the per-shard utilization / imbalance metrics print at the
end.

  PYTHONPATH=src python examples/mixed_solver_traffic.py --policy --adapt
  PYTHONPATH=src python examples/mixed_solver_traffic.py --policy --mesh 4
"""
import argparse

from repro.launch.xla_env import force_host_device_count

force_host_device_count(8)

import numpy as np

from repro import kernels as K
from repro.kernels.common import sample_spd
from repro.serve import CostModel, ManualClock, OverloadPolicy, SolverMux


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--jobs", type=int, default=30)
    ap.add_argument("--policy", action="store_true",
                    help="enable overload policy (shed / preempt / "
                         "coalesce)")
    ap.add_argument("--adapt", action="store_true",
                    help="close the cost-model calibration loop and "
                         "print drift metrics")
    ap.add_argument("--mesh", type=int, default=1,
                    help="shard the lane pools over this many local "
                         "devices (mesh-spanning flushes + cross-shard "
                         "balancing)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    clock = ManualClock()
    policy, cost_model = None, None
    if args.policy and args.adapt:
        policy = OverloadPolicy(cost_model=CostModel(adaptive=True))
    elif args.policy:
        policy = OverloadPolicy()
    elif args.adapt:
        cost_model = CostModel(adaptive=True)
    mux = SolverMux(lanes=args.lanes, max_wait=2e-3, clock=clock,
                    policy=policy, cost_model=cost_model,
                    mesh_size=args.mesh)

    def make(pipeline, n):
        m = n + 4
        if pipeline == "cholesky_solve":
            return (sample_spd(rng, 1, n)[0],
                    rng.standard_normal((n, 2)).astype(np.float32))
        return (rng.standard_normal((m, n)).astype(np.float32),
                rng.standard_normal((m, 2)).astype(np.float32))

    pipelines = K.names(kind="pipeline")
    sizes = (8, 12)
    print(f"pipelines from registry: {pipelines}; sizes {sizes}; "
          f"lanes={args.lanes}; policy={'on' if policy else 'off'}")

    # interleaved arrivals, 1 job / 0.25 ms, deadline 1.5 ms after
    # arrival; every third job is hard-deadline control-path traffic
    jobs = []
    for i in range(args.jobs):
        pipeline = pipelines[i % len(pipelines)]
        n = sizes[(i // len(pipelines)) % len(sizes)]
        priority = "hard" if i % 3 == 0 else "best_effort"
        jobs.append(mux.submit(pipeline, *make(pipeline, n),
                               deadline=clock() + 1.5e-3,
                               priority=priority))
        done = mux.poll()              # full lane groups dispatch here
        if done:
            print(f"  t={clock() * 1e3:5.2f}ms poll dispatched "
                  f"{len(done):2d} jobs ({mux.pending()} still queued)")
        clock.advance(0.25e-3)
    rest = mux.run()                   # drain stragglers (partial pads)
    print(f"  t={clock() * 1e3:5.2f}ms drain dispatched {len(rest)} jobs")

    # every SERVED job got its own oracle-checked answer (under the
    # policy, expired best-effort jobs may have been shed instead)
    served = [j for j in jobs if j.state == "done"]
    dropped = [j for j in jobs if j.state == "dropped"]
    for job in served:
        want = K.get(job.pipeline).run_oracle_lane(*job.args)
        err = (np.max(np.abs(job.out - want))
               / (np.max(np.abs(want)) + 1e-12))
        assert err < 1e-3, (job.pipeline, err)
    assert all(j.priority != "hard" for j in dropped), \
        "hard jobs must never be shed"
    print(f"all {len(served)} served results match registry oracles "
          f"({len(dropped)} best-effort shed)\n")

    snap = mux.metrics()
    print(f"{'pipeline':<16} {'jobs':>4} {'launches':>8} {'util':>6} "
          f"{'waste':>6} {'p50_ms':>7} {'p99_ms':>7} {'drop':>5} "
          f"{'coal':>5}")
    for name, st in sorted(snap.pipelines.items()):
        print(f"{name:<16} {st.jobs:>4} {st.launches:>8} "
              f"{st.lane_utilization:>6.2f} {st.padded_lane_waste:>6.2f} "
              f"{st.latency.p50 * 1e3:>7.3f} {st.latency.p99 * 1e3:>7.3f} "
              f"{st.dropped:>5} {st.lanes_coalesced:>5}")
    print(f"\n{snap.total_jobs} jobs in {snap.total_launches} grid "
          f"launches (batching: {snap.total_jobs / snap.total_launches:.1f} "
          f"jobs/launch)")
    if policy is not None:
        print(f"policy: dropped={snap.total_dropped} "
              f"preempted={snap.total_preempted} "
              f"coalesced={snap.total_coalesced}")
    if snap.shards:
        print(f"\nmesh: {len(snap.shards)} lane shards, imbalance "
              f"{snap.shard_imbalance:.3f}"
              f"{'  ALERT' if snap.shard_imbalance_alert else ''}")
        for s, st in sorted(snap.shards.items()):
            print(f"  shard {s}: launches {st.launches:>3} "
                  f"lanes {st.lanes_dispatched:>4} "
                  f"util {st.utilization:>5.2f} load {st.load:.2e}")
    if snap.drift:
        print("\ncost-model drift (predicted/measured, EWMA ratio):")
        for key, st in sorted(snap.drift.items()):
            print(f"  {key:<30} ratio {st.ratio:>9.4f} "
                  f"updates {st.updates:>3} source {st.source}"
                  f"{'  ALERT' if st.alert else ''}")
        worst = snap.worst_drift
        if worst is not None:
            print(f"  worst offender: {worst.key} "
                  f"(ratio {worst.ratio:.4f})")


if __name__ == "__main__":
    main()
