"""Serving example: batched request decode through the DecodeEngine
(continuous-batching-lite: fixed slot pool, padded slots masked).

  PYTHONPATH=src python examples/serve_batched.py --arch phi4-mini-3.8b
"""
import argparse
import time

import jax

from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serve import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pool", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)   # reduced config: CPU-serveable
    params = T.init_params(jax.random.key(0), cfg)
    engine = DecodeEngine(cfg, params, batch=args.pool, max_len=128,
                          eos_id=1)

    prompts = [[2 + i, 7, 11, (13 * i) % cfg.vocab]
               for i in range(args.requests)]
    for p in prompts:
        engine.submit(Request(prompt=p, max_new=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"arch={cfg.name} pool={args.pool}")
    for i, r in enumerate(done):
        print(f"  req{i}: prompt={r.prompt} -> {r.out}")
    print(f"\n{len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
