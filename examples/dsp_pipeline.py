"""Paper-faithful example: a 4G/5G-style MIMO receiver equalization chain
built from the seven DSP kernels (paper Fig. 4).

  channel estimate -> Cholesky(H^H H + sigma I) -> triangular solve
  (LMMSE equalizer), plus FFT demodulation and FIR filtering — the
  exact kernel set the paper targets, on DSP-sized matrices (12..32).

Run:  PYTHONPATH=src python examples/dsp_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

ANTENNAS = 16      # matrix size n (paper: 12-32 antennas/beams)
SUBCARRIERS = 64   # FFT size
BATCH = 8          # OFDM symbols processed per call (lanes)


def make_channel(rng, b, n):
    hr = rng.standard_normal((b, n, n)).astype(np.float32)
    hi = rng.standard_normal((b, n, n)).astype(np.float32)
    return hr, hi


@jax.jit
def lmmse_equalize(hr, hi, yr, yi, sigma2=0.1):
    """LMMSE: x = (H^H H + s I)^-1 H^H y, via Cholesky + two trisolves.
    Complex arithmetic expanded to real (TPU has no complex MXU path)."""
    n = hr.shape[-1]
    # G = H^H H + sigma I  (hermitian -> real SPD in expanded form)
    gr = jnp.einsum("bij,bik->bjk", hr, hr) \
        + jnp.einsum("bij,bik->bjk", hi, hi) \
        + sigma2 * jnp.eye(n)
    gi = jnp.einsum("bij,bik->bjk", hr, hi) \
        - jnp.einsum("bij,bik->bjk", hi, hr)
    # expanded real SPD:  [[Gr, -Gi], [Gi, Gr]]
    g = jnp.concatenate([
        jnp.concatenate([gr, -gi], axis=-1),
        jnp.concatenate([gi, gr], axis=-1)], axis=-2)
    # rhs = H^H y, expanded
    br = jnp.einsum("bij,bi->bj", hr, yr) + jnp.einsum("bij,bi->bj", hi, yi)
    bi = jnp.einsum("bij,bi->bj", hr, yi) - jnp.einsum("bij,bi->bj", hi, yr)
    rhs = jnp.concatenate([br, bi], axis=-1)[..., None]
    # FGOP kernels: cholesky + forward/backward substitution
    l = ops.cholesky(g)
    z = ops.trisolve(l, rhs, lower=True)
    x = ops.trisolve(jnp.swapaxes(l, -1, -2), z, lower=False)[..., 0]
    return x[:, :n], x[:, n:]


@jax.jit
def ofdm_demod(sym_r, sym_i):
    """FFT demodulation of an OFDM symbol batch."""
    return ops.fft(sym_r, sym_i)


@jax.jit
def channel_filter(x, taps):
    return ops.fir(x, taps)


def main():
    rng = np.random.default_rng(0)
    print(f"MIMO LMMSE chain: {ANTENNAS} antennas, batch {BATCH}")

    # --- channel + signal ---
    hr, hi = make_channel(rng, BATCH, ANTENNAS)
    x_true_r = rng.standard_normal((BATCH, ANTENNAS)).astype(np.float32)
    x_true_i = rng.standard_normal((BATCH, ANTENNAS)).astype(np.float32)
    yr = np.einsum("bij,bj->bi", hr, x_true_r) \
        - np.einsum("bij,bj->bi", hi, x_true_i)
    yi = np.einsum("bij,bj->bi", hr, x_true_i) \
        + np.einsum("bij,bj->bi", hi, x_true_r)

    # --- equalize (Cholesky + solves: the FGOP kernels) ---
    t0 = time.perf_counter()
    xr, xi = lmmse_equalize(jnp.asarray(hr), jnp.asarray(hi),
                            jnp.asarray(yr), jnp.asarray(yi))
    jax.block_until_ready(xr)
    dt = time.perf_counter() - t0
    nmse = (np.linalg.norm(np.asarray(xr) - x_true_r) ** 2
            + np.linalg.norm(np.asarray(xi) - x_true_i) ** 2) \
        / (np.linalg.norm(x_true_r) ** 2 + np.linalg.norm(x_true_i) ** 2)
    print(f"  equalized {BATCH} symbols in {dt * 1e3:.2f} ms "
          f"(incl. compile), NMSE={nmse:.3e}")

    # --- OFDM demod (FFT kernel) ---
    sym = rng.standard_normal((BATCH, SUBCARRIERS)).astype(np.float32)
    fre, fim = ofdm_demod(jnp.asarray(sym), jnp.zeros_like(jnp.asarray(sym)))
    ref = np.fft.fft(sym, axis=-1)
    print(f"  FFT demod err: "
          f"{np.abs(np.asarray(fre) - ref.real).max():.2e}")

    # --- front-end FIR (centro-symmetric taps) ---
    taps = rng.standard_normal(31).astype(np.float32)
    taps = (taps + taps[::-1]) / 2
    sig = rng.standard_normal(2048).astype(np.float32)
    y = channel_filter(jnp.asarray(sig), jnp.asarray(taps))
    ref = np.convolve(sig, taps[::-1], mode="valid")
    print(f"  FIR err: {np.abs(np.asarray(y) - ref).max():.2e}")

    # --- SVD-based noise reduction (paper: SVD for noise suppression) ---
    a = rng.standard_normal((1, 16, 12)).astype(np.float32)
    u, s, v = ops.svd(jnp.asarray(a), backend="xla")
    want = np.linalg.svd(a[0], compute_uv=False)
    print(f"  SVD sigma err: "
          f"{np.abs(np.sort(np.asarray(s)[0])[::-1] - want).max():.2e}")
    print("pipeline OK.")


if __name__ == "__main__":
    main()
