"""Fused vs unfused solver pipelines — the composed-workload analog of the
paper's Fig. 19 mechanism stack.

Two axes are measured for cholesky_solve / qr_solve / mmse_equalize:

  pallas-fused    one pallas_call, everything VMEM-resident (interpret
                  mode off-TPU: the *relative* fused/unfused gap still
                  reflects the dispatch + memory-round-trip overhead)
  pallas-unfused  factor-then-solve via separate pallas_calls
  xla-fused       ONE jit program of the whole chain (XLA may fuse)
  xla-unfused     one jit + device round-trip PER stage — the
                  kernel-at-a-time dispatch baseline

plus a registry sweep: every registered kernel/pipeline timed through its
uniform ``run_pallas`` adapter at its smallest size, with the stream
capability (paper F2-F4 classification) emitted in the derived column —
the registry, not a hand-maintained import list, enumerates the kernels.

``run_variants()`` (entry ``variants`` in benchmarks.run) is the
dispatch-driven sweep: every registered pipeline variant (base, blocked,
split_complex) is exercised THROUGH ``KernelSpec.dispatch`` at its
declared sizes, recording wall-clock, model FLOPs, and dispatch counts —
the data persisted to ``BENCH_pipelines.json`` via ``run.py --json-out``.

``run_slo()`` (wired separately in benchmarks.run) measures the serving
layer: a mixed cholesky/qr/mmse trace (including split-complex MMSE
jobs) through the SolverMux, emitting per-pipeline p50/p99 latency,
throughput, lane utilization, padded-lane waste, and per-variant
dispatch counts — the SLO surface of the multiplexed lane pools.  It
ends with the OVERLOAD sweep: the deterministic 2x-capacity mixed-
priority trace from ``repro.launch.serve_solvers.run_overload`` run
with the overload policy on and off at the same lane-time budget,
emitting hard-deadline SLO attainment plus the dropped / preempted /
coalesced counters (rows required by ``check_bench_json``), and the
DRIFT sweep: the same trace with the cost model's online calibration
loop closed, persisting per-variant predicted/measured drift ratios and
calibration-update counts (``serve_slo/drift/*`` rows, also required by
``check_bench_json``), and the SHARDED sweep: the overload trace
replayed on a fixed virtual window against mesh-sharded muxes (mesh
sizes 1/2/4/8 on virtual CPU devices), persisting aggregate throughput
scaling, per-shard utilization, and the per-mesh launch calibration
rows (``serve_slo/sharded/*``, also gated by ``check_bench_json``:
mesh=4 throughput must strictly beat mesh=1), and the FAULTS sweep:
the committed chaos fault trace (launch failures + NaN lanes + a
blackholed shard) replayed at mesh=4 via
``repro.launch.serve_solvers.run_chaos`` against the fault-free
reference, persisting hard-SLO attainment under faults, the zero
silent-loss count, and the quarantine/reinstatement/demotion
observables (``serve_slo/faults/*``, gated by ``check_bench_json``:
hard_lost must be 0 and the attainment ratio at least 0.8), and the
DECODE sweep: continuous-batching LM decode measured two ways — a
warmed real-clock microbenchmark for per-phase (insert / prefill /
generate) latency plus the per-step calibration rows
``CostModel.from_bench_json`` fits decode rates from, and the committed
mixed solver+decode trace replayed continuous vs lockstep at equal
budget on the virtual clock (``serve_slo/decode/*``, gated by
``check_bench_json``: continuous tokens/step must strictly beat the
lockstep baseline and hard_lost must be 0).
"""
from __future__ import annotations

import math
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, emit_decode, emit_sharded,
                               emit_variant, header, timeit)
from repro import kernels as K
from repro import pipelines as pp
from repro.kernels import ref
from repro.kernels.common import sample_spd as _spd

LANES = 8
SIZES = (8, 16, 32)          # >= 3 matrix sizes (paper's 12..32 range)
RHS = 4


# ---- xla-unfused: one jit + host sync per stage (dispatch baseline) ----

_chol = jax.jit(jnp.linalg.cholesky)
_fwd = jax.jit(jax.vmap(partial(jax.scipy.linalg.solve_triangular,
                                lower=True)))
_bwd = jax.jit(jax.vmap(partial(jax.scipy.linalg.solve_triangular,
                                lower=False)))


def chol_solve_xla_unfused(a, b):
    l = jax.block_until_ready(_chol(a))
    z = jax.block_until_ready(_fwd(l, b))
    return _bwd(jnp.swapaxes(l, -1, -2), z)


_gram = jax.jit(lambda h, s: jnp.einsum("bmi,bmj->bij", h, h)
                + s * jnp.eye(h.shape[-1], dtype=h.dtype))
_mf = jax.jit(lambda h, y: jnp.einsum("bmn,bmk->bnk", h, y))


def mmse_xla_unfused(h, y, sigma2=0.1):
    g = jax.block_until_ready(_gram(h, sigma2))
    rhs = jax.block_until_ready(_mf(h, y))
    return chol_solve_xla_unfused(g, rhs)


def run() -> None:
    rng = np.random.default_rng(0)

    for n in SIZES:
        header(f"pipelines: cholesky_solve n={n} lanes={LANES}")
        a = jnp.asarray(_spd(rng, LANES, n))
        b = jnp.asarray(rng.standard_normal((LANES, n, RHS))
                        .astype(np.float32))
        want = np.asarray(ref.cholesky_solve(a, b))
        got = np.asarray(pp.cholesky_solve_pallas(a, b))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-4)

        t_fused = timeit(pp.cholesky_solve_pallas, a, b, reps=3, warmup=1)
        t_unf = timeit(pp.cholesky_solve_unfused, a, b, reps=3, warmup=1)
        emit(f"pipelines/chol_solve{n}/pallas-unfused", t_unf, "1.0x")
        emit(f"pipelines/chol_solve{n}/pallas-fused", t_fused,
             f"{t_unf / t_fused:.2f}x")
        t_xf = timeit(partial(pp.cholesky_solve, backend="xla"), a, b)
        t_xu = timeit(chol_solve_xla_unfused, a, b)
        emit(f"pipelines/chol_solve{n}/xla-unfused", t_xu, "1.0x")
        emit(f"pipelines/chol_solve{n}/xla-fused", t_xf,
             f"{t_xu / t_xf:.2f}x")

    for n in SIZES:
        header(f"pipelines: qr_solve m={n + 4} n={n}")
        a = jnp.asarray(rng.standard_normal((LANES, n + 4, n))
                        .astype(np.float32))
        b = jnp.asarray(rng.standard_normal((LANES, n + 4, RHS))
                        .astype(np.float32))
        t_fused = timeit(pp.qr_solve_pallas, a, b, reps=3, warmup=1)
        t_unf = timeit(pp.qr_solve_unfused, a, b, reps=3, warmup=1)
        emit(f"pipelines/qr_solve{n}/pallas-unfused", t_unf, "1.0x")
        emit(f"pipelines/qr_solve{n}/pallas-fused", t_fused,
             f"{t_unf / t_fused:.2f}x")

    for n in SIZES:
        header(f"pipelines: mmse_equalize m={n + 4} n={n}")
        h = jnp.asarray(rng.standard_normal((LANES, n + 4, n))
                        .astype(np.float32))
        y = jnp.asarray(rng.standard_normal((LANES, n + 4, RHS))
                        .astype(np.float32))
        t_fused = timeit(pp.mmse_equalize_pallas, h, y, reps=3, warmup=1)
        t_comp = timeit(pp.mmse_equalize_composed, h, y, reps=3, warmup=1)
        emit(f"pipelines/mmse{n}/pallas-composed", t_comp, "1.0x")
        emit(f"pipelines/mmse{n}/pallas-fused", t_fused,
             f"{t_comp / t_fused:.2f}x")
        t_xf = timeit(partial(pp.mmse_equalize, backend="xla"), h, y)
        t_xu = timeit(mmse_xla_unfused, h, y)
        emit(f"pipelines/mmse{n}/xla-unfused", t_xu, "1.0x")
        emit(f"pipelines/mmse{n}/xla-fused", t_xf, f"{t_xu / t_xf:.2f}x")

    # ---- registry sweep: uniform enumeration, no hand-imports ----
    header("registry sweep (smallest size per kernel)")
    for spec in K.specs():
        n = spec.sizes[0]
        args = spec.make_case(rng, n)
        t = timeit(spec.run_pallas, *args, reps=3, warmup=1)
        emit(f"registry/{spec.name}{n}/pallas", t,
             f"{spec.kind},{spec.stream(n).capability}")


# ---- variant-dispatched sweep (feeds BENCH_pipelines.json) ----

VARIANT_REPS = 3
VARIANT_WARMUP = 1
# HBM-scale tiled cases (n >= 512) cost ~1s each in interpret mode; one
# timed rep keeps the CI sweep bounded while still exercising dispatch.
VARIANT_BIG_N = 512
VARIANT_BIG_REPS = 1


def run_variants() -> None:
    """Every registered pipeline variant, each at its declared sizes
    (base: the spec's paper sizes; blocked: 128/256; split: the
    split-plane arity), invoked THROUGH ``KernelSpec.dispatch`` — the
    benchmark never names an entry point, it builds a case and lets the
    registry route it, asserting the expected variant won.  Per case it
    records wall-clock of the jit'd dispatched entry point (one compile
    per variant x size, like the serving engines; warmup absorbs the
    compile so ``wall_us`` is steady-state kernel time with the
    dispatch decision hoisted out of the timed region), the closed-form
    model FLOPs, and how many calls ran via the dispatched variant
    (``dispatches`` = warmup + timed reps) for the persisted
    ``BENCH_pipelines.json`` baseline."""
    rng = np.random.default_rng(3)
    header("variant dispatch sweep (per-variant wall-clock + model flops)")
    for spec in K.specs(kind="pipeline"):
        for variant in (spec.base,) + tuple(spec.variants):
            sizes = variant.sizes or (spec.sizes[0],)
            for n in sizes:
                make = variant.make_case or spec.make_case
                args = make(rng, n)
                picked = spec.dispatch(*args)
                assert picked.name == variant.name, (
                    f"{spec.name}@{n}: dispatch chose {picked.name!r}, "
                    f"expected {variant.name!r}")
                jfn = jax.jit(picked.fn)
                reps = VARIANT_BIG_REPS if n >= VARIANT_BIG_N \
                    else VARIANT_REPS
                t = timeit(jfn, *args, reps=reps, warmup=VARIANT_WARMUP)
                dispatches = VARIANT_WARMUP + reps
                shapes = tuple(np.shape(a)[1:] for a in args)
                flops = (float(variant.flops(shapes))
                         if variant.flops is not None else 0.0)
                emit(f"variants/{spec.name}/{variant.name}{n}/pallas", t,
                     f"model_flops={flops:.0f}")
                emit_variant(pipeline=spec.name, variant=variant.name,
                             n=n, wall_us=t, model_flops=flops,
                             dispatches=dispatches)


# ---- SLO / mixed-traffic serving (SolverMux) ----

SLO_LANES = 8
SLO_SIZES = (8, 12)            # two distinct shapes per pipeline
SLO_ROUNDS = 6


def _slo_trace(rng):
    """Interleaved PUSCH-style mix: per round, MMSE bulk at every size
    (half arriving as SPLIT re/im planes — the mux must route their
    4-arg buckets to the split_complex variant), plus control-path
    Cholesky and QR jobs — three job types, >= 2 shapes each, arriving
    interleaved (never pre-grouped)."""
    trace = []
    for rnd in range(SLO_ROUNDS):
        for n in SLO_SIZES:
            m = n + 4
            for i in range(3):
                if (rnd + i) % 2:
                    trace.append(("mmse_equalize", (
                        rng.standard_normal((m, n)).astype(np.float32),
                        rng.standard_normal((m, n)).astype(np.float32),
                        rng.standard_normal((m, 2)).astype(np.float32),
                        rng.standard_normal((m, 2)).astype(np.float32))))
                else:
                    trace.append(("mmse_equalize", (
                        rng.standard_normal((m, n)).astype(np.float32),
                        rng.standard_normal((m, 2)).astype(np.float32))))
            trace.append(("cholesky_solve", (
                _spd(rng, 1, n)[0],
                rng.standard_normal((n, 2)).astype(np.float32))))
            trace.append(("qr_solve", (
                rng.standard_normal((m, n)).astype(np.float32),
                rng.standard_normal((m, 1)).astype(np.float32))))
    return trace


def run_slo() -> None:
    """Mixed-traffic SLO scenario: per-pipeline p50/p99 latency,
    throughput, lane utilization, and padded-lane waste through the
    registry-driven SolverMux (real clock; a warmup pass absorbs jit
    compiles so the percentiles reflect steady-state serving)."""
    from repro.serve import SolverMux

    rng = np.random.default_rng(7)
    trace = _slo_trace(rng)
    mux = SolverMux(lanes=SLO_LANES)

    header(f"serve SLO: mixed traffic, {len(trace)} jobs, "
           f"lanes={SLO_LANES}, sizes={SLO_SIZES}")
    for pipeline, args in trace:          # warmup: compile every bucket
        mux.submit(pipeline, *args)
    mux.run()
    mux.reset_metrics()

    t0 = time.perf_counter()
    for pipeline, args in trace:
        mux.submit(pipeline, *args, deadline=time.monotonic() + 5e-3)
    done = mux.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(trace)

    snap = mux.metrics()
    for name, st in sorted(snap.pipelines.items()):
        counts = ";".join(f"{v}:{c}" for v, c in
                          sorted(st.dispatch_counts.items()))
        emit(f"serve_slo/{name}/dispatch", float(st.launches), counts,
             unit="count")
        emit(f"serve_slo/{name}/latency_p50", st.latency.p50 * 1e6,
             f"p99={st.latency.p99 * 1e6:.0f}us,n={st.jobs}")
        emit(f"serve_slo/{name}/latency_p99", st.latency.p99 * 1e6,
             f"max={st.latency.max * 1e6:.0f}us")
        # throughput may be NaN (zero-width window: one instantaneous
        # batch) — never divide through it blindly
        if math.isfinite(st.throughput) and st.throughput > 0:
            emit(f"serve_slo/{name}/throughput", 1e6 / st.throughput,
                 f"{st.throughput:.0f} jobs/s")
        else:
            emit(f"serve_slo/{name}/throughput", 0.0,
                 "window zero-width; throughput unknown")
        emit(f"serve_slo/{name}/lane_util",
             st.lane_utilization * 100.0,
             f"waste={st.padded_lane_waste * 100:.0f}%,"
             f"launches={st.launches}", unit="percent")
    emit("serve_slo/total", wall * 1e6,
         f"{snap.total_jobs} jobs,{snap.total_launches} launches")

    # ---- overload sweep: 2x-capacity mixed-priority trace, policy
    # on vs off at the SAME lane-time budget (virtual clock, exact) ----
    from repro.launch.serve_solvers import run_overload

    header("serve SLO overload: 2x offered load, mixed priorities, "
           "policy on/off")
    for summary in (run_overload(True), run_overload(False)):
        tag = "policy" if summary["policy"] else "baseline"
        emit(f"serve_slo/overload/hard_attainment_{tag}",
             summary["attainment_hard"] * 100.0,
             f"dropped={summary['dropped']},"
             f"preempted={summary['preempted']},"
             f"coalesced={summary['coalesced']},"
             f"hard_dropped={summary['hard_dropped']},"
             f"jobs={summary['jobs']},done={summary['done']},"
             f"launches={summary['launches']}",
             unit="percent")

    # ---- cost-model drift: the overload trace again with the online
    # calibration loop CLOSED — every launch measured, sec/FLOP +
    # overhead re-fit, per-variant predicted/measured drift persisted
    # (rows required by check_bench_json) ----
    header("serve SLO drift: overload trace with online calibration on")
    adaptive = run_overload(True, adaptive=True)
    for key, d in sorted(adaptive["drift"].items()):
        emit(f"serve_slo/drift/{key}", d["ratio"],
             f"updates={d['updates']},source={d['source']},"
             f"alert={int(d['alert'])}",
             unit="ratio")
    ups = adaptive["calibration_updates"]
    emit("serve_slo/drift/calibration_updates",
         float(sum(ups.values())),
         ";".join(f"{k}={v}" for k, v in sorted(ups.items())),
         unit="count")

    # ---- mesh-sharded scaling sweep: the overload trace on a fixed
    # virtual window against 1/2/4/8-shard lane meshes (virtual CPU
    # devices) — aggregate throughput, per-shard utilization, and the
    # per-mesh calibration rows from_bench_json re-fits shard overheads
    # from (rows required by check_bench_json) ----
    from repro.launch.serve_solvers import run_sharded_overload

    n_dev = jax.device_count()
    header(f"serve SLO sharded: mesh scaling sweep on {n_dev} devices")
    throughput: dict[int, float] = {}
    for mesh in (1, 2, 4, 8):
        if mesh > n_dev:
            emit(f"serve_slo/sharded/mesh{mesh}/skipped", 0.0,
                 f"needs {mesh} devices, have {n_dev}", unit="count")
            continue
        s = run_sharded_overload(mesh)
        throughput[mesh] = s["throughput"]
        emit(f"serve_slo/sharded/mesh{mesh}/throughput",
             s["throughput"],
             f"jobs={s['jobs']},done={s['done']},"
             f"launches={s['launches']},spanning={s['spanning']},"
             f"pending={s['pending']}", unit="rate")
        emit(f"serve_slo/sharded/mesh{mesh}/attainment",
             s["attainment_hard"] * 100.0,
             f"dropped={s['dropped']}", unit="percent")
        util = s["shard_util"]
        mean_util = sum(util.values()) / len(util)
        imb = s["imbalance"]
        emit(f"serve_slo/sharded/mesh{mesh}/shard_util",
             mean_util * 100.0,
             ";".join(f"s{k}={v * 100:.0f}%"
                      for k, v in sorted(util.items()))
             + (f";imbalance={imb:.3f}" if math.isfinite(imb) else ""),
             unit="percent")
        for row in s["calibration"]:
            emit_sharded(**row)
    if 1 in throughput and 4 in throughput and throughput[1] > 0:
        emit("serve_slo/sharded/speedup_mesh4",
             throughput[4] / throughput[1],
             f"mesh4={throughput[4]:.2f}/tick,"
             f"mesh1={throughput[1]:.2f}/tick", unit="ratio")

    # ---- fault-tolerance chaos sweep: the committed fault trace
    # (launch failures + NaN lanes + a blackholed shard) replayed at
    # mesh=4 against the fault-free reference run — virtual clock +
    # seeded injector, so every observable is exact.  Rows required by
    # check_bench_json; the fault-free rows above are produced with NO
    # injector attached and stay bit-identical ----
    import pathlib

    from repro.launch.serve_solvers import run_chaos

    if n_dev >= 4:
        header("serve SLO faults: chaos replay, committed fault trace, "
               "mesh=4")
        trace_path = (pathlib.Path(__file__).parent.parent
                      / "tests" / "data" / "fault_trace.json")
        faulted = run_chaos(str(trace_path))
        clean = run_chaos(None)
        ratio = (faulted["attainment_hard"] / clean["attainment_hard"]
                 if clean["attainment_hard"] > 0 else 0.0)
        emit("serve_slo/faults/hard_attainment_chaos",
             faulted["attainment_hard"] * 100.0,
             f"jobs={faulted['jobs']},done={faulted['done']},"
             f"failed={faulted['failed']},dropped={faulted['dropped']},"
             f"retries={faulted['retries']},pending={faulted['pending']}",
             unit="percent")
        emit("serve_slo/faults/hard_attainment_clean",
             clean["attainment_hard"] * 100.0,
             f"jobs={clean['jobs']},done={clean['done']},"
             f"failed={clean['failed']}", unit="percent")
        emit("serve_slo/faults/attainment_ratio", ratio,
             f"floor=0.8,chaos={faulted['attainment_hard']:.4f},"
             f"clean={clean['attainment_hard']:.4f}", unit="ratio")
        emit("serve_slo/faults/hard_lost", float(faulted["hard_lost"]),
             f"hard_failed={faulted['hard_failed']},"
             f"failed_jobs={faulted['failed_jobs']}", unit="count")
        emit("serve_slo/faults/containment",
             float(faulted["quarantines"]),
             f"quarantines={faulted['quarantines']},"
             f"reinstatements={faulted['reinstatements']},"
             f"demotions={faulted['demotions']},"
             f"time_to_recover={faulted['time_to_recover']:.2f}",
             unit="count")
    else:
        emit("serve_slo/faults/skipped", 0.0,
             f"needs 4 devices, have {n_dev}", unit="count")

    # ---- served pipeline DAG sweep: the canonical PUSCH-receiver
    # trace (same generator as the committed golden trace) replayed
    # stage-independent vs stage-chained on the virtual clock, plus the
    # committed mid-DAG fault trace (channel-estimate stage raises
    # twice, absorbed by launch supervision).  End-to-end latencies are
    # in exact virtual ticks; rows gated by check_bench_json ----
    import pathlib as _pathlib

    from repro.launch.serve_solvers import run_pusch

    header("serve SLO DAG: PUSCH receiver, staged vs stage-chained, "
           "mid-DAG fault")
    staged = run_pusch(False, ticks=4)
    chained = run_pusch(True, ticks=4)
    fault_path = (_pathlib.Path(__file__).parent.parent
                  / "tests" / "data" / "pusch_fault_trace.json")
    faulted_dag = run_pusch(False, ticks=4, fault_trace=str(fault_path))
    for tag, s in (("staged", staged), ("chained", chained)):
        emit(f"serve_slo/dag/{tag}/e2e_p50", s["e2e_p50"],
             f"dags={s['pusch_dags']},done={s['done']},"
             f"failed={s['failed']},dropped={s['dropped']},"
             f"launches={s['launches']}", unit="count")
        emit(f"serve_slo/dag/{tag}/e2e_p99", s["e2e_p99"],
             f"dags={s['pusch_dags']},launches={s['launches']}",
             unit="count")
    emit("serve_slo/dag/chained_speedup",
         staged["e2e_p50"] / chained["e2e_p50"],
         f"staged_p50={staged['e2e_p50']:.1f},"
         f"chained_p50={chained['e2e_p50']:.1f},"
         f"staged_launches={staged['launches']},"
         f"chained_launches={chained['launches']}", unit="ratio")
    emit("serve_slo/dag/faults/hard_lost",
         float(faulted_dag["hard_lost"]),
         f"retries={faulted_dag['retries']},"
         f"done={faulted_dag['done']},dags={faulted_dag['dags']},"
         f"failed_jobs={faulted_dag['failed_jobs']}", unit="count")

    # ---- continuous-batching decode sweep: (a) per-phase latency +
    # per-step calibration on the real clock (microbenchmark shape:
    # warmed engine, pure-prefill and pure-generate step populations),
    # (b) the committed mixed solver+decode trace replayed continuous
    # vs lockstep at equal budget on the virtual clock — tokens/step is
    # the gated throughput win (rows required by check_bench_json) ----
    from repro.launch.serve_solvers import decode_model, run_decode_serve
    from repro.serve.decode import DecodeEngine, Request

    header("serve SLO decode: per-phase latency + continuous vs "
           "lockstep throughput")
    cfg, params = decode_model()
    eng = DecodeEngine(cfg, params, batch=4, max_len=64, eos_id=-1)
    eng.submit(Request(prompt=[3, 5], max_new=3))
    eng.run()                          # warmup: absorb the jit compile
    eng.reset_metrics()

    def _step_wall_us(reqs):
        """Median per-step wall of a drained population (us)."""
        for r in reqs:
            eng.submit(r)
        walls = []
        while eng.has_work():
            t0 = time.perf_counter()
            eng.step()
            walls.append(time.perf_counter() - t0)
        walls.sort()
        return walls[len(walls) // 2] * 1e6

    # full pool, every timed step a prompt feed / a generate feed
    prefill_us = _step_wall_us(
        [Request(prompt=[2 + i] * 8, max_new=1) for i in range(4)])
    generate_us = _step_wall_us(
        [Request(prompt=[10 + i], max_new=8) for i in range(4)])
    step_flops = eng.lanes * eng.token_flops
    emit_decode(phase="prefill", wall_us=prefill_us, flops=step_flops)
    emit_decode(phase="generate", wall_us=generate_us, flops=step_flops)

    # mixed fan: per-request phase latencies through the shared recorder
    eng.reset_metrics()
    for i in range(8):
        eng.submit(Request(prompt=[2 + i] * (1 + i % 4),
                           max_new=2 + (3 * i) % 5))
    eng.run()
    d = eng.metrics().decode
    emit_decode(phase="insert", wall_us=d.insert.p50 * 1e6, flops=0.0)
    emit("serve_slo/decode/insert_latency", d.insert.p50 * 1e6,
         f"p99={d.insert.p99 * 1e6:.0f}us,n={d.insert.count}")
    emit("serve_slo/decode/prefill_latency", d.prefill.p50 * 1e6,
         f"p99={d.prefill.p99 * 1e6:.0f}us,n={d.prefill.count}")
    emit("serve_slo/decode/generate_latency", d.generate.p50 * 1e6,
         f"p99={d.generate.p99 * 1e6:.0f}us,n={d.generate.count}")

    cont = run_decode_serve(True, ticks=4)
    base = run_decode_serve(False, ticks=4)
    emit("serve_slo/decode/tokens_per_step_continuous",
         cont["tokens_per_step"],
         f"tokens={cont['tokens']},steps={cont['steps']},"
         f"reuses={cont['slot_reuses']},done={cont['done']}",
         unit="rate")
    emit("serve_slo/decode/tokens_per_step_lockstep",
         base["tokens_per_step"],
         f"tokens={base['tokens']},steps={base['steps']},"
         f"done={base['done']}", unit="rate")
    emit("serve_slo/decode/continuous_speedup",
         cont["tokens_per_step"] / base["tokens_per_step"],
         f"continuous={cont['tokens_per_step']:.3f},"
         f"lockstep={base['tokens_per_step']:.3f}", unit="ratio")
    emit("serve_slo/decode/hard_lost",
         float(cont["hard_lost"] + base["hard_lost"]),
         f"requests={cont['requests']},solver_jobs={cont['solver_jobs']}",
         unit="count")
