"""Fused vs unfused solver pipelines — the composed-workload analog of the
paper's Fig. 19 mechanism stack.

Two axes are measured for cholesky_solve / qr_solve / mmse_equalize:

  pallas-fused    one pallas_call, everything VMEM-resident (interpret
                  mode off-TPU: the *relative* fused/unfused gap still
                  reflects the dispatch + memory-round-trip overhead)
  pallas-unfused  factor-then-solve via separate pallas_calls
  xla-fused       ONE jit program of the whole chain (XLA may fuse)
  xla-unfused     one jit + device round-trip PER stage — the
                  kernel-at-a-time dispatch baseline

plus a registry sweep: every registered kernel/pipeline timed through its
uniform ``run_pallas`` adapter at its smallest size, with the stream
capability (paper F2-F4 classification) emitted in the derived column —
the registry, not a hand-maintained import list, enumerates the kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, timeit
from repro import kernels as K
from repro import pipelines as pp
from repro.kernels import ref
from repro.kernels.common import sample_spd as _spd

LANES = 8
SIZES = (8, 16, 32)          # >= 3 matrix sizes (paper's 12..32 range)
RHS = 4


# ---- xla-unfused: one jit + host sync per stage (dispatch baseline) ----

_chol = jax.jit(jnp.linalg.cholesky)
_fwd = jax.jit(jax.vmap(partial(jax.scipy.linalg.solve_triangular,
                                lower=True)))
_bwd = jax.jit(jax.vmap(partial(jax.scipy.linalg.solve_triangular,
                                lower=False)))


def chol_solve_xla_unfused(a, b):
    l = jax.block_until_ready(_chol(a))
    z = jax.block_until_ready(_fwd(l, b))
    return _bwd(jnp.swapaxes(l, -1, -2), z)


_gram = jax.jit(lambda h, s: jnp.einsum("bmi,bmj->bij", h, h)
                + s * jnp.eye(h.shape[-1], dtype=h.dtype))
_mf = jax.jit(lambda h, y: jnp.einsum("bmn,bmk->bnk", h, y))


def mmse_xla_unfused(h, y, sigma2=0.1):
    g = jax.block_until_ready(_gram(h, sigma2))
    rhs = jax.block_until_ready(_mf(h, y))
    return chol_solve_xla_unfused(g, rhs)


def run() -> None:
    rng = np.random.default_rng(0)

    for n in SIZES:
        header(f"pipelines: cholesky_solve n={n} lanes={LANES}")
        a = jnp.asarray(_spd(rng, LANES, n))
        b = jnp.asarray(rng.standard_normal((LANES, n, RHS))
                        .astype(np.float32))
        want = np.asarray(ref.cholesky_solve(a, b))
        got = np.asarray(pp.cholesky_solve_pallas(a, b))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-4)

        t_fused = timeit(pp.cholesky_solve_pallas, a, b, reps=3, warmup=1)
        t_unf = timeit(pp.cholesky_solve_unfused, a, b, reps=3, warmup=1)
        emit(f"pipelines/chol_solve{n}/pallas-unfused", t_unf, "1.0x")
        emit(f"pipelines/chol_solve{n}/pallas-fused", t_fused,
             f"{t_unf / t_fused:.2f}x")
        t_xf = timeit(partial(pp.cholesky_solve, backend="xla"), a, b)
        t_xu = timeit(chol_solve_xla_unfused, a, b)
        emit(f"pipelines/chol_solve{n}/xla-unfused", t_xu, "1.0x")
        emit(f"pipelines/chol_solve{n}/xla-fused", t_xf,
             f"{t_xu / t_xf:.2f}x")

    for n in SIZES:
        header(f"pipelines: qr_solve m={n + 4} n={n}")
        a = jnp.asarray(rng.standard_normal((LANES, n + 4, n))
                        .astype(np.float32))
        b = jnp.asarray(rng.standard_normal((LANES, n + 4, RHS))
                        .astype(np.float32))
        t_fused = timeit(pp.qr_solve_pallas, a, b, reps=3, warmup=1)
        t_unf = timeit(pp.qr_solve_unfused, a, b, reps=3, warmup=1)
        emit(f"pipelines/qr_solve{n}/pallas-unfused", t_unf, "1.0x")
        emit(f"pipelines/qr_solve{n}/pallas-fused", t_fused,
             f"{t_unf / t_fused:.2f}x")

    for n in SIZES:
        header(f"pipelines: mmse_equalize m={n + 4} n={n}")
        h = jnp.asarray(rng.standard_normal((LANES, n + 4, n))
                        .astype(np.float32))
        y = jnp.asarray(rng.standard_normal((LANES, n + 4, RHS))
                        .astype(np.float32))
        t_fused = timeit(pp.mmse_equalize_pallas, h, y, reps=3, warmup=1)
        t_comp = timeit(pp.mmse_equalize_composed, h, y, reps=3, warmup=1)
        emit(f"pipelines/mmse{n}/pallas-composed", t_comp, "1.0x")
        emit(f"pipelines/mmse{n}/pallas-fused", t_fused,
             f"{t_comp / t_fused:.2f}x")
        t_xf = timeit(partial(pp.mmse_equalize, backend="xla"), h, y)
        t_xu = timeit(mmse_xla_unfused, h, y)
        emit(f"pipelines/mmse{n}/xla-unfused", t_xu, "1.0x")
        emit(f"pipelines/mmse{n}/xla-fused", t_xf, f"{t_xu / t_xf:.2f}x")

    # ---- registry sweep: uniform enumeration, no hand-imports ----
    header("registry sweep (smallest size per kernel)")
    for spec in K.specs():
        n = spec.sizes[0]
        args = spec.make_case(rng, n)
        t = timeit(spec.run_pallas, *args, reps=3, warmup=1)
        emit(f"registry/{spec.name}{n}/pallas", t,
             f"{spec.kind},{spec.stream(n).capability}")
