"""Paper Fig. 16 analog: latency-optimized kernels at the paper's data
sizes (12..32 matrices; 64..1024 FFT), FGOP-fused formulation vs the
unfused library/naive baseline on the same substrate.

The paper compares REVEL vs DSP/OOO hardware; on a single fixed substrate
(CPU-XLA) the measurable quantity is formulation-vs-formulation — fused
ordered-dependence code vs library calls — plus the Pallas kernels'
*structural* latency model from tests.  TPU wall-clock claims live in the
roofline analysis, not here (DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_mechanisms import chol_fused, solve_fused
from benchmarks.common import emit, header, timeit
from repro.kernels import ops


def _spd(rng, n, batch=1):
    a = rng.standard_normal((batch, n, n)).astype(np.float32)
    return a @ np.swapaxes(a, -1, -2) + n * np.eye(n, dtype=np.float32)


def run() -> None:
    rng = np.random.default_rng(7)
    sizes = (12, 16, 24, 32)

    header("Fig. 16: cholesky latency (fused vs library)")
    for n in sizes:
        a1 = jnp.asarray(_spd(rng, n)[0])
        t_fused = timeit(jax.jit(chol_fused), a1)
        t_lib = timeit(jax.jit(jnp.linalg.cholesky), a1)
        emit(f"fig16/cholesky/n{n}/fused", t_fused,
             f"lib={t_lib:.1f}us")

    header("Fig. 16: solver latency")
    for n in sizes:
        l = jnp.asarray(np.linalg.cholesky(_spd(rng, n)[0]))
        b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        t_fused = timeit(jax.jit(solve_fused), l, b)
        t_lib = timeit(jax.jit(functools.partial(
            jax.scipy.linalg.solve_triangular, lower=True)), l, b)
        emit(f"fig16/solver/n{n}/fused", t_fused, f"lib={t_lib:.1f}us")

    header("Fig. 16: QR latency (fused householder vs library)")
    for n in sizes:
        a = jnp.asarray(rng.standard_normal((1, n, n)).astype(np.float32))
        t_fused = timeit(jax.jit(lambda a_: ops.qr(a_, backend="xla")), a)
        t_lib = timeit(jax.jit(jnp.linalg.qr), a[0])
        emit(f"fig16/qr/n{n}/fused", t_fused, f"lib={t_lib:.1f}us")

    header("Fig. 16: SVD latency (one-sided jacobi vs library)")
    for n in (12, 16, 24):
        a = jnp.asarray(rng.standard_normal((1, n, n)).astype(np.float32))
        t_fused = timeit(
            jax.jit(lambda a_: ops.svd(a_, backend="xla")), a, reps=5)
        t_lib = timeit(jax.jit(
            functools.partial(jnp.linalg.svd, compute_uv=True)), a[0],
            reps=5)
        emit(f"fig16/svd/n{n}/fused", t_fused, f"lib={t_lib:.1f}us")

    header("Fig. 16: GEMM latency (paper sizes 12/24/48 x 16 x 64)")
    for m in (12, 24, 48):
        x = jnp.asarray(rng.standard_normal((m, 16)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
        t = timeit(jax.jit(lambda x_, y_: ops.gemm(x_, y_,
                                                   backend="xla")), x, y)
        emit(f"fig16/gemm/{m}x16x64", t, "")

    header("Fig. 16: FIR latency (sizes 12..32 taps, 2048 signal)")
    for m in (13, 17, 25, 31):
        x = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
        h = rng.standard_normal(m).astype(np.float32)
        h = jnp.asarray((h + h[::-1]) / 2)
        t = timeit(jax.jit(lambda x_, h_: ops.fir(x_, h_,
                                                  backend="xla")), x, h)
        emit(f"fig16/fir/m{m}", t, "")

    header("Fig. 16: FFT latency (paper sizes 64/128/1024)")
    for n in (64, 128, 1024):
        xr = jnp.asarray(rng.standard_normal((1, n)).astype(np.float32))
        xi = jnp.asarray(rng.standard_normal((1, n)).astype(np.float32))
        t = timeit(jax.jit(lambda r, i: ops.fft(r, i, backend="xla")),
                   xr, xi)
        emit(f"fig16/fft/n{n}", t, "")
