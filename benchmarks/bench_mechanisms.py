"""Paper Fig. 19 analog: incremental speedup of each mechanism, measured.

The paper stacks: +inductive, +fine-grain-deps, +heterogeneous fabric,
+masking.  On the XLA/CPU substrate the measurable analogs are:

  dispatch  — every region command issued separately (3 dispatches per
              outer iteration; the task-parallel / no-stream baseline
              whose synchronization+dispatch cost the paper measures)
  streamed  — one program, control amortized in time (the vector-stream
              command model: the whole factorization is ONE command
              sequence executed by the 'lane', regions fused so ordered
              dependences never leave registers)
  lanes     — + control amortized in space: 8 data-parallel lanes under
              one control program (vmap = the lane bitmask), per-matrix us
  library   — jnp.linalg / jax.scipy (the 'MKL' line)

Correctness of the fused formulations is asserted against the library
before timing.  Wall-times are CPU-XLA and used for *relative* mechanism
comparisons only (DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, timeit

LANES = 8


# ---------------- cholesky variants ----------------

def chol_fused(a):
    """Fused point/vector/matrix regions; one scan over k (FIFO=carry)."""
    n = a.shape[-1]
    idx = jnp.arange(n)

    def step(carry, k):
        a_, l_ = carry
        col = jax.lax.dynamic_slice_in_dim(a_, k, 1, axis=1)[:, 0]
        akk = jax.lax.dynamic_slice_in_dim(col, k, 1)[0]
        pivot = jnp.sqrt(jnp.maximum(akk, 1e-30))      # point region
        inva = 1.0 / pivot
        below = idx > k
        lcol = jnp.where(below, col * inva, 0.0)       # vector region
        lcol = jnp.where(idx == k, pivot, lcol)
        lm = jnp.where(below, lcol, 0.0)
        a_ = a_ - jnp.outer(lm, lm)                    # matrix region
        l_ = jax.lax.dynamic_update_slice_in_dim(
            l_, lcol[:, None], k, axis=1)
        return (a_, l_), None

    (_, l), _ = jax.lax.scan(step, (a, jnp.zeros_like(a)), idx)
    return l


# separate per-region programs (the dispatch-per-command baseline)
@jax.jit
def _point(a_, k):
    akk = jax.lax.dynamic_slice(a_, (k, k), (1, 1))[0, 0]
    pivot = jnp.sqrt(jnp.maximum(akk, 1e-30))
    return pivot, 1.0 / pivot


@jax.jit
def _vector(a_, l_, k, pivot, inva):
    n = a_.shape[-1]
    idx = jnp.arange(n)
    col = jax.lax.dynamic_slice_in_dim(a_, k, 1, axis=1)[:, 0]
    lcol = jnp.where(idx > k, col * inva, 0.0)
    lcol = jnp.where(idx == k, pivot, lcol)
    return jax.lax.dynamic_update_slice_in_dim(l_, lcol[:, None], k,
                                               axis=1), lcol


@jax.jit
def _matrix(a_, lcol, k):
    idx = jnp.arange(a_.shape[-1])
    lm = jnp.where(idx > k, lcol, 0.0)
    return a_ - jnp.outer(lm, lm)


def chol_dispatch(a):
    n = a.shape[-1]
    l = jnp.zeros_like(a)
    for k in range(n):                      # host control loop
        kk = jnp.asarray(k)
        pivot, inva = _point(a, kk)         # command 1
        l, lcol = _vector(a, l, kk, pivot, inva)   # command 2
        a = _matrix(a, lcol, kk)            # command 3
    return l


# ---------------- solver (forward substitution) variants ----------------

def solve_fused(l, b):
    n = l.shape[-1]
    idx = jnp.arange(n)

    def step(carry, j):
        b_ = carry
        ljj = jax.lax.dynamic_slice(l, (j, j), (1, 1))[0, 0]
        bj = jax.lax.dynamic_slice_in_dim(b_, j, 1)[0]
        xj = bj / ljj                                   # divide region
        col = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=1)[:, 0]
        b_ = jnp.where(idx > j, b_ - xj * col, b_)      # axpy region
        b_ = jnp.where(idx == j, xj, b_)
        return b_, None

    x, _ = jax.lax.scan(step, b, idx)
    return x


@jax.jit
def _divide(l, b_, j):
    ljj = jax.lax.dynamic_slice(l, (j, j), (1, 1))[0, 0]
    bj = jax.lax.dynamic_slice_in_dim(b_, j, 1)[0]
    return bj / ljj


@jax.jit
def _axpy(l, b_, xj, j):
    idx = jnp.arange(l.shape[-1])
    col = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=1)[:, 0]
    b_ = jnp.where(idx > j, b_ - xj * col, b_)
    return jnp.where(idx == j, xj, b_)


def solve_dispatch(l, b):
    for j in range(l.shape[-1]):
        jj = jnp.asarray(j)
        xj = _divide(l, b, jj)
        b = _axpy(l, b, xj, jj)
    return b


# ---------------- harness ----------------

def _spd(rng, n, batch=None):
    shape = (batch, n, n) if batch else (n, n)
    a = rng.standard_normal(shape).astype(np.float32)
    return a @ np.swapaxes(a, -1, -2) + n * np.eye(n, dtype=np.float32)


def run() -> None:
    rng = np.random.default_rng(0)
    for n in (16, 32):
        header(f"Fig. 19 mechanisms: cholesky n={n}")
        a = jnp.asarray(_spd(rng, n))
        want = np.linalg.cholesky(np.asarray(a))
        got = np.asarray(jax.jit(chol_fused)(a))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)

        t_disp = timeit(chol_dispatch, a, reps=5, warmup=1)
        t_stream = timeit(jax.jit(chol_fused), a)
        ab = jnp.asarray(_spd(rng, n, LANES))
        lanes_fn = jax.jit(jax.vmap(chol_fused))
        t_lanes = timeit(lanes_fn, ab) / LANES
        t_lib = timeit(jax.jit(jnp.linalg.cholesky), a)
        emit(f"fig19/cholesky{n}/dispatch", t_disp, "1.0x")
        emit(f"fig19/cholesky{n}/streamed", t_stream,
             f"{t_disp / t_stream:.1f}x")
        emit(f"fig19/cholesky{n}/lanes", t_lanes,
             f"{t_disp / t_lanes:.1f}x")
        emit(f"fig19/cholesky{n}/library", t_lib,
             f"{t_disp / t_lib:.1f}x")

        header(f"Fig. 19 mechanisms: solver n={n}")
        lmat = jnp.asarray(want)
        b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        import scipy.linalg  # noqa: F401  (via jax.scipy below)
        xs = np.asarray(jax.jit(solve_fused)(lmat, b))
        ref = np.linalg.solve(want, np.asarray(b))
        np.testing.assert_allclose(xs, ref, rtol=2e-3, atol=1e-5)

        t_disp = timeit(solve_dispatch, lmat, b, reps=5, warmup=1)
        t_stream = timeit(jax.jit(solve_fused), lmat, b)
        lb = jnp.asarray(rng.standard_normal((LANES, n)).astype(np.float32))
        lmats = jnp.broadcast_to(lmat, (LANES, n, n))
        t_lanes = timeit(jax.jit(jax.vmap(solve_fused)), lmats, lb) / LANES
        t_lib = timeit(jax.jit(functools.partial(
            jax.scipy.linalg.solve_triangular, lower=True)), lmat, b)
        emit(f"fig19/solver{n}/dispatch", t_disp, "1.0x")
        emit(f"fig19/solver{n}/streamed", t_stream,
             f"{t_disp / t_stream:.1f}x")
        emit(f"fig19/solver{n}/lanes", t_lanes, f"{t_disp / t_lanes:.1f}x")
        emit(f"fig19/solver{n}/library", t_lib, f"{t_disp / t_lib:.1f}x")
