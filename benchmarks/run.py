"""Benchmark driver: one entry per paper table/figure or subsystem.

  fig11/21/22  control-overhead analytics   bench_control_overhead
  fig2         masking utilization          bench_masking_util
  fig19        mechanism stack (timed)      bench_mechanisms
  fig16        latency-optimized kernels    bench_latency
  fig17        throughput-optimized         bench_throughput
  roofline     3-term table from dry-run    bench_roofline
  serving      mixed-traffic SLO (mux)      bench_pipelines.run_slo
  variants     variant-dispatch sweep       bench_pipelines.run_variants

Prints ``name,us_per_call,derived,unit`` CSV.  ``--only <prefix>``
filters.
``--json-out FILE`` additionally persists the run as JSON — rows plus
the per-kernel/per-variant dispatch counts, model FLOPs and wall-clock
from the ``variants`` entry — the ``BENCH_pipelines.json`` perf baseline
committed at the repo root and checked by CI's bench-smoke step
(see benchmarks.check_bench_json)."""
from __future__ import annotations

import argparse
import json
import sys
import time

# 8 virtual CPU devices (merged into XLA_FLAGS before the first jax
# import; an explicit device count in the env is respected) so the
# serve_slo entry can sweep mesh sizes up to 8 on a CPU-only runner
from repro.launch.xla_env import force_host_device_count

force_host_device_count(8)

from benchmarks import (bench_control_overhead, bench_latency,
                        bench_masking_util, bench_mechanisms,
                        bench_pipelines, bench_roofline, bench_throughput,
                        common)

ENTRIES = [
    ("control_overhead", bench_control_overhead.run),
    ("masking_util", bench_masking_util.run),
    ("mechanisms", bench_mechanisms.run),
    ("pipelines", bench_pipelines.run),
    ("variants", bench_pipelines.run_variants),
    ("serve_slo", bench_pipelines.run_slo),
    ("latency", bench_latency.run),
    ("throughput", bench_throughput.run),
    ("roofline", bench_roofline.run),
]


def json_payload(ran: list[str]) -> dict:
    """Fold the collected rows + variant records into the persisted
    baseline structure (schema 1)."""
    counts: dict[str, dict[str, int]] = {}
    for rec in common.VARIANTS:
        per = counts.setdefault(rec["pipeline"], {})
        per[rec["variant"]] = per.get(rec["variant"], 0) \
            + int(rec["dispatches"])
    return {
        "schema": 1,
        "entries": ran,
        # ratio rows (cost-model drift) live far below 1.0 in interpret
        # mode — 2-decimal rounding would flatten them to 0.0
        "rows": [{"name": n,
                  "us_per_call": round(us, 6 if u == "ratio" else 2),
                  "derived": d, "unit": u}
                 for n, us, d, u in common.ROWS],
        "variants": common.VARIANTS,
        "dispatch_counts": counts,
        "sharded": common.SHARDED,
        "decode": common.DECODE,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated entry-name substrings, e.g. "
                         "'variants,serve_slo'")
    ap.add_argument("--json-out", default=None, metavar="FILE",
                    help="write rows + variant dispatch/flops records "
                         "as JSON (the BENCH_pipelines.json baseline)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived,unit")
    t0 = time.time()
    ran = []
    for name, fn in ENTRIES:
        if args.only and not any(tok and tok in name
                                 for tok in args.only.split(",")):
            continue
        fn()
        ran.append(name)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(json_payload(ran), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json_out}", file=sys.stderr)


if __name__ == "__main__":
    main()
