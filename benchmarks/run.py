"""Benchmark driver: one entry per paper table/figure or subsystem.

  fig11/21/22  control-overhead analytics   bench_control_overhead
  fig2         masking utilization          bench_masking_util
  fig19        mechanism stack (timed)      bench_mechanisms
  fig16        latency-optimized kernels    bench_latency
  fig17        throughput-optimized         bench_throughput
  roofline     3-term table from dry-run    bench_roofline
  serving      mixed-traffic SLO (mux)      bench_pipelines.run_slo

Prints ``name,us_per_call,derived`` CSV.  ``--only <prefix>`` filters."""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_control_overhead, bench_latency,
                        bench_masking_util, bench_mechanisms,
                        bench_pipelines, bench_roofline, bench_throughput)

ENTRIES = [
    ("control_overhead", bench_control_overhead.run),
    ("masking_util", bench_masking_util.run),
    ("mechanisms", bench_mechanisms.run),
    ("pipelines", bench_pipelines.run),
    ("serve_slo", bench_pipelines.run_slo),
    ("latency", bench_latency.run),
    ("throughput", bench_throughput.run),
    ("roofline", bench_roofline.run),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in ENTRIES:
        if args.only and args.only not in name:
            continue
        fn()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
