"""Benchmark driver: one module per paper table/figure.

  fig11/21/22  control-overhead analytics   bench_control_overhead
  fig2         masking utilization          bench_masking_util
  fig19        mechanism stack (timed)      bench_mechanisms
  fig16        latency-optimized kernels    bench_latency
  fig17        throughput-optimized         bench_throughput
  roofline     3-term table from dry-run    bench_roofline

Prints ``name,us_per_call,derived`` CSV.  ``--only <prefix>`` filters."""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_control_overhead, bench_latency,
                        bench_masking_util, bench_mechanisms,
                        bench_pipelines, bench_roofline, bench_throughput)

MODULES = [
    ("control_overhead", bench_control_overhead),
    ("masking_util", bench_masking_util),
    ("mechanisms", bench_mechanisms),
    ("pipelines", bench_pipelines),
    ("latency", bench_latency),
    ("throughput", bench_throughput),
    ("roofline", bench_roofline),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        mod.run()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
