"""Paper Fig. 11 + Fig. 21 + Fig. 22: control-overhead analytics.

Reproduces the paper's own analytical model: how many stream commands a
Von-Neumann control core must issue to express each workload's access
pattern under capabilities V / R / RR / RI, the resulting mean stream
length, and control instructions per inner-loop iteration.

Claims validated (also enforced in tests/test_streams.py):
  * solver at RI capability: 8 total commands vs 3+5n at RR (Fig. 11)
  * RI always <= 1 control inst/iter on FGOP workloads (Fig. 22)
  * inductive capability unlocks long streams on FGOP patterns (Fig. 21)
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.streams import (average_stream_length, command_count,
                                commands_per_iteration, inductive, rect)

CAPS = ["V", "R", "RR", "RI"]


def workload_patterns(n: int):
    """Dominant access pattern per workload (matrix size / data size n)."""
    return {
        # triangular walk: inner trip shrinks by 1 per outer iteration
        "cholesky": inductive(n, n, -1, outer_stride=n + 1),
        "solver": inductive(n, n - 1, -1, outer_stride=n + 1),
        "qr": inductive(n, n, -1, outer_stride=n + 1),
        "svd": inductive(n, n, -1, outer_stride=n + 1),
        # rectangular workloads
        "gemm": rect(n, n),
        "fft": rect(n),
        "fir": rect(n, 16),
    }


def run() -> None:
    header("Fig. 11: solver stream commands (RI vs decomposed RR)")
    for n in (12, 16, 24, 32):
        pats = [inductive(n, n - 1, -1, outer_stride=n + 1, name="a"),
                rect(n, name="b"),
                inductive(n, n - 1, -1, name="x-reuse")]
        ri = sum(command_count(p, "RI") for p in pats) + 5
        rr = sum(command_count(p, "RR") for p in pats) + 5
        emit(f"fig11/solver/n{n}/RI_cmds", ri, f"paper=8")
        emit(f"fig11/solver/n{n}/RR_cmds", rr, f"paper=3+5n={3 + 5 * n}")

    header("Fig. 21: mean stream length by capability")
    for name, pat in workload_patterns(32).items():
        for cap in CAPS:
            emit(f"fig21/{name}/{cap}", average_stream_length(pat, cap),
                 "iters-per-command")

    header("Fig. 22: control insts per inner-loop iteration")
    for name, pat in workload_patterns(32).items():
        for cap in CAPS:
            v = commands_per_iteration(pat, cap)
            emit(f"fig22/{name}/{cap}", v,
                 "OK(<1)" if (cap != "RI" or v <= 1.0) else "VIOLATION")
