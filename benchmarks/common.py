"""Shared benchmark plumbing: wall-clock timing of jitted callables, the
``name,us_per_call,derived,unit`` CSV contract used by benchmarks.run,
and the variant-dispatch record feeding ``BENCH_pipelines.json``.

Every row carries an explicit ``unit``: ``"us"`` for wall-clock numbers
(the default), ``"percent"`` for attainment-style rows, ``"ratio"`` for
dimensionless rows like the cost-model drift (predicted/measured),
``"count"`` for event counters (launches, calibration updates), and
``"rate"`` for per-virtual-tick throughputs (the mesh-sharded scaling
sweep).  The value still travels in the ``us_per_call`` field for schema
continuity, but consumers must check ``unit`` before treating it as
microseconds — ``benchmarks.check_bench_json`` enforces this."""
from __future__ import annotations

import time

import jax

UNITS = ("us", "percent", "ratio", "count", "rate")

ROWS: list[tuple[str, float, str, str]] = []
VARIANTS: list[dict] = []
SHARDED: list[dict] = []
DECODE: list[dict] = []


def timeit(fn, *args, reps: int = 20, warmup: int = 3) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "", unit: str = "us") -> None:
    if unit not in UNITS:
        raise ValueError(f"unknown bench row unit {unit!r} "
                         f"(expected one of {UNITS})")
    ROWS.append((name, us, derived, unit))
    print(f"{name},{us:.2f},{derived},{unit}", flush=True)


def header(title: str) -> None:
    print(f"# --- {title} ---", flush=True)


def emit_variant(**fields) -> None:
    """Record one variant-dispatch bench case (pipeline, variant, n,
    dispatches, model_flops, wall-clock) for the ``--json-out``
    baseline."""
    VARIANTS.append(fields)


def emit_sharded(**fields) -> None:
    """Record one mesh-sharded launch calibration row (pipeline,
    variant, mesh, lanes, wall_us, model_flops) for the ``--json-out``
    baseline — the rows ``CostModel.from_bench_json`` re-fits per-mesh
    launch overheads from."""
    SHARDED.append(fields)


def emit_decode(**fields) -> None:
    """Record one decode-phase calibration row (phase, wall_us, flops)
    for the ``--json-out`` baseline — the rows
    ``CostModel.from_bench_json`` fits per-phase decode rates from
    (``("decode", phase)`` table keys pricing continuous-batching
    steps through the mux)."""
    DECODE.append(fields)
