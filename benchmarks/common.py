"""Shared benchmark plumbing: wall-clock timing of jitted callables and the
``name,us_per_call,derived`` CSV contract used by benchmarks.run."""
from __future__ import annotations

import time

import jax

ROWS: list[tuple[str, float, str]] = []


def timeit(fn, *args, reps: int = 20, warmup: int = 3) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def header(title: str) -> None:
    print(f"# --- {title} ---", flush=True)
