"""Shared benchmark plumbing: wall-clock timing of jitted callables, the
``name,us_per_call,derived`` CSV contract used by benchmarks.run, and the
variant-dispatch record feeding ``BENCH_pipelines.json``."""
from __future__ import annotations

import time

import jax

ROWS: list[tuple[str, float, str]] = []
VARIANTS: list[dict] = []


def timeit(fn, *args, reps: int = 20, warmup: int = 3) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def header(title: str) -> None:
    print(f"# --- {title} ---", flush=True)


def emit_variant(**fields) -> None:
    """Record one variant-dispatch bench case (pipeline, variant, n,
    dispatches, model_flops, wall-clock) for the ``--json-out``
    baseline."""
    VARIANTS.append(fields)
