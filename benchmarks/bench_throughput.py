"""Paper Fig. 17 analog: throughput-optimized kernels — each lane runs one
problem data-parallel (the paper's throughput setting), so the metric is
problems/second at batch = 8 lanes x k.

Implemented as vmap over the fused formulations: one control program, all
lanes advance under the same stream schedule (vector-stream control)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_mechanisms import chol_fused, solve_fused
from benchmarks.common import emit, header, timeit
from repro.kernels import ops

BATCH = 64


def run() -> None:
    rng = np.random.default_rng(11)
    for n in (12, 16, 24, 32):
        header(f"Fig. 17 throughput n={n} (batch {BATCH})")
        a = rng.standard_normal((BATCH, n, n)).astype(np.float32)
        spd = jnp.asarray(a @ a.swapaxes(-1, -2)
                          + n * np.eye(n, dtype=np.float32))
        t = timeit(jax.jit(jax.vmap(chol_fused)), spd, reps=10)
        emit(f"fig17/cholesky/n{n}", t / BATCH,
             f"{1e6 / (t / BATCH):.0f} problems/s")

        lmat = jnp.asarray(np.linalg.cholesky(np.asarray(spd)))
        b = jnp.asarray(rng.standard_normal((BATCH, n)).astype(np.float32))
        t = timeit(jax.jit(jax.vmap(solve_fused)), lmat, b, reps=10)
        emit(f"fig17/solver/n{n}", t / BATCH,
             f"{1e6 / (t / BATCH):.0f} problems/s")

        aa = jnp.asarray(rng.standard_normal((BATCH, n, n))
                         .astype(np.float32))
        t = timeit(jax.jit(lambda a_: ops.qr(a_, backend="xla")), aa,
                   reps=5)
        emit(f"fig17/qr/n{n}", t / BATCH,
             f"{1e6 / (t / BATCH):.0f} problems/s")

    header(f"Fig. 17 throughput: FFT batch {BATCH}")
    for n in (64, 128, 1024):
        xr = jnp.asarray(rng.standard_normal((BATCH, n)).astype(np.float32))
        xi = jnp.asarray(rng.standard_normal((BATCH, n)).astype(np.float32))
        t = timeit(jax.jit(lambda r, i: ops.fft(r, i, backend="xla")),
                   xr, xi, reps=10)
        emit(f"fig17/fft/n{n}", t / BATCH,
             f"{1e6 / (t / BATCH):.0f} problems/s")
