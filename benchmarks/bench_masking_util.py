"""Paper Fig. 2(c,d): vector utilization on triangular (inductive) domains.

Implicit masking executes ceil(t/w) vector issues per inner loop of trip t;
without masking the leftover iterations scalarize (1 lane useful/issue).
We report utilization for the paper's matrix sizes and vector widths, plus
the speedup of masked over scalarized-tail execution.
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.masking import vector_utilization
from repro.core.streams import inductive


def run() -> None:
    header("Fig. 2(c,d): triangular-domain vector utilization")
    for n in (12, 16, 24, 32):
        tri = inductive(n, n, -1)
        trips = tri.trip_counts()
        for w in (4, 8, 16):
            u = vector_utilization(trips, w)
            # issues: masked vs vectorize-then-scalarize-the-tail
            masked = sum(-(-t // w) for t in trips)
            scalar_tail = sum(t // w + (t % w) for t in trips)
            emit(f"fig2/util/n{n}/w{w}", 100.0 * u, "percent-useful-lanes")
            emit(f"fig2/speedup/n{n}/w{w}", scalar_tail / masked,
                 "masked-vs-scalar-tail")
