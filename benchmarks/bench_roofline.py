"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads dryrun_results.jsonl (written by repro.launch.dryrun) and emits the
three-term roofline per (arch x shape x mesh): compute / memory /
collective seconds, dominant bottleneck, useful-FLOPs ratio, projected
MFU.  Single-pod rows are the §Roofline table; pod rows prove DCN-axis
sharding."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, header

RESULTS = os.environ.get("DRYRUN_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "dryrun_results.jsonl"))


def run() -> None:
    try:
        recs = [json.loads(l) for l in open(RESULTS) if l.strip()]
    except FileNotFoundError:
        header(f"roofline: no dry-run artifact at {RESULTS} — run "
               "`python -m repro.launch.dryrun --all` first")
        return
    ok = [r for r in recs if r.get("status") == "ok"]
    header(f"Roofline ({len(ok)} compiled cells; "
           f"{sum(r.get('status') == 'skipped' for r in recs)} skipped)")
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        rl = r["roofline"]
        name = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        t_star = rl["step_time"]
        emit(name, t_star * 1e6,
             f"bottleneck={rl['bottleneck']}"
             f";t_comp={rl['t_compute']:.3e}"
             f";t_mem={rl['t_memory']:.3e}"
             f";t_coll={rl['t_collective']:.3e}"
             f";useful={rl['useful_ratio']:.2f}"
             f";mfu={rl['mfu']:.3f}"
             f";GiB/dev={r['memory']['per_device_total'] / 2**30:.1f}")
