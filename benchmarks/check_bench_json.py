"""Schema/coverage gate for ``BENCH_pipelines.json`` (CI bench-smoke).

Asserts the JSON written by ``benchmarks.run --json-out`` parses and
that EVERY variant registered on every pipeline spec (including each
spec's ``base``) was actually exercised — a variant silently dropping
out of the dispatch sweep (predicate typo, bench regression, registry
rename) fails CI here instead of rotting unmeasured.

Also requires the serve-SLO OVERLOAD rows (``run_slo``'s policy-on/off
sweep): hard-deadline attainment with the overload policy must be
present, strictly higher than the baseline run at the same budget, with
zero hard-deadline drops and non-zero dropped/coalesced counters — so
the baseline JSON is regenerated with ``--only variants,serve_slo``.

Every row must also declare a known ``unit`` (``us`` / ``percent`` /
``ratio`` / ``count`` / ``rate``; attainment rows must be ``percent``),
and the ``serve_slo/drift/*`` rows from the online-calibration sweep
must be present with at least one pair actually observed
(``updates > 0``).

The mesh-sharded scaling sweep is gated too: ``serve_slo/sharded/*``
throughput and shard-utilization rows must exist for mesh sizes 1, 2,
and 4 with the right units, mesh=4 aggregate throughput must strictly
beat mesh=1 (and meet the 3x scaling floor — the sweep replays a
deterministic virtual-clock trace, so this is exact, not flaky), and
the payload's ``sharded`` calibration rows must include measured
mesh > 1 launches.

The served-DAG sweep is gated as well: ``serve_slo/dag/*`` rows must
carry the staged and stage-chained PUSCH end-to-end latencies (exact
virtual ticks), chained strictly below staged at the same budget, and
the mid-DAG fault replay must report zero hard DAGs lost with at least
one supervised retry.

So is the fault-tolerance chaos replay: the ``serve_slo/faults/*``
rows must show zero silently-lost hard jobs, at least one quarantine,
reinstatement, and variant demotion, and a hard-attainment ratio of at
least 0.8 against the fault-free reference (the replay is seeded and
virtual-clocked, so the gate is exact).  The fault-free serving rows
are produced with no injector attached and stay bit-identical.

And the continuous-batching decode sweep: ``serve_slo/decode/*`` rows
must carry per-phase (insert / prefill / generate) latency, continuous
tokens/step strictly above the lockstep pool baseline on the committed
mixed solver+decode trace at equal budget (virtual clock, exact), zero
hard jobs or hard decode requests lost, and the payload's ``decode``
calibration section must include measurable prefill/generate rows.

  PYTHONPATH=src python -m benchmarks.check_bench_json BENCH_pipelines.json
"""
from __future__ import annotations

import json
import sys

from repro import kernels as K


def check(path: str) -> None:
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("schema") == 1, f"unknown schema: {payload.get('schema')}"
    assert payload["rows"], "no benchmark rows recorded"

    # Row units: every row must declare one, drawn from the known set,
    # and the value's meaning must match — attainment rows are
    # percentages, drift rows dimensionless ratios; neither is a
    # microsecond no matter what the legacy field name says.
    from benchmarks.common import UNITS
    for row in payload["rows"]:
        unit = row.get("unit")
        assert unit in UNITS, (
            f"row {row['name']!r} has unit {unit!r}; expected one of "
            f"{UNITS} — regenerate the baseline")
        if row["name"].startswith("serve_slo/overload/hard_attainment"):
            assert unit == "percent", (
                f"attainment row {row['name']!r} must carry "
                f"unit='percent', got {unit!r}")
            assert 0.0 <= row["us_per_call"] <= 100.0, (
                f"attainment row {row['name']!r} out of percent range: "
                f"{row['us_per_call']}")

    exercised = {(rec["pipeline"], rec["variant"])
                 for rec in payload["variants"]
                 if rec.get("dispatches", 0) > 0}
    expected = {(spec.name, v.name)
                for spec in K.specs(kind="pipeline")
                for v in (spec.base,) + tuple(spec.variants)}
    missing = expected - exercised
    assert not missing, (
        f"registered variants not exercised by the benchmark: "
        f"{sorted(missing)} (exercised: {sorted(exercised)})")

    counts = payload["dispatch_counts"]
    for pipeline, variant in expected:
        assert counts.get(pipeline, {}).get(variant, 0) > 0, (
            f"dispatch_counts missing {pipeline}/{variant}")
    for rec in payload["variants"]:
        assert rec["model_flops"] > 0, f"zero model flops: {rec}"
        assert rec["wall_us"] > 0, f"zero wall-clock: {rec}"

    # HBM-scale coverage: every pipeline carrying a ``tiled`` variant
    # must have exercised it at n >= 512 — the large-shape path silently
    # shrinking back to midrange sizes is a regression, not a rename.
    tiled_specs = [spec.name for spec in K.specs(kind="pipeline")
                   if any(v.name == "tiled" for v in spec.variants)]
    assert tiled_specs, "no pipeline registers a tiled variant"
    for name in tiled_specs:
        big = [rec for rec in payload["variants"]
               if rec["pipeline"] == name and rec["variant"] == "tiled"
               and rec["n"] >= 512 and rec.get("dispatches", 0) > 0]
        assert big, (f"{name}: tiled variant not exercised at n >= 512 "
                     "(HBM-scale coverage lost)")

    # Overload-policy SLO rows: the serve_slo sweep must have recorded
    # the deterministic 2x-load scenario with the policy on AND off, the
    # policy run must strictly beat the baseline on hard-deadline
    # attainment, never drop a hard job, and actually shed + coalesce
    # (a policy that no longer fires would zero these silently).
    rows = {r["name"]: r for r in payload["rows"]}
    on = rows.get("serve_slo/overload/hard_attainment_policy")
    off = rows.get("serve_slo/overload/hard_attainment_baseline")
    assert on and off, (
        "serve_slo overload rows missing — regenerate with "
        "`--only variants,serve_slo --json-out ...`")
    fields = dict(kv.split("=") for kv in on["derived"].split(","))
    assert {"dropped", "preempted", "coalesced",
            "hard_dropped"} <= set(fields), (
        f"overload row lacks policy counters: {on['derived']}")
    assert fields["hard_dropped"] == "0", (
        f"overload policy dropped hard-deadline jobs: {on['derived']}")
    assert int(fields["dropped"]) > 0 and int(fields["coalesced"]) > 0, (
        f"overload policy shed/coalesced nothing: {on['derived']}")
    assert on["us_per_call"] > off["us_per_call"], (
        f"hard-deadline SLO attainment with the policy "
        f"({on['us_per_call']}%) must be strictly higher than the "
        f"baseline ({off['us_per_call']}%)")

    # Cost-model drift rows: the calibration sweep must have observed at
    # least one (pipeline, variant) pair — a drift row with updates=0
    # (or no drift rows at all) means the predict->measure->re-fit loop
    # silently stopped closing.
    drift_rows = [r for r in payload["rows"]
                  if r["name"].startswith("serve_slo/drift/")
                  and r["unit"] == "ratio"]
    assert drift_rows, (
        "serve_slo drift rows missing — regenerate with "
        "`--only variants,serve_slo --json-out ...`")
    live = []
    for r in drift_rows:
        fields = dict(kv.split("=") for kv in r["derived"].split(","))
        assert {"updates", "source"} <= set(fields), (
            f"drift row lacks updates/source: {r['derived']}")
        assert r["us_per_call"] > 0, (
            f"drift row {r['name']!r} has non-positive ratio "
            f"{r['us_per_call']}")
        if int(fields["updates"]) > 0:
            live.append(r)
    assert live, ("every drift row has updates=0 — the calibration "
                  "loop observed no launches")

    # Mesh-sharded scaling rows: the sweep must cover mesh sizes 1/2/4
    # (8 virtual CPU devices are forced by benchmarks.run, so these can
    # never be skipped on a CI runner), carry the declared units, and
    # actually scale — mesh=4 aggregate lane throughput strictly above
    # mesh=1 and at least 3x it.  The trace and clock are deterministic
    # (virtual-clock replay), so the floor is exact.
    thr = {}
    for mesh in (1, 2, 4):
        t = rows.get(f"serve_slo/sharded/mesh{mesh}/throughput")
        u = rows.get(f"serve_slo/sharded/mesh{mesh}/shard_util")
        assert t and u, (
            f"serve_slo sharded rows missing for mesh={mesh} — "
            "regenerate with `--only variants,serve_slo --json-out ...`")
        assert t["unit"] == "rate", (
            f"sharded throughput row for mesh={mesh} must carry "
            f"unit='rate', got {t['unit']!r}")
        assert u["unit"] == "percent", (
            f"shard_util row for mesh={mesh} must carry "
            f"unit='percent', got {u['unit']!r}")
        assert t["us_per_call"] > 0, (
            f"mesh={mesh} sharded throughput is not positive: "
            f"{t['us_per_call']}")
        thr[mesh] = t["us_per_call"]
    assert thr[4] > thr[1], (
        f"mesh=4 throughput ({thr[4]}/tick) must strictly beat mesh=1 "
        f"({thr[1]}/tick)")
    assert thr[4] >= 3.0 * thr[1], (
        f"mesh=4 throughput ({thr[4]}/tick) below the 3x scaling floor "
        f"over mesh=1 ({thr[1]}/tick)")
    speedup = rows.get("serve_slo/sharded/speedup_mesh4")
    assert speedup and speedup["unit"] == "ratio", (
        "serve_slo/sharded/speedup_mesh4 ratio row missing")
    # Fault-tolerance chaos rows: the committed fault trace replayed at
    # mesh=4 must have lost ZERO hard jobs silently, quarantined AND
    # reinstated the blackholed shard, demoted at least one variant,
    # and kept hard attainment within 80% of the fault-free reference.
    # The replay is a seeded virtual-clock scenario, so these are exact.
    lost = rows.get("serve_slo/faults/hard_lost")
    ratio = rows.get("serve_slo/faults/attainment_ratio")
    contain = rows.get("serve_slo/faults/containment")
    assert lost and ratio and contain, (
        "serve_slo faults rows missing — regenerate with "
        "`--only variants,serve_slo --json-out ...`")
    assert lost["unit"] == "count" and lost["us_per_call"] == 0.0, (
        f"chaos replay silently lost hard jobs: {lost['us_per_call']} "
        f"({lost['derived']})")
    assert ratio["unit"] == "ratio" and ratio["us_per_call"] >= 0.8, (
        f"hard attainment under faults fell below 80% of the fault-free "
        f"run: {ratio['us_per_call']} ({ratio['derived']})")
    fields = dict(kv.split("=") for kv in contain["derived"].split(","))
    assert {"quarantines", "reinstatements",
            "demotions"} <= set(fields), (
        f"faults containment row lacks counters: {contain['derived']}")
    for counter in ("quarantines", "reinstatements", "demotions"):
        assert int(fields[counter]) >= 1, (
            f"chaos replay never exercised {counter}: "
            f"{contain['derived']}")

    # Served-DAG rows: the PUSCH-receiver trace must have been replayed
    # staged AND stage-chained, chaining must strictly reduce end-to-end
    # latency at the same budget (the fused channel-estimate->equalize
    # tail removes one scheduling round trip — virtual clock, exact),
    # and the mid-DAG fault replay must have lost zero hard DAGs.
    dag_staged = rows.get("serve_slo/dag/staged/e2e_p50")
    dag_chained = rows.get("serve_slo/dag/chained/e2e_p50")
    dag_speedup = rows.get("serve_slo/dag/chained_speedup")
    dag_lost = rows.get("serve_slo/dag/faults/hard_lost")
    assert dag_staged and dag_chained and dag_speedup and dag_lost, (
        "serve_slo DAG rows missing — regenerate with "
        "`--only variants,serve_slo --json-out ...`")
    for r in (dag_staged, dag_chained):
        assert r["unit"] == "count" and r["us_per_call"] > 0, (
            f"DAG e2e latency row {r['name']!r} must be positive ticks: "
            f"{r['us_per_call']} ({r['unit']})")
        assert rows.get(r["name"].replace("p50", "p99")), (
            f"DAG e2e p99 row missing next to {r['name']!r}")
        fields = dict(kv.split("=") for kv in r["derived"].split(","))
        assert fields.get("failed") == "0" and \
            fields.get("dropped") == "0", (
                f"DAG replay lost work: {r['derived']}")
    assert dag_chained["us_per_call"] < dag_staged["us_per_call"], (
        f"stage-chained e2e p50 ({dag_chained['us_per_call']} ticks) "
        f"must be strictly below stage-independent "
        f"({dag_staged['us_per_call']} ticks)")
    assert dag_speedup["unit"] == "ratio" and \
        dag_speedup["us_per_call"] > 1.0, (
            f"DAG chained speedup must exceed 1.0: "
            f"{dag_speedup['us_per_call']}")
    assert dag_lost["unit"] == "count" and \
        dag_lost["us_per_call"] == 0.0, (
            f"mid-DAG fault replay silently lost hard DAGs: "
            f"{dag_lost['us_per_call']} ({dag_lost['derived']})")
    fields = dict(kv.split("=") for kv in dag_lost["derived"].split(","))
    assert int(fields["retries"]) >= 1, (
        f"mid-DAG fault trace never fired: {dag_lost['derived']}")

    # Continuous-batching decode rows: per-phase latency must be
    # present (real-clock microbenchmark; prefill/generate strictly
    # positive), the committed mixed solver+decode trace must show
    # continuous tokens/step strictly above the lockstep baseline at
    # equal budget (virtual clock, exact), and no hard job or hard
    # decode request may have been lost in either mode.
    for phase in ("insert", "prefill", "generate"):
        r = rows.get(f"serve_slo/decode/{phase}_latency")
        assert r, (
            f"serve_slo decode {phase} latency row missing — regenerate "
            "with `--only variants,serve_slo --json-out ...`")
        assert r["unit"] == "us", (
            f"decode {phase} latency row must carry unit='us', got "
            f"{r['unit']!r}")
        floor = 0.0 if phase == "insert" else None
        if floor is None:
            assert r["us_per_call"] > 0, (
                f"decode {phase} latency is not positive: "
                f"{r['us_per_call']}")
        else:
            assert r["us_per_call"] >= floor, (
                f"decode {phase} latency is negative: {r['us_per_call']}")
    dec_cont = rows.get("serve_slo/decode/tokens_per_step_continuous")
    dec_base = rows.get("serve_slo/decode/tokens_per_step_lockstep")
    dec_speedup = rows.get("serve_slo/decode/continuous_speedup")
    dec_lost = rows.get("serve_slo/decode/hard_lost")
    assert dec_cont and dec_base and dec_speedup and dec_lost, (
        "serve_slo decode throughput rows missing — regenerate with "
        "`--only variants,serve_slo --json-out ...`")
    for r in (dec_cont, dec_base):
        assert r["unit"] == "rate" and r["us_per_call"] > 0, (
            f"decode throughput row {r['name']!r} must be a positive "
            f"rate: {r['us_per_call']} ({r['unit']})")
    assert dec_cont["us_per_call"] > dec_base["us_per_call"], (
        f"continuous-batching decode ({dec_cont['us_per_call']} "
        f"tokens/step) must strictly beat the lockstep baseline "
        f"({dec_base['us_per_call']} tokens/step)")
    assert dec_speedup["unit"] == "ratio" and \
        dec_speedup["us_per_call"] > 1.0, (
            f"decode continuous speedup must exceed 1.0: "
            f"{dec_speedup['us_per_call']}")
    assert dec_lost["unit"] == "count" and \
        dec_lost["us_per_call"] == 0.0, (
            f"decode replay silently lost hard work: "
            f"{dec_lost['us_per_call']} ({dec_lost['derived']})")
    decode_cal = payload.get("decode", [])
    cal_phases = {rec.get("phase") for rec in decode_cal}
    assert {"prefill", "insert", "generate"} <= cal_phases, (
        f"payload 'decode' calibration section incomplete: "
        f"phases {sorted(cal_phases)}")
    for rec in decode_cal:
        assert rec["wall_us"] >= 0, f"negative decode wall-clock: {rec}"
        if rec["phase"] in ("prefill", "generate"):
            assert rec["wall_us"] > 0 and rec["flops"] > 0, (
                f"decode calibration row not measurable: {rec}")

    sharded = payload.get("sharded", [])
    spanning = [rec for rec in sharded if rec.get("mesh", 1) > 1]
    assert spanning, ("payload 'sharded' section has no mesh > 1 "
                      "calibration rows")
    for rec in spanning:
        assert rec["wall_us"] > 0, f"zero sharded wall-clock: {rec}"
        assert rec["model_flops"] > 0, f"zero sharded flops: {rec}"

    print(f"{path}: ok — {len(payload['rows'])} rows (units checked), "
          f"{len(expected)} pipeline variants all exercised, "
          f"tiled at n>=512 on {sorted(tiled_specs)}, overload SLO "
          f"{on['us_per_call']:.0f}% > {off['us_per_call']:.0f}% baseline, "
          f"{len(live)} drift pairs observed, sharded mesh4 "
          f"{thr[4] / thr[1]:.1f}x mesh1 ({len(spanning)} spanning "
          f"calibration rows), chaos hard_lost=0 at attainment ratio "
          f"{ratio['us_per_call']:.3f}, DAG chained "
          f"{dag_speedup['us_per_call']:.2f}x staged with hard_lost=0, "
          f"decode continuous {dec_speedup['us_per_call']:.2f}x lockstep "
          f"with hard_lost=0")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_pipelines.json")
