"""Schema/coverage gate for ``BENCH_pipelines.json`` (CI bench-smoke).

Asserts the JSON written by ``benchmarks.run --json-out`` parses and
that EVERY variant registered on every pipeline spec (including each
spec's ``base``) was actually exercised — a variant silently dropping
out of the dispatch sweep (predicate typo, bench regression, registry
rename) fails CI here instead of rotting unmeasured.

  PYTHONPATH=src python -m benchmarks.check_bench_json BENCH_pipelines.json
"""
from __future__ import annotations

import json
import sys

from repro import kernels as K


def check(path: str) -> None:
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("schema") == 1, f"unknown schema: {payload.get('schema')}"
    assert payload["rows"], "no benchmark rows recorded"

    exercised = {(rec["pipeline"], rec["variant"])
                 for rec in payload["variants"]
                 if rec.get("dispatches", 0) > 0}
    expected = {(spec.name, v.name)
                for spec in K.specs(kind="pipeline")
                for v in (spec.base,) + tuple(spec.variants)}
    missing = expected - exercised
    assert not missing, (
        f"registered variants not exercised by the benchmark: "
        f"{sorted(missing)} (exercised: {sorted(exercised)})")

    counts = payload["dispatch_counts"]
    for pipeline, variant in expected:
        assert counts.get(pipeline, {}).get(variant, 0) > 0, (
            f"dispatch_counts missing {pipeline}/{variant}")
    for rec in payload["variants"]:
        assert rec["model_flops"] > 0, f"zero model flops: {rec}"
        assert rec["wall_us"] > 0, f"zero wall-clock: {rec}"
    print(f"{path}: ok — {len(payload['rows'])} rows, "
          f"{len(expected)} pipeline variants all exercised")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_pipelines.json")
