"""Schema/coverage gate for ``BENCH_pipelines.json`` (CI bench-smoke).

Asserts the JSON written by ``benchmarks.run --json-out`` parses and
that EVERY variant registered on every pipeline spec (including each
spec's ``base``) was actually exercised — a variant silently dropping
out of the dispatch sweep (predicate typo, bench regression, registry
rename) fails CI here instead of rotting unmeasured.

Also requires the serve-SLO OVERLOAD rows (``run_slo``'s policy-on/off
sweep): hard-deadline attainment with the overload policy must be
present, strictly higher than the baseline run at the same budget, with
zero hard-deadline drops and non-zero dropped/coalesced counters — so
the baseline JSON is regenerated with ``--only variants,serve_slo``.

  PYTHONPATH=src python -m benchmarks.check_bench_json BENCH_pipelines.json
"""
from __future__ import annotations

import json
import sys

from repro import kernels as K


def check(path: str) -> None:
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("schema") == 1, f"unknown schema: {payload.get('schema')}"
    assert payload["rows"], "no benchmark rows recorded"

    exercised = {(rec["pipeline"], rec["variant"])
                 for rec in payload["variants"]
                 if rec.get("dispatches", 0) > 0}
    expected = {(spec.name, v.name)
                for spec in K.specs(kind="pipeline")
                for v in (spec.base,) + tuple(spec.variants)}
    missing = expected - exercised
    assert not missing, (
        f"registered variants not exercised by the benchmark: "
        f"{sorted(missing)} (exercised: {sorted(exercised)})")

    counts = payload["dispatch_counts"]
    for pipeline, variant in expected:
        assert counts.get(pipeline, {}).get(variant, 0) > 0, (
            f"dispatch_counts missing {pipeline}/{variant}")
    for rec in payload["variants"]:
        assert rec["model_flops"] > 0, f"zero model flops: {rec}"
        assert rec["wall_us"] > 0, f"zero wall-clock: {rec}"

    # HBM-scale coverage: every pipeline carrying a ``tiled`` variant
    # must have exercised it at n >= 512 — the large-shape path silently
    # shrinking back to midrange sizes is a regression, not a rename.
    tiled_specs = [spec.name for spec in K.specs(kind="pipeline")
                   if any(v.name == "tiled" for v in spec.variants)]
    assert tiled_specs, "no pipeline registers a tiled variant"
    for name in tiled_specs:
        big = [rec for rec in payload["variants"]
               if rec["pipeline"] == name and rec["variant"] == "tiled"
               and rec["n"] >= 512 and rec.get("dispatches", 0) > 0]
        assert big, (f"{name}: tiled variant not exercised at n >= 512 "
                     "(HBM-scale coverage lost)")

    # Overload-policy SLO rows: the serve_slo sweep must have recorded
    # the deterministic 2x-load scenario with the policy on AND off, the
    # policy run must strictly beat the baseline on hard-deadline
    # attainment, never drop a hard job, and actually shed + coalesce
    # (a policy that no longer fires would zero these silently).
    rows = {r["name"]: r for r in payload["rows"]}
    on = rows.get("serve_slo/overload/hard_attainment_policy")
    off = rows.get("serve_slo/overload/hard_attainment_baseline")
    assert on and off, (
        "serve_slo overload rows missing — regenerate with "
        "`--only variants,serve_slo --json-out ...`")
    fields = dict(kv.split("=") for kv in on["derived"].split(","))
    assert {"dropped", "preempted", "coalesced",
            "hard_dropped"} <= set(fields), (
        f"overload row lacks policy counters: {on['derived']}")
    assert fields["hard_dropped"] == "0", (
        f"overload policy dropped hard-deadline jobs: {on['derived']}")
    assert int(fields["dropped"]) > 0 and int(fields["coalesced"]) > 0, (
        f"overload policy shed/coalesced nothing: {on['derived']}")
    assert on["us_per_call"] > off["us_per_call"], (
        f"hard-deadline SLO attainment with the policy "
        f"({on['us_per_call']}%) must be strictly higher than the "
        f"baseline ({off['us_per_call']}%)")

    print(f"{path}: ok — {len(payload['rows'])} rows, "
          f"{len(expected)} pipeline variants all exercised, "
          f"tiled at n>=512 on {sorted(tiled_specs)}, overload SLO "
          f"{on['us_per_call']:.0f}% > {off['us_per_call']:.0f}% baseline")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_pipelines.json")
