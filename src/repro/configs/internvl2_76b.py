"""internvl2-76b [vlm]: InternViT + InternLM2 backbone (arXiv:2404.16821).

Backbone only; the vision frontend is a stub — input_specs() supplies
precomputed patch embeddings (n_prefix tokens) prepended to the text.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=28672, vocab=128256, act="swiglu",
    frontend="vision", n_prefix=256,
    microbatch=16, remat="full", param_dtype="bfloat16",
)

SMOKE = ArchConfig(
    name="internvl2-76b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
    d_ff=160, vocab=512, act="swiglu",
    frontend="vision", n_prefix=8, remat="none",
)
