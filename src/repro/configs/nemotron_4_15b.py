"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP (arXiv:2402.16819)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv=8, d_head=128,
    d_ff=24576, vocab=256000, act="sq_relu",
    microbatch=4,
)

SMOKE = ArchConfig(
    name="nemotron-4-15b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
    d_ff=160, vocab=512, act="sq_relu", remat="none",
)
