"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA (arXiv:2412.08905)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_head=128,
    d_ff=8192, vocab=200064, act="swiglu",
    microbatch=2,
)

SMOKE = ArchConfig(
    name="phi4-mini-3.8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
    d_ff=160, vocab=512, act="swiglu", remat="none",
)
