"""xlstm-125m [ssm]: sLSTM + mLSTM blocks (arXiv:2405.04517).

12 layers as 3 groups of (3 mLSTM + 1 sLSTM).  d_ff=0 per spec: blocks
carry internal up/down projections.  Sub-quadratic: long_500k runs
(O(1) recurrent state decode).
"""
from repro.models.config import ArchConfig, XLSTMCfg

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_head=192,
    d_ff=0, vocab=50304, act="gelu",
    xlstm=XLSTMCfg(m_per_group=3, s_per_group=1, expand_m=2, qk_frac=0.5),
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="xlstm-125m-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=0, vocab=512, act="gelu",
    xlstm=XLSTMCfg(m_per_group=3, s_per_group=1, expand_m=2, qk_frac=0.5),
    subquadratic=True, remat="none",
)
