"""phi3-medium-14b [dense]: RoPE SwiGLU GQA (arXiv:2404.14219)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=10, d_head=128,
    d_ff=17920, vocab=100352, act="swiglu",
    microbatch=4,
)

SMOKE = ArchConfig(
    name="phi3-medium-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
    d_ff=160, vocab=512, act="swiglu", remat="none",
)
