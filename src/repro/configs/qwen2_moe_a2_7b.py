"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + shared expert
(hf:Qwen/Qwen1.5-MoE-A2.7B).  Experts padded 60 -> 64 for even EP
sharding over the 16-way model axis (padding experts masked in routing).
"""
from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1408, vocab=151936, act="swiglu",
    moe=MoECfg(n_experts=60, top_k=4, d_ff_expert=1408,
               n_shared=4, d_ff_shared=5632, padded_experts=64),
    microbatch=2,
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=96, vocab=512, act="swiglu",
    moe=MoECfg(n_experts=6, top_k=2, d_ff_expert=96,
               n_shared=1, d_ff_shared=128, padded_experts=8),
    remat="none",
)
