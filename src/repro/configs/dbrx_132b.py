"""dbrx-132b [moe]: 16 experts top-4, fine-grained (hf:databricks/dbrx)."""
from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv=8, d_head=128,
    d_ff=10752, vocab=100352, act="swiglu",
    moe=MoECfg(n_experts=16, top_k=4, d_ff_expert=10752),
    microbatch=16, remat="full", param_dtype="bfloat16",
)

SMOKE = ArchConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
    d_ff=96, vocab=512, act="swiglu",
    moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=96), remat="none",
)
