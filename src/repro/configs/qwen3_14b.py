"""qwen3-14b [dense]: qk_norm, GQA (hf:Qwen/Qwen3-8B family scaling)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=8, d_head=128,
    d_ff=17408, vocab=151936, act="swiglu", qk_norm=True,
    microbatch=4,
)

SMOKE = ArchConfig(
    name="qwen3-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
    d_ff=160, vocab=512, act="swiglu", qk_norm=True, remat="none",
)
