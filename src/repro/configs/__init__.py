"""Architecture registry: get_config(name) / get_smoke(name) / ARCHS."""
from __future__ import annotations

import importlib

_MODULES = {
    "internvl2-76b": "internvl2_76b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen3-14b": "qwen3_14b",
    "nemotron-4-15b": "nemotron_4_15b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "xlstm-125m": "xlstm_125m",
    "dbrx-132b": "dbrx_132b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
}

ARCHS = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    return _mod(name).SMOKE
