"""seamless-m4t-large-v2 [audio]: enc-dec, multimodal (arXiv:2308.11596).

Interpreted as 24 encoder + 24 decoder layers (speech encoder + text
decoder of the real model).  The audio frontend is a stub: input_specs()
provides precomputed frame embeddings for the encoder.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=48, enc_layers=24, dec_layers=24,
    d_model=1024, n_heads=16, n_kv=16, d_head=64,
    d_ff=8192, vocab=256206, act="gelu",
    frontend="audio",
    microbatch=2,
)

SMOKE = ArchConfig(
    name="seamless-m4t-large-v2-smoke", family="audio",
    n_layers=4, enc_layers=2, dec_layers=2,
    d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=160, vocab=512, act="gelu", frontend="audio", remat="none",
)
