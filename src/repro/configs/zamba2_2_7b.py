"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block
(arXiv:2411.15242).  54 mamba layers, a single shared attn+MLP block
applied every 9 layers (6 applications).  Sub-quadratic: long_500k runs.
"""
from repro.models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_head=80,
    d_ff=10240, vocab=32000, act="swiglu",
    ssm=SSMCfg(state=64, heads=32, expand=2, conv_kernel=4, chunk=128),
    shared_every=9, subquadratic=True,
    microbatch=2,
)

SMOKE = ArchConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=160, vocab=512, act="swiglu",
    ssm=SSMCfg(state=8, heads=4, expand=2, conv_kernel=4, chunk=16),
    shared_every=2, subquadratic=True, remat="none",
)
