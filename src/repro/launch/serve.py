"""Distributed serving launcher: mesh + TP-only weight shardings +
DecodeEngine (serve rules: no per-layer FSDP gathers on the decode path).

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --smoke --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config, get_smoke
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.serve import DecodeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--pool", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))

    with shd.axis_rules(mesh, shd.SERVE_RULES):
        p_abs = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = jax.tree_util.tree_map_with_path(
            lambda path, l: shd.named_safe(
                shd.param_spec(tuple(getattr(k, "key", str(k))
                                     for k in path), l.shape), l.shape),
            p_abs)
        params = jax.jit(lambda: T.init_params(
            jax.random.PRNGKey(0), cfg), out_shardings=p_sh)()
        engine = DecodeEngine(cfg, params, batch=args.pool,
                              max_len=args.max_len)
        for i in range(args.requests):
            engine.submit(Request(
                prompt=[2 + i, 7, (11 * i + 3) % cfg.vocab],
                max_new=args.max_new))
        t0 = time.time()
        done = engine.run()
        dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {total} tokens, {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile) on mesh {args.mesh}")


if __name__ == "__main__":
    main()
