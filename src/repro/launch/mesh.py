"""Production mesh construction (function, not constant: importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests (requires xla_force_host_platform_device_count
    to be set by the test before first jax use)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_lane_mesh(n_shards: int, axis: str = "data"):
    """1-D serving mesh over the first ``n_shards`` local devices — the
    axis the mux's lane dimension is sharded over (lanes are
    batch-parallel, so a flush's lane axis maps straight onto it).
    Raises when the host exposes fewer devices (on CPU, set
    ``--xla_force_host_platform_device_count`` first — see
    :mod:`repro.launch.xla_env`)."""
    import numpy as np

    devices = jax.devices()
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > len(devices):
        raise ValueError(
            f"lane mesh needs {n_shards} devices; only {len(devices)} "
            "available (set --xla_force_host_platform_device_count)")
    return jax.sharding.Mesh(np.asarray(devices[:n_shards]), (axis,))
