"""Production mesh construction (function, not constant: importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests (requires xla_force_host_platform_device_count
    to be set by the test before first jax use)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
