"""Distributed training launcher: mesh + FSDP/TP shardings + Trainer.

Single-host CPU: runs the reduced configs directly.  On a TPU pod the
same entrypoint runs under `jax.distributed.initialize()` with the
production mesh (each host feeds its data shard; the train step is one
SPMD program).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 20 --seq 128 --batch 8 --mesh 1x1
"""
from __future__ import annotations

import argparse
import logging

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config, get_smoke
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.optim.optimizer import OptConfig, init_opt_state
from repro.train import checkpoint as ckpt
from repro.train.fault import RetryPolicy, StragglerMonitor
from repro.train.trainer import TrainConfig, make_train_step

log = logging.getLogger("repro.launch.train")


def make_mesh(spec: str):
    """'DxM' -> mesh over (data, model); '1x1' works on one device."""
    d, m = (int(x) for x in spec.split("x"))
    return jax.make_mesh((d, m), ("data", "model"))


def shardings_for(mesh, cfg, seq: int, batch: int):
    """(param, opt, batch) NamedShardings under the FSDP+TP rules."""
    with shd.axis_rules(mesh):
        p_abs = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = jax.tree_util.tree_map_with_path(
            lambda path, l: shd.named_safe(
                shd.param_spec(tuple(getattr(k, "key", str(k))
                                     for k in path), l.shape), l.shape),
            p_abs)
        opt_sh = {"m": p_sh, "v": p_sh, "step": shd.named(P())}
        b_sh = shd.named_safe(P("data"), (batch, seq))
    return p_sh, opt_sh, b_sh


def run(args) -> dict:
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh(args.mesh)
    opt_cfg = OptConfig(lr=args.lr, warmup=min(50, args.steps // 5 or 1),
                        total_steps=args.steps)
    p_sh, opt_sh, b_sh = shardings_for(mesh, cfg, args.seq, args.batch)

    pipe = TokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch, seed=args.seed,
                   d_model=cfg.d_model,
                   n_prefix=cfg.n_prefix if cfg.frontend == "vision" else 0,
                   src_len=64 if cfg.frontend == "audio" else 0),
        process_index=jax.process_index(),
        process_count=jax.process_count())

    with shd.axis_rules(mesh):
        step_fn = jax.jit(make_train_step(cfg, opt_cfg),
                          in_shardings=(p_sh, opt_sh, None),
                          out_shardings=(p_sh, opt_sh, None),
                          donate_argnums=(0, 1))
        params = jax.jit(lambda: T.init_params(
            jax.random.PRNGKey(args.seed), cfg), out_shardings=p_sh)()
        opt_state = init_opt_state(params)
        opt_state = jax.device_put(opt_state, opt_sh)

        start = 0
        last = ckpt.latest_step(args.ckpt)
        if last is not None:
            _, st = ckpt.load(args.ckpt, last,
                              shardings={"params": p_sh, "opt": opt_sh})
            params, opt_state = st["params"], st["opt"]
            start = last
            log.info("resumed at step %d", start)

        retry = RetryPolicy()
        straggler = StragglerMonitor()
        losses = []
        import time as _time
        for step in range(start, args.steps):
            batch = pipe.device_batch(step)
            t0 = _time.perf_counter()
            params, opt_state, metrics = retry.run(
                lambda b=batch: step_fn(params, opt_state, b))
            dt = _time.perf_counter() - t0
            straggler.observe(step, dt)
            losses.append(float(metrics["loss"]))
            if step % 10 == 0:
                print(f"step {step} loss {losses[-1]:.4f} ({dt:.2f}s)",
                      flush=True)
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save(args.ckpt, step + 1,
                          {"params": params, "opt": opt_state},
                          blocking=(step + 1 == args.steps))
    return {"losses": losses, "stragglers": straggler.flagged_steps}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)
    out = run(args)
    ls = out["losses"]
    if ls:
        print(f"loss {ls[0]:.4f} -> {ls[-1]:.4f} over {len(ls)} steps")


if __name__ == "__main__":
    main()
