"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  - 512 placeholder host devices (merged into XLA_FLAGS below, BEFORE
    any jax import; an existing device-count flag or other user flags
    are respected, not clobbered)
  - 16x16 single-pod and 2x16x16 multi-pod production meshes
  - per cell: .lower() -> .compile() -> memory_analysis / cost_analysis /
    HLO roll-up costs (roofline terms), appended to a JSONL artifact.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k \
      --mesh single --out results.jsonl
  python -m repro.launch.dryrun --all --out results.jsonl   (driver mode:
      one subprocess per cell so XLA state/memory is isolated)
"""
from repro.launch.xla_env import force_host_device_count

force_host_device_count(512)

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models import decode as D
from repro.models import transformer as T
from repro.optim.optimizer import OptConfig
from repro.roofline import analysis as roof
from repro.roofline.hlo_costs import analyze_hlo
from repro.train.trainer import make_train_step


def _tree_named(tree_abs, spec_fn):
    """Build NamedShardings for a pytree of ShapeDtypeStructs."""

    def one(path, leaf):
        names = tuple(getattr(k, "key", str(k)) for k in path)
        return shd.named_safe(spec_fn(names, leaf.shape), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, tree_abs)


def _batch_spec_fn(names, shape):
    if len(shape) == 1:
        return P(("pod", "data") if len(shape) else None)
    return P(("pod", "data"), *([None] * (len(shape) - 1)))


def _cache_spec_fn(cfg):
    kv_div = cfg.n_kv % 16 == 0

    def fn(names, shape):
        name = names[-1]
        if name in ("k", "v") and len(shape) == 5:
            # (L, B, S, KV, Dh)
            if shape[1] >= 16:
                return P(None, ("pod", "data"),
                         "model" if not kv_div else None,
                         "model" if kv_div else None, None)
            # tiny batch (long_500k): shard the cache sequence
            return P(None, None, ("data", "model"), None, None)
        if name == "state" and len(shape) == 5:     # mamba (L,B,H,N,P)
            return P(None, ("pod", "data") if shape[1] >= 16 else None,
                     "model" if shape[2] % 16 == 0 else None, None, None)
        if name == "conv" and len(shape) == 4:
            return P(None, ("pod", "data") if shape[1] >= 16 else None,
                     None, None)
        if name == "enc_out":
            return P(("pod", "data") if shape[0] >= 16 else None,
                     None, None)
        if len(shape) >= 2 and shape[1] >= 16:      # xlstm states (L,B,...)
            return P(None, ("pod", "data"),
                     *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return fn


def _parse_overrides(sets: list[str] | None) -> dict:
    out = {}
    for kv in sets or []:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        out[k] = v
    return out


def run_cell(arch: str, shape: str, mesh_kind: str,
             donate: bool = True, overrides: dict | None = None) -> dict:
    cfg0 = get_config(arch)
    applicable, why = shp.cell_applicable(cfg0, shape)
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "time": time.time()}
    if overrides:
        rec["overrides"] = dict(overrides)
    if not applicable:
        rec.update(status="skipped", reason=why)
        return rec

    cfg = shp.tune_for_shape(cfg0, shape)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    meta = shp.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod"))
    chips = mesh.devices.size
    kind = meta["kind"]
    rules = shd.SERVE_RULES if kind == "decode" else None

    with shd.axis_rules(mesh, rules):
        p_abs = shp.abstract_params(cfg)
        p_sh = _tree_named(p_abs, shd.param_spec)

        if kind == "train":
            opt_abs = jax.eval_shape(
                lambda p: {"m": jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    "v": jax.tree.map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    "step": jnp.zeros((), jnp.int32)}, p_abs)
            opt_sh = {"m": p_sh, "v": p_sh,
                      "step": shd.named(P())}
            b_abs = shp.batch_specs(cfg, meta["seq"], meta["batch"],
                                    labels=True)
            b_sh = _tree_named(b_abs, _batch_spec_fn)
            fn = make_train_step(cfg, OptConfig())
            jfn = jax.jit(fn, in_shardings=(p_sh, opt_sh, b_sh),
                          out_shardings=(p_sh, opt_sh, None))
            lowered = jfn.lower(p_abs, opt_abs, b_abs)
            tokens = meta["seq"] * meta["batch"]
            # 6*N_active*D + 3x fwd attention (PaLM MFU convention)
            model_flops = roof.model_flops_train(
                cfg, tokens, seq=meta["seq"]) / chips

        elif kind == "prefill":
            b_abs = shp.batch_specs(cfg, meta["seq"], meta["batch"],
                                    labels=False)
            b_sh = _tree_named(b_abs, _batch_spec_fn)
            fn = lambda p, b: T.prefill(p, cfg, b)        # noqa: E731
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                          out_shardings=shd.named(P(("pod", "data"), None)))
            lowered = jfn.lower(p_abs, b_abs)
            tokens = meta["seq"] * meta["batch"]
            model_flops = roof.model_flops_prefill(
                cfg, tokens, seq=meta["seq"]) / chips

        else:  # decode
            c_abs = shp.abstract_cache(cfg, meta["batch"], meta["seq"])
            c_sh = _tree_named(c_abs, _cache_spec_fn(cfg))
            tok_abs = jax.ShapeDtypeStruct((meta["batch"], 1), jnp.int32)
            pos_abs = jax.ShapeDtypeStruct((meta["batch"],), jnp.int32)
            tok_sh = shd.named(P(("pod", "data") if meta["batch"] >= 16
                                 else None, None))
            pos_sh = shd.named(P(("pod", "data") if meta["batch"] >= 16
                                 else None))
            fn = lambda p, c, t, pos: D.decode_step(p, cfg, c, t, pos)  # noqa: E731
            jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                          out_shardings=(
                              shd.named(P(("pod", "data") if
                                          meta["batch"] >= 16 else None,
                                          None)), c_sh))
            lowered = jfn.lower(p_abs, c_abs, tok_abs, pos_abs)
            model_flops = roof.model_flops_decode(
                cfg, meta["batch"], meta["seq"]) / chips

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        try:
            cost = dict(compiled.cost_analysis())
        except Exception:
            cost = {}
        hlo = compiled.as_text()
        rolled = analyze_hlo(hlo)
        del hlo

    bytes_per_device = (mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes
                        + mem.output_size_in_bytes
                        - mem.alias_size_in_bytes)
    r = roof.analyze(
        arch, shape, mesh_kind, 1,
        {"flops": rolled["flops"], "bytes accessed": rolled["bytes"]},
        "", model_flops, bytes_per_device)
    r.coll_breakdown = {k: float(v)
                        for k, v in rolled["collectives"].items()}
    r.coll_bytes = float(sum(rolled["collectives"].values()))
    r.finish()

    rec.update(
        status="ok", chips=chips, compile_s=compile_s,
        memory=dict(
            argument=mem.argument_size_in_bytes,
            temp=mem.temp_size_in_bytes,
            output=mem.output_size_in_bytes,
            alias=mem.alias_size_in_bytes,
            per_device_total=bytes_per_device,
        ),
        cost_analysis={k: float(v) for k, v in cost.items()
                       if k in ("flops", "bytes accessed",
                                "transcendentals", "optimal_seconds")},
        rolled=dict(flops=rolled["flops"], bytes=rolled["bytes"],
                    collectives=rolled["collectives"]),
        roofline=r.to_json(),
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(shp.SHAPES))
    ap.add_argument("--mesh", choices=("single", "pod"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--set", action="append", default=[], dest="sets",
                    metavar="KEY=VALUE",
                    help="ArchConfig override(s) for perf iteration, "
                         "e.g. --set attn_impl=banded --set microbatch=8")
    args = ap.parse_args(argv)

    if args.all:
        done = set()
        try:
            for line in open(args.out):
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
        except FileNotFoundError:
            pass
        cells = [(a, s, m) for a in ARCHS for s in shp.SHAPES
                 for m in ("single", "pod")]
        for a, s, m in cells:
            if (a, s, m) in done:
                continue
            print(f"=== {a} x {s} x {m}", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--out", args.out]
            try:
                subprocess.run(cmd, timeout=args.timeout, check=False)
            except subprocess.TimeoutExpired:
                with open(args.out, "a") as f:
                    f.write(json.dumps({"arch": a, "shape": s, "mesh": m,
                                        "status": "timeout"}) + "\n")
        return

    try:
        rec = run_cell(args.arch, args.shape, args.mesh,
                       overrides=_parse_overrides(args.sets))
    except Exception as e:  # record failures as artifacts too
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    status = rec.get("status")
    print(f"[{status}] {args.arch} x {args.shape} x {args.mesh}")
    if status == "ok":
        rl = rec["roofline"]
        print(f"  compile {rec['compile_s']:.1f}s | "
              f"bytes/dev {rec['memory']['per_device_total']/2**30:.2f}GiB"
              f" | t_comp {rl['t_compute']:.2e}s t_mem {rl['t_memory']:.2e}"
              f"s t_coll {rl['t_collective']:.2e}s -> {rl['bottleneck']}")
    elif status == "error":
        print(rec["error"])
        print(rec.get("trace", ""))
        sys.exit(1)


if __name__ == "__main__":
    main()
