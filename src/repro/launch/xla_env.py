"""XLA host-platform virtual-device setup, import-order safe.

Several entry points (the dry-run driver, the test session, the bench
driver) need jax's CPU backend split into N placeholder devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.  The flag only
takes effect if it is in the environment BEFORE the first jax import,
and naively assigning ``os.environ["XLA_FLAGS"]`` discards whatever
flags the user already set.  :func:`force_host_device_count` is the one
shared, merge-don't-clobber implementation:

  * existing ``XLA_FLAGS`` content is preserved (the new flag is
    appended), and
  * an already-present ``xla_force_host_platform_device_count`` wins —
    the caller's N is NOT applied over an explicit user choice.

Deliberately jax-free: importing this module never initializes a
backend, so it is safe to call from conftest files and module top-levels
that must run before jax.
"""
from __future__ import annotations

import os

DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> bool:
    """Merge ``--xla_force_host_platform_device_count=n`` into
    ``XLA_FLAGS``.  Returns True when the flag was applied, False when
    an existing device-count flag was respected instead.  Must run
    before the first jax import to have any effect."""
    existing = os.environ.get("XLA_FLAGS", "")
    if DEVICE_COUNT_FLAG.lstrip("-") in existing:
        return False
    os.environ["XLA_FLAGS"] = \
        f"{existing} {DEVICE_COUNT_FLAG}={int(n)}".strip()
    return True
