"""Assigned input-shape cells and abstract input specs for the dry-run.

Shapes (per the assignment):
  train_4k    seq 4096  global_batch 256   -> train_step
  prefill_32k seq 32768 global_batch 32    -> prefill (forward, no loss)
  decode_32k  seq 32768 global_batch 128   -> serve_step (1 token, full cache)
  long_500k   seq 524288 global_batch 1    -> serve_step; ONLY for
              sub-quadratic archs (zamba2, xlstm) — skip documented in
              DESIGN.md for the 8 pure full-attention archs.

input_specs() returns ShapeDtypeStruct stand-ins (weak-type-correct, no
allocation); frontends are stubs (precomputed patch/frame embeddings).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ArchConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

SRC_LEN = 1024  # encoder frames for audio decode cells


def cell_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: no sub-quadratic path"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, seq: int, batch: int, *, labels: bool):
    out = {"tokens": _sds((batch, seq), jnp.int32)}
    if labels:
        out["labels"] = _sds((batch, seq), jnp.int32)
    if cfg.frontend == "vision":
        out["vision_embeds"] = _sds((batch, cfg.n_prefix, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.frontend == "audio":
        out["src_embeds"] = _sds((batch, SRC_LEN if seq > 4096 else seq,
                                  cfg.d_model), jnp.bfloat16)
    return out


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: D.init_cache(cfg, batch, max_len, src_len=SRC_LEN))


def decode_extra_specs(cfg: ArchConfig, batch: int):
    return {"tokens": _sds((batch, 1), jnp.int32),
            "pos": _sds((batch,), jnp.int32)}


def tune_for_shape(cfg: ArchConfig, shape: str) -> ArchConfig:
    """Per-cell compile policy: attention impl + chunk sizes + microbatch."""
    meta = SHAPES[shape]
    upd: dict = {}
    if meta["kind"] == "train":
        upd["attn_impl"] = "chunked"
        upd["attn_chunk"] = 512
    elif meta["kind"] == "prefill":
        upd["attn_impl"] = "chunked"
        upd["attn_chunk"] = 512
        upd["remat"] = "none"
    return dataclasses.replace(cfg, **upd) if upd else cfg
