"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
artifact:  PYTHONPATH=src python -m repro.launch.report [results.jsonl]"""
from __future__ import annotations

import json
import sys


def load(path: str):
    recs = [json.loads(l) for l in open(path) if l.strip()]
    # keep the newest record per cell
    by_cell = {}
    for r in recs:
        by_cell[(r["arch"], r["shape"], r["mesh"])] = r
    return by_cell


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.1f}"


def dryrun_table(cells) -> str:
    out = ["| arch | shape | mesh | chips | GiB/dev | HLO TFLOP/dev | "
           "HBM GB/dev | coll GB/dev | compile s |",
           "|---|---|---|---:|---:|---:|---:|---:|---:|"]
    for (a, s, m), r in sorted(cells.items()):
        if r["status"] == "skipped":
            out.append(f"| {a} | {s} | {m} | — | — | — | — | — | skipped: "
                       f"{r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | {m} | — | — | — | — | — | "
                       f"**{r['status']}** |")
            continue
        rl = r["roofline"]
        out.append(
            f"| {a} | {s} | {m} | {r['chips']} "
            f"| {fmt_bytes(r['memory']['per_device_total'])} "
            f"| {rl['hlo_flops'] / 1e12:.2f} "
            f"| {rl['hlo_bytes'] / 1e9:.1f} "
            f"| {rl['coll_bytes'] / 1e9:.2f} "
            f"| {r['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table(cells) -> str:
    out = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
           "useful | step s (max) | MFU |",
           "|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for (a, s, m), r in sorted(cells.items()):
        if m != "single" or r["status"] != "ok":
            continue
        rl = r["roofline"]
        out.append(
            f"| {a} | {s} | {rl['t_compute']:.3g} | {rl['t_memory']:.3g} "
            f"| {rl['t_collective']:.3g} | **{rl['bottleneck']}** "
            f"| {rl['useful_ratio']:.2f} | {rl['step_time']:.3g} "
            f"| {rl['mfu'] * 100:.1f}% |")
    return "\n".join(out)


def summary(cells) -> str:
    ok = sum(r["status"] == "ok" for r in cells.values())
    sk = sum(r["status"] == "skipped" for r in cells.values())
    bad = len(cells) - ok - sk
    return (f"{len(cells)} cells: {ok} compiled OK, {sk} skipped "
            f"(documented long_500k inapplicability), {bad} failed")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl"
    cells = load(path)
    print("## Dry-run —", summary(cells))
    print()
    print(dryrun_table(cells))
    print()
    print("## Roofline (single-pod 16x16, 256 chips)")
    print()
    print(roofline_table(cells))


if __name__ == "__main__":
    main()
