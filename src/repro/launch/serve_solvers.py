"""Mixed-traffic solver serving launcher: replay a PUSCH-style trace
through the registry-driven SolverMux and report SLO metrics.

A 5G PUSCH receiver processes traffic in TTI slots; each slot carries a
mix of per-subcarrier-group MMSE equalizations (the bulk), plus control-
path Cholesky solves (noise-covariance whitening) and QR least squares
(channel estimation refits), at several antenna/user sizes.  This
launcher synthesizes that trace on a virtual clock, submits each slot's
jobs with a per-slot deadline, ``poll``s the mux once per slot (full
lane groups dispatch immediately; partials wait for deadline / age /
pressure), drains at the end, checks a sample of results against the
registry oracles, and prints per-pipeline p50/p99 latency, throughput,
lane utilization, and padded-lane waste.

  PYTHONPATH=src python -m repro.launch.serve_solvers \
      --slots 8 --lanes 8 --deadline-ms 2.0
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import kernels as K
from repro.kernels.common import sample_spd
from repro.serve import ManualClock, SolverMux

SLOT_MS = 0.5          # 5G numerology-1 TTI


def build_slot_jobs(rng, slot: int, sizes: list[int]):
    """One TTI's job mix: (pipeline, args) tuples.  Alternate MMSE jobs
    arrive as SPLIT re/im planes (the form a real front end produces) —
    the mux routes their 4-arg buckets to the split_complex variant."""
    jobs = []
    for n in sizes:
        m = n + 4
        # MMSE bulk: a few subcarrier groups per size per slot
        for i in range(2 + slot % 2):
            if i % 2:
                jobs.append(("mmse_equalize", (
                    rng.standard_normal((m, n)).astype(np.float32),
                    rng.standard_normal((m, n)).astype(np.float32),
                    rng.standard_normal((m, 2)).astype(np.float32),
                    rng.standard_normal((m, 2)).astype(np.float32))))
            else:
                h = rng.standard_normal((m, n)).astype(np.float32)
                y = rng.standard_normal((m, 2)).astype(np.float32)
                jobs.append(("mmse_equalize", (h, y)))
        # control path: whitening solve + channel refit, not every slot
        if slot % 2 == 0:
            a = sample_spd(rng, 1, n)[0]
            b = rng.standard_normal((n, 2)).astype(np.float32)
            jobs.append(("cholesky_solve", (a, b)))
        if slot % 3 == 0:
            qa = rng.standard_normal((m, n)).astype(np.float32)
            qb = rng.standard_normal((m, 1)).astype(np.float32)
            jobs.append(("qr_solve", (qa, qb)))
    return jobs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8,
                    help="trace length in TTI slots")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--sizes", default="8,12",
                    help="comma-separated antenna sizes n (m = n + 4)")
    ap.add_argument("--deadline-ms", type=float, default=2.0,
                    help="per-job deadline after arrival (virtual ms)")
    ap.add_argument("--max-wait-ms", type=float, default=1.0,
                    help="partial-bucket age flush threshold (virtual ms)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    sizes = [int(s) for s in args.sizes.split(",")]

    rng = np.random.default_rng(args.seed)
    clock = ManualClock()
    mux = SolverMux(lanes=args.lanes, max_wait=args.max_wait_ms * 1e-3,
                    clock=clock)

    t0 = time.perf_counter()
    done, sample = [], None
    for slot in range(args.slots):
        for pipeline, job_args in build_slot_jobs(rng, slot, sizes):
            job = mux.submit(pipeline, *job_args,
                             deadline=clock() + args.deadline_ms * 1e-3)
            if sample is None and pipeline == "mmse_equalize":
                sample = job
        done.extend(mux.poll())
        clock.advance(SLOT_MS * 1e-3)
    done.extend(mux.run())
    wall = time.perf_counter() - t0
    assert not mux.pending(), "mux left jobs queued after drain"

    if not done:
        print(f"empty trace ({args.slots} slots): nothing served")
        return

    # spot-check a served result against the registry oracle
    sample = sample or done[0]
    want = K.get(sample.pipeline).run_oracle_lane(*sample.args)
    err = np.max(np.abs(sample.out - want)) / (np.max(np.abs(want)) + 1e-12)
    assert err < 1e-3, f"oracle mismatch on sample job: rel err {err:.2e}"

    snap = mux.metrics()
    print(f"trace: {args.slots} slots x sizes {sizes}, lanes={args.lanes} "
          f"-> {snap.total_jobs} jobs in {snap.total_launches} grid "
          f"launches ({wall:.2f}s wall, oracle check ok)")
    hdr = (f"{'pipeline':<16} {'jobs':>5} {'launch':>6} {'util':>6} "
           f"{'waste':>6} {'p50_ms':>8} {'p99_ms':>8} {'jobs/s':>10} "
           f"dispatch")
    print(hdr)
    print("-" * len(hdr))
    for name, st in sorted(snap.pipelines.items()):
        counts = ",".join(f"{v}:{c}" for v, c in
                          sorted(st.dispatch_counts.items()))
        print(f"{name:<16} {st.jobs:>5} {st.launches:>6} "
              f"{st.lane_utilization:>6.2f} {st.padded_lane_waste:>6.2f} "
              f"{st.latency.p50 * 1e3:>8.3f} {st.latency.p99 * 1e3:>8.3f} "
              f"{st.throughput:>10.1f} {counts}")
    missed = sum(1 for j in done
                 if j.deadline is not None and j.finished_at > j.deadline)
    print(f"deadline misses (virtual clock): {missed}/{len(done)}")


if __name__ == "__main__":
    main()
