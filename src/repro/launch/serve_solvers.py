"""Mixed-traffic solver serving launcher: replay a PUSCH-style trace
through the registry-driven SolverMux and report SLO metrics.

A 5G PUSCH receiver processes traffic in TTI slots; each slot carries a
mix of per-subcarrier-group MMSE equalizations (the bulk), plus control-
path Cholesky solves (noise-covariance whitening) and QR least squares
(channel estimation refits), at several antenna/user sizes.  This
launcher synthesizes that trace on a virtual clock — every job carries a
priority class (control-path solves and half the MMSE bulk are
``hard``-deadline; the rest is ``best_effort`` refinement traffic) —
submits each slot's jobs with a per-slot deadline, ``poll``s the mux
once per slot (full lane groups dispatch immediately; partials wait for
deadline / age / pressure), drains at the end, checks a sample of
results against the registry oracles, and prints per-pipeline p50/p99
latency (overall and per priority), throughput, lane utilization,
padded-lane waste, and — with ``--policy`` — the overload counters
(dropped / preempted / coalesced) and hard-deadline SLO attainment.

  PYTHONPATH=src python -m repro.launch.serve_solvers \
      --slots 8 --lanes 8 --deadline-ms 2.0 --policy

Two helpers here are shared infrastructure rather than CLI plumbing:

* :func:`run_overload` — the deterministic synthetic overload scenario
  (offered load >= 2x lane capacity, mixed priorities, virtual clock)
  behind ``benchmarks.bench_pipelines.run_slo``'s overload sweep and the
  SLO-attainment acceptance test.
* :func:`replay_trace` / :func:`load_trace` — replay a committed JSON
  trace (each entry a seed-keyed job, never raw arrays) through a mux on
  a virtual clock, returning the mux so callers can assert on its
  ``events`` decision log (the golden trace-replay regression test).
* :func:`run_chaos` — the seeded chaos-replay scenario (committed fault
  trace + mesh of lane shards) behind ``run_slo``'s ``serve_slo/faults``
  rows and the fault-tolerance acceptance test: launch failures, NaN
  lanes, and a blackholed shard injected into the mixed-priority trace,
  with the supervision/quarantine/demotion observables summarized.

Chaos flags: ``--fault-trace tests/data/fault_trace.json`` attaches a
seeded :class:`~repro.serve.faults.FaultInjector` to the TTI replay;
``--chaos`` runs the canonical chaos scenario instead (requires
``--fault-trace``; ``--fault-seed`` overrides the trace seed).
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

# launcher module: 8 virtual CPU devices (merged into XLA_FLAGS before
# the first jax import; an explicit device count in the env wins) so
# --mesh N and run_sharded_overload work standalone on a CPU-only host
from repro.launch.xla_env import force_host_device_count

force_host_device_count(8)

from repro import kernels as K
from repro.kernels.common import sample_spd
from repro.serve import CostModel, ManualClock, OverloadPolicy, SolverMux

SLOT_MS = 0.5          # 5G numerology-1 TTI


def job_args(pipeline: str, n: int, k: int, seed: int) -> tuple:
    """Deterministic per-job problem arrays, keyed by seed — the form
    committed traces store jobs in (never raw arrays)."""
    rng = np.random.default_rng(seed)
    if pipeline == "cholesky_solve":
        return (sample_spd(rng, 1, n)[0],
                rng.standard_normal((n, k)).astype(np.float32))
    m = n + 4
    return (rng.standard_normal((m, n)).astype(np.float32),
            rng.standard_normal((m, k)).astype(np.float32))


def hard_attainment(jobs) -> float:
    """Fraction of hard-deadline jobs that finished by their deadline
    (dropped or late = miss).  NaN when the trace has no hard jobs."""
    hard = [j for j in jobs
            if j.priority == "hard" and j.deadline is not None]
    if not hard:
        return math.nan
    met = sum(1 for j in hard
              if j.state == "done" and j.finished_at <= j.deadline)
    return met / len(hard)


def build_slot_jobs(rng, slot: int, sizes: list[int]):
    """One TTI's job mix: (pipeline, args, priority) tuples.  Alternate
    MMSE jobs arrive as SPLIT re/im planes (the form a real front end
    produces) — the mux routes their 4-arg buckets to the split_complex
    variant.  Control-path solves and the even MMSE groups are hard-
    deadline; odd MMSE groups are best-effort refinement passes."""
    jobs = []
    for n in sizes:
        m = n + 4
        # MMSE bulk: a few subcarrier groups per size per slot
        for i in range(2 + slot % 2):
            priority = "hard" if i % 2 == 0 else "best_effort"
            if i % 2:
                jobs.append(("mmse_equalize", (
                    rng.standard_normal((m, n)).astype(np.float32),
                    rng.standard_normal((m, n)).astype(np.float32),
                    rng.standard_normal((m, 2)).astype(np.float32),
                    rng.standard_normal((m, 2)).astype(np.float32)),
                    priority))
            else:
                h = rng.standard_normal((m, n)).astype(np.float32)
                y = rng.standard_normal((m, 2)).astype(np.float32)
                jobs.append(("mmse_equalize", (h, y), priority))
        # control path: whitening solve + channel refit, not every slot
        if slot % 2 == 0:
            a = sample_spd(rng, 1, n)[0]
            b = rng.standard_normal((n, 2)).astype(np.float32)
            jobs.append(("cholesky_solve", (a, b), "hard"))
        if slot % 3 == 0:
            qa = rng.standard_normal((m, n)).astype(np.float32)
            qb = rng.standard_normal((m, 1)).astype(np.float32)
            jobs.append(("qr_solve", (qa, qb), "hard"))
    return jobs


# ---------------- committed-trace replay (golden tests) ----------------

def load_trace(path: str) -> list[dict]:
    """A committed trace: a JSON list of job entries
    ``{"tick", "pipeline", "n", "k", "priority", "deadline_ticks",
    "seed"}`` — ``deadline_ticks`` null means no deadline."""
    with open(path) as f:
        return json.load(f)


def replay_trace(trace: list[dict], *, lanes: int = 4, tick: float = 1.0,
                 policy: OverloadPolicy | None = None,
                 max_wait: float | None = None,
                 pressure: int | None = None,
                 drain_ticks: int = 2) -> SolverMux:
    """Replay a committed trace on a virtual clock: submit each tick's
    jobs, ``poll`` once per tick, keep polling ``drain_ticks`` empty
    ticks, then ``run()``.  Returns the mux — its ``events`` list is the
    exact flush/drop/preempt/coalesce decision sequence a golden file
    pins."""
    clock = ManualClock()
    mux = SolverMux(lanes=lanes, max_wait=max_wait, pressure=pressure,
                    clock=clock, policy=policy)
    by_tick: dict[int, list[dict]] = {}
    for entry in trace:
        by_tick.setdefault(int(entry["tick"]), []).append(entry)
    last = max(by_tick) if by_tick else -1
    for t in range(last + 1 + drain_ticks):
        for e in by_tick.get(t, ()):
            deadline = e.get("deadline_ticks")
            mux.submit(e["pipeline"],
                       *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                       deadline=(None if deadline is None
                                 else clock() + deadline * tick),
                       priority=e.get("priority", "best_effort"))
        mux.poll()
        clock.advance(tick)
    mux.run()
    return mux


# ---------------- synthetic overload scenario (bench + tests) ----------

OVERLOAD_TICK = 1.0


def overload_trace(ticks: int, lanes: int, seed: int = 0) -> list[dict]:
    """Synthetic overload: per tick, ``3.5 * lanes`` jobs arrive against
    a budget of ~2 launches = ``2 * lanes`` job-slots — offered load
    well over 2x lane capacity in launch terms (the hard MMSE chunk, two
    best-effort MMSE chunks, and the partial Cholesky buckets each need
    their own launch).  The mix:

      * ``lanes`` hard MMSE bulk (deadline 3 ticks) — the traffic the
        SLO is judged by,
      * ``2 * lanes`` best-effort MMSE refinement with a tight 1.2-tick
        deadline: under EDF admission these outrank the hard chunks
        (earlier deadlines) until preemption steps in, and once expired
        they are dead weight unless shed,
      * 1 hard n=12 Cholesky whitening solve (deadline 2 ticks) — a
        chronically partial bucket, and
      * 1 best-effort n=8 Cholesky solve (deadline 2 ticks) — the
        coalescing donor that can ride the n=12 partials' free lanes.
    """
    trace, seq = [], 0
    for t in range(ticks):
        for i in range(lanes):
            trace.append(dict(tick=t, pipeline="mmse_equalize", n=8, k=2,
                              priority="hard", deadline_ticks=3.0,
                              seed=seed * 100003 + seq)); seq += 1
        for i in range(2 * lanes):
            trace.append(dict(tick=t, pipeline="mmse_equalize", n=8, k=2,
                              priority="best_effort", deadline_ticks=1.2,
                              seed=seed * 100003 + seq)); seq += 1
        trace.append(dict(tick=t, pipeline="cholesky_solve", n=12,
                          k=2, priority="hard", deadline_ticks=2.0,
                          seed=seed * 100003 + seq)); seq += 1
        trace.append(dict(tick=t, pipeline="cholesky_solve", n=8,
                          k=2, priority="best_effort",
                          deadline_ticks=2.0,
                          seed=seed * 100003 + seq)); seq += 1
    return trace


def run_overload(policy: bool, *, ticks: int = 8, lanes: int = 4,
                 seed: int = 0, adaptive: bool = False) -> dict:
    """Run the synthetic overload trace with the SAME lane-time budget
    in both modes; ``policy=True`` additionally enables shedding,
    preemption, and coalescing.  Returns the summary the SLO benchmark
    emits and the acceptance test asserts on.

    ``adaptive=True`` runs the cost model with online calibration ON
    (real wall-clock measurements feed :meth:`CostModel.observe`) and
    adds the drift-observability fields (``drift`` /
    ``calibration_updates``) to the summary — the source of the
    ``serve_slo/drift/*`` rows in the persisted bench baseline."""
    cm = CostModel(adaptive=adaptive)
    spec = K.get("mmse_equalize")
    unit = cm.launch_cost("mmse_equalize", spec.base,
                          ((12, 8), (12, 2)), lanes)
    pol = OverloadPolicy(shed=policy, preempt=policy, coalesce=policy,
                         budget=2.0 * unit, cost_model=cm)
    trace = overload_trace(ticks, lanes, seed)
    jobs, clock = [], ManualClock()
    mux = SolverMux(lanes=lanes, clock=clock, pressure=2 * lanes,
                    policy=pol)
    by_tick: dict[int, list[dict]] = {}
    for entry in trace:
        by_tick.setdefault(entry["tick"], []).append(entry)
    for t in range(ticks + ticks):        # arrival ticks + drain ticks
        for e in by_tick.get(t, ()):
            jobs.append(mux.submit(
                e["pipeline"],
                *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                deadline=clock() + e["deadline_ticks"] * OVERLOAD_TICK,
                priority=e["priority"]))
        mux.poll()
        clock.advance(OVERLOAD_TICK)
    mux.run()
    snap = mux.metrics()
    summary = {
        "policy": policy,
        "jobs": len(jobs),
        "done": sum(1 for j in jobs if j.state == "done"),
        "attainment_hard": hard_attainment(jobs),
        "dropped": snap.total_dropped,
        "hard_dropped": sum(1 for j in jobs
                            if j.priority == "hard"
                            and j.state == "dropped"),
        "preempted": snap.total_preempted,
        "coalesced": snap.total_coalesced,
        "launches": snap.total_launches,
    }
    if adaptive:
        summary["drift"] = {
            key: {"ratio": st.ratio, "updates": st.updates,
                  "source": st.source, "alert": st.alert}
            for key, st in snap.drift.items() if st.updates > 0}
        summary["calibration_updates"] = snap.calibration_updates
    return summary


def run_sharded_overload(mesh_size: int, *, ticks: int = 6,
                         lanes: int = 4, load_lanes: int | None = None,
                         seed: int = 0) -> dict:
    """Virtual-clock replay of the committed overload trace against a
    mesh of ``mesh_size`` lane shards — the scaling scenario behind
    ``benchmarks.bench_pipelines.run_slo``'s ``serve_slo/sharded/*``
    rows.

    The offered load is generated for ``load_lanes`` lanes (default
    ``8 * lanes`` — saturating even the largest swept mesh) and replayed
    over a FIXED virtual window of ``2 * ticks`` one-tick polls with NO
    final drain, so ``throughput`` measures steady-state capacity at
    this mesh size, not how fast a drain call empties the queue.  Every
    mesh size sees the identical trace and window; only the lane-pool
    capacity (``lanes * mesh_size``) changes.

    Returns the summary the benchmark emits: aggregate job throughput
    (jobs per virtual tick), hard-SLO attainment, launch counts (total
    and mesh-spanning), per-shard lane utilization, and the measured
    per-(pipeline, variant, mesh) calibration rows ``from_bench_json``
    re-fits shard overheads from."""
    if load_lanes is None:
        load_lanes = 8 * lanes
    cm = CostModel()
    spec = K.get("mmse_equalize")
    unit = cm.launch_cost("mmse_equalize", spec.base,
                          ((12, 8), (12, 2)), lanes)
    pol = OverloadPolicy(budget=2.0 * mesh_size * unit, cost_model=cm)
    trace = overload_trace(ticks, load_lanes, seed)
    jobs, clock = [], ManualClock()
    mux = SolverMux(lanes=lanes, clock=clock, pressure=2 * lanes,
                    policy=pol, mesh_size=mesh_size)
    by_tick: dict[int, list[dict]] = {}
    for entry in trace:
        by_tick.setdefault(entry["tick"], []).append(entry)
    for t in range(ticks + ticks):        # arrival ticks + drain ticks
        for e in by_tick.get(t, ()):
            jobs.append(mux.submit(
                e["pipeline"],
                *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                deadline=clock() + e["deadline_ticks"] * OVERLOAD_TICK,
                priority=e["priority"]))
        mux.poll()
        clock.advance(OVERLOAD_TICK)
    # NO mux.run(): the window is fixed, so throughput compares capacity
    window = 2 * ticks * OVERLOAD_TICK
    snap = mux.metrics()
    done = sum(1 for j in jobs if j.state == "done")
    spanning = sum(1 for l in snap.launches if l.mesh > 1)
    if snap.shards:
        shard_util = {s: st.utilization for s, st in snap.shards.items()}
    else:
        real = sum(l.real for l in snap.launches)
        width = sum(l.real + l.padded for l in snap.launches)
        shard_util = {0: (real / width) if width else 0.0}
    calibration = []
    by_pvm: dict[tuple, list] = {}
    for l in snap.launches:
        if not math.isnan(l.measured):
            by_pvm.setdefault((l.pipeline, l.variant, l.mesh),
                              []).append(l)
    for (pipeline, vname, mesh), recs in sorted(by_pvm.items()):
        pspec = K.get(pipeline)
        variant = pspec.base if vname == "base" else \
            next(v for v in pspec.variants if v.name == vname)
        shapes = tuple(tuple(shape) for shape, _ in recs[0].shape)
        walls = sorted(l.measured for l in recs)
        calibration.append({
            "pipeline": pipeline, "variant": vname, "mesh": mesh,
            "lanes": recs[0].real + recs[0].padded,
            "wall_us": walls[len(walls) // 2] * 1e6,
            "model_flops": variant.model_flops(shapes),
        })
    return {
        "mesh": mesh_size,
        "jobs": len(jobs),
        "done": done,
        "throughput": done / window,
        "attainment_hard": hard_attainment(jobs),
        "dropped": snap.total_dropped,
        "launches": snap.total_launches,
        "spanning": spanning,
        "shard_util": shard_util,
        "imbalance": snap.shard_imbalance,
        "pending": mux.pending(),
        "calibration": calibration,
    }


# ---------------- seeded chaos replay (faults bench + tests) ----------

def chaos_trace(ticks: int, lanes: int, seed: int = 0) -> list[dict]:
    """The canonical chaos workload: per tick, ``lanes`` hard MMSE
    equalizations (deadline 3 ticks — the SLO traffic), ``lanes``
    best-effort MMSE refinements (deadline 2 ticks), and one hard n=128
    Cholesky whitening solve (deadline 3 ticks) whose bucket dispatches
    to the *blocked* variant — the target the committed fault trace
    shoots at to force a variant demotion."""
    trace, seq = [], 0
    for t in range(ticks):
        for i in range(lanes):
            trace.append(dict(tick=t, pipeline="mmse_equalize", n=8, k=2,
                              priority="hard", deadline_ticks=3.0,
                              seed=seed * 100003 + seq)); seq += 1
        for i in range(lanes):
            trace.append(dict(tick=t, pipeline="mmse_equalize", n=8, k=2,
                              priority="best_effort", deadline_ticks=2.0,
                              seed=seed * 100003 + seq)); seq += 1
        trace.append(dict(tick=t, pipeline="cholesky_solve", n=128,
                          k=2, priority="hard", deadline_ticks=3.0,
                          seed=seed * 100003 + seq)); seq += 1
    return trace


def run_chaos(fault_trace: str | dict | None, *, mesh_size: int = 4,
              ticks: int = 10, lanes: int = 2, seed: int = 0,
              fault_seed: int = 0) -> dict:
    """Replay the chaos workload against a ``mesh_size`` lane mesh with
    the given fault trace injected (``None``: the fault-free reference
    run the attainment ratio is judged against).  Deterministic end to
    end — virtual clock, seed-keyed jobs, seed-keyed faults — so the
    event stream is golden-file-pinnable.

    Returns the summary ``benchmarks.bench_pipelines.run_slo`` emits as
    ``serve_slo/faults/*`` rows: hard-SLO attainment, per-state job
    counts, ``hard_lost`` (hard jobs left in no terminal state, or
    failed without a structured reason — must be zero), the supervision
    observables (retries / failed jobs / quarantines / reinstatements /
    demotions), and the drained event stream."""
    import os

    from repro.serve import FaultInjector
    if fault_trace is None:
        injector = None
    elif isinstance(fault_trace, (str, os.PathLike)):
        injector = FaultInjector.from_json(fault_trace, seed=fault_seed)
    else:
        injector = FaultInjector(fault_trace, seed=fault_seed)
    pol = OverloadPolicy(budget=None, cost_model=CostModel())
    trace = chaos_trace(ticks, lanes, seed)
    jobs, clock = [], ManualClock()
    mux = SolverMux(lanes=lanes, clock=clock, pressure=2 * lanes,
                    policy=pol, mesh_size=mesh_size, injector=injector)
    by_tick: dict[int, list[dict]] = {}
    for entry in trace:
        by_tick.setdefault(entry["tick"], []).append(entry)
    for t in range(ticks + ticks):        # arrival ticks + drain ticks
        for e in by_tick.get(t, ()):
            jobs.append(mux.submit(
                e["pipeline"],
                *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                deadline=clock() + e["deadline_ticks"] * OVERLOAD_TICK,
                priority=e["priority"]))
        mux.poll()
        clock.advance(OVERLOAD_TICK)
    mux.run()
    snap = mux.metrics()
    hard = [j for j in jobs if j.priority == "hard"]
    return {
        "faulted": injector is not None,
        "mesh": mesh_size,
        "jobs": len(jobs),
        "done": sum(1 for j in jobs if j.state == "done"),
        "failed": sum(1 for j in jobs if j.state == "failed"),
        "dropped": snap.total_dropped,
        "hard_failed": sum(1 for j in hard if j.state == "failed"),
        # a hard job is LOST iff it reached no terminal state or failed
        # without a structured reason — the acceptance gate is zero
        "hard_lost": sum(1 for j in hard
                         if j.state not in ("done", "failed", "dropped")
                         or (j.state == "failed" and not j.reason)),
        "attainment_hard": hard_attainment(jobs),
        "retries": snap.faults.retries,
        "failed_jobs": snap.faults.failed_jobs,
        "quarantines": snap.faults.quarantines,
        "reinstatements": snap.faults.reinstatements,
        "demotions": snap.faults.demotions,
        "time_to_recover": snap.faults.time_to_recover,
        "alerts": list(snap.faults.alerts),
        "pending": mux.pending(),
        "events": mux.drain_events(),
    }


# ---------------- served PUSCH DAG (bench + golden tests) -------------

def dag_job_args(dag: str, n: int, seed: int) -> tuple:
    """Deterministic per-DAG-job problem arrays, keyed by seed — the
    form committed DAG traces store jobs in (never raw arrays)."""
    return K.get_dag(dag).make_case(np.random.default_rng(seed), n)


def pusch_trace(ticks: int, seed: int = 0, *,
                chained: bool = False) -> list[dict]:
    """The canonical served-DAG workload: one hard ``pusch_receive``
    DAG per tick plus one best-effort ``svd_solve`` DAG every other
    tick (the generality traffic).  The PUSCH deadlines are *staggered
    to the same absolute tick* in pairs (tick t gets ``8 - t % 2``
    ticks), so consecutive DAGs compete at EQUAL deadline while sitting
    at different stages — the window where criticality-first admission
    is observable: the later DAG's critical channel-estimate stage must
    flush ahead of the earlier DAG's slack equalize stage (plain
    FIFO/seq order would invert that), which the golden event stream
    pins."""
    trace, seq = [], 0
    for t in range(ticks):
        trace.append(dict(tick=t, dag="pusch_receive", n=8,
                          priority="hard",
                          deadline_ticks=8.0 - t % 2,
                          chained=chained,
                          seed=seed * 100003 + seq)); seq += 1
        if t % 2 == 0:
            trace.append(dict(tick=t, dag="svd_solve", n=8,
                              priority="best_effort",
                              deadline_ticks=12.0, chained=False,
                              seed=seed * 100003 + seq)); seq += 1
    return trace


def replay_pusch(trace: list[dict], *, lanes: int = 4, tick: float = 1.0,
                 drain_ticks: int = 6, injector=None,
                 mesh_size: int | None = None):
    """Replay a committed DAG trace on a virtual clock: submit each
    tick's DAGs, ``poll`` once per tick (each poll serves the ready
    stage frontier and advances the DAGs), keep polling ``drain_ticks``
    empty ticks, then ``run()``.  Returns ``(mux, dag_jobs)`` — the
    mux's ``events`` list is the stage-scheduling decision sequence the
    golden file pins."""
    clock = ManualClock()
    mux = SolverMux(lanes=lanes, max_wait=0.0, clock=clock,
                    policy=OverloadPolicy(budget=None,
                                          cost_model=CostModel()),
                    mesh_size=mesh_size, injector=injector)
    by_tick: dict[int, list[dict]] = {}
    for entry in trace:
        by_tick.setdefault(int(entry["tick"]), []).append(entry)
    last = max(by_tick) if by_tick else -1
    dags = []
    for t in range(last + 1 + drain_ticks):
        for e in by_tick.get(t, ()):
            deadline = e.get("deadline_ticks")
            dags.append(mux.submit_dag(
                e["dag"], *dag_job_args(e["dag"], e["n"], e["seed"]),
                deadline=(None if deadline is None
                          else clock() + deadline * tick),
                priority=e.get("priority", "best_effort"),
                chained=e.get("chained", False)))
        mux.poll()
        clock.advance(tick)
    mux.run()
    return mux, dags


def dag_hard_lost(dags) -> int:
    """Hard DAGs (or their stages) left unaccounted: a hard DAG is LOST
    iff it reached no terminal state, or any submitted stage job is
    neither terminal nor explicitly cancelled — the acceptance gate is
    zero (a mid-DAG fault must cascade cleanly, never orphan)."""
    lost = 0
    for d in dags:
        if d.priority != "hard":
            continue
        if d.state not in ("done", "failed", "dropped"):
            lost += 1
            continue
        for stage in d.spec.stage_list(chained=d.chained):
            sj = d.stages.get(stage.name)
            if sj == "cancelled":
                continue
            if sj is None or sj.state not in ("done", "failed",
                                              "dropped"):
                lost += 1
                break
    return lost


def run_pusch(chained: bool, *, ticks: int = 4, lanes: int = 4,
              seed: int = 0, fault_trace: str | dict | None = None,
              fault_seed: int = 0) -> dict:
    """Run the canonical PUSCH DAG trace end to end — stage-independent
    (``chained=False``: FFT -> channel-estimate -> equalize as three
    launches with buffer handoffs) or stage-chained (``chained=True``:
    the channel-estimate->equalize tail fused lane-resident in one
    ``pallas_call``) — and summarize the end-to-end SLO view the
    ``serve_slo/dag/*`` benchmark rows gate: e2e p50/p99 latency in
    virtual ticks, launch counts, and (under an injected fault trace)
    the containment observables with ``hard_lost`` required zero."""
    import os

    from repro.serve import FaultInjector
    if fault_trace is None:
        injector = None
    elif isinstance(fault_trace, (str, os.PathLike)):
        injector = FaultInjector.from_json(fault_trace, seed=fault_seed)
    else:
        injector = FaultInjector(fault_trace, seed=fault_seed)
    trace = pusch_trace(ticks, seed, chained=chained)
    mux, dags = replay_pusch(trace, lanes=lanes, injector=injector)
    snap = mux.metrics()
    pstats = snap.dags.get("pusch_receive")
    pusch = [d for d in dags if d.dag == "pusch_receive"]
    return {
        "chained": chained,
        "faulted": injector is not None,
        "dags": len(dags),
        "pusch_dags": len(pusch),
        "done": sum(1 for d in dags if d.state == "done"),
        "failed": sum(1 for d in dags if d.state == "failed"),
        "dropped": sum(1 for d in dags if d.state == "dropped"),
        "hard_lost": dag_hard_lost(dags),
        "e2e_p50": pstats.latency.p50 if pstats else math.nan,
        "e2e_p99": pstats.latency.p99 if pstats else math.nan,
        "launches": snap.total_launches,
        "retries": snap.faults.retries,
        "failed_jobs": snap.faults.failed_jobs,
        "pending": mux.pending(),
        "events": mux.drain_events(),
    }


# ---------------- mixed solver + decode traffic ----------------

_DECODE_MODEL = None


def decode_model():
    """The smoke-scale LM ``(cfg, params)`` shared by every decode
    scenario in this launcher — deterministic (fixed init key) and
    built once per process (transformer init is the expensive part)."""
    global _DECODE_MODEL
    if _DECODE_MODEL is None:
        import jax

        from repro.configs import get_smoke
        from repro.models import transformer as T
        cfg = get_smoke("phi4-mini-3.8b")
        _DECODE_MODEL = (cfg, T.init_params(jax.random.key(0), cfg))
    return _DECODE_MODEL


def decode_prompt(length: int, seed: int) -> list[int]:
    """Deterministic seed-keyed prompt tokens — the form committed
    decode traces store prompts in (never raw token arrays)."""
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(2, 500, size=length)]


def decode_trace(ticks: int, seed: int = 0) -> list[dict]:
    """The canonical mixed solver+decode workload: per tick, one hard
    and one best-effort MMSE bulk chunk (solver lane traffic) plus two
    decode requests — one hard greedy, one best-effort (periodically
    sampled) — with prompt/output lengths that VARY per tick.  The
    heterogeneity is the point: lockstep pool decode runs every pool
    member to the longest prompt and longest ``max_new`` of its
    generation and rebuilds the cache between pools, so on this trace
    continuous per-slot batching strictly beats it in tokens per SPMD
    step at the same budget — the acceptance gate the committed
    ``serve_slo/decode/*`` rows pin."""
    trace, seq = [], 0
    for t in range(ticks):
        for i in range(2):
            trace.append(dict(
                tick=t, kind="solve", pipeline="mmse_equalize", n=8, k=2,
                priority="hard" if i == 0 else "best_effort",
                deadline_ticks=3.0, seed=seed * 100003 + seq))
            seq += 1
        trace.append(dict(
            tick=t, kind="decode", prompt_len=1 + t % 4,
            max_new=2 + (3 * t) % 7, temperature=0.0, priority="hard",
            deadline_ticks=8.0, seed=seed * 100003 + seq))
        seq += 1
        trace.append(dict(
            tick=t, kind="decode", prompt_len=1 + (t * 2) % 5,
            max_new=1 + (t * 5) % 9,
            temperature=1.0 if t % 3 == 0 else 0.0,
            priority="best_effort", deadline_ticks=12.0,
            seed=seed * 100003 + seq))
        seq += 1
    return trace


def replay_decode(trace: list[dict], *, lanes: int = 4,
                  slots: int | None = None, max_len: int = 64,
                  tick: float = 1.0, drain_ticks: int = 4,
                  lockstep: bool = False):
    """Replay a committed mixed solver+decode trace on a virtual clock:
    submit each tick's solver jobs and decode requests, ``poll`` once
    per tick (the attached policy round serves solver flushes AND up to
    ``decode_steps_per_poll`` continuous-batching decode steps), keep
    polling ``drain_ticks`` empty ticks, then ``run()``.  Returns
    ``(mux, engine, requests, jobs)`` — the mux's event list interleaves
    solver flush decisions with decode insert/step/done decisions, the
    sequence ``tests/data/decode_golden.json`` pins byte-for-byte.

    The replay engine uses ``eos_id=-1`` (token ids are non-negative,
    so EOS never fires): every request runs exactly ``max_new`` steps
    and the scheduling decision sequence depends only on the trace's
    lengths — never on model floating point — keeping the golden file
    platform-independent.  (EOS semantics are pinned separately by the
    unit suite.)

    ``lockstep=True`` is the equal-budget baseline: the SAME trace,
    clock, mux and solver path, but the engine is NOT attached — decode
    requests go straight to its FIFO and each tick runs one lockstep
    pool drain (:meth:`~repro.serve.decode.DecodeEngine.run_lockstep`)
    instead of continuous steps."""
    from repro.serve import global_config
    from repro.serve.decode import DecodeEngine, Request
    cfg, params = decode_model()
    clock = ManualClock()
    slots = global_config.decode_slots if slots is None else slots
    engine = DecodeEngine(cfg, params, batch=slots, max_len=max_len,
                          eos_id=-1, clock=clock)
    mux = SolverMux(lanes=lanes, max_wait=0.0, clock=clock,
                    policy=OverloadPolicy(budget=None,
                                          cost_model=CostModel()))
    if not lockstep:
        mux.attach_decode(engine)
    by_tick: dict[int, list[dict]] = {}
    for entry in trace:
        by_tick.setdefault(int(entry["tick"]), []).append(entry)
    last = max(by_tick) if by_tick else -1
    requests, jobs = [], []
    for t in range(last + 1 + drain_ticks):
        for e in by_tick.get(t, ()):
            deadline = e.get("deadline_ticks")
            deadline = None if deadline is None \
                else clock() + deadline * tick
            if e.get("kind") == "decode":
                r = Request(
                    prompt=decode_prompt(e["prompt_len"], e["seed"]),
                    max_new=e["max_new"],
                    temperature=e.get("temperature", 0.0))
                if lockstep:
                    r.priority = e.get("priority", "best_effort")
                    r.deadline = deadline
                    engine.submit(r)
                else:
                    mux.submit_decode(
                        r, deadline=deadline,
                        priority=e.get("priority", "best_effort"))
                requests.append(r)
            else:
                jobs.append(mux.submit(
                    e["pipeline"],
                    *job_args(e["pipeline"], e["n"], e["k"], e["seed"]),
                    deadline=deadline,
                    priority=e.get("priority", "best_effort")))
        mux.poll()
        if lockstep:
            engine.run_lockstep()
        clock.advance(tick)
    mux.run()
    if lockstep:
        engine.run_lockstep()
    return mux, engine, requests, jobs


def run_decode_serve(continuous: bool, *, ticks: int = 6, lanes: int = 4,
                     seed: int = 0) -> dict:
    """Run the canonical mixed solver+decode trace end to end —
    continuous per-slot batching through the mux (``continuous=True``)
    or the preserved lockstep pool baseline at the same budget — and
    summarize the view the ``serve_slo/decode/*`` benchmark rows gate:
    tokens per SPMD step (the throughput the continuous path must
    strictly win), per-phase latency, slot reuses, and ``hard_lost``
    (hard solver jobs not done + hard decode requests not finished)
    required zero."""
    trace = decode_trace(ticks, seed)
    mux, engine, requests, jobs = replay_decode(trace, lanes=lanes,
                                                lockstep=not continuous)
    snap = mux.metrics() if continuous else engine.metrics()
    d = snap.decode
    tokens = sum(len(r.out) for r in requests)
    steps = engine.steps
    hard_lost = sum(1 for r in requests
                    if r.priority == "hard" and not r.done)
    hard_lost += sum(1 for j in jobs
                     if j.priority == "hard" and j.state != "done")
    return {
        "continuous": continuous,
        "requests": len(requests),
        "done": sum(1 for r in requests if r.done),
        "dropped": sum(1 for r in requests if r.dropped),
        "tokens": tokens,
        "steps": steps,
        "tokens_per_step": tokens / steps if steps else math.nan,
        "hard_lost": hard_lost,
        "solver_jobs": len(jobs),
        "solver_done": sum(1 for j in jobs if j.state == "done"),
        "slot_reuses": d.slot_reuses,
        "insert_p50": d.insert.p50,
        "prefill_p50": d.prefill.p50,
        "generate_p50": d.generate.p50,
        "pending": mux.pending(),
        "events": mux.drain_events(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8,
                    help="trace length in TTI slots")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--sizes", default="8,12",
                    help="comma-separated antenna sizes n (m = n + 4)")
    ap.add_argument("--deadline-ms", type=float, default=2.0,
                    help="per-job deadline after arrival (virtual ms)")
    ap.add_argument("--max-wait-ms", type=float, default=1.0,
                    help="partial-bucket age flush threshold (virtual ms)")
    ap.add_argument("--policy", action="store_true",
                    help="enable the overload policy: shed expired "
                         "best-effort jobs and coalesce small ones; add "
                         "--budget-us for budgeted admission, which is "
                         "what makes preemption possible")
    ap.add_argument("--budget-us", type=float, default=None,
                    help="per-poll lane-time budget in cost-model "
                         "microseconds (requires --policy)")
    ap.add_argument("--adapt", action="store_true",
                    help="close the cost-model loop online: measure "
                         "every launch, re-fit sec/FLOP + overhead, tune "
                         "flush thresholds from observed traffic, and "
                         "report drift (predicted/measured) per variant")
    ap.add_argument("--mesh", type=int, default=None,
                    help="lane-shard count: span each pool's lane axis "
                         "over this many local devices (needs "
                         "--xla_force_host_platform_device_count or "
                         "real devices; default REPRO_SERVE_MESH_SIZE)")
    ap.add_argument("--fault-trace", default=None,
                    help="JSON fault trace (see repro.serve.faults) to "
                         "inject into the replay via a seeded "
                         "FaultInjector")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seed for the fault injector's per-attempt rng "
                         "streams (requires --fault-trace; a seed in "
                         "the trace file wins)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the canonical chaos scenario (mesh=4 "
                         "lane shards, mixed-priority trace, the fault "
                         "trace injected) instead of the TTI replay and "
                         "print the supervision observables (requires "
                         "--fault-trace)")
    ap.add_argument("--pusch", action="store_true",
                    help="serve the canonical PUSCH-receiver DAG trace "
                         "(staged vs stage-chained, criticality-ordered "
                         "admission) instead of the TTI replay and print "
                         "the end-to-end DAG observables; combine with "
                         "--fault-trace for a mid-DAG stage fault")
    ap.add_argument("--decode", action="store_true",
                    help="serve the canonical mixed solver+decode trace "
                         "(continuous per-slot batching through the mux "
                         "vs the lockstep pool baseline at the same "
                         "budget) instead of the TTI replay and print "
                         "the token-throughput observables")
    ap.add_argument("--ticks", type=int, default=4,
                    help="virtual ticks in the --pusch / --decode trace")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.budget_us is not None and not args.policy:
        ap.error("--budget-us requires --policy")
    if args.fault_seed is not None and args.fault_trace is None:
        ap.error("--fault-seed requires --fault-trace")
    if args.chaos and args.fault_trace is None:
        ap.error("--chaos requires --fault-trace")
    sizes = [int(s) for s in args.sizes.split(",")]

    if args.pusch:
        staged = run_pusch(False, ticks=args.ticks, lanes=args.lanes,
                           seed=args.seed, fault_trace=args.fault_trace,
                           fault_seed=args.fault_seed or 0)
        chained = run_pusch(True, ticks=args.ticks, lanes=args.lanes,
                            seed=args.seed)
        for s in (staged, chained):
            mode = "chained" if s["chained"] else "staged"
            fault = " +faults" if s["faulted"] else ""
            print(f"pusch dag [{mode}{fault}]: dags={s['dags']} "
                  f"done={s['done']} failed={s['failed']} "
                  f"dropped={s['dropped']} hard_lost={s['hard_lost']}")
            print(f"  e2e latency (ticks): p50={s['e2e_p50']:.1f} "
                  f"p99={s['e2e_p99']:.1f}  launches={s['launches']} "
                  f"retries={s['retries']}")
        if staged["e2e_p50"] and chained["e2e_p50"]:
            print(f"  stage-chained speedup: "
                  f"{staged['e2e_p50'] / chained['e2e_p50']:.2f}x e2e p50")
        assert staged["hard_lost"] == 0, "hard DAGs silently lost"
        assert chained["hard_lost"] == 0, "hard DAGs silently lost"
        return

    if args.chaos:
        summary = run_chaos(args.fault_trace, seed=args.seed,
                            fault_seed=args.fault_seed or 0)
        base = run_chaos(None, seed=args.seed)
        print(f"chaos replay: mesh={summary['mesh']} "
              f"jobs={summary['jobs']} done={summary['done']} "
              f"failed={summary['failed']} dropped={summary['dropped']}")
        print(f"  hard: lost={summary['hard_lost']} "
              f"failed={summary['hard_failed']} "
              f"attainment={summary['attainment_hard']:.2%} "
              f"(fault-free {base['attainment_hard']:.2%})")
        print(f"  supervision: retries={summary['retries']} "
              f"quarantines={summary['quarantines']} "
              f"reinstatements={summary['reinstatements']} "
              f"demotions={summary['demotions']} "
              f"t_recover={summary['time_to_recover']:.2f}")
        for alert in summary["alerts"]:
            print(f"  ALERT {alert}")
        assert summary["hard_lost"] == 0, "hard jobs silently lost"
        return

    if args.decode:
        cont = run_decode_serve(True, ticks=args.ticks,
                                lanes=args.lanes, seed=args.seed)
        base = run_decode_serve(False, ticks=args.ticks,
                                lanes=args.lanes, seed=args.seed)
        for s in (cont, base):
            mode = "continuous" if s["continuous"] else "lockstep"
            print(f"decode serve [{mode:>10}]: requests={s['requests']} "
                  f"done={s['done']} dropped={s['dropped']} "
                  f"tokens={s['tokens']} steps={s['steps']} "
                  f"tokens/step={s['tokens_per_step']:.2f} "
                  f"hard_lost={s['hard_lost']} "
                  f"solver {s['solver_done']}/{s['solver_jobs']}")
        print(f"  continuous: slot_reuses={cont['slot_reuses']} "
              f"insert p50 (ticks)={cont['insert_p50']:.1f} "
              f"prefill p50 (s)={cont['prefill_p50']:.2e} "
              f"generate p50 (s)={cont['generate_p50']:.2e}")
        print(f"  continuous-batching speedup: "
              f"{cont['tokens_per_step'] / base['tokens_per_step']:.2f}x "
              f"tokens/step at equal budget")
        assert cont["hard_lost"] == 0, "hard jobs/requests silently lost"
        assert base["hard_lost"] == 0, "hard jobs/requests silently lost"
        assert cont["tokens"] == base["tokens"], \
            "trace served different token counts across modes"
        assert cont["tokens_per_step"] > base["tokens_per_step"], \
            "continuous batching failed to beat the lockstep baseline"
        return

    rng = np.random.default_rng(args.seed)
    clock = ManualClock()
    policy, cost_model = None, None
    budget = None if args.budget_us is None else args.budget_us * 1e-6
    if args.policy and args.adapt:
        policy = OverloadPolicy(budget=budget,
                                cost_model=CostModel(adaptive=True))
    elif args.policy:
        policy = OverloadPolicy(budget=budget)
    elif args.adapt:
        cost_model = CostModel(adaptive=True)
    injector = None
    if args.fault_trace is not None:
        from repro.serve import FaultInjector
        injector = FaultInjector.from_json(args.fault_trace,
                                           seed=args.fault_seed or 0)
    mux = SolverMux(lanes=args.lanes, max_wait=args.max_wait_ms * 1e-3,
                    clock=clock, policy=policy, cost_model=cost_model,
                    adapt=args.adapt or None, mesh_size=args.mesh,
                    injector=injector)

    t0 = time.perf_counter()
    jobs, done, sample = [], [], None
    for slot in range(args.slots):
        for pipeline, job_arrays, priority in build_slot_jobs(rng, slot,
                                                              sizes):
            job = mux.submit(pipeline, *job_arrays,
                             deadline=clock() + args.deadline_ms * 1e-3,
                             priority=priority)
            jobs.append(job)
            if sample is None and pipeline == "mmse_equalize":
                sample = job
        done.extend(mux.poll())
        clock.advance(SLOT_MS * 1e-3)
    done.extend(mux.run())
    wall = time.perf_counter() - t0
    assert not mux.pending(), "mux left jobs queued after drain"

    if not done:
        print(f"empty trace ({args.slots} slots): nothing served")
        return

    # spot-check a served result against the registry oracle (under
    # fault injection some jobs may be terminally failed — skip those)
    if sample is None or sample.state != "done":
        sample = next((j for j in done if j.state == "done"), None)
    if sample is not None:
        want = K.get(sample.pipeline).run_oracle_lane(*sample.args)
        err = np.max(np.abs(sample.out - want)) \
            / (np.max(np.abs(want)) + 1e-12)
        assert err < 1e-3, \
            f"oracle mismatch on sample job: rel err {err:.2e}"

    snap = mux.metrics()
    print(f"trace: {args.slots} slots x sizes {sizes}, lanes={args.lanes} "
          f"-> {snap.total_jobs} jobs in {snap.total_launches} grid "
          f"launches ({wall:.2f}s wall, oracle check ok)")
    hdr = (f"{'pipeline':<16} {'jobs':>5} {'launch':>6} {'util':>6} "
           f"{'waste':>6} {'p50_ms':>8} {'p99_ms':>8} {'hard_p99':>9} "
           f"{'jobs/s':>10} dispatch")
    print(hdr)
    print("-" * len(hdr))
    for name, st in sorted(snap.pipelines.items()):
        counts = ",".join(f"{v}:{c}" for v, c in
                          sorted(st.dispatch_counts.items()))
        hard = st.latency_by_priority.get("hard")
        hard_p99 = f"{hard.p99 * 1e3:>9.3f}" if hard else f"{'-':>9}"
        print(f"{name:<16} {st.jobs:>5} {st.launches:>6} "
              f"{st.lane_utilization:>6.2f} {st.padded_lane_waste:>6.2f} "
              f"{st.latency.p50 * 1e3:>8.3f} {st.latency.p99 * 1e3:>8.3f} "
              f"{hard_p99} {st.throughput:>10.1f} {counts}")
    missed = sum(1 for j in done
                 if j.deadline is not None and j.finished_at > j.deadline)
    print(f"deadline misses (virtual clock): {missed}/{len(done)}")
    print(f"hard-deadline SLO attainment: {hard_attainment(jobs):.2%}")
    if policy is not None:
        print(f"overload policy: dropped={snap.total_dropped} "
              f"preempted={snap.total_preempted} "
              f"coalesced={snap.total_coalesced}")
    if snap.shards:
        util = " ".join(f"s{s}:{st.utilization:.2f}"
                        for s, st in sorted(snap.shards.items()))
        alert = "  ALERT" if snap.shard_imbalance_alert else ""
        print(f"mesh: {mux.mesh_size} shards, util {util}, "
              f"imbalance {snap.shard_imbalance:.2f}{alert}")
    if snap.drift:
        print("cost-model drift (predicted/measured, EWMA ratio):")
        for key, st in sorted(snap.drift.items()):
            flag = "  ALERT" if st.alert else ""
            print(f"  {key:<28} ratio {st.ratio:>8.3f} "
                  f"updates {st.updates:>4} source {st.source}{flag}")
        worst = snap.worst_drift
        if worst is not None:
            print(f"  worst offender: {worst.key} "
                  f"(ratio {worst.ratio:.3f})")
        ups = ",".join(f"{k}={v}" for k, v in
                       sorted(snap.calibration_updates.items()))
        print(f"  calibration updates: {ups}")


if __name__ == "__main__":
    main()
