"""Logical-axis sharding rules (MaxText-style, hand-rolled).

Model code annotates activations/params with *logical* axis names; a rule
table maps them to mesh axes.  Rules are resolved against a concrete mesh's
axis names so the same model code runs on (data, model), on
(pod, data, model), or on a single CPU device (no rules -> no constraint).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable shard_map: jax.shard_map (newer releases, with its
    ``check_vma`` knob) or jax.experimental.shard_map (``check_rep``)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)

# logical axis -> tuple of candidate mesh axes (first present ones used)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),      # DP over pods, then data axis
    "seq": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_cap": ("data",),
    "fsdp": ("data",),             # weight shard dim (ZeRO-3)
    "model": ("model",),
    "data": ("data",),
    "pod": ("pod",),
    "stage": (),                   # reserved for PP experiments
    "kv_seq": ("model",),          # long-context decode: shard the cache
    "seq_sp": ("model",),          # sequence-parallel attention chunks
    "layers": (),
}

# rule overrides for serving: no FSDP gather per layer (TP-only weights)
SERVE_RULES: dict[str, tuple[str, ...]] = {
    "fsdp": (),
    "batch": ("pod", "data"),
}

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh, rules: dict[str, tuple[str, ...]] | None = None):
    """Activate logical->mesh resolution for `mesh` (None deactivates)."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    names = set(mesh.axis_names) if mesh is not None else set()
    prev = _current()
    _state.ctx = (rules, names, mesh)
    try:
        yield
    finally:
        _state.ctx = prev


def resolve(*logical: str | None) -> P:
    """Build a PartitionSpec from logical axis names under current rules."""
    ctx = _current()
    if ctx is None:
        return P()
    rules, names, _mesh = ctx
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
            continue
        cand = tuple(a for a in rules.get(ax, ()) if a in names)
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            out.append(cand[0])
        else:
            out.append(cand)
    return P(*out)


def resolve_spec(spec: P) -> P:
    """Resolve a PartitionSpec whose entries are *logical* names."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            flat = []
            for e in entry:
                r = resolve(e)[0] if len(resolve(e)) else None
                if isinstance(r, tuple):
                    flat.extend(r)
                elif r is not None:
                    flat.append(r)
            out.append(tuple(flat) if flat else None)
        else:
            r = resolve(entry)
            r0 = r[0] if len(r) else None
            out.append(r0)
    return P(*out)


def named(spec_logical: P):
    """NamedSharding on the context mesh from a logical spec."""
    ctx = _current()
    if ctx is None:
        raise RuntimeError("axis_rules context required")
    _, _, mesh = ctx
    return jax.sharding.NamedSharding(mesh, resolve_spec(spec_logical))


def named_safe(spec_logical: P, shape: tuple[int, ...]):
    """Like named(), but drops mesh axes that don't divide the dim."""
    ctx = _current()
    if ctx is None:
        raise RuntimeError("axis_rules context required")
    _, _, mesh = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = resolve_spec(spec_logical)
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            if shape[i] % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        out.append(tuple(kept) if len(kept) > 1
                   else (kept[0] if kept else None))
    return jax.sharding.NamedSharding(mesh, P(*out))


def constrain(x, *logical: str | None):
    """with_sharding_constraint under the active rules (identity if none)."""
    ctx = _current()
    if ctx is None:
        return x
    _, _, mesh = ctx
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, resolve(*logical)))


def current_mesh():
    """Mesh of the active axis_rules context (None outside)."""
    ctx = _current()
    return ctx[2] if ctx is not None else None


def mesh_axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def param_spec(path: tuple[str, ...], shape: tuple[int, ...]) -> P:
    """Sharding spec for a parameter, keyed by its pytree path.

    Policy (FSDP+TP, pod-replicated):
      - stacked layer dim (leading L) unsharded
      - attention/mlp weights: (fsdp, model) on the (in, out) dims
      - second projections (wo/w_down/w_out): (model, fsdp)
      - embeddings / lm head: vocab on model, embed on fsdp
      - MoE expert weights: experts on model, d_model on fsdp
      - 1-D scales/biases replicated
    """
    name = path[-1]
    stacked = "layers" in "/".join(path[:-1]) or name.startswith("stk_")
    lead: list[str | None] = [None] if stacked and len(shape) >= 2 else []

    def pads(spec):
        out = lead + list(spec)
        out += [None] * (len(shape) - len(out))
        return P(*out[: len(shape)])

    if len(shape) - len(lead) <= 1:
        return pads([None])
    if name in ("embed", "lm_head"):
        return pads(["vocab", "fsdp"]) if name == "embed" \
            else pads(["fsdp", "vocab"])
    if name in ("wi", "wg") and len(shape) - len(lead) == 3:   # MoE (E,D,F)
        return pads(["experts", "fsdp", None])
    if name == "wo" and len(shape) - len(lead) == 3:           # MoE (E,F,D)
        return pads(["experts", None, "fsdp"])
    if name in ("wq", "wk", "wv", "wi", "wg", "w_in", "w_up", "w_gates",
                "r_gates", "router", "wz"):
        return pads(["fsdp", "model"])
    if name in ("wo", "w_out", "w_down"):
        return pads(["model", "fsdp"])
    return pads([None])
