"""Criticality specialization (paper §4 Feature 5, §6.3).

REVEL splits its fabric into a dedicated (critical) and temporal
(non-critical) region.  The TPU analog: the critical dataflow gets
MXU-shaped work (tiles padded/aligned to 128) while non-critical point
regions run as VPU scalar/vector ops without MXU-tile padding.  This
module provides the planning arithmetic: given region work estimates,
decide vectorization widths and check the balance argument (paper Q8/Q9).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["RegionCost", "plan_split", "MXU_DIM", "VPU_LANES"]

MXU_DIM = 128      # TPU MXU systolic dimension
VPU_LANES = 128    # VPU lane count (8 sublanes x 128 lanes; lanes dominate)


@dataclasses.dataclass(frozen=True)
class RegionCost:
    name: str
    flops_per_outer: float      # work per outer iteration
    has_transcendental: bool = False  # sqrt/div/rsqrt => non-critical hint


def plan_split(regions: list[RegionCost], threshold: float = 0.25):
    """Partition regions into critical (wide datapath) / non-critical.

    A region is critical if it carries >= `threshold` of total work and has
    no transcendental-dominated body.  Mirrors the paper's observation that
    critical regions are the easily-vectorized bulk updates while
    sub-critical ones are sqrt/div chains.
    Returns (critical_names, noncritical_names).
    """
    total = sum(r.flops_per_outer for r in regions) or 1.0
    crit, non = [], []
    for r in regions:
        share = r.flops_per_outer / total
        if share >= threshold and not r.has_transcendental:
            crit.append(r.name)
        else:
            non.append(r.name)
    if not crit:  # largest region is critical by definition
        biggest = max(regions, key=lambda r: r.flops_per_outer)
        crit = [biggest.name]
        non = [r.name for r in regions if r.name != biggest.name]
    return crit, non


def mxu_padded(n: int, dim: int = MXU_DIM) -> int:
    """Tile-aligned size the MXU would execute for an n-wide op."""
    return max(dim, math.ceil(n / dim) * dim)


def dedicated_efficiency(n: int, dim: int = MXU_DIM) -> float:
    """Utilization if a point/vector region were forced onto MXU tiles —
    the quantitative version of 'don't waste FP units on non-critical
    dataflows' (paper Q9)."""
    return n / mxu_padded(n, dim)
