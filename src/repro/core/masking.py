"""Implicit vector masking (paper §4 Feature 4, §6.2).

REVEL's stream-control unit compares the remaining stream length against
the destination port's vector width and predicates off the unused lanes.
On TPU the same idea is: tiles are always full-shape (MXU/VPU lanes are
fixed), and a mask derived from the *stream descriptor's* current trip
count predicates the tail.  These helpers generate those masks both inside
Pallas kernels (via broadcasted_iota) and in pure-jnp reference code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "lane_mask",
    "tail_mask",
    "tri_mask",
    "masked_fill",
    "vector_utilization",
]


def lane_mask(length, width: int, dtype=jnp.bool_):
    """1D mask of `width` lanes, True for lanes < length (traced ok)."""
    return (jax.lax.broadcasted_iota(jnp.int32, (width,), 0)
            < jnp.asarray(length, jnp.int32)).astype(dtype)


def tail_mask(shape: tuple[int, ...], axis: int, length) -> jnp.ndarray:
    """N-D mask, True where index along `axis` < length."""
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, axis)
    return idx < jnp.asarray(length, jnp.int32)


def tri_mask(shape: tuple[int, ...], row_axis: int, col_axis: int,
             row_offset=0, lower: bool = True) -> jnp.ndarray:
    """Triangular (inductive-domain) mask: col <= row + row_offset.

    The triangular iteration space of Cholesky/solver/causal-attention is
    exactly an RI stream; its in-tile predication is this mask.
    """
    r = jax.lax.broadcasted_iota(jnp.int32, shape, row_axis)
    c = jax.lax.broadcasted_iota(jnp.int32, shape, col_axis)
    r = r + jnp.asarray(row_offset, jnp.int32)
    return (c <= r) if lower else (c >= r)


def masked_fill(x: jnp.ndarray, mask: jnp.ndarray, fill=0.0) -> jnp.ndarray:
    return jnp.where(mask, x, jnp.asarray(fill, x.dtype))


def vector_utilization(trip_counts, width: int) -> float:
    """Fraction of vector lanes doing useful work over a set of inner-loop
    trips — the paper's Fig. 2(c,d) utilization argument, computable for
    any stream descriptor via .trip_counts()."""
    useful = sum(int(t) for t in trip_counts)
    issued = sum(-(-int(t) // width) * width for t in trip_counts)
    return useful / issued if issued else 1.0
