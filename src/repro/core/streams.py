"""FGOP stream descriptors (paper §4, Features 2-4).

A *stream* describes an affine-plus-stretch iteration domain and address
function.  REVEL encodes these in hardware state machines; here they are a
small IR that (a) drives Pallas grid/BlockSpec construction, (b) reproduces
the paper's analytical control-overhead model (Figs. 10/11/21/22), and
(c) is executable (pure Python / numpy) so properties can be tested.

Capability letters follow the paper: each dimension is either
  'R' — rectangular: trip count is a constant
  'I' — inductive: trip count is a linear function of lexicographically
        earlier iterators (the "stretch" multipliers s_ji).

So "RI" is a 2D stream whose inner trip count varies with the outer
iterator — the pattern of Cholesky / QR / Solver inner loops, and of
causal attention (kv-trip-count = q_block + 1).
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Sequence

import numpy as np

__all__ = [
    "StreamDim",
    "StreamDescriptor",
    "rect",
    "inductive",
    "command_count",
    "commands_per_iteration",
]


@dataclasses.dataclass(frozen=True)
class StreamDim:
    """One dimension of a stream's iteration domain.

    trip(outer) = base_trip + sum_j stretch[j] * outer[j]
    where outer are the values of lexicographically-earlier iterators.
    ``stride`` is this iterator's multiplier in the address function (c_i).
    Stretch entries may be fractional (paper F4: vectorization divides the
    reuse/trip rate by the vector width), hence Fraction.
    """

    base_trip: Fraction
    stride: int = 1
    stretch: tuple[Fraction, ...] = ()  # one entry per earlier dim

    @property
    def is_inductive(self) -> bool:
        return any(s != 0 for s in self.stretch)

    def trip(self, outer: Sequence[int]) -> int:
        t = Fraction(self.base_trip)
        for s, o in zip(self.stretch, outer):
            t += Fraction(s) * o
        return max(0, math.ceil(t))


@dataclasses.dataclass(frozen=True)
class StreamDescriptor:
    """N-D stream: iteration domain + affine address function.

    ``dims`` are ordered outermost-first.  ``base`` is the address offset.
    ``reuse`` / ``reuse_stretch`` describe the production:consumption rate
    (paper F2): each produced element is consumed ``reuse`` times, with the
    rate itself changing by ``reuse_stretch`` per outer iteration.
    """

    dims: tuple[StreamDim, ...]
    base: int = 0
    reuse: Fraction = Fraction(1)
    reuse_stretch: Fraction = Fraction(0)
    name: str = "stream"

    # ---------------- capability / classification ----------------
    @property
    def capability(self) -> str:
        """Pattern string, e.g. 'RI' — paper's notation."""
        return "".join("I" if d.is_inductive else "R" for d in self.dims)

    @property
    def ndim(self) -> int:
        return len(self.dims)

    # ---------------- executable semantics ----------------
    def iterate(self):
        """Yield (index_tuple, address) lexicographically.

        Reference implementation of the hardware state machine; used by
        property tests and by the masking helpers.
        """

        def rec(level: int, outer: tuple[int, ...]):
            if level == len(self.dims):
                addr = self.base + sum(
                    d.stride * i for d, i in zip(self.dims, outer)
                )
                yield outer, addr
                return
            d = self.dims[level]
            for i in range(d.trip(outer)):
                yield from rec(level + 1, outer + (i,))

        yield from rec(0, ())

    def addresses(self) -> np.ndarray:
        return np.array([a for _, a in self.iterate()], dtype=np.int64)

    def length(self) -> int:
        """Total number of iterations described by one stream command."""
        return sum(1 for _ in self.iterate())

    def trip_counts(self) -> list[int]:
        """Innermost trip count per outer iteration (diagnostics)."""
        if self.ndim == 1:
            return [self.dims[0].trip(())]
        out = []

        def rec(level: int, outer: tuple[int, ...]):
            if level == len(self.dims) - 1:
                out.append(self.dims[level].trip(outer))
                return
            d = self.dims[level]
            for i in range(d.trip(outer)):
                rec(level + 1, outer + (i,))

        rec(0, ())
        return out


# ---------------- constructors ----------------

def rect(*trips: int, strides: Sequence[int] | None = None,
         base: int = 0, name: str = "stream") -> StreamDescriptor:
    """Rectangular stream (R/RR/RRR)."""
    if strides is None:
        strides = [1] * len(trips)
        # row-major default: stride of dim k = product of inner trips
        for k in range(len(trips) - 2, -1, -1):
            strides[k] = strides[k + 1] * trips[k + 1]
    dims = tuple(
        StreamDim(Fraction(t), s, (Fraction(0),) * k)
        for k, (t, s) in enumerate(zip(trips, strides))
    )
    return StreamDescriptor(dims=dims, base=base, name=name)


def inductive(outer_trip: int, inner_base: int, inner_stretch,
              outer_stride: int = 0, inner_stride: int = 1,
              base: int = 0, name: str = "stream") -> StreamDescriptor:
    """2D RI stream: inner trip = inner_base + inner_stretch * j."""
    dims = (
        StreamDim(Fraction(outer_trip), outer_stride),
        StreamDim(Fraction(inner_base), inner_stride,
                  (Fraction(inner_stretch),)),
    )
    return StreamDescriptor(dims=dims, base=base, name=name)


# ---------------- analytical control-overhead model ----------------
# Reproduces the paper's Fig. 11 / Fig. 21 / Fig. 22 methodology: how many
# control commands must a Von-Neumann core issue to express a given
# iteration pattern, under a hardware capability?

_CAPABILITY_ORDER = ["V", "R", "RR", "RI", "RRR", "RII"]


def _supports(capability: str, pattern: StreamDescriptor) -> bool:
    """Can one command of class `capability` express `pattern` directly?"""
    if capability == "V":
        return False  # vectors always decompose (handled in command_count)
    if len(capability) < pattern.ndim:
        return False
    # align capability letters to the innermost dims of the pattern
    cap = capability[-pattern.ndim:] if len(capability) >= pattern.ndim else capability
    for letter, dim in zip(cap, pattern.dims):
        if dim.is_inductive and letter != "I":
            return False
    return True


def command_count(pattern: StreamDescriptor, capability: str,
                  vector_width: int = 8) -> int:
    """Number of control commands to express `pattern` at `capability`.

    'V'  — classic vector ISA: one instruction per vector_width elements
           of the innermost dimension (ceil), issued per inner loop, per
           outer iteration (this is the paper's "V" baseline).
    'R'  — 1D streams: one command per innermost loop instance.
    'RR' — 2D rectangular: one command expresses a rectangle; inductive
           patterns decompose into per-outer-iteration 1D commands.
    'RI' — 2D inductive: one command for any 2D (possibly inductive)
           pattern (paper: solver 3+5n -> 8 total commands).
    """
    if capability not in _CAPABILITY_ORDER:
        raise ValueError(f"unknown capability {capability!r}")

    # degenerate stream: a pattern with no iterations at all (e.g. an
    # inductive inner dim with inner_base=0 and non-positive stretch, or
    # a zero outer trip) needs no commands — without this guard the V
    # path's max(1, ...) and the _supports fast path both claim 1.
    # Individual empty rows inside a non-empty decomposed pattern still
    # charge one command each (the core issues the per-outer-iteration
    # command before the zero trip count is known — the paper's 3+5n
    # accounting), which the max(1, ...) below preserves.
    if pattern.length() == 0:
        return 0

    if capability == "V":
        total = 0
        if pattern.ndim == 1:
            return max(1, math.ceil(pattern.dims[0].trip(()) / vector_width))
        for t in pattern.trip_counts():
            total += max(1, math.ceil(t / vector_width))
        return total

    if _supports(capability, pattern):
        return 1

    if pattern.ndim == 1:
        return 1  # any stream capability covers a 1D run

    # decompose: peel the outermost dimension, recurse
    d0 = pattern.dims[0]
    total = 0
    for j in range(d0.trip(())):
        inner_dims = []
        for d in pattern.dims[1:]:
            # fold iterator-0's contribution into the base trip
            stretch0 = d.stretch[0] if d.stretch else Fraction(0)
            inner_dims.append(
                StreamDim(
                    base_trip=Fraction(d.base_trip) + stretch0 * j,
                    stride=d.stride,
                    stretch=d.stretch[1:],
                )
            )
        sub = StreamDescriptor(
            dims=tuple(inner_dims),
            base=pattern.base + d0.stride * j,
            name=pattern.name,
        )
        total += max(1, command_count(sub, capability, vector_width))
    return total


def commands_per_iteration(pattern: StreamDescriptor, capability: str,
                           vector_width: int = 8) -> float:
    """Paper Fig. 22 metric: control instructions per inner-loop iteration."""
    n = pattern.length()
    if n == 0:
        return 0.0
    return command_count(pattern, capability, vector_width) / n


def average_stream_length(pattern: StreamDescriptor, capability: str,
                          vector_width: int = 8) -> float:
    """Paper Fig. 21 metric: mean iterations covered by one command."""
    c = command_count(pattern, capability, vector_width)
    return pattern.length() / max(1, c)
