"""Ordered dependences between concurrent regions (paper §4 Feature 1-2).

REVEL expresses a kernel as multiple dataflow *regions* connected by FIFOs
with production:consumption rate annotations.  The TPU realization: regions
are fused into one `lax.scan` (or one Pallas kernel); the FIFO is the scan
carry; the rate annotation becomes how the carry is produced/consumed per
step.  This module gives that structure a name so kernels and models are
written as explicit FGOP region graphs, and so tests can check rate
consistency *before* tracing.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Any, Callable, Sequence

import jax

__all__ = ["Region", "OrderedDep", "RegionGraph", "fuse_scan"]


@dataclasses.dataclass(frozen=True)
class Region:
    """One computation region (paper: point / vector / matrix).

    ``critical`` marks the region that should own the wide datapath
    (paper Feature 5); non-critical regions hold sqrt/div-style point ops.
    """

    name: str
    fn: Callable[..., Any]
    critical: bool = False


@dataclasses.dataclass(frozen=True)
class OrderedDep:
    """producer -> consumer channel with (possibly inductive) rates.

    production:consumption = prod_rate : cons_rate, each optionally
    stretched per outer iteration (paper F2's s_p / s_c).
    """

    producer: str
    consumer: str
    prod_rate: Fraction = Fraction(1)
    cons_rate: Fraction = Fraction(1)
    prod_stretch: Fraction = Fraction(0)
    cons_stretch: Fraction = Fraction(0)

    def consumptions_at(self, k: int) -> int:
        """How many times the value produced at outer-iteration k is read."""
        return max(0, int(self.cons_rate + self.cons_stretch * k))


@dataclasses.dataclass
class RegionGraph:
    """A static FGOP region graph; validates then fuses to one scan body."""

    regions: Sequence[Region]
    deps: Sequence[OrderedDep]

    def __post_init__(self):
        names = {r.name for r in self.regions}
        for d in self.deps:
            if d.producer not in names or d.consumer not in names:
                raise ValueError(f"dep {d} references unknown region")
        if not any(r.critical for r in self.regions):
            raise ValueError("region graph needs >=1 critical region")

    @property
    def critical(self) -> Region:
        return next(r for r in self.regions if r.critical)

    def total_consumptions(self, dep: OrderedDep, n_outer: int) -> int:
        return sum(dep.consumptions_at(k) for k in range(n_outer))


def fuse_scan(step_fn: Callable, init_carry, xs=None, length=None,
              unroll: int = 1):
    """Fuse ordered-dependent regions into one scan.

    The paper's key performance move is that the point->vector->matrix
    dependence chain never round-trips through memory or synchronization;
    here the carry (the FIFO contents) stays in registers/VMEM across the
    fused body.  Thin wrapper over lax.scan kept as the single fusion
    entry-point so remat policy / unroll can be tuned in one place.
    """
    return jax.lax.scan(step_fn, init_carry, xs=xs, length=length,
                        unroll=unroll)
