"""FGOP core abstractions: stream IR, masking, ordered deps, criticality."""
from repro.core.streams import (  # noqa: F401
    StreamDescriptor,
    StreamDim,
    rect,
    inductive,
    command_count,
    commands_per_iteration,
    average_stream_length,
)
from repro.core.masking import (  # noqa: F401
    lane_mask,
    tail_mask,
    tri_mask,
    masked_fill,
    vector_utilization,
)
from repro.core.dependence import (  # noqa: F401
    Region,
    OrderedDep,
    RegionGraph,
    fuse_scan,
)
from repro.core.criticality import (  # noqa: F401
    RegionCost,
    plan_split,
)
