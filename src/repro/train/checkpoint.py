"""Sharded npz checkpointing: atomic, async, keep-k, mesh-agnostic.

Layout:  <dir>/step_<n>/ {manifest.json, shard_<host>.npz}
Writes go to a tmp dir then os.replace (atomic on POSIX) so a crash never
leaves a half-written "latest".  Arrays are saved fully-replicated-logical
(gathered), so a checkpoint written on a 256-chip mesh restores onto any
other mesh / device count — the *elastic re-mesh* path: load gives host
numpy arrays, the trainer re-device_puts them under the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split(_SEP)
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, state, *, keep: int = 3,
         blocking: bool = True) -> str:
    """state: pytree of jax/np arrays. Returns final path."""
    flat = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_0.npz"), **host)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(host),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_")
                   and os.path.exists(os.path.join(ckpt_dir, d,
                                                   "manifest.json")))
    return int(steps[-1].split("_")[1]) if steps else None


def load(ckpt_dir: str, step: int | None = None, shardings=None):
    """Returns (step, state). `shardings`: optional pytree of shardings
    to device_put each leaf onto (the elastic re-mesh path)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "shard_0.npz")) as z:
        flat = {k: z[k] for k in manifest["keys"]}
    state = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        state = _unflatten({
            k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
            for k, v in _flatten(state).items()})
    return step, state
