"""Fault tolerance & straggler mitigation for the training loop.

What runs here (CPU container) is the full control logic; on a real pod
the same hooks fire from jax.distributed heartbeat failures:

  * RetryPolicy     — step-level retry with restore-from-checkpoint on
                      any device/runtime failure (XlaRuntimeError, OOM).
  * StragglerMonitor— per-step wall-time EWMA; steps slower than
                      `threshold x` median flag the host so an external
                      scheduler can evict/replace it.  Also drives the
                      "skip-straggler" policy for data loading.
  * elastic re-mesh — checkpoints are mesh-agnostic (see checkpoint.py);
                      `remesh_state` re-device_puts a loaded state under
                      a new mesh's shardings, so training resumes on a
                      different device count after failures.
"""
from __future__ import annotations

import dataclasses
import logging
import time

import jax

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 1.0

    def run(self, fn, *args, on_failure=None, **kw):
        """Run fn with retries; on_failure() is called before each retry
        (typically: restore from last checkpoint, rebuild mesh)."""
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kw)
            except Exception as e:  # noqa: BLE001 — device faults vary
                if attempt == self.max_retries:
                    raise
                log.warning("step failed (%s); retry %d/%d",
                            type(e).__name__, attempt + 1, self.max_retries)
                time.sleep(self.backoff_s * (2 ** attempt))
                if on_failure is not None:
                    args = on_failure(e) or args
        raise RuntimeError("unreachable")


class StragglerMonitor:
    """EWMA step-time tracker with a slowdown threshold."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged_steps: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged_steps.append(step)
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        step, dt, self.ewma)
        return slow


def remesh_state(state, shardings):
    """Re-device_put a (host or device) state pytree under new shardings —
    the elastic-scaling path after a mesh change."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), state,
        shardings)
