"""Training loop: pjit'd train_step with microbatch gradient accumulation,
checkpoint/restore, retry-on-failure, straggler monitoring.

The train_step is a single SPMD program: under FSDP+TP shardings GSPMD
inserts the weight all-gathers / grad reduce-scatters; scan-over-layers
lets the XLA latency-hiding scheduler overlap the layer-k+1 all-gather
with layer-k compute (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.optim.optimizer import (OptConfig, adamw_update, init_opt_state)
from repro.train import checkpoint as ckpt
from repro.train.fault import RetryPolicy, StragglerMonitor

log = logging.getLogger("repro.train")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)


def microbatched_grads(cfg: ArchConfig, params, batch):
    """Gradient accumulation over cfg.microbatch splits of the batch.

    Activations live only for one microbatch; the f32 grad accumulator is
    params-shaped (and params-sharded under pjit)."""
    mb = cfg.microbatch
    loss_fn = lambda p, b: T.lm_loss(p, cfg, b)        # noqa: E731
    if mb <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    split = jax.tree.map(
        lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)
    acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def step(carry, mb_batch):
        loss_acc, gacc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb_batch)
        gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / mb,
                            gacc, grads)
        return (loss_acc + loss / mb, gacc), None

    (loss, grads), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), acc0), split)
    return loss, grads


def make_train_step(cfg: ArchConfig, opt: OptConfig):
    def train_step(params, opt_state, batch):
        loss, grads = microbatched_grads(cfg, params, batch)
        params, opt_state, metrics = adamw_update(opt, params, grads,
                                                  opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


class Trainer:
    """Single-host driver (multi-host: same code under jax.distributed)."""

    def __init__(self, cfg: ArchConfig, tc: TrainConfig, pipeline,
                 mesh=None, shardings=None):
        self.cfg = cfg
        self.tc = tc
        self.pipeline = pipeline
        self.mesh = mesh
        self.retry = RetryPolicy()
        self.straggler = StragglerMonitor()
        self.step_fn = jax.jit(make_train_step(cfg, tc.opt),
                               donate_argnums=(0, 1))
        key = jax.random.PRNGKey(tc.seed)
        self.params = T.init_params(key, cfg)
        self.opt_state = init_opt_state(self.params)
        self.start_step = 0
        self._maybe_resume()
        self.metrics_history: list[dict[str, float]] = []

    # ---------------- checkpoint/resume ----------------
    def _maybe_resume(self):
        last = ckpt.latest_step(self.tc.ckpt_dir)
        if last is not None:
            _, state = ckpt.load(self.tc.ckpt_dir, last)
            self.params = state["params"]
            self.opt_state = state["opt"]
            self.start_step = int(last)
            log.info("resumed from step %d", last)

    def _save(self, step: int, blocking=False):
        ckpt.save(self.tc.ckpt_dir, step,
                  {"params": self.params, "opt": self.opt_state},
                  keep=self.tc.keep, blocking=blocking)

    # ---------------- loop ----------------
    def run(self) -> dict[str, Any]:
        losses = []
        for step in range(self.start_step, self.tc.steps):
            batch = self.pipeline.device_batch(step)
            t0 = time.perf_counter()

            def attempt(b=batch):
                return self.step_fn(self.params, self.opt_state, b)

            def on_failure(_e):
                # restore-from-checkpoint path (device loss / NaN state)
                last = ckpt.latest_step(self.tc.ckpt_dir)
                if last is not None:
                    _, st = ckpt.load(self.tc.ckpt_dir, last)
                    self.params, self.opt_state = st["params"], st["opt"]

            self.params, self.opt_state, metrics = self.retry.run(
                attempt, on_failure=on_failure)
            dt = time.perf_counter() - t0
            self.straggler.observe(step, dt)
            loss = float(metrics["loss"])
            losses.append(loss)
            self.metrics_history.append(
                {"step": step, "loss": loss, "dt": dt,
                 "grad_norm": float(metrics["grad_norm"])})
            if step % self.tc.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
            if (step + 1) % self.tc.ckpt_every == 0 \
                    or step + 1 == self.tc.steps:
                self._save(step + 1, blocking=(step + 1 == self.tc.steps))
        return {"final_loss": losses[-1] if losses else float("nan"),
                "losses": losses,
                "stragglers": self.straggler.flagged_steps}
