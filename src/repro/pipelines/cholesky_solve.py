"""Fused SPD solve: Cholesky factor + forward + back substitution in ONE
Pallas grid cell (paper Figs. 5/9/13 chained as a single ordered region).

The REVEL win the paper measures is not a lone factorization — it is the
*chain* factor -> forward-solve -> back-solve executed without the matrix
ever round-tripping through memory.  Here one grid cell = one lane: the
matrix and right-hand sides stay VMEM-resident across all three stages,
and the forward substitution is interleaved *inside* the factor loop — as
soon as column k of L is finished (the ordered dependence), the divide +
AXPY of the forward solve for row k consume it.  The fori_loop carry is
REVEL's inter-region FIFO.

Numerics: only the lower triangle of A is read (the inductive-domain mask,
paper Feature 4 — verified by the NaN-poisoning test), and the pivot is
guarded by ``eps`` so singular/ill-conditioned systems produce finite
output instead of NaN lanes.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cholesky import cholesky_pallas
from repro.kernels.common import (interpret_default, resolve_backend,
                                  tpu_compiler_params)
from repro.kernels.trisolve import trisolve_pallas

# Relative pivot threshold (LAPACK pstrf-style): a pivot below
# eps * max(diag(A)) marks a numerically deficient direction.  Residual
# pivots of an exactly singular float32 matrix land around
# n * ulp * ||A|| ~ 1e-6 * scale, so 1e-5 cleanly separates "deficient"
# from merely ill-conditioned.
DEFAULT_EPS = 1e-5


def pivot_threshold(a, rows, *, eps: float):
    """Scale-relative deficiency threshold from the initial diagonal."""
    diag = jnp.where(rows[:, None] == rows[None, :], a, -jnp.inf)
    return jnp.maximum(eps * jnp.max(diag), 1e-30)


def factor_forward_step(k, a, y, rows, thresh):
    """One fused outer iteration: finish column k of L, then immediately
    run the forward-substitution step that consumes it.

    a: (n, n) working matrix (lower triangle -> L in place)
    y: (n, m) right-hand sides being forward-solved in place
    thresh: scalar deficiency threshold (see pivot_threshold)

    A pivot below ``thresh`` takes the rank-deficient path: unit diagonal,
    zeroed column, zeroed solution component — the solve proceeds on the
    numerically non-deficient subspace and every lane stays finite.
    """
    # ---- point region (non-critical): guarded rsqrt of the pivot ----
    akk = a[k, k]
    ok = akk > thresh
    inv = jnp.where(ok, jax.lax.rsqrt(jnp.maximum(akk, thresh)), 0.0)
    # ---- vector region: scale column k; diagonal set to the pivot ----
    col = a[:, k] * inv
    col = jnp.where(rows == k, jnp.where(ok, akk * inv, 1.0), col)
    col = jnp.where(rows >= k, col, 0.0)              # implicit mask (F4)
    # ---- matrix region (critical): masked rank-1 trailing update ----
    live = rows > k
    upd = col[:, None] * col[None, :]
    mask = live[:, None] & live[None, :]
    a = a - jnp.where(mask, upd, 0.0)
    a = a.at[:, k].set(jnp.where(rows >= k, col, a[:, k]))
    # ---- fused forward substitution consuming the finished column ----
    # y[k] /= l[k,k];  y[j>k] -= l[j,k] * y[k]   (divide + masked AXPY)
    yk = y[k] * inv                                   # deficient: x_k = 0
    y = y.at[k].set(yk)
    y = y - jnp.where(live[:, None], col[:, None] * yk[None, :], 0.0)
    return a, y


def back_substitution_step(i, l, y, rows, *, n: int):
    """Back-substitution outer iteration on U = L^T, k = n-1-i:
    x[k] = y[k] / l[k,k];  y[j<k] -= l[k,j] * x[k]."""
    k = n - 1 - i
    xk = y[k] / l[k, k]                   # diagonal already >= sqrt(eps)
    y = y.at[k].set(xk)
    row = l[k, :]                         # l[k, j] valid for j <= k
    return y - jnp.where(rows[:, None] < k, row[:, None] * xk[None, :], 0.0)


def _cholesky_solve_kernel(a_ref, b_ref, x_ref, *l_refs, n: int,
                           eps: float):
    a = a_ref[0]
    y = b_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    # symmetrize from the lower triangle: the upper half is never read
    # (garbage/NaN lanes there cannot leak into the solve)
    tril = rows[:, None] >= rows[None, :]
    a = jnp.where(tril, a, a.T)
    thresh = pivot_threshold(a, rows, eps=eps)

    a, y = jax.lax.fori_loop(
        0, n,
        lambda k, c: factor_forward_step(k, c[0], c[1], rows, thresh),
        (a, y))
    y = jax.lax.fori_loop(
        0, n, lambda i, y_: back_substitution_step(i, a, y_, rows, n=n), y)
    x_ref[0] = y
    if l_refs:                    # factor output requested (return_l)
        l_refs[0][0] = jnp.where(tril, a, 0.0)


def cholesky_solve_pallas(a: jax.Array, b: jax.Array, *,
                          eps: float = DEFAULT_EPS,
                          interpret: bool | None = None,
                          return_l: bool = False):
    """Solve a @ x = b for SPD a. a: (B,N,N), b: (B,N,M) -> x (B,N,M).

    One pallas_call; factor and both substitutions fused per lane.  With
    ``return_l`` also returns the Cholesky factor (it is VMEM-resident
    anyway; without the flag no factor output is declared at all, so the
    hot serving path never pays the extra HBM write).
    """
    bsz, n, n2 = a.shape
    b2, n3, m = b.shape
    assert n == n2 == n3 and bsz == b2, (a.shape, b.shape)
    if interpret is None:
        interpret = interpret_default()
    out_specs = [pl.BlockSpec((1, n, m), lambda i: (i, 0, 0),
                              memory_space=pltpu.VMEM)]
    out_shape = [jax.ShapeDtypeStruct((bsz, n, m), b.dtype)]
    if return_l:
        out_specs.append(pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                                      memory_space=pltpu.VMEM))
        out_shape.append(jax.ShapeDtypeStruct((bsz, n, n), a.dtype))
    out = pl.pallas_call(
        functools.partial(_cholesky_solve_kernel, n=n, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(a, b)
    return (out[0], out[1]) if return_l else out[0]


def _panel_factor_forward_step(j, carry, *, o, n: int, m: int, rows,
                               cols_bs, thresh):
    """One column of the blocked panel factor, fused with the forward
    substitution row it finishes (the blocked analog of
    ``factor_forward_step``).

    carry: (c, y) with c the full-height (n, bs) column slab [cols
    o..o+bs) of the working matrix] and y the (n, m) right-hand sides.
    ``g = o + j`` is the global pivot; the rank-1 update is confined to
    the REMAINING slab columns (cols_bs > j) — trailing columns outside
    the slab get their whole panel's contribution later in one SYRK.
    """
    c, y = carry
    g = o + j
    col = jax.lax.dynamic_slice(c, (0, j), (n, 1))[:, 0]
    pivot = jnp.take(col, g)
    ok = pivot > thresh
    inv = jnp.where(ok, jax.lax.rsqrt(jnp.maximum(pivot, thresh)), 0.0)
    newcol = col * inv
    newcol = jnp.where(rows == g, jnp.where(ok, pivot * inv, 1.0), newcol)
    newcol = jnp.where(rows >= g, newcol, 0.0)          # implicit mask (F4)
    live = rows > g
    # rank-1 update of the remaining panel columns only
    w = jax.lax.dynamic_slice(newcol, (o,), cols_bs.shape)
    w = jnp.where(cols_bs > j, w, 0.0)
    c = c - jnp.where(live[:, None], newcol[:, None] * w[None, :], 0.0)
    c = jax.lax.dynamic_update_slice(c, newcol[:, None], (0, j))
    # fused forward substitution consuming the finished column
    yg = jax.lax.dynamic_slice(y, (g, 0), (1, m)) * inv
    y = jax.lax.dynamic_update_slice(y, yg, (g, 0))
    y = y - jnp.where(live[:, None], newcol[:, None] * yg, 0.0)
    return c, y


def _cholesky_solve_blocked_kernel(a_ref, b_ref, x_ref, a_scr, y_scr,
                                   thr_scr, *, n: int, m: int, bs: int,
                                   eps: float):
    """One tile step of the right-looking blocked factor-solve.

    grid = (lanes, n // bs): the second grid dimension is the panel step
    (``dimension_semantics`` marks it "arbitrary" — ordered), the matrix
    and right-hand sides stay resident in VMEM scratch across steps, so
    nothing round-trips HBM between panel factor, triangular update, and
    trailing SYRK — the tiled-Cholesky chaining of Buttari et al. inside
    the paper's ordered-region model.
    """
    step = pl.program_id(1)
    steps = n // bs
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    cols_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    @pl.when(step == 0)
    def _init():
        a = a_ref[0]
        tril = rows[:, None] >= rows[None, :]
        a = jnp.where(tril, a, a.T)       # symmetrize: upper never read
        a_scr[...] = a.astype(jnp.float32)
        y_scr[...] = b_ref[0].astype(jnp.float32)
        thr_scr[0] = pivot_threshold(a.astype(jnp.float32), rows, eps=eps)

    a = a_scr[...]
    y = y_scr[...]
    o = step * bs
    thresh = thr_scr[0]

    # ---- panel factor + fused forward substitution (bs columns) ----
    c = jax.lax.dynamic_slice(a, (0, o), (n, bs))
    c, y = jax.lax.fori_loop(
        0, bs,
        functools.partial(_panel_factor_forward_step, o=o, n=n, m=m,
                          rows=rows, cols_bs=cols_bs, thresh=thresh),
        (c, y))
    a = jax.lax.dynamic_update_slice(a, c, (0, o))
    # ---- trailing SYRK (critical MXU region): one rank-bs GEMM applies
    # the whole panel's update to the trailing submatrix ----
    cm = jnp.where(rows[:, None] >= o + bs, c, 0.0)
    a = a - jnp.dot(cm, cm.T, preferred_element_type=jnp.float32)
    a_scr[...] = a
    y_scr[...] = y

    # ---- back substitution once the factor is complete (the local
    # ``a``/``y`` ARE the just-written scratch contents; reading the
    # refs back per iteration would re-copy the whole block) ----
    @pl.when(step == steps - 1)
    def _finish():
        z = jax.lax.fori_loop(
            0, n,
            lambda i, z_: back_substitution_step(i, a, z_, rows, n=n),
            y)
        x_ref[0] = z.astype(x_ref.dtype)


def cholesky_solve_blocked(a: jax.Array, b: jax.Array, *,
                           bs: int | None = None, eps: float = DEFAULT_EPS,
                           interpret: bool | None = None) -> jax.Array:
    """Right-looking blocked fused SPD solve — the large-n fast path.

    Same contract as :func:`cholesky_solve_pallas` (a: (B,N,N) SPD,
    b: (B,N,M) -> x) but tiled: the grid's second dimension walks panel
    steps of width ``bs`` (default: 64 when N divides, else 32), each
    step factoring one panel (with the forward substitution fused in)
    and applying the trailing update as a single rank-``bs`` SYRK on the
    MXU instead of ``bs`` rank-1 vector updates.  Registered as the
    ``blocked`` variant of the ``cholesky_solve`` spec; the dispatcher
    picks it for N >= 128.
    """
    bsz, n, n2 = a.shape
    b2, n3, m = b.shape
    assert n == n2 == n3 and bsz == b2, (a.shape, b.shape)
    if bs is None:
        bs = 64 if n % 64 == 0 else 32
    assert n % bs == 0 and n >= bs, (n, bs)
    if interpret is None:
        interpret = interpret_default()
    steps = n // bs
    return pl.pallas_call(
        functools.partial(_cholesky_solve_blocked_kernel, n=n, m=m, bs=bs,
                          eps=eps),
        grid=(bsz, steps),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i, s: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n, m), lambda i, s: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, m), lambda i, s: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, m), b.dtype),
        scratch_shapes=[
            pltpu.VMEM((n, n), jnp.float32),
            pltpu.VMEM((n, m), jnp.float32),
            pltpu.SMEM((1,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def cholesky_solve_unfused(a: jax.Array, b: jax.Array, *,
                           interpret: bool | None = None) -> jax.Array:
    """The no-fusion baseline: factor-then-solve via THREE separate
    pallas_calls — the matrix round-trips through HBM between regions.
    Same math; this is what bench_pipelines compares against."""
    l = cholesky_pallas(a, interpret=interpret)
    z = trisolve_pallas(l, b, lower=True, interpret=interpret)
    return trisolve_pallas(jnp.swapaxes(l, -1, -2), z, lower=False,
                           interpret=interpret)


def _cholesky_solve_xla(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused-at-XLA-level fallback (one jit program, library factor)."""
    l = jnp.linalg.cholesky(a)
    z = jax.vmap(partial(jax.scipy.linalg.solve_triangular, lower=True)
                 )(l, b)
    return jax.vmap(partial(jax.scipy.linalg.solve_triangular, lower=False)
                    )(jnp.swapaxes(l, -1, -2), z)


@partial(jax.jit, static_argnames=("backend",))
def cholesky_solve(a: jax.Array, b: jax.Array, *,
                   backend: str | None = None) -> jax.Array:
    """Public wrapper with backend dispatch (pallas on TPU, xla off)."""
    if resolve_backend(backend) == "pallas":
        return cholesky_solve_pallas(a, b)
    return _cholesky_solve_xla(a, b)
