"""Fused SPD solve: Cholesky factor + forward + back substitution in ONE
Pallas grid cell (paper Figs. 5/9/13 chained as a single ordered region).

The REVEL win the paper measures is not a lone factorization — it is the
*chain* factor -> forward-solve -> back-solve executed without the matrix
ever round-tripping through memory.  Here one grid cell = one lane: the
matrix and right-hand sides stay VMEM-resident across all three stages,
and the forward substitution is interleaved *inside* the factor loop — as
soon as column k of L is finished (the ordered dependence), the divide +
AXPY of the forward solve for row k consume it.  The fori_loop carry is
REVEL's inter-region FIFO.

Numerics: only the lower triangle of A is read (the inductive-domain mask,
paper Feature 4 — verified by the NaN-poisoning test), and the pivot is
guarded by ``eps`` so singular/ill-conditioned systems produce finite
output instead of NaN lanes.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cholesky import cholesky_pallas
from repro.kernels.common import (interpret_default, resolve_backend,
                                  tpu_compiler_params)
from repro.kernels.trisolve import trisolve_pallas

# Relative pivot threshold (LAPACK pstrf-style): a pivot below
# eps * max(diag(A)) marks a numerically deficient direction.  Residual
# pivots of an exactly singular float32 matrix land around
# n * ulp * ||A|| ~ 1e-6 * scale, so 1e-5 cleanly separates "deficient"
# from merely ill-conditioned.
DEFAULT_EPS = 1e-5


def pivot_threshold(a, rows, *, eps: float):
    """Scale-relative deficiency threshold from the initial diagonal."""
    diag = jnp.where(rows[:, None] == rows[None, :], a, -jnp.inf)
    return jnp.maximum(eps * jnp.max(diag), 1e-30)


def factor_forward_step(k, a, y, rows, thresh):
    """One fused outer iteration: finish column k of L, then immediately
    run the forward-substitution step that consumes it.

    a: (n, n) working matrix (lower triangle -> L in place)
    y: (n, m) right-hand sides being forward-solved in place
    thresh: scalar deficiency threshold (see pivot_threshold)

    A pivot below ``thresh`` takes the rank-deficient path: unit diagonal,
    zeroed column, zeroed solution component — the solve proceeds on the
    numerically non-deficient subspace and every lane stays finite.
    """
    # ---- point region (non-critical): guarded rsqrt of the pivot ----
    akk = a[k, k]
    ok = akk > thresh
    inv = jnp.where(ok, jax.lax.rsqrt(jnp.maximum(akk, thresh)), 0.0)
    # ---- vector region: scale column k; diagonal set to the pivot ----
    col = a[:, k] * inv
    col = jnp.where(rows == k, jnp.where(ok, akk * inv, 1.0), col)
    col = jnp.where(rows >= k, col, 0.0)              # implicit mask (F4)
    # ---- matrix region (critical): masked rank-1 trailing update ----
    live = rows > k
    upd = col[:, None] * col[None, :]
    mask = live[:, None] & live[None, :]
    a = a - jnp.where(mask, upd, 0.0)
    a = a.at[:, k].set(jnp.where(rows >= k, col, a[:, k]))
    # ---- fused forward substitution consuming the finished column ----
    # y[k] /= l[k,k];  y[j>k] -= l[j,k] * y[k]   (divide + masked AXPY)
    yk = y[k] * inv                                   # deficient: x_k = 0
    y = y.at[k].set(yk)
    y = y - jnp.where(live[:, None], col[:, None] * yk[None, :], 0.0)
    return a, y


def back_substitution_step(i, l, y, rows, *, n: int):
    """Back-substitution outer iteration on U = L^T, k = n-1-i:
    x[k] = y[k] / l[k,k];  y[j<k] -= l[k,j] * x[k]."""
    k = n - 1 - i
    xk = y[k] / l[k, k]                   # diagonal already >= sqrt(eps)
    y = y.at[k].set(xk)
    row = l[k, :]                         # l[k, j] valid for j <= k
    return y - jnp.where(rows[:, None] < k, row[:, None] * xk[None, :], 0.0)


def _cholesky_solve_kernel(a_ref, b_ref, x_ref, *l_refs, n: int,
                           eps: float):
    a = a_ref[0]
    y = b_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    # symmetrize from the lower triangle: the upper half is never read
    # (garbage/NaN lanes there cannot leak into the solve)
    tril = rows[:, None] >= rows[None, :]
    a = jnp.where(tril, a, a.T)
    thresh = pivot_threshold(a, rows, eps=eps)

    a, y = jax.lax.fori_loop(
        0, n,
        lambda k, c: factor_forward_step(k, c[0], c[1], rows, thresh),
        (a, y))
    y = jax.lax.fori_loop(
        0, n, lambda i, y_: back_substitution_step(i, a, y_, rows, n=n), y)
    x_ref[0] = y
    if l_refs:                    # factor output requested (return_l)
        l_refs[0][0] = jnp.where(tril, a, 0.0)


def cholesky_solve_pallas(a: jax.Array, b: jax.Array, *,
                          eps: float = DEFAULT_EPS,
                          interpret: bool | None = None,
                          return_l: bool = False):
    """Solve a @ x = b for SPD a. a: (B,N,N), b: (B,N,M) -> x (B,N,M).

    One pallas_call; factor and both substitutions fused per lane.  With
    ``return_l`` also returns the Cholesky factor (it is VMEM-resident
    anyway; without the flag no factor output is declared at all, so the
    hot serving path never pays the extra HBM write).
    """
    bsz, n, n2 = a.shape
    b2, n3, m = b.shape
    assert n == n2 == n3 and bsz == b2, (a.shape, b.shape)
    if interpret is None:
        interpret = interpret_default()
    out_specs = [pl.BlockSpec((1, n, m), lambda i: (i, 0, 0),
                              memory_space=pltpu.VMEM)]
    out_shape = [jax.ShapeDtypeStruct((bsz, n, m), b.dtype)]
    if return_l:
        out_specs.append(pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                                      memory_space=pltpu.VMEM))
        out_shape.append(jax.ShapeDtypeStruct((bsz, n, n), a.dtype))
    out = pl.pallas_call(
        functools.partial(_cholesky_solve_kernel, n=n, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(a, b)
    return (out[0], out[1]) if return_l else out[0]


def _panel_factor_forward_step(j, carry, *, o, n: int, m: int, rows,
                               cols_bs, thresh):
    """One column of the blocked panel factor, fused with the forward
    substitution row it finishes (the blocked analog of
    ``factor_forward_step``).

    carry: (c, y) with c the full-height (n, bs) column slab [cols
    o..o+bs) of the working matrix] and y the (n, m) right-hand sides.
    ``g = o + j`` is the global pivot; the rank-1 update is confined to
    the REMAINING slab columns (cols_bs > j) — trailing columns outside
    the slab get their whole panel's contribution later in one SYRK.
    """
    c, y = carry
    g = o + j
    col = jax.lax.dynamic_slice(c, (0, j), (n, 1))[:, 0]
    pivot = jnp.take(col, g)
    ok = pivot > thresh
    inv = jnp.where(ok, jax.lax.rsqrt(jnp.maximum(pivot, thresh)), 0.0)
    newcol = col * inv
    newcol = jnp.where(rows == g, jnp.where(ok, pivot * inv, 1.0), newcol)
    newcol = jnp.where(rows >= g, newcol, 0.0)          # implicit mask (F4)
    live = rows > g
    # rank-1 update of the remaining panel columns only
    w = jax.lax.dynamic_slice(newcol, (o,), cols_bs.shape)
    w = jnp.where(cols_bs > j, w, 0.0)
    c = c - jnp.where(live[:, None], newcol[:, None] * w[None, :], 0.0)
    c = jax.lax.dynamic_update_slice(c, newcol[:, None], (0, j))
    # fused forward substitution consuming the finished column
    yg = jax.lax.dynamic_slice(y, (g, 0), (1, m)) * inv
    y = jax.lax.dynamic_update_slice(y, yg, (g, 0))
    y = y - jnp.where(live[:, None], newcol[:, None] * yg, 0.0)
    return c, y


def _cholesky_solve_blocked_kernel(a_ref, b_ref, x_ref, a_scr, y_scr,
                                   thr_scr, *, n: int, m: int, bs: int,
                                   eps: float):
    """One tile step of the right-looking blocked factor-solve.

    grid = (lanes, n // bs): the second grid dimension is the panel step
    (``dimension_semantics`` marks it "arbitrary" — ordered), the matrix
    and right-hand sides stay resident in VMEM scratch across steps, so
    nothing round-trips HBM between panel factor, triangular update, and
    trailing SYRK — the tiled-Cholesky chaining of Buttari et al. inside
    the paper's ordered-region model.
    """
    step = pl.program_id(1)
    steps = n // bs
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    cols_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    @pl.when(step == 0)
    def _init():
        a = a_ref[0]
        tril = rows[:, None] >= rows[None, :]
        a = jnp.where(tril, a, a.T)       # symmetrize: upper never read
        a_scr[...] = a.astype(jnp.float32)
        y_scr[...] = b_ref[0].astype(jnp.float32)
        thr_scr[0] = pivot_threshold(a.astype(jnp.float32), rows, eps=eps)

    a = a_scr[...]
    y = y_scr[...]
    o = step * bs
    thresh = thr_scr[0]

    # ---- panel factor + fused forward substitution (bs columns) ----
    c = jax.lax.dynamic_slice(a, (0, o), (n, bs))
    c, y = jax.lax.fori_loop(
        0, bs,
        functools.partial(_panel_factor_forward_step, o=o, n=n, m=m,
                          rows=rows, cols_bs=cols_bs, thresh=thresh),
        (c, y))
    a = jax.lax.dynamic_update_slice(a, c, (0, o))
    # ---- trailing SYRK (critical MXU region): one rank-bs GEMM applies
    # the whole panel's update to the trailing submatrix ----
    cm = jnp.where(rows[:, None] >= o + bs, c, 0.0)
    a = a - jnp.dot(cm, cm.T, preferred_element_type=jnp.float32)
    a_scr[...] = a
    y_scr[...] = y

    # ---- back substitution once the factor is complete (the local
    # ``a``/``y`` ARE the just-written scratch contents; reading the
    # refs back per iteration would re-copy the whole block) ----
    @pl.when(step == steps - 1)
    def _finish():
        z = jax.lax.fori_loop(
            0, n,
            lambda i, z_: back_substitution_step(i, a, z_, rows, n=n),
            y)
        x_ref[0] = z.astype(x_ref.dtype)


def cholesky_solve_blocked(a: jax.Array, b: jax.Array, *,
                           bs: int | None = None, eps: float = DEFAULT_EPS,
                           interpret: bool | None = None) -> jax.Array:
    """Right-looking blocked fused SPD solve — the large-n fast path.

    Same contract as :func:`cholesky_solve_pallas` (a: (B,N,N) SPD,
    b: (B,N,M) -> x) but tiled: the grid's second dimension walks panel
    steps of width ``bs`` (default: 64 when N divides, else 32), each
    step factoring one panel (with the forward substitution fused in)
    and applying the trailing update as a single rank-``bs`` SYRK on the
    MXU instead of ``bs`` rank-1 vector updates.  Registered as the
    ``blocked`` variant of the ``cholesky_solve`` spec; the dispatcher
    picks it for N >= 128.
    """
    bsz, n, n2 = a.shape
    b2, n3, m = b.shape
    assert n == n2 == n3 and bsz == b2, (a.shape, b.shape)
    if bs is None:
        bs = 64 if n % 64 == 0 else 32
    assert n % bs == 0 and n >= bs, (n, bs)
    if interpret is None:
        interpret = interpret_default()
    steps = n // bs
    return pl.pallas_call(
        functools.partial(_cholesky_solve_blocked_kernel, n=n, m=m, bs=bs,
                          eps=eps),
        grid=(bsz, steps),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i, s: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n, m), lambda i, s: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, m), lambda i, s: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, m), b.dtype),
        scratch_shapes=[
            pltpu.VMEM((n, n), jnp.float32),
            pltpu.VMEM((n, m), jnp.float32),
            pltpu.SMEM((1,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# True sub-matrix tiling: HBM-resident trailing matrix, O(n*bs) VMEM
# ---------------------------------------------------------------------------
#
# The ``blocked`` kernel above tiles the *schedule* but still holds the
# whole (n, n) matrix in one VMEM block, capping it near n = 512.  The
# ``tiled`` kernel below tiles the *data*: the matrix lives in HBM (a
# ``pltpu.ANY`` ref) and every grid cell DMAs exactly one (n, bs) column
# slab into VMEM scratch, so the per-cell working set is O(n*bs) and
# n = 1024/2048 fit.  Grid = (lanes, steps + 1, tiles) with
# steps = tiles = n // bs:
#
#   cell (i, s, t) with s < steps, t == s   panel cell: factor panel s
#     (bs fused factor+forward-subst columns) from the double-buffered
#     panel carry, stash the factored panel for the trailing cells, and
#     DMA it out to the HBM factor buffer.
#   cell (i, s, t) with s < steps, t > s    trailing cell: DMA slab t in,
#     apply the panel's rank-bs SYRK update, DMA it back out.  The slab
#     for t == s + 1 is additionally stashed into the *other* half of the
#     panel-carry scratch — the next panel cell factors straight from
#     VMEM instead of round-tripping HBM (double-buffered panel carry).
#   cell (i, steps, t)                      back-substitution cell: slabs
#     re-streamed in REVERSE (rt = steps-1-t); the L^T solve is
#     left-looking per column slab, so each cell needs only its own slab.
#
# Cells with t < s are idle (no DMA, no compute) — the price of a
# rectangular grid over a triangular iteration space, exactly the
# paper's inductive-domain shape.

def _tiled_trailing_update(slab, pan, t, *, o, bs: int, rows):
    """Rank-``bs`` SYRK of factored panel ``pan`` onto column slab ``t``:
    slab[r, j] -= sum_p pan[r, p] * pan[t*bs + j, p] for rows r below the
    panel (rows >= o + bs).  ``o``/``t`` may be traced grid values."""
    pt = jax.lax.dynamic_slice(pan, (t * bs, 0), (bs, pan.shape[1]))
    pm = jnp.where(rows[:, None] >= o + bs, pan, 0.0)
    return slab - jnp.dot(pm, pt.T, preferred_element_type=jnp.float32)


def _tiled_backsub_step(slab, z, rt, *, bs: int, m: int, rows):
    """Left-looking block step of the L^T back substitution on column
    slab ``rt`` (slabs processed in reverse): subtract the contributions
    of the already-solved components below, then solve the (bs, bs)
    diagonal block.  Only THIS slab is touched — O(n*bs) working set."""
    o = rt * bs
    below = jnp.where(rows[:, None] >= o + bs, slab, 0.0)
    corr = jnp.dot(below.T, z, preferred_element_type=jnp.float32)
    zt = jax.lax.dynamic_slice(z, (o, 0), (bs, m)) - corr
    lb = jax.lax.dynamic_slice(slab, (o, 0), (bs, slab.shape[1]))
    rows_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    xt = jax.lax.fori_loop(
        0, bs,
        lambda i, zz: back_substitution_step(i, lb, zz, rows_bs, n=bs),
        zt)
    return jax.lax.dynamic_update_slice(z, xt, (o, 0))


def _pan_read(pan_scr, half):
    """Read one half of the double-buffered panel carry (``half`` is a
    traced 0/1 value; refs cannot be selected dynamically, values can)."""
    return jnp.where(half == 0, pan_scr[0], pan_scr[1])


def _pan_write(pan_scr, half, val):
    @pl.when(half == 0)
    def _w0():
        pan_scr[0] = val

    @pl.when(half != 0)
    def _w1():
        pan_scr[1] = val


# Per-cell VMEM ceiling for the tiled kernels: stay comfortably inside
# a TPU core's ~16 MiB vector memory (double-buffered DMA slack left).
TILED_VMEM_BUDGET_BYTES = 14 * 2 ** 20


def tiled_block_size(n: int) -> int:
    """Default slab width: the largest of {128, 64, 32} dividing n, so
    every n % 32 == 0 shape the dispatcher can route here (the variant
    predicate's requirement) actually tiles — n = 1888 must not fall
    back to a whole-matrix VMEM kernel for want of a 64-divisor."""
    for bs in (128, 64, 32):
        if n % bs == 0:
            return bs
    raise ValueError(f"n={n} does not tile into 32-wide slabs")


def tiled_vmem_floats(n: int, bs: int, m: int) -> int:
    """Per-grid-cell VMEM working set of the tiled solve, in float32
    elements — the single source of truth for the kernel's scratch and
    block declarations, asserted O(n*bs) by the test suite and enforced
    against :data:`TILED_VMEM_BUDGET_BYTES` at call time.

      slab scratch (n, bs) + double-buffered panel carry (2, n, bs)
      + rhs carry (n, m) + b block (n, m) + x block (n, m)
    """
    return 3 * n * bs + 3 * n * m


def _tiled_factor_cell(i, s2, t, *, first_hbm, work_hbm, slab_scr,
                       pan_scr, y_scr, sem, thresh, n: int, m: int,
                       bs: int, rows, cols_bs):
    """One factor-phase grid cell (panel at t == s2, trailing at
    t > s2) of the tiled right-looking Cholesky — shared by
    ``cholesky_solve_tiled`` and the factor phase of
    ``mmse_equalize_tiled``.  ``first_hbm`` is where a slab's FIRST read
    comes from (the raw input for the Cholesky pipeline, the work buffer
    itself for MMSE, whose Gram phase already wrote it); every later
    read and every write go to ``work_hbm``."""
    @pl.when(t == s2)
    def _panel():
        @pl.when(s2 == 0)                 # first panel: no stash yet
        def _first():
            cp = pltpu.make_async_copy(first_hbm.at[i, :, pl.ds(0, bs)],
                                       slab_scr, sem)
            cp.start()
            cp.wait()
            pan_scr[0] = slab_scr[...]

        half = s2 % 2
        c = _pan_read(pan_scr, half)      # pre-updated panel slab
        c, y = jax.lax.fori_loop(
            0, bs,
            functools.partial(_panel_factor_forward_step, o=s2 * bs, n=n,
                              m=m, rows=rows, cols_bs=cols_bs,
                              thresh=thresh),
            (c, y_scr[...]))
        _pan_write(pan_scr, half, c)      # trailing cells read this
        y_scr[...] = y
        slab_scr[...] = c
        cp = pltpu.make_async_copy(
            slab_scr, work_hbm.at[i, :, pl.ds(s2 * bs, bs)], sem)
        cp.start()
        cp.wait()

    @pl.when(t > s2)
    def _trailing():
        @pl.when(s2 == 0)
        def _from_first():
            cp = pltpu.make_async_copy(
                first_hbm.at[i, :, pl.ds(t * bs, bs)], slab_scr, sem)
            cp.start()
            cp.wait()

        @pl.when(s2 > 0)
        def _from_work():
            cp = pltpu.make_async_copy(
                work_hbm.at[i, :, pl.ds(t * bs, bs)], slab_scr, sem)
            cp.start()
            cp.wait()

        pan = _pan_read(pan_scr, s2 % 2)
        slab = _tiled_trailing_update(slab_scr[...], pan, t, o=s2 * bs,
                                      bs=bs, rows=rows)
        slab_scr[...] = slab
        cp = pltpu.make_async_copy(
            slab_scr, work_hbm.at[i, :, pl.ds(t * bs, bs)], sem)
        cp.start()
        cp.wait()

        @pl.when(t == s2 + 1)             # double-buffered panel carry
        def _stash():
            _pan_write(pan_scr, (s2 + 1) % 2, slab)


def _tiled_backsub_cell(i, t, *, steps: int, work_hbm, slab_scr, y_scr,
                        x_ref, sem, bs: int, m: int, rows):
    """One back-substitution grid cell (reverse slab order) of the tiled
    L^T solve, shared by the Cholesky and MMSE tiled kernels; the last
    cell writes the solution block."""
    rt = steps - 1 - t
    cp = pltpu.make_async_copy(work_hbm.at[i, :, pl.ds(rt * bs, bs)],
                               slab_scr, sem)
    cp.start()
    cp.wait()
    z = _tiled_backsub_step(slab_scr[...], y_scr[...], rt, bs=bs,
                            m=m, rows=rows)
    y_scr[...] = z

    @pl.when(t == steps - 1)
    def _finish():
        x_ref[0] = z.astype(x_ref.dtype)


def _cholesky_solve_tiled_kernel(thr_ref, a_hbm, b_ref, x_ref, l_hbm,
                                 slab_scr, pan_scr, y_scr, sem, *,
                                 n: int, m: int, bs: int, steps: int):
    i = pl.program_id(0)
    s = pl.program_id(1)                  # panel step; == steps: back-sub
    t = pl.program_id(2)                  # column tile
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    cols_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    @pl.when((s == 0) & (t == 0))
    def _init():
        y_scr[...] = b_ref[0].astype(jnp.float32)

    @pl.when(s < steps)
    def _factor():
        _tiled_factor_cell(i, s, t, first_hbm=a_hbm, work_hbm=l_hbm,
                           slab_scr=slab_scr, pan_scr=pan_scr,
                           y_scr=y_scr, sem=sem, thresh=thr_ref[0, 0],
                           n=n, m=m, bs=bs, rows=rows, cols_bs=cols_bs)

    @pl.when(s == steps)
    def _backsub():
        _tiled_backsub_cell(i, t, steps=steps, work_hbm=l_hbm,
                            slab_scr=slab_scr, y_scr=y_scr, x_ref=x_ref,
                            sem=sem, bs=bs, m=m, rows=rows)


def cholesky_solve_tiled(a: jax.Array, b: jax.Array, *,
                         bs: int | None = None, eps: float = DEFAULT_EPS,
                         interpret: bool | None = None) -> jax.Array:
    """True sub-matrix tiled fused SPD solve — the HBM-scale fast path.

    Same contract as :func:`cholesky_solve_pallas` (a: (B,N,N) SPD,
    b: (B,N,M) -> x), but the matrix never sits whole in VMEM: per grid
    cell exactly one (N, bs) column slab is DMA'd in (plus the
    double-buffered panel carry), the trailing matrix stays HBM-resident
    in a ``pltpu.ANY`` work buffer, and the per-cell working set is
    ``tiled_vmem_floats(n, bs, m)`` = O(N*bs).  The deficiency threshold
    is precomputed host-side (one fused O(N) diagonal reduction) because
    the first panel cell needs it before any other slab is seen.
    Registered as the ``tiled`` variant of the ``cholesky_solve`` spec;
    the dispatcher picks it for N >= 512.
    """
    bsz, n, n2 = a.shape
    b2, n3, m = b.shape
    assert n == n2 == n3 and bsz == b2, (a.shape, b.shape)
    if bs is None:
        bs = tiled_block_size(n)
    assert n % bs == 0 and n >= 2 * bs, (n, bs)
    assert tiled_vmem_floats(n, bs, m) * 4 <= TILED_VMEM_BUDGET_BYTES, \
        (n, bs, m)
    if interpret is None:
        interpret = interpret_default()
    steps = n // bs
    diag = jnp.diagonal(a, axis1=-2, axis2=-1)
    thr = jnp.maximum(eps * jnp.max(diag, axis=-1), 1e-30)
    thr = thr.astype(jnp.float32).reshape(bsz, 1)
    x, _ = pl.pallas_call(
        functools.partial(_cholesky_solve_tiled_kernel, n=n, m=m, bs=bs,
                          steps=steps),
        grid=(bsz, steps + 1, steps),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, s, t: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, n, m), lambda i, s, t: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n, m), lambda i, s, t: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n, m), b.dtype),
            jax.ShapeDtypeStruct((bsz, n, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, bs), jnp.float32),
            pltpu.VMEM((2, n, bs), jnp.float32),
            pltpu.VMEM((n, m), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(thr, a, b)
    return x


def cholesky_solve_unfused(a: jax.Array, b: jax.Array, *,
                           interpret: bool | None = None) -> jax.Array:
    """The no-fusion baseline: factor-then-solve via THREE separate
    pallas_calls — the matrix round-trips through HBM between regions.
    Same math; this is what bench_pipelines compares against."""
    l = cholesky_pallas(a, interpret=interpret)
    z = trisolve_pallas(l, b, lower=True, interpret=interpret)
    return trisolve_pallas(jnp.swapaxes(l, -1, -2), z, lower=False,
                           interpret=interpret)


def _cholesky_solve_xla(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused-at-XLA-level fallback (one jit program, library factor)."""
    l = jnp.linalg.cholesky(a)
    z = jax.vmap(partial(jax.scipy.linalg.solve_triangular, lower=True)
                 )(l, b)
    return jax.vmap(partial(jax.scipy.linalg.solve_triangular, lower=False)
                    )(jnp.swapaxes(l, -1, -2), z)


@partial(jax.jit, static_argnames=("backend",))
def cholesky_solve(a: jax.Array, b: jax.Array, *,
                   backend: str | None = None) -> jax.Array:
    """Public wrapper with backend dispatch (pallas on TPU, xla off)."""
    if resolve_backend(backend) == "pallas":
        return cholesky_solve_pallas(a, b)
    return _cholesky_solve_xla(a, b)
