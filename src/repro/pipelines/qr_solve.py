"""Fused least squares: Householder QR + implicit Q^T b + back
substitution in ONE Pallas grid cell (paper Fig. 6 chained with Fig. 9).

The fusion is structural, not just spatial: Q is never formed.  Each
reflector (v, tau) — the non-critical point/vector region — is applied to
the trailing columns of R *and* to the right-hand sides in the same outer
iteration (two critical MXU-shaped regions sharing one produced value:
the paper's inductive-consumption `tau` edge).  After min(m-1, n)
reflections the rhs holds Q^T b, and the back substitution on the n x n
upper triangle of R runs in the same kernel, everything VMEM-resident.

Pivot guard: a degenerate (zero-norm) column takes tau = 0 (identity
reflector) and the back substitution divides by a clamped diagonal, so
rank-deficient systems stay finite.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (interpret_default, resolve_backend,
                                  tpu_compiler_params)
from repro.kernels.qr import qr_pallas
from repro.kernels.trisolve import trisolve_pallas
from repro.pipelines.cholesky_solve import (TILED_VMEM_BUDGET_BYTES,
                                            _pan_read, _pan_write,
                                            tiled_block_size)

DEFAULT_TINY = 1e-20


def reflect_step(k, r, y, rows, *, tiny: float = DEFAULT_TINY):
    """One fused outer iteration: build reflector k, apply to R and rhs."""
    # ---- householder region (non-critical: norm, sqrt, div) ----
    x = jnp.where(rows >= k, r[:, k], 0.0)            # masked column (F4)
    xk = r[k, k]
    norm = jnp.sqrt(jnp.sum(x * x))
    alpha = jnp.where(xk >= 0, -norm, norm)
    v = x - alpha * (rows == k).astype(r.dtype)
    vnorm2 = jnp.maximum(jnp.sum(v * v), tiny)
    tau = jnp.where(norm < tiny, 0.0, 2.0 / vnorm2)   # degenerate: skip
    # ---- critical region 1: R update (v^T R then rank-1) ----
    r = r - v[:, None] * (tau * (v @ r))[None, :]
    # ---- critical region 2 (fused solve): rhs <- (I - tau v v^T) rhs ----
    y = y - v[:, None] * (tau * (v @ y))[None, :]
    return r, y


def back_substitute_r(r, y, *, n: int, tiny: float, thresh=None):
    """Back substitution on R[:n,:n] x = (Q^T b)[:n], shared by the
    unblocked, blocked, and tiled kernels.

    Uses a relative deficiency threshold from R's diagonal: a pivot
    below it marks a numerically dependent column, whose solution
    component is ZEROED (clamping the divisor instead would overflow
    float32: with R = [[0,1],[0,0]] a clamped 1/tiny cascades to inf
    through the remaining rows).  ``thresh`` overrides the local
    diagonal-derived threshold — the tiled kernel solves one (bs, bs)
    diagonal block at a time, so it passes the GLOBAL R-diagonal
    threshold accumulated during the panel sweep.
    """
    rows_n = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    z = y[:n]
    if thresh is None:
        diag = jnp.abs(jnp.where(rows_n[:, None] == rows_n[None, :],
                                 r[:n], 0.0).sum(axis=1))
        thresh = jnp.maximum(1e-6 * jnp.max(diag), tiny)

    def bwd(i, z):
        k = n - 1 - i
        rkk = r[k, k]
        ok = jnp.abs(rkk) > thresh
        xk = jnp.where(ok, z[k] / jnp.where(ok, rkk, 1.0), 0.0)
        z = z.at[k].set(xk)
        col = jnp.where(rows_n < k, r[:n, k], 0.0)
        return z - col[:, None] * xk[None, :]

    return jax.lax.fori_loop(0, n, bwd, z)


def _qr_solve_kernel(a_ref, b_ref, x_ref, *, m: int, n: int,
                     tiny: float):
    r = a_ref[0]                                      # (m, n)
    y = b_ref[0]                                      # (m, k)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)
    nref = min(n, m - 1) if m > 1 else 0

    r, y = jax.lax.fori_loop(
        0, nref, lambda k, c: reflect_step(k, c[0], c[1], rows, tiny=tiny),
        (r, y))

    x_ref[0] = back_substitute_r(r, y, n=n, tiny=tiny)


def qr_solve_pallas(a: jax.Array, b: jax.Array, *,
                    tiny: float = DEFAULT_TINY,
                    interpret: bool | None = None) -> jax.Array:
    """Least squares min ||a @ x - b||. a: (B,M,N) with M >= N,
    b: (B,M,K) -> x: (B,N,K).  One pallas_call, Q never materialized."""
    bsz, m, n = a.shape
    b2, m2, k = b.shape
    assert m == m2 and bsz == b2 and m >= n, (a.shape, b.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_qr_solve_kernel, m=m, n=n, tiny=tiny),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), b.dtype),
        interpret=interpret,
    )(a, b)


def _qr_panel_reflect_step(j, carry, *, o, m: int, rows, tiny: float):
    """Reflector ``g = o + j`` built from and applied to the panel only;
    (v, tau) accumulated for the compact-WY block apply."""
    pan, v_acc, tau_acc = carry
    g = o + j
    x = jax.lax.dynamic_slice(pan, (0, j), (m, 1))[:, 0]
    x = jnp.where(rows >= g, x, 0.0)                  # masked column (F4)
    xk = jnp.take(x, g)
    norm = jnp.sqrt(jnp.sum(x * x))
    alpha = jnp.where(xk >= 0, -norm, norm)
    v = x - alpha * (rows == g).astype(pan.dtype)
    vnorm2 = jnp.maximum(jnp.sum(v * v), tiny)
    tau = jnp.where(norm < tiny, 0.0, 2.0 / vnorm2)   # degenerate: skip
    pan = pan - v[:, None] * (tau * (v @ pan))[None, :]
    v_acc = jax.lax.dynamic_update_slice(v_acc, v[:, None], (0, j))
    tau_acc = jax.lax.dynamic_update_slice(tau_acc, tau[None], (j,))
    return pan, v_acc, tau_acc


def _wy_t_step(j, t, *, vt_v, taus, cols_bs):
    """Column ``j`` of the compact-WY ``T`` (LAPACK larft, forward
    columnwise): T[:j, j] = -tau_j * T[:j, :j] @ (V^T v_j); T[j,j] =
    tau_j.  Columns >= j of the carried ``t`` are still zero, so the
    full-width dot only consumes finished columns."""
    z = jax.lax.dynamic_slice(vt_v, (0, j), (vt_v.shape[0], 1))[:, 0]
    z = jnp.where(cols_bs < j, z, 0.0)
    tau_j = jnp.take(taus, j)
    tcol = -tau_j * jnp.dot(t, z, preferred_element_type=jnp.float32)
    tcol = jnp.where(cols_bs < j, tcol, 0.0)
    tcol = tcol + tau_j * (cols_bs == j).astype(t.dtype)
    return jax.lax.dynamic_update_slice(t, tcol[:, None], (0, j))


def _qr_solve_blocked_kernel(a_ref, b_ref, x_ref, *, m: int, n: int,
                             bs: int, tiny: float):
    r = a_ref[0]                                      # (m, n)
    y = b_ref[0]                                      # (m, k)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)
    cols_n = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    cols_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    def panel_step(p, carry):
        r, y = carry
        o = p * bs
        # ---- panel factor: bs reflectors applied panel-locally ----
        pan = jax.lax.dynamic_slice(r, (0, o), (m, bs))
        pan, v, taus = jax.lax.fori_loop(
            0, bs,
            functools.partial(_qr_panel_reflect_step, o=o, m=m, rows=rows,
                              tiny=tiny),
            (pan, jnp.zeros((m, bs), r.dtype), jnp.zeros((bs,), r.dtype)))
        r = jax.lax.dynamic_update_slice(r, pan, (0, o))
        # ---- T build: one V^T V gram + bs short column steps ----
        vt_v = jnp.dot(v.T, v, preferred_element_type=jnp.float32)
        t = jax.lax.fori_loop(
            0, bs,
            functools.partial(_wy_t_step, vt_v=vt_v, taus=taus,
                              cols_bs=cols_bs),
            jnp.zeros((bs, bs), r.dtype))
        # ---- block apply Q_p^T = I - V T^T V^T (critical MXU regions):
        # the whole panel's reflectors hit the trailing columns and the
        # rhs as three GEMMs instead of bs rank-1 updates ----
        wr = jnp.dot(v.T, r, preferred_element_type=jnp.float32)
        upd = jnp.dot(v, jnp.dot(t.T, wr,
                                 preferred_element_type=jnp.float32),
                      preferred_element_type=jnp.float32)
        r = r - jnp.where(cols_n[None, :] >= o + bs, upd, 0.0)
        wy = jnp.dot(v.T, y, preferred_element_type=jnp.float32)
        y = y - jnp.dot(v, jnp.dot(t.T, wy,
                                   preferred_element_type=jnp.float32),
                        preferred_element_type=jnp.float32)
        return r, y

    r, y = jax.lax.fori_loop(0, n // bs, panel_step, (r, y))
    x_ref[0] = back_substitute_r(r, y, n=n, tiny=tiny)


def qr_solve_blocked(a: jax.Array, b: jax.Array, *, bs: int | None = None,
                     tiny: float = DEFAULT_TINY,
                     interpret: bool | None = None) -> jax.Array:
    """Blocked (compact-WY) fused least squares — the large-n fast path.

    Same contract as :func:`qr_solve_pallas` but the Householder
    reflectors are accumulated per ``bs``-column panel into (V, T) and
    applied to the trailing columns and right-hand sides as rank-``bs``
    GEMMs (Q is still never formed).  Registered as the ``blocked``
    variant of the ``qr_solve`` spec; the dispatcher picks it for
    N >= 128.
    """
    bsz, m, n = a.shape
    b2, m2, k = b.shape
    assert m == m2 and bsz == b2 and m >= n, (a.shape, b.shape)
    if bs is None:
        bs = 64 if n % 64 == 0 else 32
    assert n % bs == 0 and n >= bs, (n, bs)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_qr_solve_blocked_kernel, m=m, n=n, bs=bs,
                          tiny=tiny),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), b.dtype),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# True sub-matrix tiling: HBM-resident trailing matrix, O(m*bs) VMEM
# ---------------------------------------------------------------------------
#
# Same data-tiling scheme as ``cholesky_solve_tiled`` (see the long
# comment there): grid = (lanes, steps + 1, tiles) with
# steps = tiles = n // bs, the (m, n) matrix HBM-resident in a
# ``pltpu.ANY`` work buffer, one (m, bs) column slab DMA'd per cell.
# The panel cell factors bs Householder reflectors panel-locally,
# accumulates compact-WY (V, T) in VMEM scratch, and applies the block
# reflector to the right-hand sides; trailing cells stream their slab
# through the rank-bs block apply; the final phase back-substitutes R
# right-looking over reverse-streamed slabs (each cell solves its
# (bs, bs) diagonal block against the GLOBAL deficiency threshold
# accumulated in SMEM during the panel sweep, then pushes the update to
# the rows above).

def qr_tiled_vmem_floats(m: int, n: int, bs: int, k: int) -> int:
    """Per-grid-cell VMEM working set of the tiled least squares, in
    float32 elements — slab (m, bs) + panel carry (2, m, bs) + V (m, bs)
    + T (bs, bs) + rhs carry (m, k) + b block (m, k) + x block (n, k)."""
    return 4 * m * bs + bs * bs + 2 * m * k + n * k


def _qr_solve_tiled_kernel(a_hbm, b_ref, x_ref, r_hbm, slab_scr, pan_scr,
                           v_scr, t_scr, y_scr, dmax_scr, sem, *, m: int,
                           n: int, k: int, bs: int, steps: int,
                           tiny: float):
    i = pl.program_id(0)
    s = pl.program_id(1)                  # panel step; == steps: back-sub
    t = pl.program_id(2)                  # column tile
    rows = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)
    cols_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    @pl.when((s == 0) & (t == 0))
    def _init():
        y_scr[...] = b_ref[0].astype(jnp.float32)
        dmax_scr[0] = 0.0
        cp = pltpu.make_async_copy(a_hbm.at[i, :, pl.ds(0, bs)],
                                   slab_scr, sem)
        cp.start()
        cp.wait()
        pan_scr[0] = slab_scr[...]

    @pl.when((s < steps) & (t == s))
    def _panel():
        o = s * bs
        pan = _pan_read(pan_scr, s % 2)
        pan, v, taus = jax.lax.fori_loop(
            0, bs,
            functools.partial(_qr_panel_reflect_step, o=o, m=m, rows=rows,
                              tiny=tiny),
            (pan, jnp.zeros((m, bs), jnp.float32),
             jnp.zeros((bs,), jnp.float32)))
        vt_v = jnp.dot(v.T, v, preferred_element_type=jnp.float32)
        tt = jax.lax.fori_loop(
            0, bs,
            functools.partial(_wy_t_step, vt_v=vt_v, taus=taus,
                              cols_bs=cols_bs),
            jnp.zeros((bs, bs), jnp.float32))
        # block-apply Q_p^T to the right-hand sides
        y = y_scr[...]
        wy = jnp.dot(v.T, y, preferred_element_type=jnp.float32)
        y_scr[...] = y - jnp.dot(
            v, jnp.dot(tt.T, wy, preferred_element_type=jnp.float32),
            preferred_element_type=jnp.float32)
        v_scr[...] = v
        t_scr[...] = tt
        # global |diag R| max for the back-substitution threshold
        blk = jax.lax.dynamic_slice(pan, (o, 0), (bs, bs))
        d = jnp.max(jnp.abs(jnp.where(
            cols_bs[:, None] == cols_bs[None, :], blk, 0.0)))
        dmax_scr[0] = jnp.maximum(dmax_scr[0], d)
        slab_scr[...] = pan
        cp = pltpu.make_async_copy(slab_scr,
                                   r_hbm.at[i, :, pl.ds(o, bs)], sem)
        cp.start()
        cp.wait()

    @pl.when((s < steps) & (t > s))
    def _trailing():
        @pl.when(s == 0)
        def _from_a():
            cp = pltpu.make_async_copy(a_hbm.at[i, :, pl.ds(t * bs, bs)],
                                       slab_scr, sem)
            cp.start()
            cp.wait()

        @pl.when(s > 0)
        def _from_r():
            cp = pltpu.make_async_copy(r_hbm.at[i, :, pl.ds(t * bs, bs)],
                                       slab_scr, sem)
            cp.start()
            cp.wait()

        v = v_scr[...]
        tt = t_scr[...]
        slab = slab_scr[...]
        w = jnp.dot(v.T, slab, preferred_element_type=jnp.float32)
        slab = slab - jnp.dot(
            v, jnp.dot(tt.T, w, preferred_element_type=jnp.float32),
            preferred_element_type=jnp.float32)
        slab_scr[...] = slab
        cp = pltpu.make_async_copy(slab_scr,
                                   r_hbm.at[i, :, pl.ds(t * bs, bs)], sem)
        cp.start()
        cp.wait()

        @pl.when(t == s + 1)              # double-buffered panel carry
        def _stash():
            _pan_write(pan_scr, (s + 1) % 2, slab)

    @pl.when(s == steps)
    def _backsub():
        rt = steps - 1 - t                # reverse slab order
        o = rt * bs
        cp = pltpu.make_async_copy(r_hbm.at[i, :, pl.ds(o, bs)],
                                   slab_scr, sem)
        cp.start()
        cp.wait()
        slab = slab_scr[...]
        z = y_scr[...]
        thresh = jnp.maximum(1e-6 * dmax_scr[0], tiny)
        rb = jax.lax.dynamic_slice(slab, (o, 0), (bs, bs))
        zt = jax.lax.dynamic_slice(z, (o, 0), (bs, k))
        xt = back_substitute_r(rb, zt, n=bs, tiny=tiny, thresh=thresh)
        z = jax.lax.dynamic_update_slice(z, xt, (o, 0))
        above = jnp.where(rows[:, None] < o, slab, 0.0)
        z = z - jnp.dot(above, xt, preferred_element_type=jnp.float32)
        y_scr[...] = z

        @pl.when(t == steps - 1)
        def _finish():
            x_ref[0] = z[:n].astype(x_ref.dtype)


def qr_solve_tiled(a: jax.Array, b: jax.Array, *, bs: int | None = None,
                   tiny: float = DEFAULT_TINY,
                   interpret: bool | None = None) -> jax.Array:
    """True sub-matrix tiled fused least squares — the HBM-scale path.

    Same contract as :func:`qr_solve_pallas` (a: (B,M,N), M >= N,
    b: (B,M,K) -> x: (B,N,K)) but the matrix stays HBM-resident: per
    grid cell one (M, bs) column slab plus the compact-WY (V, T) of the
    current panel live in VMEM — ``qr_tiled_vmem_floats`` = O(M*bs).
    Registered as the ``tiled`` variant of the ``qr_solve`` spec; the
    dispatcher picks it for N >= 512.
    """
    bsz, m, n = a.shape
    b2, m2, k = b.shape
    assert m == m2 and bsz == b2 and m >= n, (a.shape, b.shape)
    if bs is None:
        bs = tiled_block_size(n)
    assert n % bs == 0 and n >= 2 * bs, (n, bs)
    assert qr_tiled_vmem_floats(m, n, bs, k) * 4 <= \
        TILED_VMEM_BUDGET_BYTES, (m, n, bs, k)
    if interpret is None:
        interpret = interpret_default()
    steps = n // bs
    x, _ = pl.pallas_call(
        functools.partial(_qr_solve_tiled_kernel, m=m, n=n, k=k, bs=bs,
                          steps=steps, tiny=tiny),
        grid=(bsz, steps + 1, steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, m, k), lambda i, s, t: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n, k), lambda i, s, t: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n, k), b.dtype),
            jax.ShapeDtypeStruct((bsz, m, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((m, bs), jnp.float32),
            pltpu.VMEM((2, m, bs), jnp.float32),
            pltpu.VMEM((m, bs), jnp.float32),
            pltpu.VMEM((bs, bs), jnp.float32),
            pltpu.VMEM((m, k), jnp.float32),
            pltpu.SMEM((1,), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return x


def qr_solve_unfused(a: jax.Array, b: jax.Array, *,
                     interpret: bool | None = None) -> jax.Array:
    """No-fusion baseline: explicit Q via qr_pallas, a GEMM for Q^T b, and
    a separate triangular-solve pallas_call (three HBM round-trips)."""
    q, r = qr_pallas(a, interpret=interpret)
    n = a.shape[-1]
    qtb = jnp.einsum("bmk,bmj->bkj", q, b)[:, :n, :]
    return trisolve_pallas(r[:, :n, :n], qtb, lower=False,
                           interpret=interpret)


def _qr_solve_xla(a: jax.Array, b: jax.Array) -> jax.Array:
    q, r = jnp.linalg.qr(a)                          # reduced: (B,M,N)
    qtb = jnp.einsum("bmn,bmk->bnk", q, b)
    return jax.vmap(partial(jax.scipy.linalg.solve_triangular,
                            lower=False))(r, qtb)


@partial(jax.jit, static_argnames=("backend",))
def qr_solve(a: jax.Array, b: jax.Array, *,
             backend: str | None = None) -> jax.Array:
    """Public wrapper with backend dispatch (pallas on TPU, xla off)."""
    if resolve_backend(backend) == "pallas":
        return qr_solve_pallas(a, b)
    return _qr_solve_xla(a, b)
