"""PUSCH receiver stage kernels: the DAG-served pipeline's new stages.

The end-to-end 5G PUSCH uplink receive chain (arXiv:2210.09196) is a
producer/consumer pipeline — OFDM demod (FFT) feeds pilot-based channel
estimation feeds MMSE equalization — whose stages the serving stack
schedules as a DAG (``repro.kernels.DagSpec`` / ``SolverMux.submit_dag``).
This module holds the stage entry points that did not already exist as
registered pipelines:

``channel_estimate_pallas``
    Regularized least-squares channel estimation from pilots: given the
    known pilot block Xp (N, P) and its received observation Yp (M, P),
    solve (Xp Xp^T + ridge I) Z = Xp Yp^T and return H = Z^T (M, N) —
    a Gram + fused Cholesky chain per lane, the same VMEM-resident
    factor/substitution fusion as ``pipelines.mmse``.

``pusch_chain_pallas``
    The lane-resident fusion of channel-estimate -> MMSE equalize: one
    ``pallas_call`` whose grid cell estimates H from pilots and
    immediately consumes it for the data-symbol equalization — the
    estimated channel is handed from producer to consumer through
    VMEM/registers, never through HBM (the PR 1 fusion pattern applied
    ACROSS DAG stages).  Serving this entry instead of the two separate
    stages is the "stage-chained" mode the ``serve_slo/dag/*`` benchmark
    rows compare against stage-independent launches.

``pusch_fft_pallas``
    Stage adapter over the registered FFT kernel: per lane, A antenna
    rows of NF time samples -> a single stacked (2, A, NF) re/im
    frequency buffer (the serving stack moves ONE array per stage
    output, so the tuple-returning FFT is packed into planes).

``svd_factor_pallas`` / ``svd_apply_pallas``
    The non-wireless generality DAG: one-sided-Jacobi SVD packed into a
    single (M+N+1, N) factor buffer [U; V; s], then a ridge-regularized
    pseudo-inverse apply x = V diag(s / (s^2 + lam)) U^T b — two GEMMs
    and a scale, fused in one grid cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default
from repro.kernels.fft import fft_pallas
from repro.kernels.svd import svd_pallas
from repro.pipelines.cholesky_solve import (DEFAULT_EPS,
                                            back_substitution_step,
                                            factor_forward_step,
                                            pivot_threshold)

DEFAULT_RIDGE = 1e-3
DEFAULT_LAM = 1e-3


def _chol_solve_inline(g, rhs, *, n: int, eps: float):
    """Fused factor + both substitutions on an SPD (n, n) system already
    resident in VMEM — the shared tail of every stage kernel here."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    thresh = pivot_threshold(g, rows, eps=eps)
    g, rhs = jax.lax.fori_loop(
        0, n,
        lambda k, c: factor_forward_step(k, c[0], c[1], rows, thresh),
        (g, rhs))
    return jax.lax.fori_loop(
        0, n,
        lambda i, y_: back_substitution_step(i, g, y_, rows, n=n), rhs)


def _estimate_h(xp, yp, *, n: int, ridge: float, eps: float):
    """Regularized LS estimate H (m, n) from xp (n, p), yp (m, p)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    g = jnp.dot(xp, xp.T, preferred_element_type=jnp.float32)
    g = g + ridge * (rows[:, None] == rows[None, :]).astype(jnp.float32)
    rhs = jnp.dot(xp, yp.T, preferred_element_type=jnp.float32)
    z = _chol_solve_inline(g, rhs, n=n, eps=eps)        # (n, m)
    return z.T                                          # (m, n)


def _chanest_kernel(xp_ref, yp_ref, h_ref, *, n: int, ridge: float,
                    eps: float):
    xp = xp_ref[0].astype(jnp.float32)
    yp = yp_ref[0].astype(jnp.float32)
    h = _estimate_h(xp, yp, n=n, ridge=ridge, eps=eps)
    h_ref[0] = h.astype(h_ref.dtype)


def channel_estimate_pallas(xp: jax.Array, yp: jax.Array, *,
                            ridge: float = DEFAULT_RIDGE,
                            eps: float = DEFAULT_EPS,
                            interpret: bool | None = None) -> jax.Array:
    """LS channel estimate.  xp: (B,N,P) known pilots, yp: (B,M,P)
    received pilots -> H (B,M,N)."""
    bsz, n, p = xp.shape
    b2, m, p2 = yp.shape
    assert bsz == b2 and p == p2, (xp.shape, yp.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_chanest_kernel, n=n, ridge=ridge, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, p), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, p), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), yp.dtype),
        interpret=interpret,
    )(xp, yp)


def _pusch_chain_kernel(xp_ref, yp_ref, y_ref, x_ref, *, n: int,
                        ridge: float, sigma2: float, eps: float):
    xp = xp_ref[0].astype(jnp.float32)
    yp = yp_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    # stage 1: channel estimate — H never leaves VMEM
    h = _estimate_h(xp, yp, n=n, ridge=ridge, eps=eps)
    # stage 2: MMSE equalize consuming the just-produced H
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    g = jnp.dot(h.T, h, preferred_element_type=jnp.float32)
    g = g + sigma2 * (rows[:, None] == rows[None, :]).astype(jnp.float32)
    rhs = jnp.dot(h.T, y, preferred_element_type=jnp.float32)
    x = _chol_solve_inline(g, rhs, n=n, eps=eps)
    x_ref[0] = x.astype(x_ref.dtype)


def pusch_chain_pallas(xp: jax.Array, yp: jax.Array, y: jax.Array, *,
                       ridge: float = DEFAULT_RIDGE, sigma2: float = 0.1,
                       eps: float = DEFAULT_EPS,
                       interpret: bool | None = None) -> jax.Array:
    """Fused channel-estimate -> equalize.  xp: (B,N,P), yp: (B,M,P),
    y: (B,M,K) -> x (B,N,K), one pallas_call."""
    bsz, n, p = xp.shape
    _, m, _ = yp.shape
    b3, m2, k = y.shape
    assert bsz == b3 and m == m2, (xp.shape, yp.shape, y.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_pusch_chain_kernel, n=n, ridge=ridge,
                          sigma2=sigma2, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, p), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, p), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), y.dtype),
        interpret=interpret,
    )(xp, yp, y)


def pusch_fft_pallas(xr: jax.Array, xi: jax.Array, *,
                     interpret: bool | None = None) -> jax.Array:
    """OFDM demod stage adapter: (B, A, NF) time-domain re/im planes per
    antenna -> (B, 2, A, NF) stacked frequency planes.  The antenna axis
    is folded into the FFT kernel's batch (each row is one independent
    NF-point transform)."""
    bsz, a, nf = xr.shape
    fr, fi = fft_pallas(xr.reshape(bsz * a, nf), xi.reshape(bsz * a, nf),
                        interpret=interpret)
    return jnp.stack([fr.reshape(bsz, a, nf), fi.reshape(bsz, a, nf)],
                     axis=1)


def svd_factor_pallas(a: jax.Array, *, sweeps: int = 14,
                      interpret: bool | None = None) -> jax.Array:
    """SVD stage adapter: (B, M, N) -> packed factor buffer
    (B, M+N+1, N) = rows [U; V; s] (single-array stage output)."""
    u, s, v = svd_pallas(a, sweeps=sweeps, interpret=interpret)
    return jnp.concatenate([u, v, s[:, None, :]], axis=1)


def _svd_apply_kernel(f_ref, b_ref, x_ref, *, m: int, n: int,
                      lam: float):
    f = f_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    u = f[:m]                                           # (m, n)
    v = f[m:m + n]                                      # (n, n)
    s = f[m + n]                                        # (n,)
    w = jnp.dot(u.T, b, preferred_element_type=jnp.float32)   # (n, k)
    w = (s / (s * s + lam))[:, None] * w
    x = jnp.dot(v, w, preferred_element_type=jnp.float32)
    x_ref[0] = x.astype(x_ref.dtype)


def svd_apply_pallas(f: jax.Array, b: jax.Array, *,
                     lam: float = DEFAULT_LAM,
                     interpret: bool | None = None) -> jax.Array:
    """Ridge-regularized pseudo-inverse apply from packed SVD factors:
    x = V diag(s / (s^2 + lam)) U^T b.  f: (B, M+N+1, N), b: (B, M, K)
    -> (B, N, K).  Equals (A^T A + lam I)^{-1} A^T b, so the answer is
    invariant to the SVD's sign/order ambiguity."""
    bsz, mn1, n = f.shape
    b2, m, k = b.shape
    assert bsz == b2 and mn1 == m + n + 1, (f.shape, b.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_svd_apply_kernel, m=m, n=n, lam=lam),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, mn1, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), b.dtype),
        interpret=interpret,
    )(f, b)
