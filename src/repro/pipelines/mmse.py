"""Fused MMSE equalizer: Gram GEMM + regularize + Cholesky-solve + combine
in ONE Pallas grid cell — the paper's 5G wireless motivation end to end.

Per subcarrier (= one grid cell = one REVEL lane) with channel H (m x n)
and received symbols y (m x k):

    G   = H^T H + sigma2 * I      (critical MXU region — GEMM)
    rhs = H^T y                   (second GEMM, same residency)
    x   = G^{-1} rhs              (fused factor + fwd + bwd substitution)

which is the real-valued LMMSE estimate x = (H^H H + s I)^{-1} H^H y.
Nothing leaves VMEM between the four stages; the composed chain is what
REVEL's ordered fine-grain regions buy over kernel-at-a-time dispatch
(compare mmse_equalize_composed, the unfused baseline).

Complex channels are handled by the standard real expansion
[[Re, -Im], [Im, Re]] (see ``expand_complex_channel``), matching
examples/dsp_pipeline.py.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, resolve_backend
from repro.pipelines.cholesky_solve import (DEFAULT_EPS,
                                            back_substitution_step,
                                            cholesky_solve_unfused,
                                            factor_forward_step,
                                            pivot_threshold)


def _mmse_kernel(h_ref, y_ref, x_ref, *, m: int, n: int, sigma2: float,
                 eps: float):
    h = h_ref[0]                                       # (m, n)
    y = y_ref[0]                                       # (m, k)
    # ---- Gram GEMM region: G = H^T H + sigma2 I (MXU) ----
    g = jnp.dot(h.T, h, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    g = g + sigma2 * (rows[:, None] == rows[None, :]).astype(g.dtype)
    # ---- matched filter GEMM: rhs = H^T y ----
    rhs = jnp.dot(h.T, y, preferred_element_type=jnp.float32)
    # ---- fused Cholesky solve on the VMEM-resident Gram matrix ----
    thresh = pivot_threshold(g, rows, eps=eps)
    g, rhs = jax.lax.fori_loop(
        0, n,
        lambda kk, c: factor_forward_step(kk, c[0], c[1], rows, thresh),
        (g, rhs))
    rhs = jax.lax.fori_loop(
        0, n,
        lambda i, z: back_substitution_step(i, g, z, rows, n=n), rhs)
    x_ref[0] = rhs.astype(y.dtype)


def mmse_equalize_pallas(h: jax.Array, y: jax.Array, *,
                         sigma2: float = 0.1, eps: float = DEFAULT_EPS,
                         interpret: bool | None = None) -> jax.Array:
    """h: (B,M,N) per-subcarrier channels, y: (B,M,K) observations
    -> x: (B,N,K) equalized symbols.  One pallas_call for the whole chain.
    """
    bsz, m, n = h.shape
    b2, m2, k = y.shape
    assert m == m2 and bsz == b2 and m >= n, (h.shape, y.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_mmse_kernel, m=m, n=n, sigma2=sigma2, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), y.dtype),
        interpret=interpret,
    )(h, y)


def mmse_equalize_composed(h: jax.Array, y: jax.Array, *,
                           sigma2: float = 0.1,
                           interpret: bool | None = None) -> jax.Array:
    """Kernel-at-a-time baseline: XLA GEMMs for G and H^T y, then the
    three-pallas_call factor/solve chain — every intermediate hits HBM."""
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return cholesky_solve_unfused(g, rhs, interpret=interpret)


def _mmse_xla(h: jax.Array, y: jax.Array, *, sigma2: float) -> jax.Array:
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return jnp.linalg.solve(g, rhs)


@partial(jax.jit, static_argnames=("sigma2", "backend"))
def mmse_equalize(h: jax.Array, y: jax.Array, *, sigma2: float = 0.1,
                  backend: str | None = None) -> jax.Array:
    """Public wrapper with backend dispatch (pallas on TPU, xla off)."""
    if resolve_backend(backend) == "pallas":
        return mmse_equalize_pallas(h, y, sigma2=sigma2)
    return _mmse_xla(h, y, sigma2=sigma2)


def expand_complex_channel(hr: jax.Array, hi: jax.Array,
                           yr: jax.Array, yi: jax.Array):
    """Real expansion of a complex MIMO system: H -> [[Hr,-Hi],[Hi,Hr]]
    (2m x 2n), y -> [yr; yi] (2m x k).  The equalized output x (2n x k)
    splits back as x[:n] + 1j x[n:]."""
    top = jnp.concatenate([hr, -hi], axis=-1)
    bot = jnp.concatenate([hi, hr], axis=-1)
    h = jnp.concatenate([top, bot], axis=-2)
    y = jnp.concatenate([yr, yi], axis=-2)
    return h, y
