"""Fused MMSE equalizer: Gram GEMM + regularize + Cholesky-solve + combine
in ONE Pallas grid cell — the paper's 5G wireless motivation end to end.

Per subcarrier (= one grid cell = one REVEL lane) with channel H (m x n)
and received symbols y (m x k):

    G   = H^T H + sigma2 * I      (critical MXU region — GEMM)
    rhs = H^T y                   (second GEMM, same residency)
    x   = G^{-1} rhs              (fused factor + fwd + bwd substitution)

which is the real-valued LMMSE estimate x = (H^H H + s I)^{-1} H^H y.
Nothing leaves VMEM between the four stages; the composed chain is what
REVEL's ordered fine-grain regions buy over kernel-at-a-time dispatch
(compare mmse_equalize_composed, the unfused baseline).

Complex channels are handled two ways:

  * the standard real expansion [[Re, -Im], [Im, Re]] (see
    ``expand_complex_channel``), matching examples/dsp_pipeline.py —
    simple, but the expanded (2m x 2n) Gram GEMM does 16 m n^2 model
    flops where the complex math needs 6;
  * the split re/im fast path ``mmse_equalize_split``: Gram and matched
    filter accumulated from the Re/Im planes directly
    (G = Hr^T Hr + Hi^T Hi + i (Hr^T Hi - (Hr^T Hi)^T), exploiting the
    Hermitian structure so the cross term is ONE GEMM), then the same
    fused Cholesky-solve chain on the real-embedded 2n x 2n system.
    Identical output layout [Re x; Im x], ~0.4x the GEMM flops — what a
    production 5G PUSCH chain ships.  Registered as the
    ``split_complex`` variant of the ``mmse_equalize`` spec; the
    registry dispatcher picks it whenever a job presents 4 (split)
    planes instead of one expanded matrix.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (interpret_default, resolve_backend,
                                  tpu_compiler_params)
from repro.pipelines.cholesky_solve import (DEFAULT_EPS,
                                            TILED_VMEM_BUDGET_BYTES,
                                            _tiled_backsub_cell,
                                            _tiled_factor_cell,
                                            back_substitution_step,
                                            cholesky_solve_unfused,
                                            factor_forward_step,
                                            pivot_threshold,
                                            tiled_block_size)


def _mmse_kernel(h_ref, y_ref, x_ref, *, m: int, n: int, sigma2: float,
                 eps: float):
    h = h_ref[0]                                       # (m, n)
    y = y_ref[0]                                       # (m, k)
    # ---- Gram GEMM region: G = H^T H + sigma2 I (MXU) ----
    g = jnp.dot(h.T, h, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    g = g + sigma2 * (rows[:, None] == rows[None, :]).astype(g.dtype)
    # ---- matched filter GEMM: rhs = H^T y ----
    rhs = jnp.dot(h.T, y, preferred_element_type=jnp.float32)
    # ---- fused Cholesky solve on the VMEM-resident Gram matrix ----
    thresh = pivot_threshold(g, rows, eps=eps)
    g, rhs = jax.lax.fori_loop(
        0, n,
        lambda kk, c: factor_forward_step(kk, c[0], c[1], rows, thresh),
        (g, rhs))
    rhs = jax.lax.fori_loop(
        0, n,
        lambda i, z: back_substitution_step(i, g, z, rows, n=n), rhs)
    x_ref[0] = rhs.astype(y.dtype)


def mmse_equalize_pallas(h: jax.Array, y: jax.Array, *,
                         sigma2: float = 0.1, eps: float = DEFAULT_EPS,
                         interpret: bool | None = None) -> jax.Array:
    """h: (B,M,N) per-subcarrier channels, y: (B,M,K) observations
    -> x: (B,N,K) equalized symbols.  One pallas_call for the whole chain.
    """
    bsz, m, n = h.shape
    b2, m2, k = y.shape
    assert m == m2 and bsz == b2 and m >= n, (h.shape, y.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_mmse_kernel, m=m, n=n, sigma2=sigma2, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), y.dtype),
        interpret=interpret,
    )(h, y)


def _mmse_split_kernel(hr_ref, hi_ref, yr_ref, yi_ref, x_ref, *, m: int,
                       n: int, sigma2: float, eps: float):
    hr = hr_ref[0]                                     # (m, n)
    hi = hi_ref[0]                                     # (m, n)
    yr = yr_ref[0]                                     # (m, k)
    yi = yi_ref[0]                                     # (m, k)
    f32 = jnp.float32
    # ---- split Gram region (MXU): Gr = Hr^T Hr + Hi^T Hi as ONE dot on
    # the stacked (2m, n) planes; Gi = C - C^T from the single cross GEMM
    # C = Hr^T Hi (antisymmetry replaces the second cross dot).  6 m n^2
    # model flops vs 16 m n^2 for the real-expansion Gram. ----
    hs = jnp.concatenate([hr, hi], axis=0)             # (2m, n)
    gr = jnp.dot(hs.T, hs, preferred_element_type=f32)
    c = jnp.dot(hr.T, hi, preferred_element_type=f32)
    gi = c - c.T
    # ---- split matched filter: rhs_r = Hr^T yr + Hi^T yi and
    # rhs_i = Hr^T yi - Hi^T yr, each one stacked dot ----
    ys = jnp.concatenate([yr, yi], axis=0)             # (2m, k)
    yt = jnp.concatenate([yi, -yr], axis=0)
    rr = jnp.dot(hs.T, ys, preferred_element_type=f32)
    ri = jnp.dot(hs.T, yt, preferred_element_type=f32)
    # ---- real embedding of the Hermitian system: the SAME 2n x 2n SPD
    # matrix the expansion path builds, assembled from n x n blocks ----
    rows_n = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    gr = gr + sigma2 * (rows_n[:, None] == rows_n[None, :]).astype(gr.dtype)
    g = jnp.concatenate(
        [jnp.concatenate([gr, -gi], axis=1),
         jnp.concatenate([gi, gr], axis=1)], axis=0)   # (2n, 2n)
    rhs = jnp.concatenate([rr, ri], axis=0)            # (2n, k)
    # ---- fused Cholesky solve, identical chain to the expansion path ----
    rows = jax.lax.broadcasted_iota(jnp.int32, (2 * n,), 0)
    thresh = pivot_threshold(g, rows, eps=eps)
    g, rhs = jax.lax.fori_loop(
        0, 2 * n,
        lambda kk, carry: factor_forward_step(kk, carry[0], carry[1], rows,
                                              thresh),
        (g, rhs))
    rhs = jax.lax.fori_loop(
        0, 2 * n,
        lambda i, z: back_substitution_step(i, g, z, rows, n=2 * n), rhs)
    x_ref[0] = rhs.astype(yr.dtype)


def mmse_equalize_split_pallas(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                               yi: jax.Array, *, sigma2: float = 0.1,
                               eps: float = DEFAULT_EPS,
                               interpret: bool | None = None) -> jax.Array:
    """Split re/im fused MMSE equalizer — the complex-native fast path.

    hr/hi: (B,M,N) channel planes, yr/yi: (B,M,K) observations ->
    x: (B,2N,K) stacked [Re x; Im x] (the real-expansion output layout,
    so both paths answer the same complex problem identically).  One
    pallas_call per lane; ~0.4x the Gram/matched-filter GEMM flops of
    ``mmse_equalize_pallas`` on the expanded system.
    """
    bsz, m, n = hr.shape
    assert hi.shape == hr.shape, (hr.shape, hi.shape)
    b2, m2, k = yr.shape
    assert yi.shape == yr.shape, (yr.shape, yi.shape)
    assert m == m2 and bsz == b2 and m >= n, (hr.shape, yr.shape)
    if interpret is None:
        interpret = interpret_default()
    mat = pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                       memory_space=pltpu.VMEM)
    obs = pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                       memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_mmse_split_kernel, m=m, n=n, sigma2=sigma2,
                          eps=eps),
        grid=(bsz,),
        in_specs=[mat, mat, obs, obs],
        out_specs=pl.BlockSpec((1, 2 * n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, 2 * n, k), yr.dtype),
        interpret=interpret,
    )(hr, hi, yr, yi)


def _mmse_split_xla(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                    yi: jax.Array, *, sigma2: float) -> jax.Array:
    """XLA face of the split path, mirroring the kernel's dot structure
    exactly (stacked Gram + single cross GEMM + two stacked matched
    filters) — the HLO dot-flops counter sees the same 6 m n^2 + 8 m n k
    model cost, which tests/benchmarks assert against the expansion."""
    n = hr.shape[-1]
    hs = jnp.concatenate([hr, hi], axis=1)             # (B, 2m, n)
    gr = jnp.einsum("bmi,bmj->bij", hs, hs) \
        + sigma2 * jnp.eye(n, dtype=hr.dtype)
    c = jnp.einsum("bmi,bmj->bij", hr, hi)
    gi = c - jnp.swapaxes(c, -1, -2)
    ys = jnp.concatenate([yr, yi], axis=1)             # (B, 2m, k)
    yt = jnp.concatenate([yi, -yr], axis=1)
    rr = jnp.einsum("bmn,bmk->bnk", hs, ys)
    ri = jnp.einsum("bmn,bmk->bnk", hs, yt)
    g = jnp.concatenate(
        [jnp.concatenate([gr, -gi], axis=2),
         jnp.concatenate([gi, gr], axis=2)], axis=1)
    rhs = jnp.concatenate([rr, ri], axis=1)
    return jnp.linalg.solve(g, rhs)


@partial(jax.jit, static_argnames=("sigma2", "backend"))
def mmse_equalize_split(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                        yi: jax.Array, *, sigma2: float = 0.1,
                        backend: str | None = None) -> jax.Array:
    """Public split-complex wrapper with backend dispatch."""
    if resolve_backend(backend) == "pallas":
        return mmse_equalize_split_pallas(hr, hi, yr, yi, sigma2=sigma2)
    return _mmse_split_xla(hr, hi, yr, yi, sigma2=sigma2)


# ---------------------------------------------------------------------------
# True sub-matrix tiling: HBM-resident Gram + factor, O(n*bs) VMEM
# ---------------------------------------------------------------------------
#
# ``mmse_equalize_tiled`` completes the large-shape 5G story: the Gram
# matrix G = H^T H + sigma^2 I is BUILT tile-by-tile into an HBM work
# buffer (never materialized in VMEM), then the tiled Cholesky
# factor/solve phases of ``cholesky_solve_tiled`` run over the same
# buffer.  Grid = (lanes, 2*steps + 1, tiles), steps = tiles = n // bs:
#
#   Gram phase   s in [0, steps), active for t <= s: cell (r=s, t) DMAs
#     the two (m, bs) channel column slabs H_r, H_t, computes the
#     (bs, bs) Gram block G(r, t) = H_r^T H_t (+ sigma^2 I and the
#     matched-filter rows H_r^T y on the diagonal), and DMAs it into the
#     HBM Gram buffer.  Only the lower triangle r >= t is built — the
#     factor/solve chain never reads above the diagonal (paper F4).
#   factor phase s in [steps, 2*steps): exactly the panel/trailing cells
#     of the tiled Cholesky, streaming (n, bs) slabs of the HBM Gram
#     buffer; the deficiency threshold comes from the max Gram diagonal
#     accumulated in SMEM during the Gram phase.
#   back-sub     s == 2*steps: the reverse-streamed L^T block solve.

def mmse_tiled_vmem_floats(m: int, n: int, bs: int, k: int) -> int:
    """Per-grid-cell VMEM working set of the tiled MMSE equalizer, in
    float32 elements — two (m, bs) channel slabs + Gram staging (bs, bs)
    + Cholesky slab (n, bs) + panel carry (2, n, bs) + rhs carry (n, k)
    + y block (m, k) + x block (n, k)."""
    return 2 * m * bs + bs * bs + 3 * n * bs + m * k + 2 * n * k


def _mmse_tiled_kernel(h_hbm, y_ref, x_ref, g_hbm, hr_scr, ht_scr, gb_scr,
                       slab_scr, pan_scr, z_scr, stat_scr, sem, *, m: int,
                       n: int, k: int, bs: int, steps: int, sigma2: float,
                       eps: float):
    i = pl.program_id(0)
    s = pl.program_id(1)          # [0,steps) gram; [steps,2*steps) factor
    t = pl.program_id(2)          # column tile
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    cols_bs = jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)

    @pl.when((s == 0) & (t == 0))
    def _init():
        stat_scr[0] = 0.0                 # running max Gram diagonal

    # ---- Gram phase: G(r=s, t) for the lower triangle t <= s ----
    @pl.when((s < steps) & (t <= s))
    def _gram():
        r = s
        # H_r is shared by every cell of row r — load once at t == 0
        # (the first active cell of each row); hr_scr persists across
        # the row's remaining cells.  The diagonal cell needs no second
        # slab at all (G(r, r) = H_r^T H_r).
        @pl.when(t == 0)
        def _load_row():
            cp = pltpu.make_async_copy(h_hbm.at[i, :, pl.ds(r * bs, bs)],
                                       hr_scr, sem)
            cp.start()
            cp.wait()

        @pl.when(r != t)
        def _load_col():
            cp = pltpu.make_async_copy(h_hbm.at[i, :, pl.ds(t * bs, bs)],
                                       ht_scr, sem)
            cp.start()
            cp.wait()

        ht = jnp.where(r == t, hr_scr[...], ht_scr[...])
        gb = jnp.dot(hr_scr[...].T, ht,
                     preferred_element_type=jnp.float32)

        @pl.when(r == t)
        def _diag():
            eye = (cols_bs[:, None] == cols_bs[None, :])
            gd = gb + sigma2 * eye.astype(jnp.float32)
            gb_scr[...] = gd
            stat_scr[0] = jnp.maximum(
                stat_scr[0], jnp.max(jnp.where(eye, gd, -jnp.inf)))
            # matched-filter rows: z[r-slab] = H_r^T y
            rhs_r = jnp.dot(hr_scr[...].T, y_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            z = jax.lax.dynamic_update_slice(z_scr[...], rhs_r,
                                             (r * bs, 0))
            z_scr[...] = z

        @pl.when(r != t)
        def _off():
            gb_scr[...] = gb

        cp = pltpu.make_async_copy(
            gb_scr, g_hbm.at[i, pl.ds(r * bs, bs), pl.ds(t * bs, bs)],
            sem)
        cp.start()
        cp.wait()

    # ---- factor phase: the shared tiled Cholesky cells on the Gram
    # buffer (first_hbm == work_hbm: the Gram phase already wrote it) ----
    s2 = s - steps                        # factor-phase panel step

    @pl.when((s >= steps) & (s < 2 * steps))
    def _factor():
        @pl.when((s2 == 0) & (t == 0))    # threshold from the Gram diag
        def _thresh():
            stat_scr[1] = jnp.maximum(eps * stat_scr[0], 1e-30)

        _tiled_factor_cell(i, s2, t, first_hbm=g_hbm, work_hbm=g_hbm,
                           slab_scr=slab_scr, pan_scr=pan_scr,
                           y_scr=z_scr, sem=sem, thresh=stat_scr[1],
                           n=n, m=k, bs=bs, rows=rows, cols_bs=cols_bs)

    # ---- back substitution: reverse-streamed L^T block solve ----
    @pl.when(s == 2 * steps)
    def _backsub():
        _tiled_backsub_cell(i, t, steps=steps, work_hbm=g_hbm,
                            slab_scr=slab_scr, y_scr=z_scr, x_ref=x_ref,
                            sem=sem, bs=bs, m=k, rows=rows)


def mmse_equalize_tiled(h: jax.Array, y: jax.Array, *,
                        bs: int | None = None, sigma2: float = 0.1,
                        eps: float = DEFAULT_EPS,
                        interpret: bool | None = None) -> jax.Array:
    """True sub-matrix tiled MMSE equalizer — the HBM-scale 5G path.

    Same contract as :func:`mmse_equalize_pallas` (h: (B,M,N) channels,
    y: (B,M,K) -> x: (B,N,K)) but the (N, N) Gram matrix is built
    tile-by-tile straight into an HBM work buffer and factored/solved by
    the tiled Cholesky phases over that buffer — per-cell VMEM is
    ``mmse_tiled_vmem_floats`` = O((M+N)*bs), so N = 1024/2048 channel
    counts (the n >> 512 PUSCH shapes) become servable.  Registered as
    the ``tiled`` variant of the ``mmse_equalize`` spec for N >= 512.
    """
    bsz, m, n = h.shape
    b2, m2, k = y.shape
    assert m == m2 and bsz == b2 and m >= n, (h.shape, y.shape)
    if bs is None:
        bs = tiled_block_size(n)
    assert n % bs == 0 and n >= 2 * bs, (n, bs)
    assert mmse_tiled_vmem_floats(m, n, bs, k) * 4 <= \
        TILED_VMEM_BUDGET_BYTES, (m, n, bs, k)
    if interpret is None:
        interpret = interpret_default()
    steps = n // bs
    x, _ = pl.pallas_call(
        functools.partial(_mmse_tiled_kernel, m=m, n=n, k=k, bs=bs,
                          steps=steps, sigma2=sigma2, eps=eps),
        grid=(bsz, 2 * steps + 1, steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, m, k), lambda i, s, t: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n, k), lambda i, s, t: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n, k), y.dtype),
            jax.ShapeDtypeStruct((bsz, n, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((m, bs), jnp.float32),
            pltpu.VMEM((m, bs), jnp.float32),
            pltpu.VMEM((bs, bs), jnp.float32),
            pltpu.VMEM((n, bs), jnp.float32),
            pltpu.VMEM((2, n, bs), jnp.float32),
            pltpu.VMEM((n, k), jnp.float32),
            pltpu.SMEM((2,), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(h, y)
    return x


# The ROADMAP's "Blocked MMSE Gram" item ships as the tiled kernel; keep
# the blocked-family name as an alias so both vocabularies resolve.
mmse_equalize_blocked = mmse_equalize_tiled


def mmse_equalize_composed(h: jax.Array, y: jax.Array, *,
                           sigma2: float = 0.1,
                           interpret: bool | None = None) -> jax.Array:
    """Kernel-at-a-time baseline: XLA GEMMs for G and H^T y, then the
    three-pallas_call factor/solve chain — every intermediate hits HBM."""
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return cholesky_solve_unfused(g, rhs, interpret=interpret)


def _mmse_xla(h: jax.Array, y: jax.Array, *, sigma2: float) -> jax.Array:
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return jnp.linalg.solve(g, rhs)


@partial(jax.jit, static_argnames=("sigma2", "backend"))
def mmse_equalize(h: jax.Array, y: jax.Array, *, sigma2: float = 0.1,
                  backend: str | None = None) -> jax.Array:
    """Public wrapper with backend dispatch (pallas on TPU, xla off)."""
    if resolve_backend(backend) == "pallas":
        return mmse_equalize_pallas(h, y, sigma2=sigma2)
    return _mmse_xla(h, y, sigma2=sigma2)


def expand_complex_channel(hr: jax.Array, hi: jax.Array,
                           yr: jax.Array, yi: jax.Array):
    """Real expansion of a complex MIMO system: H -> [[Hr,-Hi],[Hi,Hr]]
    (2m x 2n), y -> [yr; yi] (2m x k).  The equalized output x (2n x k)
    splits back as x[:n] + 1j x[n:]."""
    top = jnp.concatenate([hr, -hi], axis=-1)
    bot = jnp.concatenate([hi, hr], axis=-1)
    h = jnp.concatenate([top, bot], axis=-2)
    y = jnp.concatenate([yr, yi], axis=-2)
    return h, y
