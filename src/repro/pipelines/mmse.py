"""Fused MMSE equalizer: Gram GEMM + regularize + Cholesky-solve + combine
in ONE Pallas grid cell — the paper's 5G wireless motivation end to end.

Per subcarrier (= one grid cell = one REVEL lane) with channel H (m x n)
and received symbols y (m x k):

    G   = H^T H + sigma2 * I      (critical MXU region — GEMM)
    rhs = H^T y                   (second GEMM, same residency)
    x   = G^{-1} rhs              (fused factor + fwd + bwd substitution)

which is the real-valued LMMSE estimate x = (H^H H + s I)^{-1} H^H y.
Nothing leaves VMEM between the four stages; the composed chain is what
REVEL's ordered fine-grain regions buy over kernel-at-a-time dispatch
(compare mmse_equalize_composed, the unfused baseline).

Complex channels are handled two ways:

  * the standard real expansion [[Re, -Im], [Im, Re]] (see
    ``expand_complex_channel``), matching examples/dsp_pipeline.py —
    simple, but the expanded (2m x 2n) Gram GEMM does 16 m n^2 model
    flops where the complex math needs 6;
  * the split re/im fast path ``mmse_equalize_split``: Gram and matched
    filter accumulated from the Re/Im planes directly
    (G = Hr^T Hr + Hi^T Hi + i (Hr^T Hi - (Hr^T Hi)^T), exploiting the
    Hermitian structure so the cross term is ONE GEMM), then the same
    fused Cholesky-solve chain on the real-embedded 2n x 2n system.
    Identical output layout [Re x; Im x], ~0.4x the GEMM flops — what a
    production 5G PUSCH chain ships.  Registered as the
    ``split_complex`` variant of the ``mmse_equalize`` spec; the
    registry dispatcher picks it whenever a job presents 4 (split)
    planes instead of one expanded matrix.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, resolve_backend
from repro.pipelines.cholesky_solve import (DEFAULT_EPS,
                                            back_substitution_step,
                                            cholesky_solve_unfused,
                                            factor_forward_step,
                                            pivot_threshold)


def _mmse_kernel(h_ref, y_ref, x_ref, *, m: int, n: int, sigma2: float,
                 eps: float):
    h = h_ref[0]                                       # (m, n)
    y = y_ref[0]                                       # (m, k)
    # ---- Gram GEMM region: G = H^T H + sigma2 I (MXU) ----
    g = jnp.dot(h.T, h, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    g = g + sigma2 * (rows[:, None] == rows[None, :]).astype(g.dtype)
    # ---- matched filter GEMM: rhs = H^T y ----
    rhs = jnp.dot(h.T, y, preferred_element_type=jnp.float32)
    # ---- fused Cholesky solve on the VMEM-resident Gram matrix ----
    thresh = pivot_threshold(g, rows, eps=eps)
    g, rhs = jax.lax.fori_loop(
        0, n,
        lambda kk, c: factor_forward_step(kk, c[0], c[1], rows, thresh),
        (g, rhs))
    rhs = jax.lax.fori_loop(
        0, n,
        lambda i, z: back_substitution_step(i, g, z, rows, n=n), rhs)
    x_ref[0] = rhs.astype(y.dtype)


def mmse_equalize_pallas(h: jax.Array, y: jax.Array, *,
                         sigma2: float = 0.1, eps: float = DEFAULT_EPS,
                         interpret: bool | None = None) -> jax.Array:
    """h: (B,M,N) per-subcarrier channels, y: (B,M,K) observations
    -> x: (B,N,K) equalized symbols.  One pallas_call for the whole chain.
    """
    bsz, m, n = h.shape
    b2, m2, k = y.shape
    assert m == m2 and bsz == b2 and m >= n, (h.shape, y.shape)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_mmse_kernel, m=m, n=n, sigma2=sigma2, eps=eps),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, k), y.dtype),
        interpret=interpret,
    )(h, y)


def _mmse_split_kernel(hr_ref, hi_ref, yr_ref, yi_ref, x_ref, *, m: int,
                       n: int, sigma2: float, eps: float):
    hr = hr_ref[0]                                     # (m, n)
    hi = hi_ref[0]                                     # (m, n)
    yr = yr_ref[0]                                     # (m, k)
    yi = yi_ref[0]                                     # (m, k)
    f32 = jnp.float32
    # ---- split Gram region (MXU): Gr = Hr^T Hr + Hi^T Hi as ONE dot on
    # the stacked (2m, n) planes; Gi = C - C^T from the single cross GEMM
    # C = Hr^T Hi (antisymmetry replaces the second cross dot).  6 m n^2
    # model flops vs 16 m n^2 for the real-expansion Gram. ----
    hs = jnp.concatenate([hr, hi], axis=0)             # (2m, n)
    gr = jnp.dot(hs.T, hs, preferred_element_type=f32)
    c = jnp.dot(hr.T, hi, preferred_element_type=f32)
    gi = c - c.T
    # ---- split matched filter: rhs_r = Hr^T yr + Hi^T yi and
    # rhs_i = Hr^T yi - Hi^T yr, each one stacked dot ----
    ys = jnp.concatenate([yr, yi], axis=0)             # (2m, k)
    yt = jnp.concatenate([yi, -yr], axis=0)
    rr = jnp.dot(hs.T, ys, preferred_element_type=f32)
    ri = jnp.dot(hs.T, yt, preferred_element_type=f32)
    # ---- real embedding of the Hermitian system: the SAME 2n x 2n SPD
    # matrix the expansion path builds, assembled from n x n blocks ----
    rows_n = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    gr = gr + sigma2 * (rows_n[:, None] == rows_n[None, :]).astype(gr.dtype)
    g = jnp.concatenate(
        [jnp.concatenate([gr, -gi], axis=1),
         jnp.concatenate([gi, gr], axis=1)], axis=0)   # (2n, 2n)
    rhs = jnp.concatenate([rr, ri], axis=0)            # (2n, k)
    # ---- fused Cholesky solve, identical chain to the expansion path ----
    rows = jax.lax.broadcasted_iota(jnp.int32, (2 * n,), 0)
    thresh = pivot_threshold(g, rows, eps=eps)
    g, rhs = jax.lax.fori_loop(
        0, 2 * n,
        lambda kk, carry: factor_forward_step(kk, carry[0], carry[1], rows,
                                              thresh),
        (g, rhs))
    rhs = jax.lax.fori_loop(
        0, 2 * n,
        lambda i, z: back_substitution_step(i, g, z, rows, n=2 * n), rhs)
    x_ref[0] = rhs.astype(yr.dtype)


def mmse_equalize_split_pallas(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                               yi: jax.Array, *, sigma2: float = 0.1,
                               eps: float = DEFAULT_EPS,
                               interpret: bool | None = None) -> jax.Array:
    """Split re/im fused MMSE equalizer — the complex-native fast path.

    hr/hi: (B,M,N) channel planes, yr/yi: (B,M,K) observations ->
    x: (B,2N,K) stacked [Re x; Im x] (the real-expansion output layout,
    so both paths answer the same complex problem identically).  One
    pallas_call per lane; ~0.4x the Gram/matched-filter GEMM flops of
    ``mmse_equalize_pallas`` on the expanded system.
    """
    bsz, m, n = hr.shape
    assert hi.shape == hr.shape, (hr.shape, hi.shape)
    b2, m2, k = yr.shape
    assert yi.shape == yr.shape, (yr.shape, yi.shape)
    assert m == m2 and bsz == b2 and m >= n, (hr.shape, yr.shape)
    if interpret is None:
        interpret = interpret_default()
    mat = pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                       memory_space=pltpu.VMEM)
    obs = pl.BlockSpec((1, m, k), lambda i: (i, 0, 0),
                       memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_mmse_split_kernel, m=m, n=n, sigma2=sigma2,
                          eps=eps),
        grid=(bsz,),
        in_specs=[mat, mat, obs, obs],
        out_specs=pl.BlockSpec((1, 2 * n, k), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, 2 * n, k), yr.dtype),
        interpret=interpret,
    )(hr, hi, yr, yi)


def _mmse_split_xla(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                    yi: jax.Array, *, sigma2: float) -> jax.Array:
    """XLA face of the split path, mirroring the kernel's dot structure
    exactly (stacked Gram + single cross GEMM + two stacked matched
    filters) — the HLO dot-flops counter sees the same 6 m n^2 + 8 m n k
    model cost, which tests/benchmarks assert against the expansion."""
    n = hr.shape[-1]
    hs = jnp.concatenate([hr, hi], axis=1)             # (B, 2m, n)
    gr = jnp.einsum("bmi,bmj->bij", hs, hs) \
        + sigma2 * jnp.eye(n, dtype=hr.dtype)
    c = jnp.einsum("bmi,bmj->bij", hr, hi)
    gi = c - jnp.swapaxes(c, -1, -2)
    ys = jnp.concatenate([yr, yi], axis=1)             # (B, 2m, k)
    yt = jnp.concatenate([yi, -yr], axis=1)
    rr = jnp.einsum("bmn,bmk->bnk", hs, ys)
    ri = jnp.einsum("bmn,bmk->bnk", hs, yt)
    g = jnp.concatenate(
        [jnp.concatenate([gr, -gi], axis=2),
         jnp.concatenate([gi, gr], axis=2)], axis=1)
    rhs = jnp.concatenate([rr, ri], axis=1)
    return jnp.linalg.solve(g, rhs)


@partial(jax.jit, static_argnames=("sigma2", "backend"))
def mmse_equalize_split(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                        yi: jax.Array, *, sigma2: float = 0.1,
                        backend: str | None = None) -> jax.Array:
    """Public split-complex wrapper with backend dispatch."""
    if resolve_backend(backend) == "pallas":
        return mmse_equalize_split_pallas(hr, hi, yr, yi, sigma2=sigma2)
    return _mmse_split_xla(hr, hi, yr, yi, sigma2=sigma2)


def mmse_equalize_composed(h: jax.Array, y: jax.Array, *,
                           sigma2: float = 0.1,
                           interpret: bool | None = None) -> jax.Array:
    """Kernel-at-a-time baseline: XLA GEMMs for G and H^T y, then the
    three-pallas_call factor/solve chain — every intermediate hits HBM."""
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return cholesky_solve_unfused(g, rhs, interpret=interpret)


def _mmse_xla(h: jax.Array, y: jax.Array, *, sigma2: float) -> jax.Array:
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return jnp.linalg.solve(g, rhs)


@partial(jax.jit, static_argnames=("sigma2", "backend"))
def mmse_equalize(h: jax.Array, y: jax.Array, *, sigma2: float = 0.1,
                  backend: str | None = None) -> jax.Array:
    """Public wrapper with backend dispatch (pallas on TPU, xla off)."""
    if resolve_backend(backend) == "pallas":
        return mmse_equalize_pallas(h, y, sigma2=sigma2)
    return _mmse_xla(h, y, sigma2=sigma2)


def expand_complex_channel(hr: jax.Array, hi: jax.Array,
                           yr: jax.Array, yi: jax.Array):
    """Real expansion of a complex MIMO system: H -> [[Hr,-Hi],[Hi,Hr]]
    (2m x 2n), y -> [yr; yi] (2m x k).  The equalized output x (2n x k)
    splits back as x[:n] + 1j x[n:]."""
    top = jnp.concatenate([hr, -hi], axis=-1)
    bot = jnp.concatenate([hi, hr], axis=-1)
    h = jnp.concatenate([top, bot], axis=-2)
    y = jnp.concatenate([yr, yi], axis=-2)
    return h, y
