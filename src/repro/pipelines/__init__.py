"""Fused solver pipelines — composed FGOP workloads as single kernels.

The paper's REVEL results (Figs. 13-19) are per-kernel, but its wireless
motivation (§1, Fig. 4) is a *chain*: in a 5G MMSE receiver every
subcarrier runs channel-Gram GEMM -> Cholesky -> forward solve -> back
solve -> combine, thousands of times per slot.  Fine-grain ordered
parallelism is exactly what lets those stages overlap without spilling
the (12..32-antenna sized) matrices to memory between them.  This package
provides those chains as first-class single-``pallas_call`` kernels, one
lane (grid cell) per subcarrier/problem:

  cholesky_solve  — factor + both substitutions fused (the chain of paper
                    Fig. 5 [Cholesky regions] and Fig. 9 [Solver's
                    inductive a/b edge]); forward substitution interleaved
                    into the factor loop at column granularity.
  qr_solve        — Householder least squares (paper Fig. 6 left) with
                    Q^T b applied reflector-by-reflector (never forming
                    Q) + fused back substitution — the `tau` ordered edge
                    consumed by two critical regions per iteration.
  mmse_equalize   — the full 5G use case: H^T H + sigma^2 I (GEMM,
                    Fig. 7), fused Cholesky solve, matched-filter GEMM;
                    x = (H^H H + s I)^{-1} H^H y per subcarrier.

Each pipeline ships three faces (mirroring repro.kernels): the fused
Pallas kernel (``*_pallas``), an unfused multi-``pallas_call`` baseline
(``*_unfused`` / ``*_composed``) whose HBM round-trips quantify the
fusion win in benchmarks/bench_pipelines.py, and a jit'd dispatching
wrapper.  All are registered in the kernel registry
(``repro.kernels.get/names/specs``) next to the primitive kernels, so
tests, benchmarks, and the serve engine enumerate them uniformly.

Each pipeline additionally registers performance *variants* the registry
dispatcher (``KernelSpec.dispatch``) selects by shape/arity: blocked
(schedule-tiled, whole matrix VMEM-resident) ``cholesky_solve_blocked``
/ ``qr_solve_blocked`` for the 128 <= n < 512 midrange, true
sub-matrix-tiled ``cholesky_solve_tiled`` / ``qr_solve_tiled`` /
``mmse_equalize_tiled`` (HBM-resident matrix, O(n*bs) VMEM slabs, DMA'd
per grid cell) for n >= 512, and the split re/im ``mmse_equalize_split``
fast path for jobs arriving as 4 complex planes.
"""
from repro.pipelines.cholesky_solve import (cholesky_solve,  # noqa: F401
                                            cholesky_solve_blocked,
                                            cholesky_solve_pallas,
                                            cholesky_solve_tiled,
                                            cholesky_solve_unfused,
                                            tiled_vmem_floats)
from repro.pipelines.mmse import (expand_complex_channel,  # noqa: F401
                                  mmse_equalize, mmse_equalize_blocked,
                                  mmse_equalize_composed,
                                  mmse_equalize_pallas,
                                  mmse_equalize_split,
                                  mmse_equalize_split_pallas,
                                  mmse_equalize_tiled,
                                  mmse_tiled_vmem_floats)
from repro.pipelines.pusch import (channel_estimate_pallas,  # noqa: F401
                                   pusch_chain_pallas, pusch_fft_pallas,
                                   svd_apply_pallas, svd_factor_pallas)
from repro.pipelines.qr_solve import (qr_solve,  # noqa: F401
                                      qr_solve_blocked, qr_solve_pallas,
                                      qr_solve_tiled, qr_solve_unfused,
                                      qr_tiled_vmem_floats)

__all__ = [
    "cholesky_solve", "cholesky_solve_pallas", "cholesky_solve_unfused",
    "cholesky_solve_blocked", "cholesky_solve_tiled",
    "qr_solve", "qr_solve_pallas", "qr_solve_unfused", "qr_solve_blocked",
    "qr_solve_tiled",
    "mmse_equalize", "mmse_equalize_pallas", "mmse_equalize_composed",
    "mmse_equalize_split", "mmse_equalize_split_pallas",
    "mmse_equalize_tiled", "mmse_equalize_blocked",
    "expand_complex_channel",
    "channel_estimate_pallas", "pusch_chain_pallas", "pusch_fft_pallas",
    "svd_apply_pallas", "svd_factor_pallas",
    "tiled_vmem_floats", "qr_tiled_vmem_floats", "mmse_tiled_vmem_floats",
]
