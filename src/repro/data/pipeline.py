"""Token data pipeline: synthetic + memmap'd binary corpora.

Deterministic and resumable: batch(step) is a pure function of
(seed, step), so a restore-from-checkpoint replays the exact stream with
no pipeline state to save (the fault-tolerance contract in train/fault.py
relies on this).  Per-host sharding: each host materializes only its
slice of the global batch (process_index-strided), as on a real multi-host
pod.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: str | None = None   # memmap of uint16/uint32 tokens
    n_prefix: int = 0                # vision prefix embeddings
    d_model: int = 0
    src_len: int = 0                 # audio encoder frames


class TokenPipeline:
    """batch(step) -> dict of numpy arrays for this host's batch shard."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        self.cfg = cfg
        self.pi = process_index
        self.pc = process_count
        assert cfg.global_batch % process_count == 0
        self.local_batch = cfg.global_batch // process_count
        self._corpus = None
        if cfg.corpus_path:
            self._corpus = np.memmap(cfg.corpus_path, dtype=np.uint16,
                                     mode="r")

    def _rng(self, step: int) -> np.random.Generator:
        # fold host + step into the stream: restart-safe, host-disjoint
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.pi)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s = self.local_batch, cfg.seq_len
        if self._corpus is not None:
            max_start = len(self._corpus) - (s + 1)
            starts = rng.integers(0, max_start, size=b)
            toks = np.stack([np.asarray(self._corpus[st:st + s + 1])
                             for st in starts]).astype(np.int32)
            toks = np.clip(toks, 0, cfg.vocab - 1)
        else:
            # synthetic: markov-ish stream so loss can actually decrease
            base = rng.integers(0, cfg.vocab, size=(b, 1), dtype=np.int64)
            drift = rng.integers(0, 17, size=(b, s + 1), dtype=np.int64)
            toks = ((base + np.cumsum(drift, axis=1)) % cfg.vocab
                    ).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.n_prefix:
            out["vision_embeds"] = rng.standard_normal(
                (b, cfg.n_prefix, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.src_len:
            out["src_embeds"] = rng.standard_normal(
                (b, cfg.src_len, cfg.d_model)).astype(np.float32) * 0.02
        return out

    def device_batch(self, step: int, sharding=None):
        host = self.batch(step)
        if sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, sharding[k] if isinstance(
            sharding, dict) else sharding) for k, v in host.items()}
