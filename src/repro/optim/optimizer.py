"""Hand-rolled optimizers (no optax): AdamW + schedules + clipping +
int8 gradient compression with error feedback (for the cross-pod DCN
all-reduce — a distributed-optimization trick, see DESIGN.md §5).

Optimizer state inherits parameter sharding (ZeRO-1 comes for free under
FSDP param sharding: m/v shard exactly like the weights they track).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False   # int8 + error feedback across 'pod'


def lr_at(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup, 1)
    t = jnp.clip((step - cfg.warmup)
                 / max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup, warm, cfg.lr * cos)


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda p: jax.tree.map(                      # noqa: E731
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, state["step"])
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay, matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(tdef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"grad_norm": gn, "lr": lr}


# ---------------- int8 gradient compression (error feedback) ----------

def compress_int8(g: jax.Array):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str, errors):
    """int8-compress + psum over `axis_name` (DCN/pod axis) with error
    feedback.  errors: pytree like grads (f32 residuals)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        new_e = g32 - deq
        summed = jax.lax.psum(deq, axis_name)
        return summed.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
