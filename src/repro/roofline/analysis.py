"""Roofline derivation from compiled dry-run artifacts.

Three terms (seconds), TPU v5e constants:
  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s ICI link)
               (DCN collectives — ops whose replica groups span pods —
                are charged at 25 GB/s/host separately)

cost_analysis() provides flops/bytes; collective bytes are parsed from
the *optimized* (post-SPMD) HLO text, summing result-shape bytes of each
collective op weighted by a transfer factor:
  all-reduce 2x (reduce-scatter + all-gather ring), all-gather (g-1)/g,
  reduce-scatter (g-1)/g, all-to-all (g-1)/g, collective-permute 1x.
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link
DCN_BW = 25e9             # B/s / host (cross-pod)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-op-type result bytes x transfer factor, from optimized HLO."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes = m.group(1) or m.group(2)
        op = m.group(3)
        b = _shape_bytes(shapes) * _FACTOR[op]
        out[op] = out.get(op, 0.0) + b
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, float]
    model_flops: float
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    bytes_per_device: float = 0.0

    def finish(self):
        self.t_compute = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.t_memory = self.hlo_bytes / (self.chips * HBM_BW)
        self.t_collective = self.coll_bytes / (self.chips * ICI_BW)
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / self.hlo_flops
                             if self.hlo_flops else 0.0)
        return self

    @property
    def step_time(self) -> float:
        """Optimistic overlap model: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-projected step time."""
        if self.step_time == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["step_time"] = self.step_time
        d["mfu"] = self.mfu
        return d


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            bytes_per_device: float = 0.0) -> Roofline:
    coll = collective_bytes(hlo_text)
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
    )
    return r.finish()


def _attn_flops_fwd(cfg, tokens: int, seq: int) -> float:
    """Causal self-attention matmul FLOPs (QK^T + PV), forward pass.
    Counted for full-attention stacks; hybrid counts its shared blocks;
    ssm/enc-dec kept conservative (0 / decoder-only)."""
    if cfg.family in ("dense", "moe", "vlm"):
        layers = cfg.n_layers
    elif cfg.family == "hybrid" and cfg.shared_every:
        layers = cfg.n_layers // cfg.shared_every
    elif cfg.family == "audio":
        layers = cfg.dec_layers          # decoder self-attn (causal)
    else:
        return 0.0
    # 2 matmuls x 2 flops/MAC x tokens x seq x H x Dh, causal half
    return 2.0 * 2.0 * tokens * seq * cfg.n_heads * cfg.d_head \
        * layers * 0.5


def model_flops_train(cfg, tokens: int, seq: int | None = None) -> float:
    """PaLM-style MFU numerator: 6*N_active*D + 3x fwd attention flops."""
    n = cfg.active_param_count()
    base = 6.0 * n * tokens
    if seq:
        base += 3.0 * _attn_flops_fwd(cfg, tokens, seq)
    return base


def model_flops_prefill(cfg, tokens: int, seq: int) -> float:
    """Forward-only: 2*N_active*D + fwd attention flops."""
    return 2.0 * cfg.active_param_count() * tokens \
        + _attn_flops_fwd(cfg, tokens, seq)


def model_flops_decode(cfg, batch: int, ctx: int) -> float:
    n = cfg.active_param_count()
    base = 2.0 * n * batch  # one token per sequence
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        attn = 2.0 * 2.0 * batch * cfg.n_layers * cfg.n_heads \
            * cfg.d_head * ctx
        base += attn
    return base


def load_results(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
