"""Roll-up cost model over optimized (post-SPMD) HLO text.

Why: xla's HloCostAnalysis (compiled.cost_analysis()) visits each while
body ONCE — a scan-over-layers model is undercounted by n_layers x.  This
parser rebuilds the computation call graph, extracts while trip counts
from loop-condition constants, and rolls up:

  flops        — dot/convolution FLOPs (elementwise ignored: <1% in LMs)
  bytes        — HBM traffic model, FUSION-AWARE: the CPU backend leaves
                 long elementwise chains unfused that TPU-XLA would fuse,
                 so charging every op wildly overestimates HBM traffic.
                 Instead we simulate fusion: only *materializing* ops
                 (dot, fusion call-sites, reduce, slicing, collectives,
                 layout ops) write their result to HBM; an elementwise op
                 is free, and a materializing consumer charges one read
                 per *materialized leaf* reachable through the elementwise
                 chain feeding it (parameters count as leaves).
                 Slicing ops keep HloCostAnalysis conventions: 2x the
                 slice/update bytes, never the backing buffer.
  collectives  — result bytes x transfer factor per op type

All quantities are PER-DEVICE (the text is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)="
    r"(?:\{([^}]*)\}|(%[\w.\-]+))")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s+\(")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                "reduce-scatter": 1.0, "all-to-all": 1.0,
                "collective-permute": 1.0, "ragged-all-to-all": 1.0}

_SKIP_BYTES_OPS = ("parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "iota", "after-all", "partition-id",
                   "replica-id")


def _shape_sizes(text: str) -> tuple[int, list[tuple[str, list[int]]]]:
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


def _dot_flops(result_shape, line: str, name2shape) -> float:
    """2 * prod(result) * contracted size."""
    dt, rdims = result_shape
    out = 1
    for d in rdims:
        out *= d
    # operands may be typed ("dot(f32[64,128]{1,0} %lhs, ...)") or bare
    # ("dot(%lhs, ...)") depending on the HLO printer version
    m = re.search(r"dot\([^%)]*(%[\w.\-]+)", line)
    c = 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if m and cm and m.group(1) in name2shape:
        ldims = name2shape[m.group(1)][1]
        for idx in cm.group(1).split(","):
            if idx:
                c *= ldims[int(idx)]
    return 2.0 * out * c


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (name, kind)


def _parse_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if (hdr and not line.startswith(" ") and ") -> " in line
                and line.rstrip().endswith("{")):
            cur = hdr.group(1).lstrip("%")
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Max integer constant in the loop condition = trip count bound."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _comp_roots(comps: dict[str, list[str]]) -> dict[str, str]:
    roots = {}
    for name, lines in comps.items():
        for ln in reversed(lines):
            if "ROOT" in ln:
                d = _DEF_RE.match(ln)
                if d:
                    m = re.match(r"((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)",
                                 d.group(2))
                    if m:
                        roots[name] = m.group(2)
                break
    return roots


# Ops that materialize their result in HBM (everything else is assumed
# fused into its consumer by TPU-XLA).  Slicing ops are special-cased.
_MATERIALIZE = frozenset({
    "dot", "convolution", "fusion", "reduce", "reduce-window", "sort",
    "while", "conditional", "call", "custom-call", "rng",
    "rng-bit-generator", "cholesky", "triangular-solve", "fft", "copy",
    "transpose", "concatenate", "pad", "reverse", "copy-start",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "all-reduce-start",
    "all-gather-start", "send", "recv", "infeed", "outfeed",
})
_SLICING = frozenset({"dynamic-slice", "gather", "slice",
                      "dynamic-update-slice", "scatter"})
_TRANSPARENT = frozenset({"get-tuple-element", "tuple", "bitcast",
                          "optimization-barrier"})
_FREE = frozenset({"constant", "iota", "after-all", "partition-id",
                   "replica-id", "parameter"})


def _nbytes(shape: tuple[str, list[int]]) -> float:
    dt, dims = shape
    n = 1
    for d in dims:
        n *= d
    return float(n * _DTYPE_BYTES.get(dt, 0))


def analyze_hlo(text: str) -> dict:
    comps = _parse_computations(text)
    roots = _comp_roots(comps)
    costs: dict[str, CompCost] = {}
    trips: dict[str, int] = {}   # body computation -> trip count

    for name, lines in comps.items():
        cc = CompCost()
        name2shape: dict[str, tuple[str, list[int]]] = {}
        insts: dict[str, tuple[str, float, list[str]]] = {}
        fusion_target: dict[str, str] = {}
        order: list[str] = []
        root_var = None
        for ln in lines:
            d = _DEF_RE.match(ln)
            if not d:
                continue
            var, rest = d.group(1), d.group(2)
            if "ROOT" in ln.split("=", 1)[0]:
                root_var = var
            # result shape = first shape(s) on the line before the op name
            m = re.match(r"((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)", rest)
            if not m:
                continue
            shape_str, op = m.group(1), m.group(2)
            rbytes, rshapes = _shape_sizes(shape_str)
            if rshapes:
                name2shape[var] = rshapes[0]
            om = re.search(r"\w[\w\-]*\(([^)]*)\)", rest)
            operands = re.findall(r"%[\w.\-]+", om.group(1)) if om else []
            insts[var] = (op, float(rbytes), operands)
            order.append(var)
            if op == "fusion":
                fm = re.search(r"calls=(%[\w.\-]+)", ln)
                if fm:
                    fusion_target[var] = fm.group(1).lstrip("%")
            # called computations
            for cm in _CALLED_RE.finditer(ln):
                grp = cm.group(1)
                targets = ([t.strip().lstrip("%") for t in grp.split(",")]
                           if grp else [cm.group(2).lstrip("%")])
                kind = ln[cm.start():cm.start() + 9]
                for tgt in targets:
                    cc.calls.append((tgt, kind))
            if op == "while":
                bm = re.search(r"body=(%[\w.\-]+)", ln)
                cm2 = re.search(r"condition=(%[\w.\-]+)", ln)
                if bm and cm2:
                    cond = cm2.group(1).lstrip("%")
                    body = bm.group(1).lstrip("%")
                    trips[body] = _trip_count(comps.get(cond, []))
            # flops
            if op == "dot":
                cc.flops += _dot_flops(rshapes[0] if rshapes else
                                       ("f32", []), ln, name2shape)
            elif op == "convolution":
                cc.flops += 2.0 * rbytes  # coarse: conv rare in our models
            # collectives (result bytes x factor)
            base_op = op.removesuffix("-start").removesuffix("-done")
            if base_op in _COLL_FACTOR and not op.endswith("-done"):
                cc.coll[base_op] = cc.coll.get(base_op, 0.0) \
                    + rbytes * _COLL_FACTOR[base_op]

        # ---- fusion-aware byte charging ----
        def is_mat(var: str) -> bool:
            if var not in insts:
                return False
            if var == root_var and insts[var][0] not in _TRANSPARENT \
                    and insts[var][0] not in _FREE:
                # non-tuple program/loop outputs are written (tuple roots
                # are aliasing plumbing: the elements' producers already
                # charged; fusion-body roots are excluded at roll-up)
                return True
            op = insts[var][0]
            if op == "fusion":
                return True
            return op in _MATERIALIZE or op in _SLICING

        def leaves(var: str, seen: set) -> float:
            """Read-bytes of materialized leaves feeding `var` through
            fused (elementwise/transparent) chains."""
            if var in seen or var not in insts:
                return 0.0
            seen.add(var)
            op, rbytes, operands = insts[var]
            if op in ("constant", "iota", "after-all", "partition-id",
                      "replica-id"):
                return 0.0
            if op == "get-tuple-element":
                # reading ONE element of a (possibly huge) carry tuple
                return rbytes
            if op == "parameter" or is_mat(var):
                return rbytes
            if op in _TRANSPARENT:
                return sum(leaves(o, seen) for o in operands)
            # fused elementwise: read its own leaves
            return sum(leaves(o, seen) for o in operands)

        def slice_eff_op(var: str) -> str:
            # DUS/DS-rooted fusions behave like the slicing op
            rop = roots.get(fusion_target.get(var, ""), "")
            return rop if rop in _SLICING else insts[var][0]

        for var in order:
            op, rbytes, operands = insts[var]
            eff = slice_eff_op(var) if op == "fusion" else op
            if eff in ("dynamic-slice", "gather", "slice"):
                cc.bytes += 2.0 * rbytes      # read slice + write result
                continue
            if eff in ("dynamic-update-slice", "scatter"):
                ub = rbytes
                if len(operands) >= 2 and operands[1] in name2shape:
                    ub = _nbytes(name2shape[operands[1]])
                cc.bytes += 2.0 * min(ub, rbytes)
                continue
            if not is_mat(var):
                continue                      # fused away: no HBM traffic
            seen: set = set()
            reads = sum(leaves(o, seen) for o in operands)
            cc.bytes += rbytes + reads
        costs[name] = cc

    # roll up from ENTRY with while-trip multipliers (memoized DFS)
    memo: dict[str, tuple[float, float, dict]] = {}

    def roll(name: str, stack=()) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        if name not in costs or name in stack:
            return 0.0, 0.0, {}
        cc = costs[name]
        f, b, cl = cc.flops, cc.bytes, dict(cc.coll)
        for tgt, kind in cc.calls:
            tf, tb, tcl = roll(tgt, stack + (name,))
            mult = trips.get(tgt, 1) if kind.startswith("body") else 1
            f += tf * mult
            # fusion internals are NOT HBM traffic (the fusion call site
            # already charged its operands+result)
            if not kind.startswith("calls"):
                b += tb * mult
            for k, v in tcl.items():
                cl[k] = cl.get(k, 0.0) + v * mult
        memo[name] = (f, b, cl)
        return memo[name]

    entry = None
    for ln in text.splitlines():
        if ln.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(ln[len("ENTRY "):])
            if m:
                entry = m.group(1).lstrip("%")
            break
    if entry is None or entry not in costs:
        # fall back: computation with max flops
        entry = max(costs, key=lambda n: costs[n].flops) if costs else None
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {}, "trips": {}}
    f, b, cl = roll(entry)
    per_comp = {n: c.bytes for n, c in costs.items() if c.bytes > 0}
    return {"flops": f, "bytes": b, "collectives": cl, "trips": trips,
            "per_comp_bytes": per_comp}
