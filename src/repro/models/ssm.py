"""Mamba2 block built on the chunked ssm_scan kernel (ordered dependence).

Train path uses ops.ssm_scan (chunked FGOP scan); decode path is the O(1)
recurrent update (state + short-conv buffer carried in the decode cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import dense_init, rms_norm


def init_mamba(key, d: int, cfg_ssm):
    di = cfg_ssm.expand * d
    n = cfg_ssm.state
    h = cfg_ssm.heads
    kc = cfg_ssm.conv_kernel
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z(di), x(di), B(n), C(n), dt(h)]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + h)),
        "w_out": dense_init(ks[1], (di, d)),
        "conv_w": dense_init(ks[2], (kc, di + 2 * n)),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
    }


def _split_proj(p, cfg_ssm, d, proj):
    di = cfg_ssm.expand * d
    n = cfg_ssm.state
    z = proj[..., :di]
    xc = proj[..., di:2 * di]
    bmat = proj[..., 2 * di:2 * di + n]
    cmat = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, xc, bmat, cmat, dt


def _causal_conv(x, w):
    """x: (B,S,C), w: (K,C) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out


def mamba_train(p, cfg, x):
    """x: (B,S,D) -> (B,S,D)."""
    ssm = cfg.ssm
    b, s, d = x.shape
    di = ssm.expand * d
    hh = ssm.heads
    pp = di // hh
    proj = x @ p["w_in"].astype(x.dtype)
    z, xc, bmat, cmat, dt = _split_proj(p, ssm, d, proj)
    # causal short conv over [x, B, C] (mamba2 convention)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(x.dtype)))
    xc = conv[..., :di]
    bmat = conv[..., di:di + ssm.state]
    cmat = conv[..., di + ssm.state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])      # (B,S,H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, None, :] * dt)    # decay (0,1)
    xh = xc.reshape(b, s, hh, pp)
    xin = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, _ = ops.ssm_scan(xin, a.astype(x.dtype), bmat, cmat, chunk=ssm.chunk,
                        backend="xla")
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["w_out"].astype(x.dtype)


# ---------------- decode ----------------

def init_mamba_cache(cfg, batch: int, n_layers: int, dtype=jnp.float32):
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    return {
        "state": jnp.zeros((n_layers, batch, ssm.heads, ssm.state,
                            di // ssm.heads), dtype),
        "conv": jnp.zeros((n_layers, batch, ssm.conv_kernel - 1,
                           di + 2 * ssm.state), dtype),
    }


def mamba_decode(p, cfg, x, state, conv_buf):
    """x: (B,1,D); state: (B,H,N,P); conv_buf: (B,K-1,C)."""
    ssm = cfg.ssm
    b, _, d = x.shape
    di = ssm.expand * d
    hh = ssm.heads
    pp = di // hh
    proj = x[:, 0] @ p["w_in"].astype(x.dtype)               # (B, ...)
    z, xc, bmat, cmat, dt = _split_proj(p, ssm, d, proj)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)     # (B,C)
    window = jnp.concatenate(
        [conv_buf.astype(x.dtype), conv_in[:, None]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))
    new_buf = window[:, 1:].astype(conv_buf.dtype)
    xc = conv[:, :di]
    bmat = conv[:, di:di + ssm.state]
    cmat = conv[:, di + ssm.state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt)          # (B,H)
    xh = xc.reshape(b, hh, pp).astype(jnp.float32) * dt[..., None]
    state = a[..., None, None] * state + jnp.einsum(
        "bn,bhp->bhnp", bmat.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), state)
    y = y.astype(x.dtype) + xc.reshape(b, hh, pp) \
        * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"].astype(x.dtype))[:, None]
    return out, state, new_buf
