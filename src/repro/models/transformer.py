"""Model assembly for all assigned architectures.

Families:
  dense / moe / vlm : token embed (+ vision prefix) -> scan(L x block)
  hybrid (zamba2)   : scan over groups of mamba layers with one *shared*
                      attention+MLP block applied between groups
  ssm (xlstm)       : groups of (m x mLSTM + s x sLSTM)
  audio (enc-dec)   : encoder (bidir) over frame embeds + causal decoder
                      with cross-attention

All stacks scan over layers (compile-time O(1) in depth) with a
configurable remat policy.  The LM loss is chunked over the sequence so
(B*S, V) logits never materialize.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models import ssm as ssmm
from repro.models import xlstm as xlm
from repro.models.config import ArchConfig
from repro.models.layers import embed_init, rms_norm, softmax_xent


# ---------------- init ----------------

def _init_block(key, cfg: ArchConfig):
    """One dense transformer block (attention + mlp/moe)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.family == "moe":
        p["moe"] = mlpm.init_moe(k2, cfg.d_model, cfg.moe)
    else:
        p["mlp"] = mlpm.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _init_cross_block(key, cfg: ArchConfig):
    p = _init_block(key, cfg)
    k = jax.random.fold_in(key, 99)
    p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["cross"] = attn.init_attention(k, cfg)
    return p


def _stack(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    p: dict = {"embed": embed_init(ks[0], (cfg.vocab, cfg.d_model)),
               "ln_f": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[1], (cfg.d_model, cfg.vocab))

    if cfg.family in ("dense", "moe", "vlm"):
        p["layers"] = _stack(lambda k: _init_block(k, cfg), ks[2],
                             cfg.n_layers)
    elif cfg.family == "hybrid":
        p["layers"] = _stack(
            lambda k: ssmm.init_mamba(k, cfg.d_model, cfg.ssm), ks[2],
            cfg.n_layers)
        p["shared"] = _init_block(ks[3], cfg)
    elif cfg.family == "ssm":
        g = cfg.xlstm.m_per_group + cfg.xlstm.s_per_group
        groups = cfg.n_layers // g
        p["layers"] = {
            "m": _stack(lambda k: xlm.init_mlstm(k, cfg.d_model, cfg.xlstm),
                        ks[2], groups * cfg.xlstm.m_per_group),
            "s": _stack(lambda k: xlm.init_slstm(k, cfg.d_model, cfg.xlstm),
                        ks[3], groups * cfg.xlstm.s_per_group),
        }
    elif cfg.family == "audio":
        p["enc_layers"] = _stack(lambda k: _init_block(k, cfg), ks[2],
                                 cfg.enc_layers)
        p["layers"] = _stack(lambda k: _init_cross_block(k, cfg), ks[3],
                             cfg.dec_layers)
        p["ln_enc"] = jnp.ones((cfg.d_model,), jnp.float32)
    else:
        raise ValueError(cfg.family)
    return p


# ---------------- blocks (train) ----------------

def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        policy = jax.checkpoint_policies.nothing_saveable
    else:
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def _dense_block(p, cfg: ArchConfig, x, positions, *, causal=True,
                 enc_out=None):
    h = attn.attention_train(p["attn"], cfg, rms_norm(x, p["ln1"],
                                                      cfg.norm_eps),
                             positions, causal=causal)
    x = x + h
    x = constrain(x, "batch", "seq", "embed")
    aux = jnp.zeros((), jnp.float32)
    if "cross" in p and enc_out is not None:
        h = attn.attention_train(p["cross"], cfg,
                                 rms_norm(x, p["ln_x"], cfg.norm_eps),
                                 positions, causal=False, kv_x=enc_out)
        x = x + h
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        if cfg.moe_dispatch == "a2a":
            h, aux = mlpm.moe_a2a(p["moe"], xn, cfg.moe)
        else:
            h, aux = mlpm.moe(p["moe"], xn, cfg.moe,
                              dispatch=cfg.moe_dispatch)
    else:
        h = mlpm.mlp(p["mlp"], xn, cfg.act)
    x = x + h
    return constrain(x, "batch", "seq", "embed"), aux


def _scan_blocks(params_stacked, cfg, x, positions, *, causal=True,
                 enc_out=None):
    block = _remat(
        lambda x_, p_: _dense_block(p_, cfg, x_, positions, causal=causal,
                                    enc_out=enc_out), cfg)

    def step(carry, p_):
        x_, aux = carry
        x_, a = block(x_, p_)
        return (x_, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params_stacked)
    return x, aux


def _hybrid_stack(p, cfg: ArchConfig, x, positions):
    """zamba2: groups of `shared_every` mamba layers + shared attn block."""
    mamba = _remat(lambda x_, p_: ssmm.mamba_train(p_, cfg, x_), cfg)
    n_groups = cfg.n_layers // cfg.shared_every
    stacked = jax.tree.map(
        lambda a: a.reshape((n_groups, cfg.shared_every) + a.shape[1:]),
        p["layers"])
    shared = _remat(
        lambda x_, p_: _dense_block(p_, cfg, x_, positions)[0], cfg)

    def group(x_, gp):
        def inner(c, lp):
            return c + mamba(c, lp), None
        x_, _ = jax.lax.scan(inner, x_, gp)
        return shared(x_, p["shared"]), None

    x, _ = jax.lax.scan(lambda c, gp: group(c, gp), x, stacked)
    return x, jnp.zeros((), jnp.float32)


def _xlstm_stack(p, cfg: ArchConfig, x):
    cx = cfg.xlstm
    g = cx.m_per_group + cx.s_per_group
    groups = cfg.n_layers // g
    m_st = jax.tree.map(
        lambda a: a.reshape((groups, cx.m_per_group) + a.shape[1:]),
        p["layers"]["m"])
    s_st = jax.tree.map(
        lambda a: a.reshape((groups, cx.s_per_group) + a.shape[1:]),
        p["layers"]["s"])
    mf = _remat(lambda x_, p_: xlm.mlstm_train(p_, cfg, x_, cfg.n_heads),
                cfg)
    sf = _remat(lambda x_, p_: xlm.slstm_train(p_, cfg, x_), cfg)

    def group(x_, gp):
        mp, sp = gp

        def mstep(c, lp):
            return c + mf(c, lp), None

        x_, _ = jax.lax.scan(mstep, x_, mp)

        def sstep(c, lp):
            return c + sf(c, lp), None

        x_, _ = jax.lax.scan(sstep, x_, sp)
        return x_, None

    x, _ = jax.lax.scan(group, x, (m_st, s_st))
    return x, jnp.zeros((), jnp.float32)


# ---------------- forward / loss ----------------

def embed_tokens(p, cfg, tokens, extra_embeds=None):
    x = p["embed"].astype(jnp.dtype(cfg.compute_dtype))[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate(
            [extra_embeds.astype(x.dtype), x], axis=1)
    return constrain(x, "batch", "seq", "embed")


def backbone(p, cfg: ArchConfig, x, positions, enc_out=None):
    if cfg.family in ("dense", "moe", "vlm"):
        return _scan_blocks(p["layers"], cfg, x, positions)
    if cfg.family == "hybrid":
        return _hybrid_stack(p, cfg, x, positions)
    if cfg.family == "ssm":
        return _xlstm_stack(p, cfg, x)
    if cfg.family == "audio":
        return _scan_blocks(p["layers"], cfg, x, positions, causal=True,
                            enc_out=enc_out)
    raise ValueError(cfg.family)


def encode(p, cfg: ArchConfig, src_embeds):
    pos = jnp.broadcast_to(jnp.arange(src_embeds.shape[1]),
                           src_embeds.shape[:2])
    x = src_embeds.astype(jnp.dtype(cfg.compute_dtype))
    x, _ = _scan_blocks(p["enc_layers"], cfg, x, pos, causal=False)
    return rms_norm(x, p["ln_enc"], cfg.norm_eps)


def _out_head(p, cfg):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return w


def chunked_ce(p, cfg: ArchConfig, x, labels, mask=None):
    """x: (B,S,D) final hidden; labels: (B,S). Scan over S chunks."""
    b, s, d = x.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    w = _out_head(p, cfg)
    xs = jnp.moveaxis(x.reshape(b, s // c, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, s // c, c), 1, 0)
    ms = None if mask is None else jnp.moveaxis(
        mask.reshape(b, s // c, c), 1, 0)

    def step(acc, t):
        if ms is None:
            xc, lc = t
            mc = jnp.ones(lc.shape, jnp.float32)
        else:
            xc, lc, mc = t
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
        logits = constrain(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (acc[0] + jnp.sum((lse - ll) * mc), acc[1] + jnp.sum(mc)), None

    xs_all = (xs, ls) if ms is None else (xs, ls, ms)
    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), xs_all)
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(p, cfg: ArchConfig, batch):
    """batch: tokens (B,S), labels (B,S); optional vision_embeds (B,P,D),
    src_embeds (B,Ss,D) [audio], loss_mask (B,S)."""
    tokens = batch["tokens"]
    enc_out = None
    extra = batch.get("vision_embeds") if cfg.frontend == "vision" else None
    if cfg.frontend == "audio":
        enc_out = encode(p, cfg, batch["src_embeds"])
    x = embed_tokens(p, cfg, tokens, extra)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, aux = backbone(p, cfg, x, pos, enc_out=enc_out)
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    if extra is not None:        # loss only over the token tail
        x = x[:, extra.shape[1]:]
    loss = chunked_ce(p, cfg, x, batch["labels"],
                      batch.get("loss_mask"))
    return loss + 0.01 * aux


def prefill(p, cfg: ArchConfig, batch):
    """Forward w/o loss: returns last-position logits (B, V)."""
    tokens = batch["tokens"]
    extra = batch.get("vision_embeds") if cfg.frontend == "vision" else None
    enc_out = None
    if cfg.frontend == "audio":
        enc_out = encode(p, cfg, batch["src_embeds"])
    x = embed_tokens(p, cfg, tokens, extra)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _ = backbone(p, cfg, x, pos, enc_out=enc_out)
    x = rms_norm(x[:, -1:], p["ln_f"], cfg.norm_eps)
    w = _out_head(p, cfg)
    return (x[:, 0] @ w.astype(x.dtype)).astype(jnp.float32)
