"""GQA attention: init, train paths (incl. FGOP-inductive banding), decode.

Train-path implementations:
  'xla'     — one dense einsum + mask (small S only)
  'chunked' — lax.scan over q blocks, full-width kv with causal mask
              (rectangular tiling: the no-FGOP baseline at scale)
  'banded'  — q-band b attends kv[0 : band_end(b)] with *static* inductive
              lengths: the paper's RI-stream tiling at coarse grain; saves
              ~(1 - (nb+1)/(2 nb)) of attention FLOPs vs 'chunked'
  'flash'   — the Pallas kernel (TPU runtime path)
Decode: single-token attention over a pre-allocated KV cache (length-
masked — implicit vector masking over the cache tail).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init, rms_norm

NEG = -1e30


def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, kv * dh)),
        "wv": dense_init(ks[2], (d, kv * dh)),
        "wo": dense_init(ks[3], (h * dh, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _qkv(p, cfg, x, positions, rope: bool = True):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, kv, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_logits(q, k, scale):
    """q: (B,Sq,H,Dh), k: (B,Skv,KV,Dh) -> (B,H,Sq,Skv) f32, grouped."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    lg = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    return lg.reshape(b, h, sq, k.shape[1])


def _gqa_out(w, v):
    """w: (B,H,Sq,Skv) f32, v: (B,Skv,KV,Dh) -> (B,Sq,H,Dh)."""
    b, h, sq, skv = w.shape
    kvh = v.shape[2]
    g = h // kvh
    wg = w.reshape(b, kvh, g, sq, skv)
    o = jnp.einsum("bkgqs,bskd->bqkgd", wg.astype(v.dtype), v)
    return o.reshape(b, sq, h, v.shape[-1])


def _attend_dense(q, k, v, scale, causal, q_off=0):
    logits = _gqa_logits(q, k, scale)
    if causal:
        qi = q_off + jnp.arange(q.shape[1])[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where((ki <= qi)[None, None], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(w, v)


def attend_train(q, k, v, cfg, causal: bool = True):
    """q,k,v: (B,S,H/KV,Dh) -> (B,S,H,Dh)."""
    b, s, h, dh = q.shape
    scale = 1.0 / np.sqrt(dh)
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "xla" if s <= max(cfg.attn_chunk, 1024) else "chunked"

    if impl == "flash":
        qt = jnp.moveaxis(q, 2, 1)
        kt = jnp.moveaxis(k, 2, 1)
        vt = jnp.moveaxis(v, 2, 1)
        o = ops.flash_attention(qt, kt, vt, causal=causal, backend="pallas")
        return jnp.moveaxis(o, 1, 2)

    if impl == "xla" or not causal:
        return _attend_dense(q, k, v, scale, causal)

    sp = ("seq_sp", None, None) if getattr(cfg, "attn_sp", False) \
        else (None, None, None)

    if impl == "chunked":
        c = min(cfg.attn_chunk, s)
        while s % c != 0:      # largest divisor of s <= attn_chunk
            c -= 1             # (vlm prefix makes s non-power-of-two)
        qs = jnp.moveaxis(q.reshape(b, s // c, c, h, dh), 1, 0)
        offs = jnp.arange(s // c) * c

        def step(_, qo):
            qc, off = qo
            # sequence-parallel: shard the q rows of this chunk over
            # 'model' so the (B,H,c,S) logits live 1/16th per device
            qc = constrain(qc, "batch", *sp)
            oc = _attend_dense(qc, k, v, scale, True, q_off=off)
            return None, constrain(oc, "batch", *sp)

        _, os_ = jax.lax.scan(step, None, (qs, offs))
        o = jnp.moveaxis(os_, 0, 1).reshape(b, s, h, dh)
        return constrain(o, "batch", None, None, None)

    if impl == "banded":
        # FGOP: inductive trip count at band granularity — band i reads
        # kv[0 : (i+1)*band] only (static slice sizes, unrolled: the
        # coarse-grain RI stream).  Within a band the q rows are scanned
        # in attn_chunk tiles so only one (B,H,chunk,band_kv) logits tile
        # is ever live (footprint = rectangular-chunked, traffic = 0.5x).
        nb = min(cfg.attn_bands, s)
        assert s % nb == 0
        band = s // nb
        outs = []
        for i in range(nb):
            qb = constrain(q[:, i * band:(i + 1) * band], "batch", *sp)
            kc = k[:, : (i + 1) * band]
            vc = v[:, : (i + 1) * band]
            c = min(cfg.attn_chunk, band)
            while band % c != 0:
                c -= 1
            if c == band:
                oc = _attend_dense(qb, kc, vc, scale, True,
                                   q_off=i * band)
            else:
                qs = jnp.moveaxis(qb.reshape(b, band // c, c, h, dh), 1, 0)
                offs = i * band + jnp.arange(band // c) * c

                def stp(_, qo, kc=kc, vc=vc):
                    qc_, off = qo
                    return None, _attend_dense(qc_, kc, vc, scale, True,
                                               q_off=off)

                _, os_ = jax.lax.scan(stp, None, (qs, offs))
                oc = jnp.moveaxis(os_, 0, 1).reshape(b, band, h, dh)
            outs.append(constrain(oc, "batch", *sp))
        o = jnp.concatenate(outs, axis=1)
        return constrain(o, "batch", None, None, None)

    raise ValueError(f"unknown attn_impl {impl!r}")


def attention_train(p, cfg, x, positions, *, causal=True, kv_x=None,
                    rope=True):
    """Full attention block (no residual). kv_x: cross-attn memory."""
    q, k, v = _qkv(p, cfg, x, positions, rope=rope) if kv_x is None else \
        _qkv_cross(p, cfg, x, kv_x, positions, rope)
    o = attend_train(q, k, v, cfg, causal=causal)
    b, s, h, dh = o.shape
    return o.reshape(b, s, h * dh) @ p["wo"].astype(x.dtype)


def _qkv_cross(p, cfg, x, kv_x, positions, rope):
    b, s, _ = x.shape
    skv = kv_x.shape[1]
    h, kvh, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (kv_x @ p["wk"].astype(kv_x.dtype)).reshape(b, skv, kvh, dh)
    v = (kv_x @ p["wv"].astype(kv_x.dtype)).reshape(b, skv, kvh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------- decode ----------------

def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16):
    kv, dh = cfg.n_kv, cfg.d_head
    shape = (n_layers, batch, max_len, kv, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p, cfg, x, cache_k, cache_v, pos, *, rope=True):
    """One-token decode. x: (B,1,D); cache_k/v: (B,Smax,KV,Dh); pos: (B,)
    PER-BATCH positions — each batch row (slot) carries its own position,
    so a continuous-batching pool can mix rows mid-prefill with rows
    deep into generation. Returns (out (B,1,D), new_k, new_v).
    Each row's cache tail beyond its own `pos` is masked — implicit
    vector masking over the rectangular cache (the inductive 'live
    length' is pos+1) — which is also what makes slot reuse safe:
    resetting a row's pos to 0 orphans its stale pages without zeroing."""
    b, _, d = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q, k, v = _qkv(p, cfg, x, pos[:, None], rope=rope)
    # write each row's new kv at that row's own position
    upd = jax.vmap(
        lambda c, new, p_: jax.lax.dynamic_update_slice_in_dim(
            c, new, p_, axis=0))
    cache_k = upd(cache_k, k.astype(cache_k.dtype), pos)
    cache_v = upd(cache_v, v.astype(cache_v.dtype), pos)
    smax = cache_k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    logits = _gqa_logits(q, cache_k.astype(q.dtype), scale)  # (B,H,1,Smax)
    live = jnp.arange(smax)[None, None, None, :] <= pos[:, None, None, None]
    logits = jnp.where(live, logits, NEG)
    w = jax.nn.softmax(logits, axis=-1)
    o = _gqa_out(w, cache_v.astype(q.dtype))
    out = o.reshape(b, 1, h * dh) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v
