"""Single-token decode (serve_step) for every family.

The decode step consumes a pre-allocated cache:
  dense/moe/vlm : per-layer KV cache (L,B,Smax,KV,Dh); live length = pos+1
                  (implicit masking over the rectangular cache)
  hybrid        : mamba states (O(1)) + KV caches for the 6 shared-block
                  applications
  ssm (xlstm)   : mLSTM matrix memories + sLSTM scalar states (O(1) —
                  the sub-quadratic long_500k path)
  audio         : decoder self-KV cache + precomputed encoder memory and
                  the cross-attention K/V never change during decode
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models import ssm as ssmm
from repro.models import xlstm as xlm
from repro.models.config import ArchConfig
from repro.models.layers import rms_norm
from repro.models.transformer import _out_head, encode


# ---------------- cache init ----------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, src_len: int = 1024):
    if cfg.family in ("dense", "moe", "vlm"):
        kv, dh = cfg.n_kv, cfg.d_head
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, kv, dh),
                               dtype),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, kv, dh),
                               dtype)}
    if cfg.family == "hybrid":
        napp = cfg.n_layers // cfg.shared_every
        kv, dh = cfg.n_kv, cfg.d_head
        mc = ssmm.init_mamba_cache(cfg, batch, cfg.n_layers)
        mc["k"] = jnp.zeros((napp, batch, max_len, kv, dh), dtype)
        mc["v"] = jnp.zeros((napp, batch, max_len, kv, dh), dtype)
        return mc
    if cfg.family == "ssm":
        cx = cfg.xlstm
        g = cx.m_per_group + cx.s_per_group
        groups = cfg.n_layers // g
        nm = groups * cx.m_per_group
        ns = groups * cx.s_per_group
        return {
            "m": jax.vmap(lambda _: xlm.init_mlstm_state(
                cfg, cfg.d_model, batch, cfg.n_heads))(jnp.arange(nm)),
            "s": jax.vmap(lambda _: xlm.init_slstm_state(
                cfg.d_model, batch))(jnp.arange(ns)),
        }
    if cfg.family == "audio":
        kv, dh = cfg.n_kv, cfg.d_head
        return {
            "k": jnp.zeros((cfg.dec_layers, batch, max_len, kv, dh), dtype),
            "v": jnp.zeros((cfg.dec_layers, batch, max_len, kv, dh), dtype),
            "enc_out": jnp.zeros((batch, src_len, cfg.d_model), dtype),
        }
    raise ValueError(cfg.family)


def warm_cache_audio(p, cfg, cache, src_embeds):
    cache = dict(cache)
    cache["enc_out"] = encode(p, cfg, src_embeds).astype(
        cache["enc_out"].dtype)
    return cache


# ---------------- per-family steps ----------------

def _dense_decode_stack(p_layers, cfg, x, cache_k, cache_v, pos,
                        enc_out=None):
    def step(x_, t):
        lp, ck, cv = t
        h, ck, cv = attn.attention_decode(
            lp["attn"], cfg, rms_norm(x_, lp["ln1"], cfg.norm_eps),
            ck, cv, pos)
        x_ = x_ + h
        if "cross" in lp and enc_out is not None:
            q = rms_norm(x_, lp["ln_x"], cfg.norm_eps)
            h = attn.attention_train(lp["cross"], cfg, q, pos[:, None],
                                     causal=False, kv_x=enc_out)
            x_ = x_ + h
        xn = rms_norm(x_, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, _ = mlpm.moe(lp["moe"], xn, cfg.moe,
                            deterministic_capacity=max(
                                8, xn.shape[0] * cfg.moe.top_k
                                // cfg.moe.n_experts + 1))
            h = h
        else:
            h = mlpm.mlp(lp["mlp"], xn, cfg.act)
        x_ = x_ + h
        return x_, (ck, cv)

    x, (ks, vs) = jax.lax.scan(step, x, (p_layers, cache_k, cache_v))
    return x, ks, vs


def decode_step(p, cfg: ArchConfig, cache, tokens, pos):
    """tokens: (B,1) int32; pos: (B,) current positions (uniform).
    Returns (logits (B,V) f32, new cache)."""
    x = p["embed"].astype(jnp.dtype(cfg.compute_dtype))[tokens]
    b = x.shape[0]

    if cfg.family in ("dense", "moe", "vlm"):
        x, ks, vs = _dense_decode_stack(p["layers"], cfg, x,
                                        cache["k"], cache["v"], pos)
        cache = {"k": ks, "v": vs}

    elif cfg.family == "audio":
        x, ks, vs = _dense_decode_stack(
            p["layers"], cfg, x, cache["k"], cache["v"], pos,
            enc_out=cache["enc_out"].astype(x.dtype))
        cache = dict(cache, k=ks, v=vs)

    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.shared_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_groups, cfg.shared_every) + a.shape[1:]),
            {k: v for k, v in p["layers"].items()})
        mstate = cache["state"].reshape(
            (n_groups, cfg.shared_every) + cache["state"].shape[1:])
        mconv = cache["conv"].reshape(
            (n_groups, cfg.shared_every) + cache["conv"].shape[1:])

        def group(x_, t):
            gp, st, cv, ck_, cv_ = t

            def inner(x__, tt):
                lp, s_, c_ = tt
                h, s_, c_ = ssmm.mamba_decode(lp, cfg, x__, s_, c_)
                return x__ + h, (s_, c_)

            x_, (st, cv) = jax.lax.scan(inner, x_, (gp, st, cv))
            # shared attention+mlp block
            h, ck_, cv_ = attn.attention_decode(
                p["shared"]["attn"], cfg,
                rms_norm(x_, p["shared"]["ln1"], cfg.norm_eps),
                ck_, cv_, pos)
            x_ = x_ + h
            xn = rms_norm(x_, p["shared"]["ln2"], cfg.norm_eps)
            x_ = x_ + mlpm.mlp(p["shared"]["mlp"], xn, cfg.act)
            return x_, (st, cv, ck_, cv_)

        x, (st, cv, ks, vs) = jax.lax.scan(
            group, x, (stacked, mstate, mconv, cache["k"], cache["v"]))
        cache = {"state": st.reshape(cache["state"].shape),
                 "conv": cv.reshape(cache["conv"].shape),
                 "k": ks, "v": vs}

    elif cfg.family == "ssm":
        cx = cfg.xlstm
        g = cx.m_per_group + cx.s_per_group
        groups = cfg.n_layers // g
        mp = jax.tree.map(
            lambda a: a.reshape((groups, cx.m_per_group) + a.shape[1:]),
            p["layers"]["m"])
        sp = jax.tree.map(
            lambda a: a.reshape((groups, cx.s_per_group) + a.shape[1:]),
            p["layers"]["s"])
        mst = cache["m"].reshape((groups, cx.m_per_group)
                                 + cache["m"].shape[1:])
        sst = jax.tree.map(
            lambda a: a.reshape((groups, cx.s_per_group) + a.shape[1:]),
            cache["s"])

        def group(x_, t):
            gmp, gsp, gms, gss = t

            def mstep(x__, tt):
                lp, s_ = tt
                h, s_ = xlm.mlstm_decode(lp, cfg, x__, s_, cfg.n_heads)
                return x__ + h, s_

            x_, gms = jax.lax.scan(mstep, x_, (gmp, gms))

            def sstep(x__, tt):
                lp, s_ = tt
                h, s_ = xlm.slstm_decode(lp, cfg, x__, s_)
                return x__ + h, s_

            x_, gss = jax.lax.scan(sstep, x_, (gsp, gss))
            return x_, (gms, gss)

        x, (mst, sst) = jax.lax.scan(group, x, (mp, sp, mst, sst))
        cache = {"m": mst.reshape(cache["m"].shape),
                 "s": jax.tree.map(lambda a, ref: a.reshape(ref.shape),
                                   sst, cache["s"])}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    w = _out_head(p, cfg)
    logits = (x[:, 0] @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, cache
