"""xLSTM blocks: mLSTM (matrix memory, chunkable) + sLSTM (strictly
sequential scalar memory).

mLSTM's recurrence  C_t = f_t C_{t-1} + i_t k_t v_t^T,  n_t = f_t n + i_t k
is the same ordered-dependence shape as Mamba2's SSD, so it reuses
ops.ssm_scan with per-head B/C streams and an augmented value channel
(v ++ 1) that carries the normalizer in the same scan — one fused FGOP
kernel instead of two.  sLSTM is *not* chunkable (its nonlinearity sits
inside the recurrence): it is the paper's strictly-ordered, non-tileable
case (FGOP Property 1) and runs as a lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import dense_init, rms_norm


# ---------------- mLSTM ----------------

def init_mlstm(key, d: int, cfg_x):
    di = cfg_x.expand_m * d
    dqk = int(di * cfg_x.qk_frac)
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, dqk)),
        "wk": dense_init(ks[1], (d, dqk)),
        "wv": dense_init(ks[2], (d, di)),
        "wz": dense_init(ks[3], (d, di)),
        "wf": dense_init(ks[4], (d, 1)),   # per-layer scalar gates/head add
        "wi": dense_init(ks[5], (d, 1)),
        "wo": dense_init(ks[6], (di, d)),
        "norm": jnp.ones((di,), jnp.float32),
    }


def mlstm_train(p, cfg, x, n_heads: int):
    b, s, d = x.shape
    cfg_x = cfg.xlstm
    di = cfg_x.expand_m * d
    dqk = int(di * cfg_x.qk_frac)
    pv = di // n_heads
    pk = dqk // n_heads
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, n_heads, pk)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, n_heads, pk) / (pk ** 0.5)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, n_heads, pv)
    z = x @ p["wz"].astype(dt)
    f = jax.nn.sigmoid((x @ p["wf"].astype(dt)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["wi"].astype(dt)).astype(jnp.float32))
    a = jnp.broadcast_to(f, (b, s, 1)).repeat(n_heads, axis=2)  # (B,S,H)
    # augmented value channel carries the normalizer in the same scan
    ones = jnp.ones((b, s, n_heads, 1), dt)
    v_aug = jnp.concatenate([v, ones], axis=-1)                 # (B,S,H,P+1)
    bik = (k * i[..., None].astype(dt))                         # (B,S,H,N)
    y_aug, _ = ops.ssm_scan(v_aug, a.astype(dt), bik, q,
                            chunk=cfg.ssm.chunk if cfg.ssm else 64,
                            backend="xla")
    y = y_aug[..., :pv]
    n = y_aug[..., pv:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"].astype(dt)


def init_mlstm_state(cfg, d: int, batch: int, n_heads: int,
                     dtype=jnp.float32):
    cfg_x = cfg.xlstm
    di = cfg_x.expand_m * d
    dqk = int(di * cfg_x.qk_frac)
    return jnp.zeros((batch, n_heads, dqk // n_heads,
                      di // n_heads + 1), dtype)


def mlstm_decode(p, cfg, x, state, n_heads: int):
    """x: (B,1,D); state: (B,H,N,P+1)."""
    b, _, d = x.shape
    cfg_x = cfg.xlstm
    di = cfg_x.expand_m * d
    dqk = int(di * cfg_x.qk_frac)
    pv = di // n_heads
    pk = dqk // n_heads
    dt = x.dtype
    xt = x[:, 0]
    q = (xt @ p["wq"].astype(dt)).reshape(b, n_heads, pk)
    k = (xt @ p["wk"].astype(dt)).reshape(b, n_heads, pk) / (pk ** 0.5)
    v = (xt @ p["wv"].astype(dt)).reshape(b, n_heads, pv)
    z = xt @ p["wz"].astype(dt)
    f = jax.nn.sigmoid((xt @ p["wf"].astype(dt)).astype(jnp.float32))
    i = jax.nn.sigmoid((xt @ p["wi"].astype(dt)).astype(jnp.float32))
    v_aug = jnp.concatenate([v, jnp.ones((b, n_heads, 1), dt)], -1)
    state = f[..., None, None] * state + jnp.einsum(
        "bhn,bhp->bhnp", (k * i[..., None].astype(dt)).astype(jnp.float32),
        v_aug.astype(jnp.float32))
    y_aug = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), state)
    y = (y_aug[..., :pv] / jnp.maximum(jnp.abs(y_aug[..., pv:]), 1.0))
    y = y.reshape(b, di).astype(dt)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (y @ p["wo"].astype(dt))[:, None], state


# ---------------- sLSTM ----------------

def init_slstm(key, d: int, cfg_x):
    ks = jax.random.split(key, 3)
    fd = int(d * cfg_x.expand_s_ffn)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d)),   # z, i, f, o pre-acts
        "r_gates": dense_init(ks[1], (d, 4 * d)),   # recurrent weights
        "w_up": dense_init(ks[2], (d, fd)),
        "w_down": dense_init(jax.random.fold_in(ks[2], 1), (fd, d)),
        "norm": jnp.ones((d,), jnp.float32),
    }


def _slstm_cell(p, carry, wx):
    """Stabilized sLSTM cell. carry: (h, c, n, m) each (B, D)."""
    h, c, n, m = carry
    pre = wx + h @ p["r_gates"].astype(h.dtype)
    z, i, f, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + m, i)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(i - m_new)
    c = fp * c + ip * jnp.tanh(z)
    n = fp * n + ip
    h_new = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return (h_new.astype(wx.dtype), c, n, m_new)


def slstm_train(p, cfg, x):
    """Strictly-ordered scan over time (non-tileable FGOP case)."""
    b, s, d = x.shape
    wx = x @ p["w_gates"].astype(x.dtype)                    # (B,S,4D)
    f32 = jnp.float32
    carry = (jnp.zeros((b, d), x.dtype), jnp.zeros((b, d), f32),
             jnp.zeros((b, d), f32), jnp.full((b, d), -1e30, f32))

    def step(carry, wxt):
        carry = _slstm_cell(p, carry, wxt)
        return carry, carry[0]

    _, hs = jax.lax.scan(step, carry, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                               # (B,S,D)
    h = rms_norm(h, p["norm"], cfg.norm_eps)
    ff = jax.nn.gelu(h @ p["w_up"].astype(x.dtype))
    return ff @ p["w_down"].astype(x.dtype)


def init_slstm_state(d: int, batch: int):
    f32 = jnp.float32
    return {"h": jnp.zeros((batch, d), f32), "c": jnp.zeros((batch, d), f32),
            "n": jnp.zeros((batch, d), f32),
            "m": jnp.full((batch, d), -1e30, f32)}


def slstm_decode(p, cfg, x, st):
    b, _, d = x.shape
    wx = x[:, 0] @ p["w_gates"].astype(x.dtype)
    carry = (st["h"].astype(x.dtype), st["c"], st["n"], st["m"])
    h, c, n, m = _slstm_cell(p, carry, wx)
    st = {"h": h.astype(jnp.float32), "c": c, "n": n, "m": m}
    hn = rms_norm(h, p["norm"], cfg.norm_eps)
    ff = jax.nn.gelu(hn @ p["w_up"].astype(x.dtype))
    return (ff @ p["w_down"].astype(x.dtype))[:, None], st
