"""Architecture configuration dataclasses (one instance per assigned arch)."""
from __future__ import annotations

import dataclasses
from typing import Literal

Act = Literal["swiglu", "sq_relu", "gelu"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int           # routed experts (padded for sharding if needed)
    top_k: int
    d_ff_expert: int
    n_shared: int = 0        # always-on shared experts (merged into one)
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    padded_experts: int | None = None  # for sharding (>= n_experts)

    @property
    def e_pad(self) -> int:
        return self.padded_experts or self.n_experts


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state: int = 64          # N
    heads: int = 32
    expand: int = 2          # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    m_per_group: int = 3     # mLSTM layers per group
    s_per_group: int = 1     # sLSTM layers per group
    expand_m: int = 2
    qk_frac: float = 0.5     # qk head dim as fraction of v head dim
    expand_s_ffn: float = 1.3333


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    act: Act = "swiglu"
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # family extensions
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    xlstm: XLSTMCfg | None = None
    # hybrid (zamba2): shared attn+mlp block applied every `shared_every`
    shared_every: int = 0
    # enc-dec (seamless)
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub: 'none' | 'vision' | 'audio'
    frontend: str = "none"
    n_prefix: int = 0        # prefix embeddings from the frontend stub
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "dots"      # 'none' | 'dots' | 'full'
    loss_chunk: int = 512    # chunked cross-entropy block
    attn_impl: str = "auto"  # 'auto'|'xla'|'chunked'|'banded'|'flash'
    attn_chunk: int = 512
    # MoE dispatch collective policy: 'dense' (XLA default: all-reduce of
    # the scattered output) | 'sharded' (constrain expert/tokens layouts so
    # GSPMD emits reduce-scatter; the dbrx hillclimb, EXPERIMENTS.md §Perf)
    moe_dispatch: str = "dense"
    # sequence-parallel attention: shard the q-chunk rows of the attention
    # logits over 'model' so the (B,H,c,S) softmax tensor is 16x smaller
    # per device (heads often don't divide the model axis; the q-seq dim
    # always does).  Off = paper-faithful baseline; the qwen3/phi3
    # hillclimb (EXPERIMENTS.md §Perf)
    attn_sp: bool = False
    attn_bands: int = 8      # for 'banded' inductive attention
    # training-shape policy
    microbatch: int = 1      # gradient-accumulation steps
    # long-context capability (sub-quadratic path exists)
    subquadratic: bool = False

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def param_count(self) -> int:
        """Rough analytical parameter count (sanity checks / roofline N)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        att = d * (self.n_heads + 2 * self.n_kv) * self.d_head \
            + self.n_heads * self.d_head * d
        if self.act == "swiglu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.family == "moe" and self.moe:
            ffn = 3 * d * self.moe.d_ff_expert * self.moe.n_experts
            if self.moe.n_shared:
                ffn += 3 * d * self.moe.d_ff_shared
            ffn += d * self.moe.n_experts  # router
        if self.family == "hybrid" and self.ssm:
            di = self.ssm.expand * d
            n = self.ssm.state
            mamba = d * (2 * di + 2 * n + self.ssm.heads) + di * d \
                + self.ssm.conv_kernel * (di + 2 * n)
            shared = att + 3 * d * self.d_ff
            return total + self.n_layers * mamba \
                + (shared if self.shared_every else 0)
        if self.family == "ssm" and self.xlstm:
            di = self.xlstm.expand_m * d
            dqk = int(di * self.xlstm.qk_frac)
            m = d * (2 * dqk + 2 * di) + di * d + 3 * self.n_heads * di
            s = 4 * d * d + d * d + 2 * int(
                d * self.xlstm.expand_s_ffn) * d
            g = self.xlstm.m_per_group + self.xlstm.s_per_group
            groups = self.n_layers // g
            return total + groups * (self.xlstm.m_per_group * m
                                     + self.xlstm.s_per_group * s)
        layers = self.enc_layers + self.dec_layers if self.is_encdec \
            else self.n_layers
        cross = att if self.is_encdec else 0
        return total + layers * (att + ffn) + self.dec_layers * cross

    def active_param_count(self) -> int:
        """Activated params per token (MoE-aware) for MODEL_FLOPS=6*N*D."""
        if self.family == "moe" and self.moe:
            d = self.d_model
            att = d * (self.n_heads + 2 * self.n_kv) * self.d_head \
                + self.n_heads * self.d_head * d
            ffn = 3 * d * self.moe.d_ff_expert * self.moe.top_k
            if self.moe.n_shared:
                ffn += 3 * d * self.moe.d_ff_shared
            return self.vocab * d * 2 + self.n_layers * (att + ffn)
        return self.param_count()
