"""Feed-forward blocks: dense (SwiGLU / squared-ReLU / GELU) and MoE.

MoE uses capacity-based per-expert token selection (expert-capacity
top-C over router gates).  The capacity cut is *implicit vector masking*
over a data-dependent (inductive) production rate: each expert consumes a
different, router-determined number of tokens per step — the FGOP F2
analog at the distributed level (see DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.sharding import constrain
from repro.models.layers import dense_init


def init_mlp(key, d: int, f: int, act: str):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"wi": dense_init(ks[0], (d, f)),
                "wg": dense_init(ks[1], (d, f)),
                "wo": dense_init(ks[2], (f, d))}
    return {"wi": dense_init(ks[0], (d, f)),
            "wo": dense_init(ks[2], (f, d))}


def mlp(p, x, act: str):
    dt = x.dtype
    if act == "swiglu":
        hi = x @ p["wi"].astype(dt)
        hg = x @ p["wg"].astype(dt)
        h = jax.nn.silu(hg) * hi
    elif act == "sq_relu":
        h = x @ p["wi"].astype(dt)
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    return h @ p["wo"].astype(dt)


# ---------------- MoE ----------------

def init_moe(key, d: int, cfg_moe):
    e = cfg_moe.e_pad
    f = cfg_moe.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "wi": dense_init(ks[1], (e, d, f)),
        "wg": dense_init(ks[2], (e, d, f)),
        "wo": dense_init(ks[3], (e, f, d)),
    }
    if cfg_moe.n_shared:
        p["shared"] = init_mlp(ks[4], d, cfg_moe.d_ff_shared, "swiglu")
    return p


def moe_a2a(p, x, cfg_moe, norm_w=None, eps=1e-5):
    """Explicit expert parallelism via shard_map (the production EP path;
    `moe_dispatch='a2a'`).

    Key observation: under DP+TP the token activations are *replicated*
    across the 'model' axis, so every model shard can route its OWN
    experts' tokens locally — the dispatch needs NO communication at all
    (GSPMD's dense lowering instead all-reduces the full token tensor).
    Only the combine is collective: each model shard contributes partial
    outputs for the experts it owns -> one psum over 'model'.  Expert
    weights stay FSDP-sharded and are all-gathered over 'data' per layer
    (overlappable; bytes = weights/16, tiny next to the token tensor).

    Per-layer collective bytes (dbrx, per device):
      dense-GSPMD:  all-reduce(T_loc x D f32) interleaved with gathers of
                    the full dispatched (E, C, D) tensor  ->  ~220 GB
      a2a/EP:       psum(T_loc x D) + weight gather       ->  ~3.3 GB
    """
    mesh = shd.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        out, aux = moe(p, x, cfg_moe)
        return out, aux

    b, s, d = x.shape
    e_pad = cfg_moe.e_pad
    m = shd.mesh_axis_size("model")
    assert e_pad % m == 0, (e_pad, m)
    e_loc = e_pad // m
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    for a in data_axes:
        dsize *= shd.mesh_axis_size(a)
    # tokens dim to shard over the data axes: batch if divisible (the
    # natural DP layout), else sequence (gradient-accumulation microbatches
    # can make B_local < data size; S always divides at our shapes)
    if b % max(dsize, 1) == 0 or not data_axes:
        tok_spec = P(data_axes or None, None, None)
    elif s % dsize == 0:
        tok_spec = P(None, data_axes, None)
    else:  # fall back to the GSPMD dense path
        return moe(p, x, cfg_moe)

    def local(xl, router, wi, wg, wo):
        """Per-device body. xl: (B_loc, S, D) local tokens (replicated
        over 'model'); router replicated; wi/wg/wo: this model shard's
        experts, FSDP-sharded on D -> gathered over 'data'."""
        wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        bl, sl, _ = xl.shape
        t = bl * sl
        k = cfg_moe.top_k
        e_real = cfg_moe.n_experts
        cap = max(8, int(cfg_moe.capacity_factor * k * t / e_real))
        cap = min(cap, t)

        xt = xl.reshape(t, d)
        logits = (xt @ router.astype(xl.dtype)).astype(jnp.float32)
        if e_pad != e_real:
            pad_mask = jnp.arange(e_pad) < e_real
            logits = jnp.where(pad_mask[None, :], logits, -1e30)
        gates = jax.nn.softmax(logits, axis=-1)              # (T, E)
        topv, topi = jax.lax.top_k(gates, k)
        elig = jnp.zeros_like(gates).at[
            jnp.arange(t)[:, None], topi].set(topv)          # (T, E)

        # my experts only: dispatch is local (tokens replicated on model)
        my0 = jax.lax.axis_index("model") * e_loc
        elig_my = jax.lax.dynamic_slice(elig, (0, my0), (t, e_loc))
        gv, gi = jax.lax.top_k(elig_my.T, cap)               # (e_loc, C)
        xe = xt[gi]                                          # (e_loc,C,D)

        dt = xl.dtype
        hi = jnp.einsum("ecd,edf->ecf", xe, wi.astype(dt))
        hg = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))
        he = jax.nn.silu(hg) * hi
        ye = jnp.einsum("ecf,efd->ecd", he, wo.astype(dt))
        ye = ye * gv[..., None].astype(dt)

        part = jnp.zeros((t, d), jnp.float32).at[gi.reshape(-1)].add(
            ye.reshape(-1, d).astype(jnp.float32))
        out = jax.lax.psum(part, "model").astype(dt)         # combine

        # aux loss from global stats (cheap scalars)
        pe = jnp.mean(gates[:, :e_real], axis=0)
        fe = jnp.mean((elig[:, :e_real] > 0).astype(jnp.float32), axis=0)
        if data_axes:
            pe = jax.lax.pmean(pe, data_axes)
            fe = jax.lax.pmean(fe, data_axes)
        aux = e_real * jnp.sum(fe * pe)
        return out.reshape(bl, sl, d), aux

    in_specs = (
        tok_spec,                                          # x
        P(None, None),                                     # router
        P("model", "data", None),                          # wi (E,D,F)
        P("model", "data", None),                          # wg
        P("model", None, "data"),                          # wo (E,F,D)
    )
    fn = shd.shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=(tok_spec, P()),
        check_vma=False)
    out, aux = fn(x, p["router"], p["wi"], p["wg"], p["wo"])
    if "shared" in p:       # always-on shared experts: plain GSPMD path
        out = out + mlp(p["shared"], x, "swiglu")
    return out, aux


def moe(p, x, cfg_moe, *, deterministic_capacity: int | None = None,
        dispatch: str = "dense"):
    """x: (B,S,D) -> (B,S,D).  Expert-capacity routing:

    1. router logits -> softmax gates (T, E); padded experts masked off.
    2. token-choice top-k defines eligibility (gate kept only for chosen).
    3. each expert gathers its top-C eligible tokens (capacity C).
    4. FFN per expert (vmap -> einsum over E), weighted scatter-add back.
    """
    b, s, d = x.shape
    t = b * s
    e_real, e_pad, k = cfg_moe.n_experts, cfg_moe.e_pad, cfg_moe.top_k
    cap = deterministic_capacity or max(
        8, int(cfg_moe.capacity_factor * k * t / e_real))
    cap = min(cap, t)

    xt = x.reshape(t, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    if e_pad != e_real:
        pad_mask = jnp.arange(e_pad) < e_real
        logits = jnp.where(pad_mask[None, :], logits, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)                  # (T, E)

    # token-choice top-k eligibility
    topv, topi = jax.lax.top_k(gates, k)                     # (T, k)
    elig = jnp.zeros_like(gates).at[
        jnp.arange(t)[:, None], topi].set(topv)              # (T, E)

    # expert-choice capacity: each expert takes its top-C eligible tokens
    gv, gi = jax.lax.top_k(elig.T, cap)                      # (E, C)
    xe = xt[gi]                                              # (E, C, D)
    if dispatch == "sharded":
        # keep the dispatched tokens expert-sharded (EP over 'model'): the
        # gather becomes the all-to-all-style dispatch, expert FFN compute
        # never leaves the expert shard
        xe = constrain(xe, "experts", None, None)
    elif dispatch == "ep2d":
        # 2D dispatch: experts over 'model', capacity over 'data' — each
        # device owns a (E/16, C/16) tile of the dispatched tokens, so
        # neither the token gather nor the expert compute replicates
        xe = constrain(xe, "experts", "expert_cap", None)

    dt = x.dtype
    hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    hg = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    he = jax.nn.silu(hg) * hi
    ye = jnp.einsum("ecf,efd->ecd", he, p["wo"].astype(dt))  # (E, C, D)
    ye = ye * gv[..., None].astype(dt)                       # gate weight
    if dispatch == "sharded":
        ye = constrain(ye, "experts", None, None)
    elif dispatch == "ep2d":
        ye = constrain(ye, "experts", "expert_cap", None)

    out = jnp.zeros((t, d), dt).at[gi.reshape(-1)].add(
        ye.reshape(-1, d))
    if dispatch in ("sharded", "rs", "ep2d"):
        # combine: partial sums per expert shard reduce-scatter into the
        # token (batch) sharding instead of a replicated all-reduce
        # ('rs' = combine-only: no dispatch-side constraints)
        out = constrain(out, "batch", None)
    if cfg_moe.n_shared:
        out = out + mlp(p["shared"], xt, "swiglu")
    # aux load-balancing loss (Switch-style): E * sum(f_e * p_e)
    pe = jnp.mean(gates[:, :e_real], axis=0)                 # mean gate
    fe = jnp.mean((elig[:, :e_real] > 0).astype(jnp.float32), axis=0)
    aux = e_real * jnp.sum(fe * pe)
    return out.reshape(b, s, d), aux
