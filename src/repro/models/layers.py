"""Shared layer primitives: norms, RoPE, inits (pure pytree, no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32)
                            / d_head))


def apply_rope(x, positions, theta: float = 1e6):
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    inv = jnp.asarray(rope_freqs(dh, theta))            # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, Dh/2)
    if x.ndim == ang.ndim + 1:                          # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def softmax_xent(logits, labels, mask=None):
    """Token-level CE. logits (..., V) f32; labels (...,) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if mask is not None:
        loss = loss * mask
        return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
