"""Batched Cholesky — the paper's running FGOP example (Fig. 5/13).

One Pallas grid cell = one REVEL "lane": a whole small matrix resident in
VMEM.  Inside, the three regions are fused per outer iteration k:

  point  region (non-critical): rsqrt(a[k,k])            — VPU scalar
  vector region               : scale column k            — VPU, masked
  matrix region (critical)    : rank-1 trailing update    — MXU-shaped,
                                 triangular (inductive) domain, masked

The ordered dependences point->vector->matrix and matrix->point(next k)
never leave VMEM — the carry of the fori_loop is REVEL's FIFO.  The
trailing update's iteration domain shrinks with k: an RI stream, realized
as implicit masks (paper Feature 4) instead of scalar leftovers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def _cholesky_kernel(a_ref, l_ref, *, n: int):
    a = a_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def outer(k, a):
        # ---- point region (non-critical: rsqrt) ----
        akk = a[k, k]
        inv = jax.lax.rsqrt(akk)
        # ---- vector region: scale column k below the diagonal ----
        col = a[:, k] * inv
        col = jnp.where(rows >= k, col, 0.0)      # implicit mask (F4)
        # ---- matrix region (critical): masked rank-1 update ----
        # inductive domain: rows>k & cols>k — the RI stream's mask
        live = rows > k
        upd = col[:, None] * col[None, :]
        mask = live[:, None] & live[None, :]
        a = a - jnp.where(mask, upd, 0.0)
        # write the finished L column back (ordered dep to next k)
        a = a.at[:, k].set(jnp.where(rows >= k, col, a[:, k]))
        return a

    a = jax.lax.fori_loop(0, n, outer, a)
    tri = rows[:, None] >= rows[None, :]
    l_ref[0] = jnp.where(tri, a, 0.0)


def cholesky_pallas(a: jax.Array, *, interpret: bool | None = None
                    ) -> jax.Array:
    """a: (B, N, N) SPD -> L lower-triangular with a = L @ L.T."""
    b, n, n2 = a.shape
    assert n == n2, "square matrices required"
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_cholesky_kernel, n=n),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, n, n), a.dtype),
        interpret=interpret,
    )(a)
