"""Pallas TPU kernels for the REVEL/FGOP reproduction.

Layout: <name>.py holds the pl.pallas_call + BlockSpec kernel, ops.py the
jit'd backend-dispatching wrappers, ref.py the pure-jnp oracles.
"""
from repro.kernels.ops import (  # noqa: F401
    cholesky,
    trisolve,
    qr,
    svd,
    gemm,
    fir,
    fft,
    flash_attention,
    ssm_scan,
)
