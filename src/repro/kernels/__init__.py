"""Pallas TPU kernels for the REVEL/FGOP reproduction, plus the kernel
registry — the single enumeration point for tests, benchmarks, and serve.

Layout: <name>.py holds the pl.pallas_call + BlockSpec kernel, ops.py the
jit'd backend-dispatching wrappers, ref.py the pure-jnp oracles, and
repro.pipelines the fused multi-stage solver chains.  Every kernel and
pipeline registers a ``KernelSpec`` binding together its Pallas entry
point, its oracle, its characteristic stream descriptor
(repro.core.streams — the paper's F2-F4 classification), and a
deterministic case generator, so consumers iterate ``specs()`` instead of
hand-importing each kernel:

    for spec in repro.kernels.specs():
        args = spec.make_case(rng, n)
        assert close(spec.run_pallas(*args), spec.run_oracle(*args))

The registry is built lazily on first access: repro.pipelines imports
kernel modules, so eager registration here would be circular.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import sample_spd as _spd
from repro.kernels.ops import (  # noqa: F401
    cholesky,
    trisolve,
    qr,
    svd,
    gemm,
    fir,
    fft,
    flash_attention,
    ssm_scan,
)

__all__ = ["cholesky", "trisolve", "qr", "svd", "gemm", "fir", "fft",
           "flash_attention", "ssm_scan", "KernelSpec", "Variant",
           "Coalescer", "register", "get", "names", "specs",
           "StageSpec", "DagSpec", "register_dag", "get_dag",
           "dag_names", "dag_specs"]


@dataclasses.dataclass(frozen=True)
class Coalescer:
    """Cross-shape ragged-batching adapter for a served pipeline.

    Under overload the mux may pad a *small* job into a *larger*
    compatible bucket's free lanes instead of benign filler — one fewer
    grid launch at the price of padded-lane FLOPs.  The spec declares
    how (the engine never guesses):

    ``compatible(small_key, big_key)`` — both are SolveJob shape keys
    (per-arg ``(shape, dtype_str)`` tuples); True iff a small job can be
    embedded into a big-bucket lane AND the embedding is exact (the
    small solution is recoverable from the big one).
    ``embed(args, big_shapes)`` — per-lane small arrays -> per-lane
    arrays at the big bucket's shapes.
    ``extract(out_lane, small_shapes)`` — slice the small job's answer
    back out of the big lane's result.
    """

    compatible: Callable
    embed: Callable
    extract: Callable


@dataclasses.dataclass(frozen=True)
class Variant:
    """One performance variant of a registered kernel/pipeline.

    ``fn`` is a batched entry point with the same calling convention as
    the spec's ``pallas`` (serving binds per-pipeline options into it);
    ``when(shapes, dtypes)`` — per-lane (unbatched) arg shapes and numpy
    dtypes — is the applicability predicate the dispatcher evaluates in
    registration order (first match wins, ``base`` otherwise).

    A variant that changes the calling convention (e.g. split-complex
    MMSE takes 4 planes instead of one expanded matrix) carries its own
    ``oracle`` (batched run_oracle-style adapter), ``filler`` (benign
    padding lane), and ``make_case``; ``None`` inherits the spec's.
    ``sizes`` is the variant's default bench/test sweep and ``flops`` an
    optional closed-form model-FLOP count over per-lane shapes (feeds
    BENCH_pipelines.json).
    """

    name: str
    fn: Callable
    when: Callable
    oracle: Callable | None = None
    filler: Callable | None = None
    make_case: Callable | None = None
    sizes: tuple[int, ...] = ()
    flops: Callable | None = None

    def model_flops(self, shapes) -> float:
        """Closed-form model FLOPs for ONE lane at per-lane arg shapes —
        the launch-cost model's workload term.  Falls back to the first
        arg's element count when the variant declares no flops model, so
        a cost is always orderable (bigger problems price higher)."""
        shapes = tuple(tuple(s) for s in shapes)
        if self.flops is not None:
            return float(self.flops(shapes))
        if shapes and shapes[0]:
            return float(np.prod(shapes[0]))
        return 1.0


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel or pipeline.

    ``pallas`` is the raw Pallas entry point (kwargs like block sizes or
    ``sweeps`` remain available to callers); ``run_pallas``/``run_oracle``
    are signature-aligned adapters used for uniform oracle checking — both
    accept the arrays produced by ``make_case(rng, n)`` and return
    comparable pytrees.  ``stream`` maps a problem size to the kernel's
    characteristic StreamDescriptor (paper F2-F4); ``sizes`` is the
    default sweep for registry-driven tests/benchmarks.

    ``filler`` is the spec's benign-padding descriptor for lane-pooled
    serving: ``filler(shapes, dtypes)`` — per-lane (unbatched) arg shapes
    and dtypes — returns one well-conditioned problem (e.g. identity
    system, zero rhs) whose result padded lanes can safely discard.  The
    serving engines pad exclusively from this declaration; a spec without
    one cannot be served padded.

    ``variants`` is the spec's performance-variant table; consumers that
    execute a spec (serving engines, benchmarks) go through
    :meth:`dispatch` / :meth:`dispatch_key` so large or split-complex
    jobs transparently land on the fast entry point.

    ``coalesce`` is the spec's optional :class:`Coalescer` — the
    declared cross-shape embedding that lets the serving mux ragged-
    batch a small job into a larger bucket's free lanes under overload.
    """

    name: str
    pallas: Callable
    oracle: Callable
    run_pallas: Callable
    run_oracle: Callable
    make_case: Callable
    stream: Callable
    sizes: tuple[int, ...]
    rtol: float = 1e-4
    kind: str = "kernel"          # "kernel" | "pipeline"
    filler: Callable | None = None
    variants: tuple[Variant, ...] = ()
    flops: Callable | None = None
    coalesce: Coalescer | None = None
    serve_oracle: Callable | None = None
    """Optional serving-side ground truth overriding ``run_oracle`` for
    per-job spot checks (:meth:`run_oracle_lane`): needed when the
    served output is not what the conformance faces compare — e.g.
    ``svd_factor`` serves sign/order-ambiguous packed factors, so its
    serving oracle is a standalone run of the kernel itself
    (bit-identity) while ``run_pallas``/``run_oracle`` check the sorted
    spectrum + reconstruction."""

    @property
    def base(self) -> Variant:
        """The spec's own entry point as the fallback Variant."""
        oracle = self.serve_oracle if self.serve_oracle is not None \
            else self.run_oracle
        return Variant(name="base", fn=self.pallas, when=lambda s, d: True,
                       oracle=oracle, filler=self.filler,
                       make_case=self.make_case, sizes=self.sizes,
                       flops=self.flops)

    def dispatch_key(self, shapes, dtypes) -> Variant:
        """Pick the variant for per-lane (unbatched) arg shapes/dtypes —
        the serving engines' entry (a shape bucket IS such a key)."""
        dtypes = tuple(np.dtype(d) for d in dtypes)
        shapes = tuple(tuple(s) for s in shapes)
        for v in self.variants:
            if v.when(shapes, dtypes):
                return v
        return self.base

    def dispatch(self, *args) -> Variant:
        """Pick the variant for BATCHED kernel args (the ``pallas``
        calling convention used by benchmarks and direct callers)."""
        return self.dispatch_key(
            tuple(np.shape(a)[1:] for a in args),
            tuple(np.asarray(a).dtype if not hasattr(a, "dtype") else a.dtype
                  for a in args))

    def model_flops(self, shapes, dtypes) -> float:
        """Model FLOPs of one lane at per-lane shapes under whichever
        variant :meth:`dispatch_key` would route it to — the registry
        side of the serving cost model (calibration to wall-clock lives
        in :class:`repro.serve.cost.CostModel`)."""
        return self.dispatch_key(shapes, dtypes).model_flops(shapes)

    def run_oracle_lane(self, *args):
        """Oracle answer for ONE unbatched problem: adds the batch dim,
        runs the dispatched variant's oracle adapter (so split-complex /
        blocked jobs check against the right ground truth), strips it
        again — the serving stack's per-job spot check."""
        import jax
        variant = self.dispatch_key(
            tuple(np.shape(a) for a in args),
            tuple(np.asarray(a).dtype for a in args))
        oracle = variant.oracle if variant.oracle is not None \
            else self.run_oracle
        batched = [np.asarray(a)[None] for a in args]
        return jax.tree.map(lambda x: np.asarray(x)[0], oracle(*batched))


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One named stage of a pipeline DAG.

    ``pipeline`` names the registered ``kind="pipeline"`` KernelSpec that
    serves the stage — the stage's entry point, variants, filler,
    coalescer, and cost model are all the pipeline's own, so a DAG stage
    rides every serving mechanism (padding, coalescing, sharding, fault
    supervision) a plain job does.  ``bind(args, outs)`` maps the DAG
    job's per-lane input args plus the completed producer outputs (by
    stage name) to this stage's per-lane args — the declared dataflow.
    ``consumes`` lists producer stage names; the DagSpec's ``deps``
    (:class:`repro.core.dependence.OrderedDep`) must carry exactly these
    edges.  ``stream`` maps the DAG's problem size to the
    StreamDescriptor of the stage's output handoff buffer (how results
    travel between launches when the stage is NOT fused with its
    consumer).  ``flops(shapes)`` — per-lane DAG input arg shapes — is
    the stage's model-FLOP weight for criticality planning, and
    ``transcendental`` marks stages dominated by non-MXU special
    functions (excluded from threshold criticality by
    :func:`repro.core.criticality.plan_split`).  ``oracle`` optionally
    overrides the stage pipeline's ``run_oracle_lane`` for per-stage
    ground truth (stages with ambiguous outputs, e.g. SVD factors,
    leave it None and are checked by bit-identity against a standalone
    run instead)."""

    name: str
    pipeline: str
    bind: Callable
    consumes: tuple[str, ...] = ()
    stream: Callable | None = None
    oracle: Callable | None = None
    flops: Callable | None = None
    transcendental: bool = False

    def model_flops(self, shapes) -> float:
        if self.flops is None:
            return 1.0
        return float(self.flops(tuple(tuple(s) for s in shapes)))


@dataclasses.dataclass(frozen=True)
class DagSpec:
    """A served pipeline DAG: named stages + ordered producer→consumer
    edges, the registry's extension of KernelSpec from one entry point
    to a stage graph (``SolverMux.submit_dag`` executes it).

    ``stages`` is the stage-independent decomposition (one launch per
    stage, handoff through stage output buffers); ``chained`` is the
    optional lane-resident alternative where adjacent stages whose
    shapes allow it are fused into one ``pallas_call`` (VMEM handoff),
    reducing DAG depth.  Both lists are topologically ordered by
    declaration; a stage may only consume earlier stages.  ``deps``
    declares the staged edges as :class:`OrderedDep`s and must match the
    stages' ``consumes`` exactly (chained edges are derived from
    ``chained[i].consumes``).  The DAG's terminal output is the LAST
    stage's output.

    ``make_case(rng, n)`` builds one PER-LANE (unbatched) set of DAG
    input args — the ``submit_dag`` calling convention — and ``oracle``
    maps those args to the terminal output (ground truth for end-to-end
    checks, compared at ``rtol``).

    ``crit_threshold`` is the criticality knob: :meth:`criticality`
    weighs every stage's ``flops`` model and hands the shares to
    :func:`repro.core.criticality.plan_split` at this threshold —
    stages planned critical are admitted ahead of slack stages at equal
    deadline by the mux."""

    name: str
    stages: tuple[StageSpec, ...]
    deps: tuple["OrderedDep", ...]
    make_case: Callable
    oracle: Callable
    chained: tuple[StageSpec, ...] = ()
    crit_threshold: float = 0.25
    rtol: float = 1e-4

    def __post_init__(self):
        for stages, label in ((self.stages, "stages"),
                              (self.chained, "chained")):
            seen: set[str] = set()
            for s in stages:
                if s.name in seen:
                    raise ValueError(
                        f"dag {self.name!r}: duplicate {label} stage "
                        f"{s.name!r}")
                missing = [c for c in s.consumes if c not in seen]
                if missing:
                    raise ValueError(
                        f"dag {self.name!r}: stage {s.name!r} consumes "
                        f"{missing} before they are produced")
                seen.add(s.name)
        if not self.stages:
            raise ValueError(f"dag {self.name!r}: no stages")
        declared = {(d.producer, d.consumer) for d in self.deps}
        consumed = {(c, s.name) for s in self.stages for c in s.consumes}
        if declared != consumed:
            raise ValueError(
                f"dag {self.name!r}: OrderedDep edges {sorted(declared)} "
                f"do not match stage consumes {sorted(consumed)}")

    def stage_list(self, chained: bool = False) -> tuple[StageSpec, ...]:
        if chained:
            if not self.chained:
                raise ValueError(
                    f"dag {self.name!r} declares no chained stage list")
            return self.chained
        return self.stages

    def criticality(self, shapes, chained: bool = False):
        """(critical, slack) stage-name lists from the per-stage model-
        FLOP shares via ``plan_split`` at ``crit_threshold``."""
        from repro.core.criticality import RegionCost, plan_split
        costs = [RegionCost(s.name, self.__cost(s, shapes),
                            has_transcendental=s.transcendental)
                 for s in self.stage_list(chained)]
        return plan_split(costs, threshold=self.crit_threshold)

    @staticmethod
    def __cost(stage: StageSpec, shapes) -> float:
        return max(stage.model_flops(shapes), 1.0)

    def region_graph(self, shapes, chained: bool = False) -> "RegionGraph":
        """The DAG as a validated :class:`RegionGraph`, critical flags
        planned from the model-FLOP shares at these input shapes."""
        from repro.core.dependence import OrderedDep as _Dep
        from repro.core.dependence import Region, RegionGraph
        stages = self.stage_list(chained)
        crit, _ = self.criticality(shapes, chained)
        regions = [Region(s.name, fn=None, critical=s.name in crit)
                   for s in stages]
        deps = tuple(self.deps) if not chained else tuple(
            _Dep(c, s.name) for s in stages for c in s.consumes)
        return RegionGraph(regions=regions, deps=list(deps))


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """A servable token-decode workload: the registry's description of
    continuous-batching LM decode (:class:`repro.serve.decode.
    DecodeEngine`), the third traffic class next to solver pipelines
    (:class:`KernelSpec`) and stage DAGs (:class:`DagSpec`).

    Decode is not a ``pallas_call`` over a lane group — its unit of
    dispatch is one SPMD decode *step* over the slot pool — so it gets
    its own registry rather than a ``kind`` on KernelSpec (benchmarks
    and padding machinery iterate ``specs()`` expecting ``make_case`` /
    ``run_pallas``, which decode deliberately does not have).  What the
    mux needs to price and admit decode traffic lives here instead:
    the phase names (maxtext's prefill / insert / generate microbench
    shape) and a closed-form per-token FLOP model over the serving
    :class:`~repro.models.config.ArchConfig` — the decode analogue of
    ``Variant.model_flops``."""

    name: str
    phases: tuple[str, ...] = ("prefill", "insert", "generate")
    description: str = ""
    flops_fn: Callable | None = None
    """Optional override: ``flops_fn(cfg) -> float`` per-token FLOPs."""

    def token_flops(self, cfg) -> float:
        """Model FLOPs to decode ONE token on one slot: ~2 FLOPs per
        weight touched (QKVO projections, the FFN at the config's
        arity, the LM head) — attention over the live cache is
        position-dependent and deliberately excluded, matching the
        closed-form (shape-only) convention of the solver FLOP
        models."""
        if self.flops_fn is not None:
            return float(self.flops_fn(cfg))
        d = cfg.d_model
        attn = 2 * d * (cfg.n_heads + cfg.n_kv) * cfg.d_head \
            + 2 * d * cfg.n_heads * cfg.d_head
        ffn_mats = 3 if cfg.act == "swiglu" else 2
        ffn = ffn_mats * 2 * d * cfg.d_ff
        return float(cfg.n_layers * (attn + ffn) + 2 * d * cfg.vocab)


_REGISTRY: dict[str, KernelSpec] = {}
_DAGS: dict[str, DagSpec] = {}
_DECODES: dict[str, DecodeSpec] = {}
_BUILT = False
_LOCK = threading.Lock()


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"duplicate kernel registration: {spec.name!r}")
    _REGISTRY[spec.name] = spec
    return spec


def register_dag(spec: DagSpec) -> DagSpec:
    if spec.name in _DAGS:
        raise ValueError(f"duplicate dag registration: {spec.name!r}")
    for s in spec.stages + spec.chained:
        pipe = _REGISTRY.get(s.pipeline)
        if pipe is None or pipe.kind != "pipeline":
            raise ValueError(
                f"dag {spec.name!r}: stage {s.name!r} references "
                f"{s.pipeline!r}, which is not a registered pipeline")
    _DAGS[spec.name] = spec
    return spec


def register_decode(spec: DecodeSpec) -> DecodeSpec:
    if spec.name in _DECODES:
        raise ValueError(f"duplicate decode registration: {spec.name!r}")
    _DECODES[spec.name] = spec
    return spec


def _build() -> None:
    """Populate the registry (idempotent, thread-safe, atomic: a failed
    build clears the partial state so the root-cause error — not a
    misleading duplicate-registration one — resurfaces on every call)."""
    global _BUILT
    with _LOCK:
        if _BUILT:
            return
        try:
            _register_all()
        except BaseException:
            _REGISTRY.clear()
            _DAGS.clear()
            _DECODES.clear()
            raise
        _BUILT = True


def _register_all() -> None:
    from repro.core.streams import inductive, rect
    from repro.kernels import ref
    from repro.kernels.attention import flash_attention_pallas
    from repro.kernels.cholesky import cholesky_pallas
    from repro.kernels.fft import fft_pallas
    from repro.kernels.fir import fir_pallas
    from repro.kernels.qr import qr_pallas
    from repro.kernels.ssm_scan import ssm_scan_pallas
    from repro.kernels.svd import svd_pallas
    from repro.kernels.trisolve import trisolve_pallas
    from repro import pipelines as pp

    tri_ri = lambda n: inductive(outer_trip=n, inner_base=n,
                                 inner_stretch=-1)

    # ---------------- factorizations ----------------
    register(KernelSpec(
        name="cholesky", pallas=cholesky_pallas, oracle=ref.cholesky,
        run_pallas=lambda a: cholesky_pallas(a),
        run_oracle=lambda a: ref.cholesky(a),
        make_case=lambda rng, n: (jnp.asarray(_spd(rng, 2, n)),),
        stream=tri_ri, sizes=(8, 12, 16, 24, 32)))

    def _tri_case(rng, n):
        l = np.linalg.cholesky(_spd(rng, 2, n))
        b = rng.standard_normal((2, n, 3)).astype(np.float32)
        return jnp.asarray(l), jnp.asarray(b)

    register(KernelSpec(
        name="trisolve", pallas=trisolve_pallas, oracle=ref.trisolve,
        run_pallas=lambda l, b: trisolve_pallas(l, b, lower=True),
        run_oracle=lambda l, b: ref.trisolve(l, b, lower=True),
        make_case=_tri_case, stream=tri_ri, sizes=(8, 12, 16, 24, 32),
        rtol=1e-3))

    register(KernelSpec(
        name="qr", pallas=qr_pallas, oracle=ref.qr,
        run_pallas=lambda a: qr_pallas(a),
        run_oracle=lambda a: ref.qr(a),
        make_case=lambda rng, n: (jnp.asarray(
            rng.standard_normal((2, n + 4, n)).astype(np.float32)),),
        stream=tri_ri, sizes=(8, 12, 16, 24)))

    def _svd_adapter(a):
        """Reconstruction-based oracle adapter (ROADMAP registry-coverage
        item): check the sorted spectrum AND that U diag(S) V^T rebuilds
        A — one-sided Jacobi guarantees A V = U S, so reconstruction is
        exact up to float32 rounding and catches U/V corruption that a
        singular-values-only check cannot."""
        u, s, v = svd_pallas(a, sweeps=14)
        recon = jnp.einsum("bmn,bn,bkn->bmk", u, s, v)
        return jnp.sort(s, axis=-1)[:, ::-1], recon

    # dtype-relative tolerance: one-sided Jacobi converges to working
    # precision, so the reconstruction check budget is a small multiple
    # of sqrt(eps(float32)) (~3.5e-4) rather than a hard-coded constant
    # that silently loosens or breaks if the kernel dtype changes.
    svd_rtol = float(4.0 * np.sqrt(np.finfo(np.float32).eps))

    register(KernelSpec(
        name="svd", pallas=svd_pallas, oracle=ref.svd_vals,
        run_pallas=_svd_adapter,
        run_oracle=lambda a: (ref.svd_vals(a), a),
        make_case=lambda rng, n: (jnp.asarray(
            rng.standard_normal((2, n + 4, n)).astype(np.float32)),),
        stream=lambda n: inductive(outer_trip=n, inner_base=n - 1,
                                   inner_stretch=-1),
        sizes=(8, 12, 16), rtol=svd_rtol))

    # ---------------- dense / DSP ----------------
    from repro.kernels import ops as _ops
    from repro.kernels.gemm import gemm_pallas

    register(KernelSpec(
        name="gemm", pallas=gemm_pallas,
        oracle=ref.gemm,
        run_pallas=lambda x, y: _ops.gemm(x, y, backend="pallas"),
        run_oracle=lambda x, y: ref.gemm(x, y),
        make_case=lambda rng, n: (
            jnp.asarray(rng.standard_normal((4 * n, 4 * n))
                        .astype(np.float32)),
            jnp.asarray(rng.standard_normal((4 * n, 4 * n))
                        .astype(np.float32))),
        stream=lambda n: rect(4 * n, 4 * n), sizes=(16, 32)))

    def _fir_case(rng, n):
        x = rng.standard_normal((16 * n,)).astype(np.float32)
        h = rng.standard_normal((9,)).astype(np.float32)
        h = (h + h[::-1]) / 2
        return jnp.asarray(x), jnp.asarray(h)

    register(KernelSpec(
        name="fir", pallas=fir_pallas, oracle=ref.fir,
        run_pallas=lambda x, h: _ops.fir(x, h, backend="pallas"),
        run_oracle=lambda x, h: ref.fir(x, h),
        make_case=_fir_case,
        stream=lambda n: rect(16 * n - 8, 9), sizes=(8, 16, 32)))

    register(KernelSpec(
        name="fft", pallas=fft_pallas, oracle=ref.fft,
        run_pallas=lambda xr, xi: fft_pallas(xr, xi),
        run_oracle=lambda xr, xi: ref.fft(xr, xi),
        make_case=lambda rng, n: (
            jnp.asarray(rng.standard_normal((2, n)).astype(np.float32)),
            jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))),
        stream=lambda n: rect(int(np.log2(n)), n // 2),
        sizes=(64, 128, 256, 1024), rtol=1e-3))

    # ---------------- LM-side ----------------
    def _attn_case(rng, n):
        s, d = 128, 64
        mk = lambda sc: jnp.asarray(
            (rng.standard_normal((1, 2, s, d)) * sc).astype(np.float32))
        return mk(0.3), mk(0.3), mk(1.0)

    register(KernelSpec(
        name="flash_attention", pallas=flash_attention_pallas,
        oracle=ref.mha,
        run_pallas=lambda q, k, v: flash_attention_pallas(
            q, k, v, causal=True),
        run_oracle=lambda q, k, v: ref.mha(q, k, v, causal=True),
        make_case=_attn_case,
        stream=lambda n: inductive(outer_trip=n, inner_base=1,
                                   inner_stretch=1),
        sizes=(128,), rtol=1e-3))

    def _ssm_case(rng, n):
        b, h, nn, p = 1, 2, 8, 4
        return (jnp.asarray(rng.standard_normal((b, h, n, p))
                            .astype(np.float32)),
                jnp.asarray(rng.uniform(0.8, 0.999, (b, h, n))
                            .astype(np.float32)),
                jnp.asarray(rng.standard_normal((b, n, nn))
                            .astype(np.float32)),
                jnp.asarray(rng.standard_normal((b, n, nn))
                            .astype(np.float32)))

    def _ssm_oracle(x, a, b, c):
        y, hf = ref.ssm_scan(jnp.moveaxis(x, 1, 2),
                             jnp.moveaxis(a, 1, 2), b, c)
        return jnp.moveaxis(y, 1, 2), hf

    register(KernelSpec(
        name="ssm_scan", pallas=ssm_scan_pallas, oracle=ref.ssm_scan,
        run_pallas=lambda x, a, b, c: ssm_scan_pallas(
            x, a, b, c, chunk=16),
        run_oracle=_ssm_oracle,
        make_case=_ssm_case,
        stream=lambda n: rect(n // 16, 16), sizes=(64,), rtol=1e-3))

    # ---------------- fused solver pipelines ----------------
    def _identity_system_filler(shapes, dtypes):
        """Benign padding lane for (matrix, rhs) solver pipelines: an
        identity(-embedded) matrix and a zero right-hand side.  Works for
        square SPD systems (cholesky_solve) and tall least-squares /
        channel matrices (qr_solve, mmse_equalize): eye(m, n) is full
        rank with unit singular values, so padded lanes stay perfectly
        conditioned and solve to exactly zero."""
        (m, n), rhs_shape = shapes
        return (np.eye(m, n, dtype=dtypes[0]),
                np.zeros(rhs_shape, dtype=dtypes[1]))

    # Cross-shape coalescing for (matrix, rhs) solver pipelines: embed
    # the small problem block-diagonally —
    #     A_big = [[A, 0], [0, I]],  b_big = [[b, 0], [0, 0]]
    # with A in the top-left (ms, ns) corner, an identity block on the
    # trailing (N - ns) columns placed BELOW A's rows (rows ms..), and b
    # zero-padded.  The blocks touch disjoint rows, so the factor /
    # least-squares / MMSE solution of the big system is exactly
    # block-separable: x_big[:ns, :ks] IS the small solution — bit-
    # identical in float (the padded zeros contribute exact +0 terms),
    # which tests/test_overload.py pins.  Requires M - ms >= N - ns so
    # the identity block fits below A (square systems: always; tall
    # m = n + c systems: same overhang c).
    def _solver_coalesce_compatible(small_key, big_key):
        if len(small_key) != 2 or len(big_key) != 2:
            return False                     # e.g. 4-plane split-complex
        (sa, sda), (sb, sdb) = small_key
        (ba, bda), (bb, bdb) = big_key
        if (sda, sdb) != (bda, bdb):
            return False
        if any(len(s) != 2 for s in (sa, sb, ba, bb)):
            return False
        (ms, ns), (M, N) = sa, ba
        ks, K = sb[1], bb[1]
        if sb[0] != ms or bb[0] != M:        # rhs rows ride the matrix
            return False
        return (ms <= M and ns <= N and ks <= K
                and (ms, ns, ks) != (M, N, K)
                and M - ms >= N - ns)

    def _solver_coalesce_embed(args, big_shapes):
        a, b = (np.asarray(x) for x in args)
        (M, N), (_, K) = big_shapes
        ms, ns = a.shape
        big_a = np.zeros((M, N), dtype=a.dtype)
        big_a[:ms, :ns] = a
        t = N - ns
        if t:
            big_a[ms:ms + t, ns:] = np.eye(t, dtype=a.dtype)
        big_b = np.zeros((M, K), dtype=b.dtype)
        big_b[:ms, :b.shape[1]] = b
        return big_a, big_b

    def _solver_coalesce_extract(out_lane, small_shapes):
        (_, ns), (_, ks) = small_shapes
        return np.asarray(out_lane)[:ns, :ks]

    _solver_coalescer = Coalescer(compatible=_solver_coalesce_compatible,
                                  embed=_solver_coalesce_embed,
                                  extract=_solver_coalesce_extract)

    def _blocked_when(shapes, dtypes):
        """Blocked factor applicability: two (matrix, rhs) args whose
        inner dimension reaches panel scale and tiles evenly (the
        pl.BlockSpec panels need n % bs == 0; bs in {32, 64})."""
        return (len(shapes) == 2 and len(shapes[0]) == 2
                and shapes[0][-1] >= 128 and shapes[0][-1] % 32 == 0)

    def _tiled_when(shapes, dtypes):
        """HBM-scale tiled applicability: two (matrix, rhs) args at
        n >= 512 tiling evenly into the (n, bs) DMA slabs (bs falls
        back 128 -> 64 -> 32, so n % 32 == 0 suffices — the same
        divisibility the blocked kernels need, ensuring NO n >= 512
        shape the registry can serve falls back to a whole-matrix VMEM
        kernel).  Listed BEFORE ``blocked`` in each variants table so
        large shapes leave VMEM-residency behind; the midrange stays on
        the blocked kernels."""
        return (len(shapes) == 2 and len(shapes[0]) == 2
                and shapes[0][-1] >= 512 and shapes[0][-1] % 32 == 0)

    # One lane and a narrow rhs keep the n >= 512 registry cases cheap
    # enough for CI's interpret-mode dispatch sweep while still proving
    # the HBM-resident path end to end.
    def _chol_tiled_case(rng, n):
        a = jnp.asarray(_spd(rng, 1, n))
        b = jnp.asarray(rng.standard_normal((1, n, 2)).astype(np.float32))
        return a, b

    def _tall_tiled_case(rng, n):
        a = jnp.asarray(rng.standard_normal((1, n + 16, n))
                        .astype(np.float32))
        b = jnp.asarray(rng.standard_normal((1, n + 16, 2))
                        .astype(np.float32))
        return a, b

    def _chol_solve_case(rng, n):
        a = jnp.asarray(_spd(rng, 2, n))
        b = jnp.asarray(rng.standard_normal((2, n, 3))
                        .astype(np.float32))
        return a, b

    def _chol_solve_flops(shapes):
        """Closed-form model: n^3/3 factor + 2 n^2 k substitutions."""
        (n, _), (_, k) = shapes
        return n ** 3 / 3.0 + 2.0 * n * n * k

    register(KernelSpec(
        name="cholesky_solve", pallas=pp.cholesky_solve_pallas,
        oracle=ref.cholesky_solve,
        run_pallas=lambda a, b: pp.cholesky_solve_pallas(a, b),
        run_oracle=lambda a, b: ref.cholesky_solve(a, b),
        make_case=_chol_solve_case, stream=tri_ri,
        sizes=(8, 12, 16, 24, 32), kind="pipeline",
        filler=_identity_system_filler,
        coalesce=_solver_coalescer,
        flops=_chol_solve_flops,
        variants=(
            Variant(name="tiled", fn=pp.cholesky_solve_tiled,
                    when=_tiled_when, make_case=_chol_tiled_case,
                    sizes=(512, 1024), flops=_chol_solve_flops),
            Variant(name="blocked", fn=pp.cholesky_solve_blocked,
                    when=_blocked_when, sizes=(128, 256),
                    flops=_chol_solve_flops))))

    def _qr_solve_case(rng, n):
        a = jnp.asarray(rng.standard_normal((2, n + 4, n))
                        .astype(np.float32))
        b = jnp.asarray(rng.standard_normal((2, n + 4, 2))
                        .astype(np.float32))
        return a, b

    def _qr_solve_flops(shapes):
        """Closed-form model: Householder 2(m n^2 - n^3/3) + rhs
        reflections 4 m n k + back substitution n^2 k."""
        (m, n), (_, k) = shapes
        return (2.0 * (m * n * n - n ** 3 / 3.0) + 4.0 * m * n * k
                + n * n * k)

    register(KernelSpec(
        name="qr_solve", pallas=pp.qr_solve_pallas,
        oracle=ref.qr_solve,
        run_pallas=lambda a, b: pp.qr_solve_pallas(a, b),
        run_oracle=lambda a, b: ref.qr_solve(a, b),
        make_case=_qr_solve_case, stream=tri_ri,
        sizes=(8, 12, 16, 24, 32), kind="pipeline",
        filler=_identity_system_filler,
        coalesce=_solver_coalescer,
        flops=_qr_solve_flops,
        variants=(
            Variant(name="tiled", fn=pp.qr_solve_tiled,
                    when=_tiled_when, make_case=_tall_tiled_case,
                    sizes=(512, 1024), flops=_qr_solve_flops),
            Variant(name="blocked", fn=pp.qr_solve_blocked,
                    when=_blocked_when, sizes=(128, 256),
                    flops=_qr_solve_flops))))

    def _mmse_case(rng, n):
        h = jnp.asarray(rng.standard_normal((2, n + 4, n))
                        .astype(np.float32))
        y = jnp.asarray(rng.standard_normal((2, n + 4, 2))
                        .astype(np.float32))
        return h, y

    def _mmse_flops(shapes):
        """Real-path model: Gram 2 m n^2 + matched filter 2 m n k +
        n^3/3 factor + 2 n^2 k substitutions (on whatever real/expanded
        shapes arrive)."""
        (m, n), (_, k) = shapes
        return (2.0 * m * n * n + 2.0 * m * n * k + n ** 3 / 3.0
                + 2.0 * n * n * k)

    def _mmse_split_when(shapes, dtypes):
        """Split-complex jobs present 4 planes (Hr, Hi, yr, yi)."""
        return len(shapes) == 4

    def _mmse_split_filler(shapes, dtypes):
        """Benign split-complex lane: identity real channel, zero
        imaginary part, zero observations -> x = 0 exactly."""
        (m, n), _, yr_shape, yi_shape = shapes
        return (np.eye(m, n, dtype=dtypes[0]),
                np.zeros((m, n), dtype=dtypes[1]),
                np.zeros(yr_shape, dtype=dtypes[2]),
                np.zeros(yi_shape, dtype=dtypes[3]))

    def _mmse_split_case(rng, n):
        m = n + 4
        mk = lambda *s: jnp.asarray(rng.standard_normal(s)
                                    .astype(np.float32))
        return (mk(2, m, n), mk(2, m, n), mk(2, m, 2), mk(2, m, 2))

    def _mmse_split_flops(shapes):
        """Split-complex model: stacked Gram 4 m n^2 + cross GEMM
        2 m n^2 + two stacked matched filters 8 m n k + the real-embedded
        (2n)^3/3 factor + 2 (2n)^2 k substitutions."""
        (m, n), _, (_, k), _ = shapes
        return (6.0 * m * n * n + 8.0 * m * n * k
                + (2 * n) ** 3 / 3.0 + 2.0 * (2 * n) ** 2 * k)

    register(KernelSpec(
        name="mmse_equalize", pallas=pp.mmse_equalize_pallas,
        oracle=ref.mmse_equalize,
        run_pallas=lambda h, y: pp.mmse_equalize_pallas(h, y,
                                                        sigma2=0.1),
        run_oracle=lambda h, y: ref.mmse_equalize(h, y, sigma2=0.1),
        make_case=_mmse_case, stream=tri_ri,
        sizes=(8, 12, 16, 24, 32), kind="pipeline",
        filler=_identity_system_filler,
        coalesce=_solver_coalescer,
        flops=_mmse_flops,
        variants=(
            Variant(name="split_complex",
                    fn=pp.mmse_equalize_split_pallas,
                    when=_mmse_split_when,
                    oracle=lambda hr, hi, yr, yi: ref.mmse_equalize_split(
                        hr, hi, yr, yi, sigma2=0.1),
                    filler=_mmse_split_filler,
                    make_case=_mmse_split_case,
                    sizes=(8, 16, 24),
                    flops=_mmse_split_flops),
            Variant(name="tiled", fn=pp.mmse_equalize_tiled,
                    when=_tiled_when, make_case=_tall_tiled_case,
                    sizes=(512, 1024), flops=_mmse_flops))))

    # ---------------- DAG stage pipelines (PUSCH + SVD-solve) ----------
    # Per-lane DAG geometry: A = n + 4 antennas, NF-point OFDM FFT, the
    # first P = 2n frequency bins carry pilots and the next K_SYMS carry
    # the data symbols the equalizer recovers.
    NFFT = 64
    K_SYMS = 2

    def _pusch_fft_case(rng, n):
        a = n + 4
        mk = lambda: jnp.asarray(rng.standard_normal((2, a, NFFT))
                                 .astype(np.float32))
        return mk(), mk()

    def _pusch_fft_filler(shapes, dtypes):
        return tuple(np.zeros(s, dtype=d) for s, d in zip(shapes, dtypes))

    def _pusch_fft_flops(shapes):
        a, nf = shapes[0]
        return 5.0 * a * nf * np.log2(nf)

    register(KernelSpec(
        name="pusch_fft", pallas=pp.pusch_fft_pallas,
        oracle=ref.pusch_fft,
        run_pallas=lambda xr, xi: pp.pusch_fft_pallas(xr, xi),
        run_oracle=lambda xr, xi: ref.pusch_fft(xr, xi),
        make_case=_pusch_fft_case,
        stream=lambda n: rect(2, n + 4, NFFT),
        sizes=(8, 12), rtol=1e-3, kind="pipeline",
        filler=_pusch_fft_filler, flops=_pusch_fft_flops))

    def _chanest_case(rng, n):
        p, a = 2 * n, n + 4
        xp = jnp.asarray(rng.standard_normal((2, n, p))
                         .astype(np.float32))
        yp = jnp.asarray(rng.standard_normal((2, a, p))
                         .astype(np.float32))
        return xp, yp

    def _chanest_filler(shapes, dtypes):
        """Benign pilot lane: orthonormal pilot rows, zero observation
        -> Gram = I + ridge, H = 0 exactly."""
        (n, p), yp_shape = shapes
        return (np.eye(n, p, dtype=dtypes[0]),
                np.zeros(yp_shape, dtype=dtypes[1]))

    def _chanest_flops(shapes):
        """Pilot Gram 2 p n^2 + rhs GEMM 2 n p a + n^3/3 factor +
        2 n^2 a substitutions (a rhs columns = antennas)."""
        (n, p), (a, _) = shapes
        return (2.0 * p * n * n + 2.0 * n * p * a + n ** 3 / 3.0
                + 2.0 * n * n * a)

    register(KernelSpec(
        name="pusch_chanest", pallas=pp.channel_estimate_pallas,
        oracle=ref.channel_estimate,
        run_pallas=lambda xp, yp: pp.channel_estimate_pallas(xp, yp),
        run_oracle=lambda xp, yp: ref.channel_estimate(xp, yp),
        make_case=_chanest_case, stream=tri_ri,
        sizes=(8, 12), kind="pipeline",
        filler=_chanest_filler, flops=_chanest_flops))

    def _pusch_chain_case(rng, n):
        xp, yp = _chanest_case(rng, n)
        y = jnp.asarray(rng.standard_normal((2, n + 4, K_SYMS))
                        .astype(np.float32))
        return xp, yp, y

    def _pusch_chain_filler(shapes, dtypes):
        (n, p), yp_shape, y_shape = shapes
        return (np.eye(n, p, dtype=dtypes[0]),
                np.zeros(yp_shape, dtype=dtypes[1]),
                np.zeros(y_shape, dtype=dtypes[2]))

    def _pusch_chain_flops(shapes):
        (n, p), (a, _), (_, k) = shapes
        est = _chanest_flops(shapes[:2])
        eq = (2.0 * a * n * n + 2.0 * a * n * k + n ** 3 / 3.0
              + 2.0 * n * n * k)
        return est + eq

    register(KernelSpec(
        name="pusch_chain", pallas=pp.pusch_chain_pallas,
        oracle=ref.pusch_chain,
        run_pallas=lambda xp, yp, y: pp.pusch_chain_pallas(xp, yp, y),
        run_oracle=lambda xp, yp, y: ref.pusch_chain(xp, yp, y),
        make_case=_pusch_chain_case, stream=tri_ri,
        sizes=(8, 12), kind="pipeline",
        filler=_pusch_chain_filler, flops=_pusch_chain_flops))

    def _svd_factor_check(a):
        """Conformance adapter: packed factors are sign/order ambiguous,
        so check the sorted spectrum + the reconstruction (same contract
        as the ``svd`` kernel spec)."""
        f = pp.svd_factor_pallas(a)
        m = a.shape[1]
        n = a.shape[2]
        u, v, s = f[:, :m], f[:, m:m + n], f[:, m + n]
        recon = jnp.einsum("bmn,bn,bkn->bmk", u, s, v)
        return jnp.sort(s, axis=-1)[:, ::-1], recon

    def _svd_factor_filler(shapes, dtypes):
        (m, n), = shapes
        return (np.eye(m, n, dtype=dtypes[0]),)

    def _svd_factor_flops(shapes):
        """One-sided Jacobi: 14 sweeps x n(n-1)/2 pairs x (6m dot work
        + 12(m+n) rotation work)."""
        m, n = shapes[0]
        return 14.0 * n * (n - 1) / 2.0 * (6.0 * m + 12.0 * (m + n))

    register(KernelSpec(
        name="svd_factor", pallas=pp.svd_factor_pallas,
        oracle=ref.svd_vals,
        run_pallas=_svd_factor_check,
        run_oracle=lambda a: (ref.svd_vals(a), a),
        make_case=lambda rng, n: (jnp.asarray(
            rng.standard_normal((2, n + 4, n)).astype(np.float32)),),
        stream=lambda n: inductive(outer_trip=n, inner_base=n - 1,
                                   inner_stretch=-1),
        sizes=(8, 12), rtol=svd_rtol, kind="pipeline",
        filler=_svd_factor_filler, flops=_svd_factor_flops,
        serve_oracle=lambda a: pp.svd_factor_pallas(a)))

    def _svd_apply_case(rng, n):
        m = n + 4
        f = rng.standard_normal((2, m + n + 1, n)).astype(np.float32)
        f[:, m + n] = np.abs(f[:, m + n]) + 0.1      # s row: positive
        b = rng.standard_normal((2, m, K_SYMS)).astype(np.float32)
        return jnp.asarray(f), jnp.asarray(b)

    def _svd_apply_filler(shapes, dtypes):
        """Benign packed-identity factors + zero rhs -> x = 0."""
        (mn1, n), b_shape = shapes
        m = mn1 - n - 1
        f = np.zeros((mn1, n), dtype=dtypes[0])
        f[:m] = np.eye(m, n, dtype=dtypes[0])
        f[m:m + n] = np.eye(n, dtype=dtypes[0])
        f[m + n] = 1.0
        return f, np.zeros(b_shape, dtype=dtypes[1])

    def _svd_apply_flops(shapes):
        (mn1, n), (m, k) = shapes
        return 2.0 * m * n * k + 2.0 * n * n * k + 3.0 * n * k

    register(KernelSpec(
        name="svd_apply", pallas=pp.svd_apply_pallas,
        oracle=ref.svd_apply,
        run_pallas=lambda f, b: pp.svd_apply_pallas(f, b),
        run_oracle=lambda f, b: ref.svd_apply(f, b),
        make_case=_svd_apply_case,
        stream=lambda n: rect(n, K_SYMS),
        sizes=(8, 12), kind="pipeline",
        filler=_svd_apply_filler, flops=_svd_apply_flops))

    # ---------------- the served DAGs ----------------
    from repro.core.dependence import OrderedDep

    def _pusch_dag_case(rng, n):
        a, p = n + 4, 2 * n
        return (rng.standard_normal((a, NFFT)).astype(np.float32),
                rng.standard_normal((a, NFFT)).astype(np.float32),
                rng.standard_normal((n, p)).astype(np.float32))

    def _pusch_dag_oracle(tdr, tdi, xp):
        f = np.asarray(ref.pusch_fft(jnp.asarray(tdr)[None],
                                     jnp.asarray(tdi)[None]))[0]
        p = xp.shape[1]
        h = np.asarray(ref.channel_estimate(
            jnp.asarray(xp)[None], jnp.asarray(f[0][:, :p])[None]))[0]
        return np.asarray(ref.mmse_equalize(
            jnp.asarray(h)[None],
            jnp.asarray(f[0][:, p:p + K_SYMS])[None], sigma2=0.1))[0]

    def _bind_fft(args, outs):
        return args[0], args[1]

    def _bind_chanest(args, outs):
        xp = args[2]
        return xp, outs["fft"][0][:, :xp.shape[1]]

    def _bind_equalize(args, outs):
        p = args[2].shape[1]
        return outs["chanest"], outs["fft"][0][:, p:p + K_SYMS]

    def _bind_chain(args, outs):
        xp = args[2]
        p = xp.shape[1]
        f0 = outs["fft"][0]
        return xp, f0[:, :p], f0[:, p:p + K_SYMS]

    def _stage_flops_chanest(shapes):
        (a, _), _, (n, p) = shapes
        return _chanest_flops(((n, p), (a, p)))

    def _stage_flops_equalize(shapes):
        (a, _), _, (n, p) = shapes
        return (2.0 * a * n * n + 2.0 * a * n * K_SYMS + n ** 3 / 3.0
                + 2.0 * n * n * K_SYMS)

    _fft_stage = StageSpec(
        name="fft", pipeline="pusch_fft", bind=_bind_fft,
        stream=lambda n: rect(2, n + 4, NFFT),
        oracle=lambda tdr, tdi: np.asarray(ref.pusch_fft(
            jnp.asarray(tdr)[None], jnp.asarray(tdi)[None]))[0],
        flops=lambda shapes: _pusch_fft_flops(shapes[:2]),
        transcendental=True)       # twiddle sin/cos chains, not MXU work

    register_dag(DagSpec(
        name="pusch_receive",
        stages=(
            _fft_stage,
            StageSpec(name="chanest", pipeline="pusch_chanest",
                      bind=_bind_chanest, consumes=("fft",),
                      stream=tri_ri, flops=_stage_flops_chanest),
            StageSpec(name="equalize", pipeline="mmse_equalize",
                      bind=_bind_equalize, consumes=("fft", "chanest"),
                      stream=tri_ri, flops=_stage_flops_equalize),
        ),
        deps=(OrderedDep("fft", "chanest"),
              OrderedDep("fft", "equalize"),
              OrderedDep("chanest", "equalize")),
        chained=(
            _fft_stage,
            StageSpec(name="chain", pipeline="pusch_chain",
                      bind=_bind_chain, consumes=("fft",),
                      stream=tri_ri,
                      flops=lambda shapes: (
                          _stage_flops_chanest(shapes)
                          + _stage_flops_equalize(shapes))),
        ),
        make_case=_pusch_dag_case, oracle=_pusch_dag_oracle,
        # knob: 0.15 keeps the mid-chain channel-estimate stage (share
        # ~0.2 of the DAG's model FLOPs) on the critical path while the
        # transcendental FFT front-end and the small equalize tail stay
        # slack — the admission ordering the golden trace pins.
        crit_threshold=0.15, rtol=2e-3))

    def _svd_dag_case(rng, n):
        return (rng.standard_normal((n + 4, n)).astype(np.float32),
                rng.standard_normal((n + 4, K_SYMS)).astype(np.float32))

    def _svd_dag_oracle(a, b):
        return np.asarray(ref.ridge_solve(jnp.asarray(a)[None],
                                          jnp.asarray(b)[None]))[0]

    register_dag(DagSpec(
        name="svd_solve",
        stages=(
            StageSpec(name="factor", pipeline="svd_factor",
                      bind=lambda args, outs: (args[0],),
                      stream=lambda n: inductive(outer_trip=n,
                                                 inner_base=n - 1,
                                                 inner_stretch=-1),
                      flops=lambda shapes: _svd_factor_flops(
                          shapes[:1])),
            StageSpec(name="apply", pipeline="svd_apply",
                      bind=lambda args, outs: (outs["factor"], args[1]),
                      consumes=("factor",),
                      stream=lambda n: rect(n, K_SYMS),
                      oracle=lambda f, b: np.asarray(ref.svd_apply(
                          jnp.asarray(f)[None], jnp.asarray(b)[None]))[0],
                      flops=lambda shapes: _svd_apply_flops(
                          (((shapes[0][0] + shapes[0][1] + 1),
                            shapes[0][1]), shapes[1]))),
        ),
        deps=(OrderedDep("factor", "apply"),),
        make_case=_svd_dag_case, oracle=_svd_dag_oracle, rtol=2e-3))

    # ---------------- token decode (continuous batching) ----------------
    register_decode(DecodeSpec(
        name="lm_decode",
        description="continuous-batching LM token decode: per-slot "
                    "positions, slot-level paged KV reuse, one SPMD "
                    "step program over the slot pool"))


def get(name: str) -> KernelSpec:
    _build()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def names(kind: str | None = None) -> list[str]:
    _build()
    return [n for n, s in _REGISTRY.items()
            if kind is None or s.kind == kind]


def specs(kind: str | None = None) -> list[KernelSpec]:
    _build()
    return [s for s in _REGISTRY.values()
            if kind is None or s.kind == kind]


def get_dag(name: str) -> DagSpec:
    _build()
    try:
        return _DAGS[name]
    except KeyError:
        raise KeyError(f"unknown dag {name!r}; registered: "
                       f"{sorted(_DAGS)}") from None


def dag_names() -> list[str]:
    _build()
    return sorted(_DAGS)


def dag_specs() -> list[DagSpec]:
    _build()
    return [_DAGS[n] for n in sorted(_DAGS)]


def get_decode(name: str) -> DecodeSpec:
    _build()
    try:
        return _DECODES[name]
    except KeyError:
        raise KeyError(f"unknown decode spec {name!r}; registered: "
                       f"{sorted(_DECODES)}") from None


def decode_names() -> list[str]:
    _build()
    return sorted(_DECODES)
