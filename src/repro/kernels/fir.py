"""Centro-symmetric FIR (paper's Centro-FIR workload).

Exploits h[j] == h[m-1-j]: each tap pair shares one multiply,
y[i] = sum_{j<m/2} h[j]*(x[i+j] + x[i+m-1-j]) (+ middle tap if m odd),
halving multiplies exactly as the paper's ASIC model assumes.  The signal
stays VMEM-resident (DSP-sized inputs); the grid tiles the output and each
tile slices its overlapping input window with pl.ds — overlapping windows
cannot be expressed as BlockSpec strides, so the window read is the
kernel's own (rectangular) stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, interpret_default


def _fir_kernel(x_ref, h_ref, o_ref, *, bo: int, m: int):
    i = pl.program_id(0)
    x = x_ref[0, pl.ds(i * bo, bo + m - 1)]   # overlapping window
    h = h_ref[...]                            # (m,)
    half = m // 2
    acc = jnp.zeros((bo,), jnp.float32)

    def tap(j, acc):
        # paired taps: one multiply for two symmetric positions
        lo = jax.lax.dynamic_slice(x, (j,), (bo,))
        hi = jax.lax.dynamic_slice(x, (m - 1 - j,), (bo,))
        return acc + h[j] * (lo + hi)

    acc = jax.lax.fori_loop(0, half, tap, acc)
    if m % 2 == 1:
        acc = acc + h[half] * jax.lax.dynamic_slice(x, (half,), (bo,))
    o_ref[0] = acc.astype(o_ref.dtype)


def fir_pallas(x: jax.Array, h: jax.Array, *, bo: int = 256,
               interpret: bool | None = None) -> jax.Array:
    """Valid-mode centro-symmetric FIR. x: (N,), h: (M,) symmetric.
    Returns y: (N - M + 1,). Requires (N - M + 1) % bo == 0 after the
    ops.py wrapper pads (bo is clamped for short signals)."""
    n, = x.shape
    m, = h.shape
    out = n - m + 1
    bo = min(bo, out)
    assert out % bo == 0, "ops.py must pad output length to a bo multiple"
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_fir_kernel, bo=bo, m=m),
        grid=(cdiv(out, bo),),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((m,), lambda i: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, bo), lambda i: (0, i),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((1, out), x.dtype),
        interpret=interpret,
    )(x[None, :], h)[0]
