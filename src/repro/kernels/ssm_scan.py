"""Chunked SSD/Mamba2 scan — ordered inter-chunk dependence (FGOP F1/F2).

The SSM recurrence h_t = a_t h_{t-1} + b_t x_t^T is strictly ordered in t
(paper Property 1/2: parallel flows with ordered fine-grain deps).  The
chunked decomposition is the REVEL move: *within* a chunk everything is
parallel MXU work over a triangular (inductive!) decay matrix L_ij =
exp(la_i - la_j), j <= i; *across* chunks a small state h (N, P) is the
ordered dependence, carried in VMEM scratch across the sequential chunk
grid dimension — never touching HBM.  The cumulative-log-decay chain is
the non-critical region; the three matmuls (CB^T, M@X, B^T X) are the
critical region.

Layouts: x (B,H,S,P), a (B,H,S), b/c (B,S,N) shared across heads (G=1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, interpret_default, tpu_compiler_params


def _ssm_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                cs: int, n: int, p: int, chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (cs, P)
    a = a_ref[0, 0].astype(jnp.float32)          # (cs,)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (cs, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (cs, N)
    h = h_ref[...]                               # (N, P) carried state

    # ---- non-critical region: cumulative log-decay chain ----
    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-20)))          # (cs,)

    # ---- critical region 1: pairwise gram + triangular decay ----
    g = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (cs, cs)
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    ldec = jnp.exp(la[:, None] - la[None, :])
    mmat = jnp.where(jj <= ii, g * ldec, 0.0)    # inductive-domain mask

    # ---- critical region 2: intra-chunk output ----
    y = jax.lax.dot_general(mmat, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- inter-chunk contribution (consumes the ordered dep h) ----
    y = y + jnp.exp(la)[:, None] * jax.lax.dot_general(
        cmat, h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # ---- state update (produces the ordered dep for chunk ic+1) ----
    total = la[cs - 1]
    bw = bmat * jnp.exp(total - la)[:, None]     # (cs, N)
    h_new = jnp.exp(total) * h + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (N, P)
    h_ref[...] = h_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssm_scan_pallas(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                    *, chunk: int = 128, interpret: bool | None = None):
    """x: (B,H,S,P), a: (B,H,S), b/c: (B,S,N) shared or (B,H,S,N) per-head
    -> y (B,H,S,P), h (B,H,N,P)."""
    bs, h, s, p = x.shape
    n = b.shape[-1]
    if b.ndim == 3:  # shared across heads -> broadcast (kernel is 4D)
        b = jnp.broadcast_to(b[:, None], (bs, h, s, n))
        c = jnp.broadcast_to(c[:, None], (bs, h, s, n))
    chunk = min(chunk, s)
    assert s % chunk == 0
    chunks = cdiv(s, chunk)
    if interpret is None:
        interpret = interpret_default()

    y, hf = pl.pallas_call(
        functools.partial(_ssm_kernel, cs=chunk, n=n, p=p, chunks=chunks),
        grid=(bs, h, chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda b_, h_, c_: (b_, h_, c_, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, chunk),
                         lambda b_, h_, c_: (b_, h_, c_),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda b_, h_, c_: (b_, h_, c_, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda b_, h_, c_: (b_, h_, c_, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda b_, h_, c_: (b_, h_, c_, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, n, p),
                         lambda b_, h_, c_: (b_, h_, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((bs, h, n, p), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, a, b, c)
    return y, hf
