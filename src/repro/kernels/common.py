"""Shared kernel plumbing: backend selection, interpret-mode default.

Every kernel in this package has three faces:
  <name>.py  — the Pallas TPU kernel (pl.pallas_call + BlockSpec)
  ops.py     — the jit'd public wrapper, backend-dispatching
  ref.py     — the pure-jnp oracle

On TPU the Pallas path compiles natively; on this CPU container it runs in
interpret=True mode (Python evaluation of the kernel body) for correctness
validation, while `backend='xla'` gives the fast pure-jnp path used by the
CPU benchmarks and as the production fallback.
"""
from __future__ import annotations

import functools
import os

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = ["interpret_default", "on_tpu", "resolve_backend", "cdiv",
           "round_up", "tpu_compiler_params", "sample_spd"]


def sample_spd(rng, b: int, n: int):
    """Batched well-conditioned SPD test matrices (B,N,N) float32 — the
    shared generator for registry cases, benchmarks, and tests."""
    import numpy as np
    a = rng.standard_normal((b, n, n)).astype(np.float32)
    return a @ a.swapaxes(-1, -2) + n * np.eye(n, dtype=np.float32)

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; resolve
# whichever this jaxlib ships so kernels stay version-portable.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Version-portable pltpu compiler-params constructor."""
    return _COMPILER_PARAMS_CLS(**kwargs)


@functools.cache
def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


def interpret_default() -> bool:
    """Pallas interpret mode unless we are actually on TPU."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return not on_tpu()


def resolve_backend(backend: str | None) -> str:
    """'pallas' | 'xla' | None(auto: pallas on TPU, xla elsewhere)."""
    if backend is None:
        return "pallas" if on_tpu() else "xla"
    if backend not in ("pallas", "xla"):
        raise ValueError(f"backend must be 'pallas'|'xla', got {backend!r}")
    return backend


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
