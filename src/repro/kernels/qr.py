"""Batched Householder QR (paper Fig. 6 left).

Per outer column k: the householder region (norm + rsqrt — non-critical
point/vector flow producing tau and v) feeds two critical updates
R -= tau * v (v^T R) and Q -= tau * (Q v) v^T.  v is masked to rows >= k
(inductive domain), tau is consumed across the whole trailing submatrix —
an ordered dependence with inductive consumption rate (paper's `tau` edge).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def _qr_kernel(a_ref, q_ref, r_ref, *, m: int, n: int):
    r = a_ref[0]
    q = jnp.eye(m, dtype=r.dtype)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)

    def outer(k, carry):
        q, r = carry
        # ---- householder region (non-critical: norm, sqrt, div) ----
        x = jnp.where(rows >= k, r[:, k], 0.0)          # masked column
        xk = r[k, k]
        sigma = jnp.sum(x * x)
        norm = jnp.sqrt(sigma)
        alpha = jnp.where(xk >= 0, -norm, norm)
        v = x - alpha * (rows == k).astype(r.dtype)
        vnorm2 = jnp.maximum(jnp.sum(v * v), 1e-30)
        tau = 2.0 / vnorm2
        # degenerate column: no reflection
        tau = jnp.where(norm < 1e-30, 0.0, tau)
        # ---- critical region 1: R update (MXU: v^T R then outer) ----
        w = tau * (v @ r)                                # (n,)
        r = r - v[:, None] * w[None, :]
        # ---- critical region 2: Q accumulation ----
        u = tau * (q @ v)                                # (m,)
        q = q - u[:, None] * v[None, :]
        return q, r

    q, r = jax.lax.fori_loop(0, min(n, m - 1) if m > 1 else 0, outer, (q, r))
    rows_n = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
    cols_n = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
    q_ref[0] = q
    r_ref[0] = jnp.where(rows_n <= cols_n, r, 0.0)


def qr_pallas(a: jax.Array, *, interpret: bool | None = None):
    """a: (B, M, N), M >= N -> (Q (B,M,M), R (B,M,N)) with a = Q @ R."""
    b, m, n = a.shape
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_qr_kernel, m=m, n=n),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec((1, m, m), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m, m), a.dtype),
            jax.ShapeDtypeStruct((b, m, n), a.dtype),
        ],
        interpret=interpret,
    )(a)
