"""Public kernel API — jit'd wrappers with backend dispatch.

backend=None  -> pallas on TPU, xla elsewhere (production default)
backend='pallas' -> the Pallas kernel (interpret=True off-TPU: validation)
backend='xla' -> pure-jnp path (CPU benchmarks / fallback)

The xla paths are *not* the naive oracles from ref.py: they are the fused
FGOP formulations (same region fusion, same masking) expressed in jnp so
the mechanism benchmarks can compare fused-vs-naive on any backend.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.common import resolve_backend, round_up
from repro.kernels.cholesky import cholesky_pallas
from repro.kernels.trisolve import trisolve_pallas
from repro.kernels.qr import qr_pallas
from repro.kernels.svd import svd_pallas
from repro.kernels.gemm import gemm_pallas
from repro.kernels.fir import fir_pallas
from repro.kernels.fft import fft_pallas
from repro.kernels.attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

__all__ = ["cholesky", "trisolve", "qr", "svd", "gemm", "fir", "fft",
           "flash_attention", "ssm_scan"]


# ---------------- factorizations ----------------

@partial(jax.jit, static_argnames=("backend",))
def cholesky(a: jax.Array, *, backend: str | None = None) -> jax.Array:
    if resolve_backend(backend) == "pallas":
        return cholesky_pallas(a)
    return ref.cholesky(a)


@partial(jax.jit, static_argnames=("backend", "lower"))
def trisolve(l: jax.Array, b: jax.Array, *, lower: bool = True,
             backend: str | None = None) -> jax.Array:
    if resolve_backend(backend) == "pallas":
        return trisolve_pallas(l, b, lower=lower)
    return ref.trisolve(l, b, lower=lower)


@partial(jax.jit, static_argnames=("backend",))
def qr(a: jax.Array, *, backend: str | None = None):
    if resolve_backend(backend) == "pallas":
        return qr_pallas(a)
    return ref.qr(a)


@partial(jax.jit, static_argnames=("backend", "sweeps", "sort"))
def svd(a: jax.Array, *, sweeps: int = 12, sort: bool = True,
        backend: str | None = None):
    """One-sided Jacobi SVD: returns (U, S, V), A ~= U*S @ V^T."""
    if resolve_backend(backend) == "pallas":
        u, s, v = svd_pallas(a, sweeps=sweeps)
    else:
        u, s, v = _svd_xla(a, sweeps=sweeps)
    if sort:
        order = jnp.argsort(-s, axis=-1)
        u = jnp.take_along_axis(u, order[:, None, :], axis=2)
        s = jnp.take_along_axis(s, order, axis=1)
        v = jnp.take_along_axis(v, order[:, None, :], axis=2)
    return u, s, v


def _svd_xla(a: jax.Array, *, sweeps: int):
    """Fused jacobi in plain jnp (vmapped over batch)."""

    def one(a0):
        m, n = a0.shape
        v0 = jnp.eye(n, dtype=jnp.float32)

        def pair(p, q, av):
            a, v = av
            colp = jax.lax.dynamic_slice(a, (0, p), (m, 1))[:, 0]
            colq = jax.lax.dynamic_slice(a, (0, q), (m, 1))[:, 0]
            alpha = jnp.sum(colp * colp)
            beta = jnp.sum(colq * colq)
            gamma = jnp.sum(colp * colq)
            small = jnp.abs(gamma) <= 1e-12 * jnp.sqrt(alpha * beta) + 1e-30
            zeta = (beta - alpha) / (2.0 * jnp.where(small, 1.0, gamma))
            t = jnp.sign(zeta) / (jnp.abs(zeta)
                                  + jnp.sqrt(1.0 + zeta * zeta))
            t = jnp.where(zeta == 0.0, 1.0, t)
            cs = jax.lax.rsqrt(1.0 + t * t)
            sn = cs * t
            cs = jnp.where(small, 1.0, cs)
            sn = jnp.where(small, 0.0, sn)

            def rot(mat):
                cp = jax.lax.dynamic_slice(mat, (0, p), (mat.shape[0], 1))
                cq = jax.lax.dynamic_slice(mat, (0, q), (mat.shape[0], 1))
                mat = jax.lax.dynamic_update_slice(
                    mat, cs * cp - sn * cq, (0, p))
                return jax.lax.dynamic_update_slice(
                    mat, sn * cp + cs * cq, (0, q))

            return rot(a), rot(v)

        def sweep(_, av):
            return jax.lax.fori_loop(
                0, n - 1,
                lambda p, av_: jax.lax.fori_loop(
                    p + 1, n, lambda q, av__: pair(p, q, av__), av_),
                av)

        a1, v1 = jax.lax.fori_loop(0, sweeps, sweep,
                                   (a0.astype(jnp.float32), v0))
        s = jnp.sqrt(jnp.sum(a1 * a1, axis=0))
        u = a1 / jnp.maximum(s, 1e-30)[None, :]
        return u.astype(a0.dtype), s.astype(a0.dtype), v1.astype(a0.dtype)

    return jax.vmap(one)(a)


# ---------------- dense / DSP ----------------

@partial(jax.jit, static_argnames=("backend", "bm", "bn", "bk"))
def gemm(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
         bk: int = 128, backend: str | None = None) -> jax.Array:
    if resolve_backend(backend) == "pallas":
        m, k = x.shape
        _, n = y.shape
        mp = round_up(m, min(bm, max(m, 8)))
        np_ = round_up(n, min(bn, max(n, 8)))
        kp = round_up(k, min(bk, max(k, 8)))
        xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
        yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
        return gemm_pallas(xp, yp, bm=min(bm, mp), bn=min(bn, np_),
                           bk=min(bk, kp))[:m, :n]
    return ref.gemm(x, y)


@partial(jax.jit, static_argnames=("backend", "bo"))
def fir(x: jax.Array, h: jax.Array, *, bo: int = 256,
        backend: str | None = None) -> jax.Array:
    """Centro-symmetric FIR, valid mode: y[i] = sum_j h[j] x[i+j]."""
    if resolve_backend(backend) == "pallas":
        n, = x.shape
        m, = h.shape
        out = n - m + 1
        bo = min(bo, out)
        pad = round_up(out, bo) - out
        xp = jnp.pad(x, (0, pad))
        return fir_pallas(xp, h, bo=bo)[:out]
    return ref.fir(x, h)


@partial(jax.jit, static_argnames=("backend",))
def fft(x_re: jax.Array, x_im: jax.Array, *, backend: str | None = None):
    if resolve_backend(backend) == "pallas":
        return fft_pallas(x_re, x_im)
    return ref.fft(x_re, x_im)


# ---------------- LM-side ----------------

@partial(jax.jit, static_argnames=("backend", "causal", "scale", "bq",
                                   "bkv"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    bq: int = 128, bkv: int = 128,
                    backend: str | None = None) -> jax.Array:
    if resolve_backend(backend) == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                      bq=bq, bkv=bkv)
    return ref.mha(q, k, v, causal=causal, scale=scale)


@partial(jax.jit, static_argnames=("backend", "chunk"))
def ssm_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
             chunk: int = 128, backend: str | None = None):
    """x: (B,S,H,P), a: (B,S,H), b/c: (B,S,N) shared-across-heads or
    (B,S,H,N) per-head -> y (B,S,H,P), h (B,H,N,P).

    (Time-major-per-head relayout for the kernel happens inside.)
    """
    if resolve_backend(backend) == "pallas":
        xk = jnp.moveaxis(x, 1, 2)            # (B,H,S,P)
        ak = jnp.moveaxis(a, 1, 2)            # (B,H,S)
        bk = b if b.ndim == 3 else jnp.moveaxis(b, 1, 2)
        ck = c if c.ndim == 3 else jnp.moveaxis(c, 1, 2)
        y, hf = ssm_scan_pallas(xk, ak, bk, ck, chunk=chunk)
        return jnp.moveaxis(y, 1, 2), hf
    return _ssm_chunked_xla(x, a, b, c, chunk=chunk)


def _ssm_chunked_xla(x, a, b, c, *, chunk: int):
    """Chunked SSD in plain jnp: same math as the kernel, scan over
    chunks (the ordered dependence is the scan carry)."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    per_head = b.ndim == 4
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xc = x.reshape(bs, nc, chunk, h, p)
    ac = a.reshape(bs, nc, chunk, h)
    bshape = (bs, nc, chunk, h, n) if per_head else (bs, nc, chunk, n)
    bc = b.reshape(bshape)
    cc = c.reshape(bshape)

    ii = jnp.arange(chunk)[:, None]
    jj = jnp.arange(chunk)[None, :]
    tri = (jj <= ii)

    def step(hprev, t):
        xt, at, bt, ct = t                     # (B,cs,H,P),(B,cs,H),...
        la = jnp.cumsum(jnp.log(jnp.maximum(at, 1e-20)), axis=1)  # (B,cs,H)
        if per_head:
            g = jnp.einsum("bihn,bjhn->bijh", ct, bt)     # (B,i,j,H)
        else:
            g = jnp.einsum("bin,bjn->bij", ct, bt)[..., None]
        ldec = jnp.exp(la[:, :, None, :] - la[:, None, :, :])     # (B,i,j,H)
        m = jnp.where(tri[None, :, :, None], g * ldec, 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", m, xt)
        if per_head:
            y = y + jnp.exp(la)[..., None] * jnp.einsum(
                "bihn,bhnp->bihp", ct, hprev)
        else:
            y = y + jnp.exp(la)[..., None] * jnp.einsum(
                "bin,bhnp->bihp", ct, hprev)
        total = la[:, -1, :]                                      # (B,H)
        dec = jnp.exp(total[:, None, :] - la)                     # (B,cs,H)
        if per_head:
            bw = bt * dec[..., None]                              # (B,cs,H,N)
        else:
            bw = bt[..., None, :] * dec[..., None]                # (B,cs,H,N)
        hnew = jnp.exp(total)[:, :, None, None] * hprev + jnp.einsum(
            "bjhn,bjhp->bhnp", bw, xt)
        return hnew, y

    h0 = jnp.zeros((bs, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    hf, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bs, s, h, p).astype(x.dtype)
    return y, hf.astype(x.dtype)
