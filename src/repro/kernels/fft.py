"""Batched radix-2 FFT (paper's FFT workload; RR streams per Table 5).

Iterative Cooley-Tukey, fully VMEM-resident.  All per-stage gather
indices and twiddles are host-precomputed *stream tables* (the REVEL
analog: the control core issues one stream command per stage; the pattern
state machines do the rest).  Complex values travel as separate re/im
planes (TPU has no native complex).  The stage loop is an ordered
dependence chain — stage s+1 consumes everything stage s produced — so it
stays inside one kernel rather than round-tripping HBM per stage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def fft_tables(n: int):
    """Host-side stream tables: bit-reversal perm, per-stage butterfly
    gather indices (i, j) and twiddles (re, im)."""
    stages = int(np.log2(n))
    assert 2 ** stages == n, "n must be a power of two"
    rev = np.zeros(n, np.int32)
    bits = stages
    for i in range(n):
        rev[i] = int(format(i, f"0{bits}b")[::-1], 2) if bits else 0
    i_idx = np.zeros((stages, n // 2), np.int32)
    j_idx = np.zeros((stages, n // 2), np.int32)
    w_re = np.zeros((stages, n // 2), np.float32)
    w_im = np.zeros((stages, n // 2), np.float32)
    for s in range(stages):
        half = 1 << s
        span = half << 1
        for b in range(n // 2):
            blk, off = divmod(b, half)
            i = blk * span + off
            i_idx[s, b] = i
            j_idx[s, b] = i + half
            ang = -2.0 * np.pi * off / span
            w_re[s, b] = np.cos(ang)
            w_im[s, b] = np.sin(ang)
    return rev, i_idx, j_idx, w_re, w_im


def _fft_kernel(xr_ref, xi_ref, rev_ref, ii_ref, jj_ref, wr_ref, wi_ref,
                or_ref, oi_ref, *, n: int, stages: int):
    rev = rev_ref[...]
    xr = jnp.take(xr_ref[0], rev)
    xi = jnp.take(xi_ref[0], rev)

    def stage(s, x):
        xr, xi = x
        ii = ii_ref[s]
        jj = jj_ref[s]
        wr = wr_ref[s]
        wi = wi_ref[s]
        ur, ui = jnp.take(xr, ii), jnp.take(xi, ii)
        vr, vi = jnp.take(xr, jj), jnp.take(xi, jj)
        # twiddle multiply (critical vector region)
        tr = wr * vr - wi * vi
        ti = wr * vi + wi * vr
        xr = xr.at[ii].set(ur + tr).at[jj].set(ur - tr)
        xi = xi.at[ii].set(ui + ti).at[jj].set(ui - ti)
        return xr, xi

    xr, xi = jax.lax.fori_loop(0, stages, stage, (xr, xi))
    or_ref[0] = xr
    oi_ref[0] = xi


def fft_pallas(x_re: jax.Array, x_im: jax.Array, *,
               interpret: bool | None = None):
    """(B, N) re/im -> (re, im) of the DFT."""
    b, n = x_re.shape
    stages = int(np.log2(n))
    rev, ii, jj, wr, wi = fft_tables(n)
    if interpret is None:
        interpret = interpret_default()
    row = lambda i: (i, 0)          # noqa: E731
    tab = lambda i: (0, 0)          # noqa: E731
    return pl.pallas_call(
        functools.partial(_fft_kernel, n=n, stages=stages),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
            pl.BlockSpec((n,), lambda i: (0,), memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, n // 2), tab, memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, n // 2), tab, memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, n // 2), tab, memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, n // 2), tab, memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), x_re.dtype),
            jax.ShapeDtypeStruct((b, n), x_im.dtype),
        ],
        interpret=interpret,
    )(x_re, x_im, jnp.asarray(rev), jnp.asarray(ii), jnp.asarray(jj),
      jnp.asarray(wr), jnp.asarray(wi))
