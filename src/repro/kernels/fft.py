"""Batched radix-2 FFT (paper's FFT workload; RR streams per Table 5).

Iterative Cooley-Tukey, fully VMEM-resident.  The bit-reversal
permutation and the twiddle factors are host-precomputed *stream tables*
(the REVEL analog: the control core issues one stream command per stage;
the pattern state machines do the rest).  Complex values travel as
separate re/im planes (TPU has no native complex).  The stage loop is an
ordered dependence chain — stage s+1 consumes everything stage s
produced — so it stays inside one kernel rather than round-tripping HBM
per stage.

Twiddle storage is CHUNKED: stage ``s`` only has ``2**s`` distinct
twiddles (w_span^off for off < span/2), so the table packs stage ``s``
at offset ``2**s - 1`` for a total of ``n - 1`` complex entries.  The
old layout materialized all ``stages * n/2`` repeated entries plus two
equally-sized butterfly index tables — at the paper's 1024-point size
that is ~11x the VMEM footprint, which is what capped the registered
sizes at 128.  Butterfly partners and per-stage twiddle offsets are now
recomputed in-kernel from an iota with shift/mask arithmetic (a pattern
state machine, not a stored stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def fft_tables(n: int):
    """Host-side stream tables: bit-reversal permutation and the CHUNKED
    twiddle table (re, im) — stage ``s`` occupies slots
    ``[2**s - 1, 2**(s+1) - 1)``, ``n - 1`` entries total."""
    stages = int(np.log2(n))
    assert 2 ** stages == n, "n must be a power of two"
    rev = np.zeros(n, np.int32)
    bits = stages
    for i in range(n):
        rev[i] = int(format(i, f"0{bits}b")[::-1], 2) if bits else 0
    w_re = np.zeros(max(n - 1, 1), np.float32)
    w_im = np.zeros(max(n - 1, 1), np.float32)
    for s in range(stages):
        half = 1 << s
        span = half << 1
        base = half - 1                  # sum_{t<s} 2**t
        for off in range(half):
            ang = -2.0 * np.pi * off / span
            w_re[base + off] = np.cos(ang)
            w_im[base + off] = np.sin(ang)
    return rev, w_re, w_im


def _fft_kernel(xr_ref, xi_ref, rev_ref, wr_ref, wi_ref, or_ref, oi_ref,
                *, n: int, stages: int):
    rev = rev_ref[...]
    xr = jnp.take(xr_ref[0], rev)
    xi = jnp.take(xi_ref[0], rev)
    b_idx = jax.lax.broadcasted_iota(jnp.int32, (n // 2,), 0)

    def stage(s, x):
        xr, xi = x
        half = jnp.left_shift(1, s)
        off = jnp.bitwise_and(b_idx, half - 1)
        # butterfly partners: i = (b >> s) << (s+1) | off, j = i + half
        ii = jnp.left_shift(jnp.right_shift(b_idx, s), s + 1) + off
        jj = ii + half
        # chunked twiddle gather: stage s lives at offset 2**s - 1
        widx = (half - 1) + off
        wr = jnp.take(wr_ref[...], widx)
        wi = jnp.take(wi_ref[...], widx)
        ur, ui = jnp.take(xr, ii), jnp.take(xi, ii)
        vr, vi = jnp.take(xr, jj), jnp.take(xi, jj)
        # twiddle multiply (critical vector region)
        tr = wr * vr - wi * vi
        ti = wr * vi + wi * vr
        xr = xr.at[ii].set(ur + tr).at[jj].set(ur - tr)
        xi = xi.at[ii].set(ui + ti).at[jj].set(ui - ti)
        return xr, xi

    xr, xi = jax.lax.fori_loop(0, stages, stage, (xr, xi))
    or_ref[0] = xr
    oi_ref[0] = xi


def fft_pallas(x_re: jax.Array, x_im: jax.Array, *,
               interpret: bool | None = None):
    """(B, N) re/im -> (re, im) of the DFT.  VMEM per lane is O(N)
    (signal + bit-reversal + chunked twiddles), so the paper's
    1024-point size stays resident."""
    b, n = x_re.shape
    stages = int(np.log2(n))
    rev, wr, wi = fft_tables(n)
    if interpret is None:
        interpret = interpret_default()
    row = lambda i: (i, 0)          # noqa: E731
    tab = lambda i: (0,)            # noqa: E731
    return pl.pallas_call(
        functools.partial(_fft_kernel, n=n, stages=stages),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
            pl.BlockSpec((n,), tab, memory_space=pltpu.VMEM),
            pl.BlockSpec((max(n - 1, 1),), tab, memory_space=pltpu.VMEM),
            pl.BlockSpec((max(n - 1, 1),), tab, memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n), row, memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), x_re.dtype),
            jax.ShapeDtypeStruct((b, n), x_im.dtype),
        ],
        interpret=interpret,
    )(x_re, x_im, jnp.asarray(rev), jnp.asarray(wr), jnp.asarray(wi))
