"""Pure-jnp oracles for every kernel — the ground truth for allclose tests.

These are deliberately naive/unfused implementations (the "no-FGOP"
baselines): each region is a separate pass over memory, triangular domains
are computed rectangularly then masked, nothing stays in registers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------- factorizations ----------------

def cholesky(a: jax.Array) -> jax.Array:
    """(B, N, N) SPD -> lower L."""
    return jnp.linalg.cholesky(a)


def trisolve(l: jax.Array, b: jax.Array, *, lower: bool = True) -> jax.Array:
    """(B,N,N) x (B,N,M)."""
    return jax.vmap(
        lambda li, bi: jax.scipy.linalg.solve_triangular(li, bi, lower=lower)
    )(l, b)


def qr(a: jax.Array):
    """Householder QR, same math as the kernel but unfused jnp.
    a: (B, M, N) -> (Q, R)."""

    def one(a0):
        m, n = a0.shape
        q = jnp.eye(m, dtype=a0.dtype)
        r = a0
        rows = jnp.arange(m)

        def step(k, qr_):
            q, r = qr_
            x = jnp.where(rows >= k, r[:, k], 0.0)
            xk = r[k, k]
            norm = jnp.sqrt(jnp.sum(x * x))
            alpha = jnp.where(xk >= 0, -norm, norm)
            v = x - alpha * (rows == k).astype(r.dtype)
            vnorm2 = jnp.maximum(jnp.sum(v * v), 1e-30)
            tau = jnp.where(norm < 1e-30, 0.0, 2.0 / vnorm2)
            w = tau * (v @ r)
            r = r - v[:, None] * w[None, :]
            u = tau * (q @ v)
            q = q - u[:, None] * v[None, :]
            return q, r

        q, r = jax.lax.fori_loop(0, min(n, m - 1) if m > 1 else 0,
                                 step, (q, r))
        return q, jnp.triu(r[:, :n])

    return jax.vmap(one)(a)


def svd_vals(a: jax.Array) -> jax.Array:
    """Singular values, descending. a: (B, M, N)."""
    return jnp.linalg.svd(a, compute_uv=False)


# ---------------- composed solver pipelines ----------------

def cholesky_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """SPD solve a @ x = b, the unfused library path.
    a: (B,N,N), b: (B,N,M)."""
    return jnp.linalg.solve(a, b)


def qr_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Least squares min ||a x - b||, full-rank tall a.
    a: (B,M,N), b: (B,M,K) -> (B,N,K)."""
    q, r = jnp.linalg.qr(a)          # reduced
    qtb = jnp.einsum("bmn,bmk->bnk", q, b)
    return jax.vmap(lambda ri, bi: jax.scipy.linalg.solve_triangular(
        ri, bi, lower=False))(r, qtb)


def mmse_equalize(h: jax.Array, y: jax.Array, *,
                  sigma2: float = 0.1) -> jax.Array:
    """LMMSE x = (H^T H + s I)^{-1} H^T y.  h: (B,M,N), y: (B,M,K)."""
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", h, h) + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", h, y)
    return jnp.linalg.solve(g, rhs)


def mmse_equalize_split(hr: jax.Array, hi: jax.Array, yr: jax.Array,
                        yi: jax.Array, *, sigma2: float = 0.1) -> jax.Array:
    """Complex-valued LMMSE oracle for the split re/im kernel.

    hr/hi: (B,M,N) channel planes, yr/yi: (B,M,K) observation planes.
    Solves x = (H^H H + s I)^{-1} H^H y in complex64 and returns the
    REAL-STACKED result (B, 2N, K) = [Re x; Im x] — the layout the real
    expansion produces, so split- and expansion-path answers to the same
    complex problem compare element-for-element.
    """
    h = hr.astype(jnp.complex64) + 1j * hi.astype(jnp.complex64)
    y = yr.astype(jnp.complex64) + 1j * yi.astype(jnp.complex64)
    n = h.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", jnp.conj(h), h) \
        + sigma2 * jnp.eye(n, dtype=h.dtype)
    rhs = jnp.einsum("bmn,bmk->bnk", jnp.conj(h), y)
    x = jnp.linalg.solve(g, rhs)
    return jnp.concatenate([jnp.real(x), jnp.imag(x)],
                           axis=-2).astype(hr.dtype)


def channel_estimate(xp: jax.Array, yp: jax.Array, *,
                     ridge: float = 1e-3) -> jax.Array:
    """Regularized LS channel estimate from pilots: solve
    (Xp Xp^T + ridge I) Z = Xp Yp^T, H = Z^T.
    xp: (B,N,P) known pilots, yp: (B,M,P) observations -> (B,M,N)."""
    n = xp.shape[-2]
    g = jnp.einsum("bnp,bmp->bnm", xp, xp) \
        + ridge * jnp.eye(n, dtype=xp.dtype)
    rhs = jnp.einsum("bnp,bmp->bnm", xp, yp)
    return jnp.swapaxes(jnp.linalg.solve(g, rhs), -1, -2)


def pusch_chain(xp: jax.Array, yp: jax.Array, y: jax.Array, *,
                ridge: float = 1e-3, sigma2: float = 0.1) -> jax.Array:
    """Channel-estimate -> MMSE equalize, the unfused two-stage path.
    xp: (B,N,P), yp: (B,M,P), y: (B,M,K) -> (B,N,K)."""
    return mmse_equalize(channel_estimate(xp, yp, ridge=ridge), y,
                         sigma2=sigma2)


def svd_apply(f: jax.Array, b: jax.Array, *, lam: float = 1e-3
              ) -> jax.Array:
    """Pseudo-inverse apply from a packed (B, M+N+1, N) factor buffer
    [U; V; s]: x = V diag(s / (s^2 + lam)) U^T b.  b: (B,M,K)."""
    n = f.shape[-1]
    m = f.shape[-2] - n - 1
    u, v, s = f[:, :m], f[:, m:m + n], f[:, m + n]
    w = jnp.einsum("bmn,bmk->bnk", u, b)
    w = (s / (s * s + lam))[:, :, None] * w
    return jnp.einsum("bnj,bjk->bnk", v, w)


def ridge_solve(a: jax.Array, b: jax.Array, *, lam: float = 1e-3
                ) -> jax.Array:
    """Closed-form ridge regression x = (A^T A + lam I)^{-1} A^T b — the
    factor-free ground truth for the svd_factor -> svd_apply DAG (the
    composition is invariant to SVD sign/order ambiguity)."""
    n = a.shape[-1]
    g = jnp.einsum("bmi,bmj->bij", a, a) + lam * jnp.eye(n, dtype=a.dtype)
    return jnp.linalg.solve(g, jnp.einsum("bmn,bmk->bnk", a, b))


# ---------------- dense / DSP ----------------

def gemm(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """Valid-mode correlation-style FIR matching the kernel tap order:
    y[i] = sum_j h[j] * x[i + j]."""
    return jnp.convolve(x, h[::-1], mode="valid")


def fft(x_re: jax.Array, x_im: jax.Array):
    """Batched complex FFT. (B, N) each -> (re, im)."""
    z = jnp.fft.fft(x_re + 1j * x_im.astype(jnp.complex64))
    return jnp.real(z).astype(x_re.dtype), jnp.imag(z).astype(x_im.dtype)


def pusch_fft(xr: jax.Array, xi: jax.Array) -> jax.Array:
    """OFDM demod stage oracle: per-antenna FFT over the last axis,
    packed into stacked planes.  (B, A, NF) re/im -> (B, 2, A, NF)."""
    z = jnp.fft.fft(xr + 1j * xi.astype(jnp.complex64))
    return jnp.stack([jnp.real(z).astype(xr.dtype),
                      jnp.imag(z).astype(xi.dtype)], axis=1)


# ---------------- LM-side kernels ----------------

def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        scale: float | None = None, bias: jax.Array | None = None
        ) -> jax.Array:
    """Reference attention. q: (B,H,S,D), k/v: (B,Hkv,S,D); GQA by head
    replication. f32 softmax."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(k.shape[2])[None, :]
        logits = jnp.where(ki <= qi, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)


def ssm_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
             h0: jax.Array | None = None):
    """Naive sequential SSD/Mamba2 recurrence (the oracle).

    x: (B, S, H, P)   per-head inputs
    a: (B, S, H)      decay in (0,1]  (already exp(-softplus...) form)
    b: (B, S, N)      input projection  (shared across heads, G=1)
                      or (B, S, H, N) per-head
    c: (B, S, N)      output projection (same layouts as b)
    h0: (B, H, N, P)  initial state
    returns y: (B, S, H, P), h_final: (B, H, N, P)
    state update: h = a_t * h + b_t outer x_t ;  y_t = c_t @ h
    """
    bs, s, hh, p = x.shape
    n = b.shape[-1]
    per_head = b.ndim == 4
    if h0 is None:
        h0 = jnp.zeros((bs, hh, n, p), x.dtype)

    def step(h, t):
        xt, at, bt, ct = t
        # h: (B,H,N,P)
        if per_head:
            h = at[:, :, None, None] * h \
                + jnp.einsum("bhn,bhp->bhnp", bt, xt)
            y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        else:
            h = at[:, :, None, None] * h \
                + jnp.einsum("bn,bhp->bhnp", bt, xt)
            y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    hf, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hf
