"""Blocked GEMM — the paper's non-FGOP baseline workload (RR streams).

Classic MXU-tiled matmul: grid (M/bm, N/bn, K/bk) with the K dimension
sequential ("arbitrary"), accumulating in an f32 VMEM scratch.  Block
shapes default to MXU-aligned 128s (criticality: this entire kernel is a
critical dataflow, so it owns full MXU tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, interpret_default, tpu_compiler_params


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 128, interpret: bool | None = None) -> jax.Array:
    """x: (M, K) @ y: (K, N) -> (M, N). Dims must divide by block sizes
    (ops.py pads); accumulation in f32."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    k_steps = cdiv(k, bk)
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(cdiv(m, bm), cdiv(n, bn), k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y)
