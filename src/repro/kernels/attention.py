"""Causal flash attention with an inductive kv trip count — the flagship
LM-side FGOP kernel.

Causal attention's iteration domain is triangular: q block i attends to
kv blocks 0..i.  That is *exactly* the paper's RI stream (inner trip =
outer iterator + 1, stretch s_ji = +1), and the diagonal block's partial
tile is the implicit-vector-masking case (Feature 4).  On a rectangular
vector machine this costs 2x wasted work or scalar tails; here the
off-triangle blocks are predicated off with pl.when (compute skipped on
TPU) and the diagonal is lane-masked, never scalarized.

The online-softmax running (m, l, acc) carried across kv grid steps in
VMEM scratch is the ordered dependence between the "score" region
(critical, MXU) and the "rescale" region (non-critical exp/max, VPU).

GQA is folded into the BlockSpec index maps (kv head = q head * Hkv // H).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, interpret_default, tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bkv: int,
                  kv_steps: int):
    iq, ikv = pl.program_id(2), pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # inductive trip count: kv blocks 0..iq active for causal
    active = (ikv <= iq) if causal else (ikv >= 0)

    @pl.when(active)
    def _compute():
        q = q_ref[0, 0]                                   # (bq, d)
        k = k_ref[0, 0]                                   # (bkv, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)
        if causal:
            # implicit masking of the diagonal (partial) tile
            qi = iq * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 0)
            ki = ikv * bkv + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    last = iq if causal else kv_steps - 1

    @pl.when(ikv == last)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """q: (B,H,S,D); k/v: (B,Hkv,S,D), H % Hkv == 0. Returns (B,H,S,D)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0
    assert causal is False or sq == skv, "causal path assumes square attn"
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    kv_steps = cdiv(skv, bkv)
    if interpret is None:
        interpret = interpret_default()
    grp = h // hkv

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bkv=bkv, kv_steps=kv_steps),
        grid=(b, h, cdiv(sq, bq), kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h_, iq, ikv: (b_, h_, iq, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, iq, ikv: (b_, h_ // grp, ikv, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, iq, ikv: (b_, h_ // grp, ikv, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, iq, ikv: (b_, h_, iq, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
