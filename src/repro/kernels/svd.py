"""Batched one-sided Jacobi SVD (paper Fig. 6 right).

The pair loop (p, q) with q in [p+1, n) is itself an inductive (RI)
iteration domain — the inner fori_loop's lower bound depends on the outer
iterator, exactly the stream shape REVEL encodes with a stretch parameter.
The rotation-parameter region (div/sqrt chains) is the non-critical
dataflow; the two-column rotations are the critical vector region.

Works on (B, M, N) with M >= N; returns U (B,M,N), S (B,N), V (B,N,N)
with A ~= U * S @ V^T (singular values unsorted; ops.py sorts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def _rotate_pair(mat, p, q, cs, sn):
    colp = jax.lax.dynamic_slice(mat, (0, p), (mat.shape[0], 1))
    colq = jax.lax.dynamic_slice(mat, (0, q), (mat.shape[0], 1))
    newp = cs * colp - sn * colq
    newq = sn * colp + cs * colq
    mat = jax.lax.dynamic_update_slice(mat, newp, (0, p))
    return jax.lax.dynamic_update_slice(mat, newq, (0, q))


def _svd_kernel(a_ref, u_ref, s_ref, v_ref, *, m: int, n: int, sweeps: int):
    a = a_ref[0].astype(jnp.float32)
    v = jnp.eye(n, dtype=jnp.float32)

    def pair_body(p, q, av):
        a, v = av
        colp = jax.lax.dynamic_slice(a, (0, p), (m, 1))[:, 0]
        colq = jax.lax.dynamic_slice(a, (0, q), (m, 1))[:, 0]
        # ---- non-critical point region: rotation parameters ----
        alpha = jnp.sum(colp * colp)
        beta = jnp.sum(colq * colq)
        gamma = jnp.sum(colp * colq)
        small = jnp.abs(gamma) <= 1e-12 * jnp.sqrt(alpha * beta) + 1e-30
        zeta = (beta - alpha) / (2.0 * jnp.where(small, 1.0, gamma))
        t = jnp.sign(zeta) / (jnp.abs(zeta) + jnp.sqrt(1.0 + zeta * zeta))
        t = jnp.where(zeta == 0.0, 1.0, t)
        cs = jax.lax.rsqrt(1.0 + t * t)
        sn = cs * t
        cs = jnp.where(small, 1.0, cs)
        sn = jnp.where(small, 0.0, sn)
        # ---- critical region: rotate columns of A and V ----
        a = _rotate_pair(a, p, q, cs, sn)
        v = _rotate_pair(v, p, q, cs, sn)
        return a, v

    def sweep(_, av):
        def outer(p, av):
            # inductive inner bound: q in [p+1, n) — RI domain
            return jax.lax.fori_loop(
                p + 1, n, lambda q, av_: pair_body(p, q, av_), av)
        return jax.lax.fori_loop(0, n - 1, outer, av)

    a, v = jax.lax.fori_loop(0, sweeps, sweep, (a, v))
    s = jnp.sqrt(jnp.sum(a * a, axis=0))
    u = a / jnp.maximum(s, 1e-30)[None, :]
    u_ref[0] = u.astype(u_ref.dtype)
    s_ref[0] = s.astype(s_ref.dtype)
    v_ref[0] = v.astype(v_ref.dtype)


def svd_pallas(a: jax.Array, *, sweeps: int = 12,
               interpret: bool | None = None):
    b, m, n = a.shape
    assert m >= n
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_svd_kernel, m=m, n=n, sweeps=sweeps),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec((1, m, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m, n), a.dtype),
            jax.ShapeDtypeStruct((b, n), a.dtype),
            jax.ShapeDtypeStruct((b, n, n), a.dtype),
        ],
        interpret=interpret,
    )(a)
