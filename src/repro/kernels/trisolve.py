"""Batched triangular solve (paper Fig. 2 / Fig. 9 — the Solver kernel).

Forward substitution L y = b with multiple right-hand sides.  The divide
dataflow (non-critical, 1 per row) feeds the vectorized AXPY update
(critical) — production:consumption rate n-1-k:1, an inductive ordered
dependence (paper Fig. 9's a/b edge).  The trailing update is masked to
rows > k: the RI stream realized as implicit predication.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def _trisolve_kernel(l_ref, b_ref, y_ref, *, n: int, lower: bool):
    l = l_ref[0]
    y = b_ref[0]                       # (n, m) rhs, solved in place
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def outer(i, y):
        k = i if lower else n - 1 - i
        # point region: reciprocal of the pivot (non-critical)
        inv = 1.0 / l[k, k]
        yk = y[k] * inv                # (m,) — the produced value
        y = y.at[k].set(yk)
        # critical region: masked AXPY over the remaining rows
        live = (rows > k) if lower else (rows < k)
        upd = l[:, k][:, None] * yk[None, :]
        return y - jnp.where(live[:, None], upd, 0.0)

    y = jax.lax.fori_loop(0, n, outer, y)
    y_ref[0] = y


def trisolve_pallas(l: jax.Array, b: jax.Array, *, lower: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """l: (B, N, N) triangular, b: (B, N, M) -> y with l @ y = b."""
    bsz, n, _ = l.shape
    _, n2, m = b.shape
    assert n == n2
    if interpret is None:
        interpret = interpret_default()
    return pl.pallas_call(
        functools.partial(_trisolve_kernel, n=n, lower=lower),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, n, m), b.dtype),
        interpret=interpret,
    )(l, b)
