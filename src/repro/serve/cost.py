"""Launch-cost model for overload-aware scheduling.

The mux's overload policy (:class:`repro.serve.mux.OverloadPolicy`) must
price a bucket flush *before* committing lanes: shed / preempt / coalesce
decisions are only defensible if "how expensive is this launch?" has one
answer everywhere.  That answer is::

    launch_cost = launch_overhead + lanes * model_flops * sec_per_flop

``model_flops`` comes from the registry (each :class:`repro.kernels.Variant`
carries a closed-form per-lane FLOP model — the same numbers persisted to
``BENCH_pipelines.json``); ``sec_per_flop`` is either a global default or
a per-(pipeline, variant) rate calibrated from that benchmark baseline's
measured wall-clock (:meth:`CostModel.from_bench_json`), so blocked /
tiled launches price at their *measured* cost, not a guess.  The
``launch_overhead`` term is what makes coalescing worthwhile: riding a
free lane of an already-paid launch avoids a whole overhead quantum.

All costs are seconds-shaped floats; with the default constants they are
only *relatively* meaningful (bigger = more lane time), which is all the
scheduler needs — budgets, preemption and coalescing decisions compare
costs against each other, never against the wall clock.
"""
from __future__ import annotations

import json

# Uncalibrated defaults: ~0.5 GFLOP/s/lane of useful work and a 50 us
# dispatch quantum per grid launch.  Arbitrary but *orderable* — they
# preserve the two facts the policy relies on (cost grows with model
# FLOPs; a launch has a fixed overhead worth amortizing).
DEFAULT_SEC_PER_FLOP = 2e-9
DEFAULT_LAUNCH_OVERHEAD = 5e-5


class CostModel:
    """Prices one grid launch of a dispatched variant.

    ``table`` maps ``(pipeline, variant_name) -> sec_per_flop`` rates
    calibrated from measured wall-clock; pairs absent from the table fall
    back to the uniform ``sec_per_flop``.  ``launch_overhead`` is the
    fixed per-launch cost (dispatch + compile-cache lookup + host sync)
    that batching and coalescing amortize.
    """

    def __init__(self, sec_per_flop: float = DEFAULT_SEC_PER_FLOP,
                 launch_overhead: float = DEFAULT_LAUNCH_OVERHEAD,
                 table: dict | None = None):
        self.sec_per_flop = float(sec_per_flop)
        self.launch_overhead = float(launch_overhead)
        self.table = dict(table or {})

    @classmethod
    def from_bench_json(cls, path: str = "BENCH_pipelines.json",
                        **kwargs) -> "CostModel":
        """Calibrate per-(pipeline, variant) sec/FLOP rates from the
        persisted benchmark baseline: for every ``variants`` record with
        a positive FLOP model, rate = wall_us * 1e-6 / model_flops; the
        median across that variant's measured sizes becomes the table
        entry.  Unmeasured pairs keep the uniform default rate."""
        with open(path) as f:
            payload = json.load(f)
        rates: dict[tuple, list[float]] = {}
        for rec in payload.get("variants", ()):
            flops = rec.get("model_flops", 0.0)
            wall = rec.get("wall_us", 0.0)
            if flops > 0.0 and wall > 0.0:
                key = (rec["pipeline"], rec["variant"])
                rates.setdefault(key, []).append(wall * 1e-6 / flops)
        table = {k: sorted(v)[len(v) // 2] for k, v in rates.items()}
        return cls(table=table, **kwargs)

    def rate(self, pipeline: str, variant_name: str) -> float:
        return self.table.get((pipeline, variant_name), self.sec_per_flop)

    def lane_cost(self, pipeline: str, variant, shapes) -> float:
        """Seconds of lane time for ONE lane of ``variant`` at per-lane
        ``shapes`` (``variant`` is a registry Variant)."""
        return variant.model_flops(shapes) * self.rate(pipeline,
                                                       variant.name)

    def launch_cost(self, pipeline: str, variant, shapes,
                    lanes: int = 1) -> float:
        """Seconds for one grid launch ``lanes`` wide.  Padded filler
        lanes execute the same program, so callers price the full pool
        width — which is also why a coalesced rider lane is free at the
        margin: its lane time was already paid for as filler."""
        return self.launch_overhead + lanes * self.lane_cost(
            pipeline, variant, shapes)
