"""Self-tuning launch-cost model: predict -> measure -> re-fit.

The mux's overload policy (:class:`repro.serve.mux.OverloadPolicy`) must
price a bucket flush *before* committing lanes: shed / preempt / coalesce
decisions are only defensible if "how expensive is this launch?" has one
answer everywhere.  That answer is::

    launch_cost = launch_overhead + lanes * model_flops * sec_per_flop

``model_flops`` comes from the registry (each :class:`repro.kernels.Variant`
carries a closed-form per-lane FLOP model); ``sec_per_flop`` is a
per-(pipeline, variant) rate and ``launch_overhead`` the fixed per-launch
cost (dispatch + compile-cache lookup + host sync) that batching and
coalescing amortize.  Both start as guesses or as an offline calibration
(:meth:`CostModel.from_bench_json` — medians of the committed
``BENCH_pipelines.json`` wall-clock) and, unlike the one-shot model this
replaces, neither is trusted forever:

**The online loop.**  Every serve-side flush measures its wall-clock
(:meth:`repro.serve.core.EngineCore.dispatch_group` stamps it onto the
:class:`~repro.serve.metrics.LaunchRecord`) and feeds it back through
:meth:`CostModel.observe`.  Each observation

1. records the **drift** of that (pipeline, variant) pair — the EWMA of
   predicted/measured launch-cost ratios, exposed per pair (with its
   calibration source: ``default`` / ``bench`` / ``online``) through
   :meth:`drift` and folded into ``MetricsSnapshot`` so a mispriced
   variant is visible in SLO reports *before* it costs attainment; and
2. when the model is **adaptive** (``CostModel(adaptive=True)`` or
   ``REPRO_SERVE_CALIBRATE=1`` — see :mod:`repro.serve.config`),
   re-fits the pair's ``sec_per_flop`` and the shared
   ``launch_overhead`` by coordinate descent on the residuals::

       overhead_sample = measured - flops * rate[pair]     # rate held
       rate_sample     = (measured - overhead) / flops     # oh held

   Each sample stream runs through a :class:`RobustEstimator` — the
   MEDIAN of every ``calibration_window`` samples is EWMA-blended
   (``calibration_alpha``), and the estimate only *replaces* the seeded
   value after ``calibration_warmup`` window-medians — so one outlier
   flush (GC pause, first-touch page faults, a neighbor's compile)
   cannot destabilize admission.  Samples are clamped to positivity
   floors: no measurement stream can drive an estimate non-positive.

All costs are seconds-shaped floats; with the default constants they are
only *relatively* meaningful (bigger = more lane time), which is all the
scheduler needs — budgets, preemption and coalescing decisions compare
costs against each other, never against the wall clock.  Once the online
loop has warmed up they converge toward real wall-clock seconds, which
is what makes the drift ratio (predicted/measured, 1.0 = perfectly
priced) a meaningful SLO-side observable.

Every knob (alpha, window, warmup, floors, alert threshold, master
switch) lives in :class:`repro.serve.config.ServeConfig` behind a
``REPRO_SERVE_*`` env var — deployments pin or free calibration without
code edits.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import math

from repro.serve.config import global_config

log = logging.getLogger(__name__)

# Uncalibrated defaults: ~0.5 GFLOP/s/lane of useful work and a 50 us
# dispatch quantum per grid launch.  Arbitrary but *orderable* — they
# preserve the two facts the policy relies on (cost grows with model
# FLOPs; a launch has a fixed overhead worth amortizing) until the
# online loop replaces them with measured values.
DEFAULT_SEC_PER_FLOP = 2e-9
DEFAULT_LAUNCH_OVERHEAD = 5e-5
# Decode pricing phases (maxtext's experimental_decode_microbenchmark
# shape): "prefill" steps consume prompt tokens, "generate" steps
# consume previously generated tokens, "insert" is the slot-assignment
# bookkeeping between them (no model FLOPs — pure fixed cost).
DECODE_PHASES = ("prefill", "insert", "generate")
# Extra fixed cost per additional mesh shard participating in a sharded
# flush (collective setup + multi-device dispatch) — 20% of the launch
# overhead per shard until the sharded bench rows calibrate the real
# per-mesh overhead table.  Monotone in mesh size, so splitting is never
# priced as free.
DEFAULT_SHARD_OVERHEAD = 1e-5


def _median(vals) -> float:
    """True median: the average of the two middle elements for
    even-length inputs (``sorted(v)[len(v) // 2]`` is the UPPER middle
    element, which biased every calibrated rate upward)."""
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    if n % 2:
        return s[mid]
    return 0.5 * (s[mid - 1] + s[mid])


class RobustEstimator:
    """EWMA-of-window-medians with an update-count warmup.

    ``value`` stays at the seeded ``initial`` until ``warmup`` full
    windows have been folded; from then on it is the running EWMA of
    window medians.  Because every applied value is a convex combination
    of medians of observed (floored) samples, the warmed estimate always
    lies within the observed sample envelope ``[min(sample),
    max(sample)]`` and can never go non-positive — the property the
    fuzzed calibration tests pin.
    """

    def __init__(self, initial: float, *, alpha: float, window: int,
                 warmup: int, floor: float):
        self.initial = float(initial)
        self.alpha = float(alpha)
        self.window = max(1, int(window))
        self.warmup = max(1, int(warmup))
        self.floor = float(floor)
        self.updates = 0            # window-medians folded so far
        self.samples = 0
        self._est = math.nan        # EWMA of window medians
        self._buf: list[float] = []

    @property
    def warmed(self) -> bool:
        return self.updates >= self.warmup

    @property
    def value(self) -> float:
        return self._est if self.warmed else self.initial

    def observe(self, sample: float) -> bool:
        """Fold one sample; returns True when a full window was folded
        (i.e. the running estimate moved)."""
        self.samples += 1
        self._buf.append(max(self.floor, float(sample)))
        if len(self._buf) < self.window:
            return False
        med = _median(self._buf)
        self._buf.clear()
        self.updates += 1
        if self.updates == 1:
            self._est = med         # jump to the first median: the
        else:                       # seeded value never leaks into the
            self._est += self.alpha * (med - self._est)   # envelope
        return True


@dataclasses.dataclass(frozen=True)
class DriftStat:
    """Predicted-vs-measured health of one (pipeline, variant) pair.

    ``ratio`` is the EWMA of per-launch predicted/measured launch-cost
    ratios (1.0 = perfectly priced, >1 overpriced, <1 underpriced;
    NaN until the pair has been observed); ``last`` the most recent
    ratio; ``updates`` how many flushes have been observed; ``source``
    where the pair's current rate comes from (``"default"`` /
    ``"bench"`` / ``"online"``); ``alert`` whether ``|log(ratio)|``
    exceeds the configured ``drift_alert_ratio``.

    ``mesh`` is the shard count the launches spanned: drift is
    attributed per (pipeline, variant, mesh_size), so a mispriced
    sharded path is visible separately from the single-device path it
    shares rates with.  Single-device stats keep the legacy
    ``"pipeline/variant"`` key; sharded ones append ``"@meshN"``."""

    pipeline: str
    variant: str
    ratio: float
    last: float
    updates: int
    source: str
    alert: bool
    mesh: int = 1

    @property
    def key(self) -> str:
        base = f"{self.pipeline}/{self.variant}"
        return base if self.mesh <= 1 else f"{base}@mesh{self.mesh}"


class _PairDrift:
    """Mutable per-pair drift accumulator behind :class:`DriftStat`."""

    __slots__ = ("ratio", "last", "updates")

    def __init__(self):
        self.ratio = math.nan
        self.last = math.nan
        self.updates = 0

    def observe(self, ratio: float, alpha: float) -> None:
        self.last = ratio
        self.updates += 1
        if math.isnan(self.ratio):
            self.ratio = ratio
        else:
            self.ratio += alpha * (ratio - self.ratio)


class CostModel:
    """Prices one grid launch of a dispatched variant — and, when
    adaptive, re-fits itself from measured launch wall-clock.

    ``table`` maps ``(pipeline, variant_name) -> sec_per_flop`` rates;
    pairs absent from the table fall back to the uniform
    ``sec_per_flop``.  ``launch_overhead`` is the fixed per-launch cost
    that batching and coalescing amortize — the coalescing lever, and
    the number the online loop most needs to measure (module docstring).

    ``adaptive=None`` defers to ``config.calibrate``
    (``REPRO_SERVE_CALIBRATE``); ``config`` defaults to the process-wide
    :data:`repro.serve.config.global_config`.
    """

    def __init__(self, sec_per_flop: float = DEFAULT_SEC_PER_FLOP,
                 launch_overhead: float = DEFAULT_LAUNCH_OVERHEAD,
                 table: dict | None = None, *,
                 adaptive: bool | None = None, config=None,
                 calibrated: frozenset | None = None,
                 shard_overhead: float = DEFAULT_SHARD_OVERHEAD,
                 mesh_overhead: dict | None = None):
        self.config = config if config is not None else global_config
        self.sec_per_flop = float(sec_per_flop)
        self.launch_overhead = float(launch_overhead)
        self.table = dict(table or {})
        self.adaptive = (self.config.calibrate if adaptive is None
                         else bool(adaptive))
        #: pairs whose rate came from the offline bench calibration —
        #: surfaced as ``source="bench"`` in the drift metrics so
        #: "calibrated vs default" is visible per pair.
        self.calibrated = frozenset(calibrated if calibrated is not None
                                    else self.table)
        #: per-extra-shard fixed cost used by :meth:`overhead` for mesh
        #: sizes absent from the calibrated ``mesh_overhead`` table.
        self.shard_overhead = float(shard_overhead)
        #: ``mesh_size -> fixed overhead`` of one mesh-spanning launch,
        #: calibrated from the sharded bench rows
        #: (:meth:`from_bench_json`) or re-fit online per mesh size.
        self.mesh_overhead = dict(mesh_overhead or {})
        self._drift: dict[tuple, _PairDrift] = {}
        self._rate_est: dict[tuple, RobustEstimator] = {}
        self._oh_est = self._estimator(self.launch_overhead,
                                       self.config.overhead_floor)
        self._mesh_oh_est: dict[int, RobustEstimator] = {}

    def _estimator(self, initial: float, floor: float) -> RobustEstimator:
        cfg = self.config
        return RobustEstimator(initial, alpha=cfg.calibration_alpha,
                               window=cfg.calibration_window,
                               warmup=cfg.calibration_warmup, floor=floor)

    # ---------------- offline calibration ----------------

    @classmethod
    def from_bench_json(cls, path: str | None = None,
                        **kwargs) -> "CostModel":
        """Calibrate per-(pipeline, variant) sec/FLOP rates from the
        persisted benchmark baseline: for every ``variants`` record with
        a positive FLOP model, rate = wall_us * 1e-6 / model_flops; the
        true median across that variant's measured sizes becomes the
        table entry.  Unmeasured pairs keep the uniform default rate.

        A missing, unreadable, or malformed baseline — and a baseline
        with no usable rows — falls back to an UNCALIBRATED model with a
        logged warning instead of raising deep inside mux construction;
        the resulting all-``default`` sources show up in the drift
        metrics."""
        config = kwargs.get("config") or global_config
        if path is None:
            path = config.bench_json
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            log.warning("cost model: cannot read bench baseline %s (%s); "
                        "falling back to uncalibrated defaults", path, e)
            return cls(**kwargs)
        rates: dict[tuple, list[float]] = {}
        try:
            for rec in payload.get("variants", ()):
                flops = rec.get("model_flops", 0.0)
                wall = rec.get("wall_us", 0.0)
                if flops > 0.0 and wall > 0.0:
                    key = (rec["pipeline"], rec["variant"])
                    rates.setdefault(key, []).append(wall * 1e-6 / flops)
        except (KeyError, TypeError, AttributeError) as e:
            log.warning("cost model: malformed bench baseline %s (%s); "
                        "falling back to uncalibrated defaults", path, e)
            return cls(**kwargs)
        # decode phase rows (optional — older baselines lack them): each
        # carries one phase's measured wall + token FLOPs; the median
        # rate lands in the table under the ("decode", phase) pseudo-pair
        # (see decode_rate).  Zero-FLOP phases (insert) stay uncalibrated
        # — they are priced as pure overhead.
        try:
            for rec in payload.get("decode", ()):
                flops = rec.get("flops", 0.0)
                wall = rec.get("wall_us", 0.0)
                if flops > 0.0 and wall > 0.0:
                    key = ("decode", rec["phase"])
                    rates.setdefault(key, []).append(wall * 1e-6 / flops)
        except (KeyError, TypeError, AttributeError) as e:
            log.warning("cost model: malformed decode rows in %s (%s); "
                        "ignoring them", path, e)
        if not rates:
            log.warning("cost model: bench baseline %s has no usable "
                        "variant rows; falling back to uncalibrated "
                        "defaults", path)
            return cls(**kwargs)
        table = {k: _median(v) for k, v in rates.items()}
        # sharded rows (optional — older baselines lack them): each
        # carries the median measured wall of mesh-spanning launches;
        # the residual over the calibrated lane work is that mesh
        # size's fixed overhead.
        mesh_oh: dict[int, list[float]] = {}
        try:
            for rec in payload.get("sharded", ()):
                mesh = int(rec.get("mesh", 1))
                wall = rec.get("wall_us", 0.0)
                flops = rec.get("model_flops", 0.0)
                lanes = int(rec.get("lanes", 0))
                if mesh <= 1 or wall <= 0.0 or lanes <= 0:
                    continue
                rate = table.get((rec["pipeline"], rec["variant"]),
                                 DEFAULT_SEC_PER_FLOP)
                residual = wall * 1e-6 \
                    - math.ceil(lanes / mesh) * flops * rate
                mesh_oh.setdefault(mesh, []).append(residual)
        except (KeyError, TypeError, AttributeError, ValueError) as e:
            log.warning("cost model: malformed sharded rows in %s (%s); "
                        "ignoring them", path, e)
            mesh_oh = {}
        if mesh_oh and "mesh_overhead" not in kwargs:
            floor = config.overhead_floor
            kwargs["mesh_overhead"] = {m: max(_median(v), floor)
                                       for m, v in mesh_oh.items()}
        return cls(table=table, **kwargs)

    # ---------------- pricing ----------------

    def rate(self, pipeline: str, variant_name: str) -> float:
        return self.table.get((pipeline, variant_name), self.sec_per_flop)

    def lane_cost(self, pipeline: str, variant, shapes) -> float:
        """Seconds of lane time for ONE lane of ``variant`` at per-lane
        ``shapes`` (``variant`` is a registry Variant)."""
        return variant.model_flops(shapes) * self.rate(pipeline,
                                                       variant.name)

    def overhead(self, mesh: int = 1) -> float:
        """Fixed cost of one launch spanning ``mesh`` shards: the plain
        ``launch_overhead`` for a single-device launch, the calibrated
        per-mesh entry when the sharded bench rows (or the online loop)
        have measured that mesh size, else a linear
        ``launch_overhead + (mesh - 1) * shard_overhead`` estimate —
        monotone in mesh size, so a sharded flush is never priced
        cheaper than the same work on one shard plus zero."""
        if mesh <= 1:
            return self.launch_overhead
        got = self.mesh_overhead.get(int(mesh))
        if got is not None:
            return got
        return self.launch_overhead + (mesh - 1) * self.shard_overhead

    def launch_cost(self, pipeline: str, variant, shapes,
                    lanes: int = 1, mesh: int = 1) -> float:
        """Seconds for one grid launch ``lanes`` wide.  Padded filler
        lanes execute the same program, so callers price the full pool
        width — which is also why a coalesced rider lane is free at the
        margin: its lane time was already paid for as filler.

        ``mesh > 1`` prices a mesh-spanning sharded flush: shards run
        their lane slabs in parallel, so the lane term divides by the
        shard count (``ceil`` — the padded width is what each shard
        executes) while the fixed term grows to :meth:`overhead`.

        ``mesh`` is the count of shards actually PARTICIPATING in the
        launch, not the configured mesh size: under graceful
        degradation (a quarantined shard, see
        :class:`repro.serve.shard.LaneShards`) the scheduler stops
        spanning and falls back to per-shard local launches priced at
        ``mesh=1`` — capacity loss shows up as honestly higher
        predicted cost rather than a stale full-mesh price.
        """
        if mesh <= 1:
            return self.launch_overhead + lanes * self.lane_cost(
                pipeline, variant, shapes)
        return self.overhead(mesh) + math.ceil(lanes / mesh) \
            * self.lane_cost(pipeline, variant, shapes)

    # ---------------- decode pricing ----------------

    def decode_rate(self, phase: str) -> float:
        """sec/FLOP of one decode ``phase`` (:data:`DECODE_PHASES`).
        Decode rates live in the same ``table`` under the pseudo-pair
        ``("decode", phase)``, so calibration source ("default" /
        "bench" / "online") and drift reporting come for free from the
        machinery above."""
        return self.table.get(("decode", phase), self.sec_per_flop)

    def decode_cost(self, phase: str, flops: float = 0.0) -> float:
        """Seconds for one pool-wide SPMD decode step of ``phase``:
        the fixed launch overhead plus the step's token FLOPs (active
        slots x per-token FLOPs from the decode spec) at the phase's
        rate.  ``insert`` carries no FLOPs — it is priced as pure
        overhead."""
        return self.launch_overhead + flops * self.decode_rate(phase)

    def observe_decode(self, phase: str, flops: float,
                       measured: float) -> None:
        """Feed one measured decode step back into the model: drift is
        tracked under the ``("decode", phase)`` pseudo-pair (surfacing
        as ``"decode/<phase>"`` in :meth:`drift`), and — when adaptive —
        the phase's sec/FLOP rate is re-fit through the same robust
        estimator stream the solver rates use.  The shared launch
        overhead is NOT re-fit from decode steps: solver flushes own
        that estimator, and a decode step's fixed cost is far smaller
        than a padded grid launch's."""
        if measured is None or not math.isfinite(measured) \
                or measured <= 0.0:
            return
        pair = ("decode", phase)
        predicted = self.decode_cost(phase, flops)
        drift = self._drift.get((*pair, 1))
        if drift is None:
            drift = self._drift[(*pair, 1)] = _PairDrift()
        drift.observe(predicted / measured, self.config.calibration_alpha)
        if not self.adaptive or flops <= 0.0:
            return
        est = self._rate_est.get(pair)
        if est is None:
            est = self._rate_est[pair] = self._estimator(
                self.decode_rate(phase), self.config.rate_floor)
        rate_sample = (measured - self.launch_overhead) / flops
        if est.observe(rate_sample) and est.warmed:
            self.table[pair] = est.value

    # ---------------- the online loop ----------------

    def observe(self, pipeline: str, variant, shapes, lanes: int,
                measured: float, mesh: int = 1) -> None:
        """Feed one measured launch back into the model (module
        docstring): record the pair's drift ratio, and — when adaptive —
        re-fit its ``sec_per_flop`` and the shared ``launch_overhead``
        through the robust estimators.  Non-positive / non-finite
        measurements are ignored.

        ``mesh > 1`` attributes the observation to the (pipeline,
        variant, mesh_size) triple: drift is tracked separately per mesh
        size, and — when adaptive — the measurement re-fits that mesh's
        :attr:`mesh_overhead` entry (the wall-clock is parallel time, so
        it must NOT feed the per-lane rate stream)."""
        if measured is None or not math.isfinite(measured) \
                or measured <= 0.0:
            return
        mesh = max(1, int(mesh))
        pair = (pipeline, variant.name)
        predicted = self.launch_cost(pipeline, variant, shapes, lanes,
                                     mesh=mesh)
        drift = self._drift.get((*pair, mesh))
        if drift is None:
            drift = self._drift[(*pair, mesh)] = _PairDrift()
        drift.observe(predicted / measured, self.config.calibration_alpha)
        if not self.adaptive:
            return
        cfg = self.config
        if mesh > 1:
            # sharded flush: measured is the parallel makespan.  The
            # per-shard lane work is ceil(lanes/mesh) lanes; the
            # residual re-fits this mesh size's fixed overhead.
            per_shard = math.ceil(lanes / mesh) \
                * self.lane_cost(pipeline, variant, shapes)
            est = self._mesh_oh_est.get(mesh)
            if est is None:
                est = self._mesh_oh_est[mesh] = self._estimator(
                    self.overhead(mesh), cfg.overhead_floor)
            if est.observe(measured - per_shard) and est.warmed:
                self.mesh_overhead[mesh] = est.value
            return
        flops = lanes * variant.model_flops(shapes)
        # coordinate descent on the residuals: overhead sample with the
        # pair's CURRENT rate held fixed, then the rate sample with the
        # current overhead held fixed — a wrong overhead cannot poison
        # the rate stream once its own estimator has warmed, and vice
        # versa.
        oh_sample = measured - flops * self.rate(*pair)
        if self._oh_est.observe(oh_sample) and self._oh_est.warmed:
            self.launch_overhead = self._oh_est.value
        if flops > 0.0:
            est = self._rate_est.get(pair)
            if est is None:
                est = self._rate_est[pair] = self._estimator(
                    self.rate(*pair), cfg.rate_floor)
            rate_sample = (measured - self.launch_overhead) / flops
            if est.observe(rate_sample) and est.warmed:
                self.table[pair] = est.value

    def source(self, pipeline: str, variant_name: str) -> str:
        """Where the pair's current rate comes from: ``"online"`` once
        its estimator has warmed, else ``"bench"`` for offline-calibrated
        pairs, else ``"default"``."""
        pair = (pipeline, variant_name)
        est = self._rate_est.get(pair)
        if est is not None and est.warmed:
            return "online"
        return "bench" if pair in self.calibrated else "default"

    def drift(self) -> dict[str, DriftStat]:
        """Per-pair drift health, keyed ``"pipeline/variant"``
        (single-device) or ``"pipeline/variant@meshN"`` (sharded) —
        every (pipeline, variant, mesh) triple that has been observed,
        plus every pair that carries a calibrated rate (so
        bench-calibrated pairs that never see traffic still report
        their source with ``updates=0``)."""
        alert_logratio = math.log(self.config.drift_alert_ratio)
        out: dict[str, DriftStat] = {}
        keys = set(self._drift) | {(p, v, 1) for p, v in
                                   self.calibrated | set(self.table)}
        for pipeline, vname, mesh in sorted(keys):
            d = self._drift.get((pipeline, vname, mesh))
            ratio = d.ratio if d is not None else math.nan
            alert = bool(ratio > 0
                         and abs(math.log(ratio)) > alert_logratio) \
                if (d is not None and math.isfinite(ratio)) else False
            stat = DriftStat(pipeline=pipeline, variant=vname,
                             ratio=ratio,
                             last=d.last if d is not None else math.nan,
                             updates=d.updates if d is not None else 0,
                             source=self.source(pipeline, vname),
                             alert=alert, mesh=mesh)
            out[stat.key] = stat
        return out

    def worst_drift(self) -> DriftStat | None:
        """The observed pair whose EWMA ratio is furthest from 1.0 in
        log space — the first place to look when attainment slips."""
        worst, worst_mag = None, -1.0
        for stat in self.drift().values():
            if stat.updates == 0 or not math.isfinite(stat.ratio) \
                    or stat.ratio <= 0:
                continue
            mag = abs(math.log(stat.ratio))
            if mag > worst_mag:
                worst, worst_mag = stat, mag
        return worst

    def calibration_updates(self) -> dict[str, int]:
        """Applied window-median update counts per estimator (the
        ``"overhead"`` key plus one per pair, plus one
        ``"overhead@meshN"`` per observed mesh size) — the observability
        hook for "is the loop actually learning?"."""
        out = {"overhead": self._oh_est.updates}
        for (pipeline, vname), est in sorted(self._rate_est.items()):
            out[f"{pipeline}/{vname}"] = est.updates
        for mesh, est in sorted(self._mesh_oh_est.items()):
            out[f"overhead@mesh{mesh}"] = est.updates
        return out
