"""Mesh-sharded lane pools: :class:`LaneShards`.

The paper's lane dimension is batch-parallel — every pipeline grid
declares it ``("parallel", ...)`` — so a flush's lane axis shards
trivially across a 1-D device mesh: each device executes its own slab
of lanes in lockstep and the outputs gather back.  ``LaneShards`` is
the serve-side handle on that mesh:

  * **wrapping** — :meth:`wrap` turns a pipeline entry point into its
    mesh-spanning form via the version-portable
    :func:`repro.distributed.sharding.shard_map` shim (``P(axis)`` on
    the batch dim of every input and output; trailing dims replicated).
    Because lanes are independent, the sharded program is bit-identical
    to the single-device launch on the same batch — the property the
    sharded-serve tests pin.
  * **placement** — non-spanning launches are committed to one shard's
    device (:attr:`devices`); :meth:`pick` chooses the least-loaded
    shard (optionally budget-first, for the mux's per-shard admission).
  * **load accounting** — :meth:`note` / :meth:`note_all` accumulate
    priced launch cost per shard; :meth:`imbalance` is the max/mean
    skew the metrics snapshot reports.
  * **health** — per-shard consecutive-failure streaks
    (:meth:`note_failure` / :meth:`note_success`).  A shard whose
    streak reaches the quarantine threshold is **quarantined**: it
    stops receiving placements (:meth:`pick` restricted to
    :meth:`healthy`), aggregate capacity shrinks, and the mux stops
    offering mesh-spanning launches (which would execute on the dead
    device).  After ``probe_after`` scheduling-clock seconds the shard
    becomes :meth:`probe_due`: the mux routes one real launch at it as
    a probe — success reinstates (:meth:`reinstate`), failure re-arms
    the quarantine timer.

A ``LaneShards`` over a 1-device mesh is legal but pointless — the mux
only constructs one for ``mesh_size > 1`` so the single-device path
stays exactly the code it always was.
"""
from __future__ import annotations

import math

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


class LaneShards:
    """One 1-D lane mesh + per-shard load accounting for a SolverMux."""

    def __init__(self, mesh, axis: str = "data"):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no {axis!r} axis: "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self.devices = tuple(np.ravel(mesh.devices))
        self.size = len(self.devices)
        self.load = [0.0] * self.size
        # per-shard health: consecutive launch-failure streaks and
        # quarantine state (see the module docstring)
        self.fail_streak = [0] * self.size
        self.quarantined_at: list[float | None] = [None] * self.size
        self.quarantines = 0            # lifetime count (metrics)
        self.reinstatements = 0
        self.recovery_times: list[float] = []

    @classmethod
    def build(cls, size: int, axis: str = "data") -> "LaneShards":
        """Construct over the first ``size`` local devices (on CPU this
        needs virtual devices — :mod:`repro.launch.xla_env`)."""
        from repro.launch.mesh import make_lane_mesh
        return cls(make_lane_mesh(size, axis=axis), axis=axis)

    # ---------------- sharded launch path ----------------

    def wrap(self, fn, nargs: int):
        """Mesh-spanning form of a pipeline entry point: batch dim 0 of
        all ``nargs`` inputs and of the output is split over the lane
        axis; each shard sees its own contiguous lane slab.  The caller
        is responsible for padding the batch to a multiple of
        ``size * lanes_per_device`` so no shard sees a partial
        remainder (``EngineCore.dispatch_group`` pads to the full
        ``lanes * mesh`` width)."""
        spec = P(self.axis)
        return shard_map(fn, mesh=self.mesh,
                         in_specs=(spec,) * nargs, out_specs=spec)

    # ---------------- health / quarantine ----------------

    def quarantined(self, shard: int) -> bool:
        return self.quarantined_at[shard] is not None

    def healthy(self) -> list[int]:
        """Shards eligible for placement (not quarantined)."""
        return [s for s in range(self.size) if not self.quarantined(s)]

    def all_healthy(self) -> bool:
        return all(q is None for q in self.quarantined_at)

    def note_failure(self, shard: int, t: float,
                     threshold: int) -> bool:
        """Account one launch failure on ``shard`` at scheduling time
        ``t``.  Returns True when this failure newly quarantines the
        shard (streak reached ``threshold``); a failure on an
        already-quarantined shard (a failed probe) re-arms its timer
        instead."""
        self.fail_streak[shard] += 1
        if self.quarantined(shard):
            self.quarantined_at[shard] = t          # re-arm probe timer
            return False
        if threshold > 0 and self.fail_streak[shard] >= threshold:
            self.quarantined_at[shard] = t
            self.quarantines += 1
            return True
        return False

    def note_success(self, shard: int) -> None:
        self.fail_streak[shard] = 0

    def probe_due(self, t: float, after: float) -> list[int]:
        """Quarantined shards whose sit-out window has elapsed — each is
        owed one probe launch."""
        return [s for s in range(self.size)
                if self.quarantined_at[s] is not None
                and t - self.quarantined_at[s] >= after]

    def reinstate(self, shard: int, t: float,
                  quarantined_since: float) -> float:
        """Return a probed shard to service; returns its downtime (the
        time-to-recover observable)."""
        downtime = t - quarantined_since
        self.quarantined_at[shard] = None
        self.fail_streak[shard] = 0
        self.reinstatements += 1
        self.recovery_times.append(downtime)
        return downtime

    # ---------------- placement / balancing ----------------

    def pick(self, budgets: list[float] | None = None,
             among: list[int] | None = None) -> int:
        """Shard for the next non-spanning launch: most remaining
        budget first (when per-shard budgets are in play), least
        accumulated load second, lowest index last — deterministic, so
        replayed traces place identically.  ``among`` restricts the
        candidates (the mux passes :meth:`healthy` while any shard is
        quarantined; an empty restriction falls back to all shards)."""
        shards = among if among else range(self.size)
        if budgets is None:
            return max(shards, key=lambda s: (-self.load[s], -s))
        return max(shards, key=lambda s: (budgets[s], -self.load[s], -s))

    def note(self, shard: int, cost: float) -> None:
        self.load[shard] += cost

    def note_all(self, cost: float) -> None:
        """A mesh-spanning launch occupies every shard for its
        duration."""
        for s in range(self.size):
            self.load[s] += cost

    def imbalance(self) -> float:
        """max/mean accumulated load across shards (1.0 = perfectly
        balanced; NaN before any launch)."""
        total = sum(self.load)
        if total <= 0.0:
            return math.nan
        return max(self.load) / (total / self.size)
