"""Mesh-sharded lane pools: :class:`LaneShards`.

The paper's lane dimension is batch-parallel — every pipeline grid
declares it ``("parallel", ...)`` — so a flush's lane axis shards
trivially across a 1-D device mesh: each device executes its own slab
of lanes in lockstep and the outputs gather back.  ``LaneShards`` is
the serve-side handle on that mesh:

  * **wrapping** — :meth:`wrap` turns a pipeline entry point into its
    mesh-spanning form via the version-portable
    :func:`repro.distributed.sharding.shard_map` shim (``P(axis)`` on
    the batch dim of every input and output; trailing dims replicated).
    Because lanes are independent, the sharded program is bit-identical
    to the single-device launch on the same batch — the property the
    sharded-serve tests pin.
  * **placement** — non-spanning launches are committed to one shard's
    device (:attr:`devices`); :meth:`pick` chooses the least-loaded
    shard (optionally budget-first, for the mux's per-shard admission).
  * **load accounting** — :meth:`note` / :meth:`note_all` accumulate
    priced launch cost per shard; :meth:`imbalance` is the max/mean
    skew the metrics snapshot reports.

A ``LaneShards`` over a 1-device mesh is legal but pointless — the mux
only constructs one for ``mesh_size > 1`` so the single-device path
stays exactly the code it always was.
"""
from __future__ import annotations

import math

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


class LaneShards:
    """One 1-D lane mesh + per-shard load accounting for a SolverMux."""

    def __init__(self, mesh, axis: str = "data"):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no {axis!r} axis: "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self.devices = tuple(np.ravel(mesh.devices))
        self.size = len(self.devices)
        self.load = [0.0] * self.size

    @classmethod
    def build(cls, size: int, axis: str = "data") -> "LaneShards":
        """Construct over the first ``size`` local devices (on CPU this
        needs virtual devices — :mod:`repro.launch.xla_env`)."""
        from repro.launch.mesh import make_lane_mesh
        return cls(make_lane_mesh(size, axis=axis), axis=axis)

    # ---------------- sharded launch path ----------------

    def wrap(self, fn, nargs: int):
        """Mesh-spanning form of a pipeline entry point: batch dim 0 of
        all ``nargs`` inputs and of the output is split over the lane
        axis; each shard sees its own contiguous lane slab.  The caller
        is responsible for padding the batch to a multiple of
        ``size * lanes_per_device`` so no shard sees a partial
        remainder (``EngineCore.dispatch_group`` pads to the full
        ``lanes * mesh`` width)."""
        spec = P(self.axis)
        return shard_map(fn, mesh=self.mesh,
                         in_specs=(spec,) * nargs, out_specs=spec)

    # ---------------- placement / balancing ----------------

    def pick(self, budgets: list[float] | None = None) -> int:
        """Shard for the next non-spanning launch: most remaining
        budget first (when per-shard budgets are in play), least
        accumulated load second, lowest index last — deterministic, so
        replayed traces place identically."""
        if budgets is None:
            return max(range(self.size),
                       key=lambda s: (-self.load[s], -s))
        return max(range(self.size),
                   key=lambda s: (budgets[s], -self.load[s], -s))

    def note(self, shard: int, cost: float) -> None:
        self.load[shard] += cost

    def note_all(self, cost: float) -> None:
        """A mesh-spanning launch occupies every shard for its
        duration."""
        for s in range(self.size):
            self.load[s] += cost

    def imbalance(self) -> float:
        """max/mean accumulated load across shards (1.0 = perfectly
        balanced; NaN before any launch)."""
        total = sum(self.load)
        if total <= 0.0:
            return math.nan
        return max(self.load) / (total / self.size)
