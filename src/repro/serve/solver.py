"""Single-pipeline solver serving: :class:`SolveJob` + :class:`PipelineEngine`.

``PipelineEngine`` is the one-pipeline-per-instance engine from the
original serving stack, rebased on :class:`repro.serve.core.EngineCore`:
the queue, lane accounting and registry-driven padding are shared with
the decode engine and the multi-pipeline :class:`repro.serve.mux.SolverMux`
(which is what you want for mixed traffic).
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import numpy as np

from repro.serve.core import FifoEngineCore


@dataclasses.dataclass(eq=False)
class SolveJob:
    """One solver problem.  (``eq=False``: jobs are identity objects —
    the generated field-wise ``__eq__`` would compare numpy array args,
    which raises instead of answering.)

    ``args`` are the per-problem arrays WITHOUT the batch dimension
    (e.g. cholesky_solve: ``(a (N,N), b (N,M))``); ``out`` is filled by
    the serving engine.  ``pipeline`` and ``deadline`` (absolute clock
    seconds; ``None`` = no deadline) are used by :class:`SolverMux`;
    ``submitted_at``/``finished_at`` are stamped by the engine clock and
    feed the SLO metrics; ``seq`` is the mux's global arrival order (the
    FIFO tiebreak among equal-deadline buckets).

    ``priority`` is the overload-policy traffic class: ``"hard"`` jobs
    must never be shed and may preempt; ``"best_effort"`` jobs may be
    dropped once their deadline has expired.  ``state`` is the lifecycle
    marker — ``"queued"`` until a dispatch serves it (``"done"``, ``out``
    filled), the overload policy sheds it (``"dropped"``, terminal,
    ``out`` stays ``None``), or launch supervision gives up on it
    (``"failed"``, terminal, ``out`` stays ``None``, ``reason`` set to
    the structured failure reason — e.g. ``"nonfinite_input"`` rejected
    at submit, ``"nonfinite_output"`` for a persistently poisoned lane,
    or the exhausted-retries launch error).  A job is never silently
    lost: every submitted job ends in exactly one of those states.
    """

    PRIORITIES = ("hard", "best_effort")

    args: tuple
    out: np.ndarray | None = None
    pipeline: str | None = None
    deadline: float | None = None
    submitted_at: float | None = None
    finished_at: float | None = None
    seq: int = 0
    priority: str = "best_effort"
    state: str = "queued"
    reason: str | None = None
    dag: object | None = None
    """The :class:`repro.serve.mux.DagJob` this job is a stage of
    (``None`` for ordinary standalone jobs)."""
    stage: str | None = None
    """Stage name within ``dag`` (``None`` for standalone jobs)."""
    crit: bool = False
    """True when criticality planning (``DagSpec.criticality``) put this
    stage on the DAG's critical path — the mux admits critical-stage
    buckets ahead of slack ones at equal deadline."""

    def shape_key(self) -> tuple:
        """Shape bucket: per-arg (shape, dtype) — jobs sharing it can be
        stacked into one lane group / one compiled program."""
        return tuple((np.shape(a), str(np.asarray(a).dtype))
                     for a in self.args)


def resolve_pipeline_spec(pipeline: str):
    """Registry lookup + kind check shared by the solver engines."""
    from repro import kernels as K
    spec = K.get(pipeline)
    if spec.kind != "pipeline":
        raise ValueError(f"{pipeline!r} is a {spec.kind}, "
                         "not a servable pipeline")
    return spec


class VariantDispatcher:
    """Shape-bucket -> (Variant, jit'd fn) resolution with a per-variant
    compile cache, shared by PipelineEngine and the SolverMux pools.

    Every serve-side launch goes through :meth:`resolve` — the engines
    never touch ``spec.pallas`` directly — so a bucket of large or
    split-complex jobs transparently lands on the registry's fast
    variant, with one compiled program per variant x shape bucket.
    ``options`` (e.g. ``sigma2``) are bound into every variant entry
    point alike.

    ``cost_model`` (a :class:`repro.serve.cost.CostModel`, lazily
    defaulted) makes the dispatcher the one place a bucket flush gets
    priced: :meth:`price` resolves the bucket's variant and returns the
    estimated launch cost, so admission / preemption / coalescing
    decisions all price through the same dispatch the launch will use.

    ``shards`` (a :class:`repro.serve.shard.LaneShards`, optional) adds
    the mesh-spanning resolution path: :meth:`resolve_sharded` wraps the
    same options-bound variant entry point in ``shard_map`` over the
    lane mesh, cached per (variant, arity) alongside the single-device
    cache.

    **Demotion ladder.**  Launch supervision feeds per-bucket failure
    streaks back through :meth:`note_failure` / :meth:`note_success`.
    A variant that fails ``demote_after`` consecutive supervised
    launches on one shape bucket is *banned* for that bucket: resolution
    falls to the next applicable variant in registration order
    (tiled -> blocked -> base), so a buggy fast path degrades gracefully
    instead of failing the same jobs forever.  Only variants sharing the
    spec's calling convention (``variant.filler is None``) are
    demotable — a variant with its own filler (e.g. split-complex MMSE's
    4 planes) takes different arguments, so there is nothing below it to
    fall to and its jobs fail terminally instead.
    """

    def __init__(self, spec, options: dict | None = None, cost_model=None,
                 shards=None):
        self.spec = spec
        self.options = dict(options or {})
        self.cost_model = cost_model
        self.shards = shards
        self._fns: dict[str, object] = {}
        self._sharded_fns: dict[tuple, object] = {}
        self._bans: dict[tuple, set[str]] = {}
        self._fail_streaks: dict[tuple, int] = {}
        self.demotions: list[dict] = []

    def _dispatch(self, key: tuple):
        """``dispatch_key`` with this dispatcher's per-bucket bans
        applied: first applicable non-banned variant in registration
        order, the spec's base otherwise (base is never banned)."""
        shapes = tuple(tuple(s) for s, _ in key)
        dtypes = tuple(np.dtype(dt) for _, dt in key)
        banned = self._bans.get(key, ())
        for v in self.spec.variants:
            if v.name in banned:
                continue
            if v.when(shapes, dtypes):
                return v
        return self.spec.base

    def demotable(self, key: tuple, variant) -> bool:
        """True when a failing ``variant`` on ``key`` has somewhere to
        fall: it is not the base and it shares the spec's calling
        convention (``filler is None`` — same args, so the queued jobs
        can re-resolve to the demoted variant unchanged)."""
        return variant is not self.spec.base and variant.filler is None

    def note_failure(self, key: tuple, variant,
                     demote_after: int) -> object | None:
        """Account one supervised-launch failure of ``variant`` on shape
        bucket ``key``.  When the consecutive streak reaches
        ``demote_after`` and the variant is demotable, ban it for this
        bucket and return the variant resolution falls to (the mux turns
        that into a ``demote`` event + alert); otherwise return None."""
        sk = (key, variant.name)
        self._fail_streaks[sk] = self._fail_streaks.get(sk, 0) + 1
        if (demote_after > 0 and self._fail_streaks[sk] >= demote_after
                and self.demotable(key, variant)):
            self._bans.setdefault(key, set()).add(variant.name)
            self._fail_streaks.pop(sk, None)
            fallback = self._dispatch(key)
            self.demotions.append({
                "pipeline": self.spec.name, "key": key,
                "from": variant.name, "to": fallback.name})
            return fallback
        return None

    def note_success(self, key: tuple, variant) -> None:
        self._fail_streaks.pop((key, variant.name), None)

    def resolve(self, key: tuple):
        """``key`` is a SolveJob.shape_key(): per-arg ((shape, dtype)).
        Returns the dispatched registry Variant and its jit'd, options-
        bound entry point."""
        variant = self._dispatch(key)
        fn = self._fns.get(variant.name)
        if fn is None:
            fn = jax.jit(functools.partial(variant.fn, **self.options))
            self._fns[variant.name] = fn
        return variant, fn

    def resolve_sharded(self, key: tuple):
        """Mesh-spanning counterpart of :meth:`resolve`: the same
        dispatched variant, wrapped over the lane mesh so the batch dim
        splits across shards.  Requires ``shards``."""
        if self.shards is None:
            raise ValueError(
                f"{self.spec.name!r} dispatcher has no lane shards; "
                "sharded resolution needs a mesh")
        variant = self._dispatch(key)
        cache_key = (variant.name, len(key))
        fn = self._sharded_fns.get(cache_key)
        if fn is None:
            fn = jax.jit(self.shards.wrap(
                functools.partial(variant.fn, **self.options), len(key)))
            self._sharded_fns[cache_key] = fn
        return variant, fn

    def price(self, key: tuple, lanes: int = 1, mesh: int = 1) -> float:
        """Estimated launch cost (cost-model seconds) of flushing one
        ``lanes``-wide grid of this shape bucket through whichever
        variant :meth:`resolve` dispatches it to.  ``mesh > 1`` prices
        the mesh-spanning form of the same flush (lanes split across
        shards, per-mesh launch overhead)."""
        if self.cost_model is None:
            from repro.serve.cost import CostModel
            self.cost_model = CostModel()
        variant, _ = self.resolve(key)
        shapes = tuple(shape for shape, _ in key)
        return self.cost_model.launch_cost(self.spec.name, variant,
                                           shapes, lanes, mesh=mesh)


class PipelineEngine(FifoEngineCore):
    """Batched solver service over a single registered pipeline.

    Jobs are grouped by problem shape, stacked, padded to a multiple of
    the ``lanes`` pool size with the spec's declared benign filler
    (padded lanes' results are discarded), and executed as one grid
    launch per group, routed through ``KernelSpec.dispatch`` so each
    shape group lands on the right performance variant.  ``pipeline`` is
    any ``kind="pipeline"`` name in the kernel registry; extra keyword
    ``options`` (e.g. ``sigma2`` for mmse_equalize) are bound into the
    served kernel.
    """

    def __init__(self, pipeline: str = "cholesky_solve", lanes: int = 8,
                 clock=None, **options):
        super().__init__(lanes, clock=clock)
        self.spec = resolve_pipeline_spec(pipeline)
        self._dispatcher = VariantDispatcher(self.spec, options)

    def submit(self, job: SolveJob) -> SolveJob:
        job.pipeline = self.spec.name
        return super().submit(job)

    def observe_launch(self, spec, variant, key, lanes, measured,
                       mesh: int = 1):
        """Feed measured launch wall-clock to the dispatcher's cost
        model when one is attached (set ``engine._dispatcher.cost_model``
        or pass one to the dispatcher) — same calibration loop as the
        mux, no-op otherwise."""
        cm = self._dispatcher.cost_model
        if cm is not None:
            shapes = tuple(shape for shape, _ in key)
            cm.observe(spec.name,
                       variant if variant is not None else spec.base,
                       shapes, lanes, measured, mesh=mesh)

    def run(self) -> list[SolveJob]:
        done: list[SolveJob] = []
        groups: dict[tuple, list[SolveJob]] = collections.defaultdict(list)
        for job in self.drain():
            groups[job.shape_key()].append(job)
        for key, jobs in groups.items():
            variant, fn = self._dispatcher.resolve(key)
            done.extend(self.dispatch_group(self.spec, fn, key, jobs,
                                            variant=variant))
        return done
