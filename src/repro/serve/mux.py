"""Registry-driven multi-pipeline serving front-end: :class:`SolverMux`.

A real 5G PUSCH chain mixes Cholesky-, QR-, and MMSE-shaped traffic in
one pipeline rather than one kernel at a time.  ``SolverMux`` accepts
that interleaved stream and serves it with the paper's lane model:

  * **routing** — each submitted job names its pipeline; the kernel
    registry resolves it to a per-pipeline :class:`_LanePool` (created
    lazily), and each shape bucket resolves through
    ``KernelSpec.dispatch`` to a performance variant (one jit'd program
    per pipeline × variant × shape bucket) — n >= 128 buckets serve from
    the blocked kernels, 4-plane MMSE buckets from the split-complex
    fast path, without the caller choosing anything.
  * **shape buckets** — within a pool, jobs are bucketed by their
    per-arg (shape, dtype) key; only bucket-mates share a lane group.
  * **continuous batching** — ``poll(now)`` dispatches full lane groups
    immediately and flushes *partial* buckets only when a deadline has
    expired, the bucket has waited ``max_wait``, or pool pressure
    (queued jobs ≥ ``pressure``) demands draining; ``run()`` drains
    everything.  Bucket flush order is deadline-aware: the bucket with
    the oldest (earliest) deadline flushes first, ties broken by
    submission order.
  * **padding** — a short lane group is topped up from the pipeline's
    ``KernelSpec.filler`` (a declared benign problem, e.g. identity
    system / zero rhs) so padded lanes stay finite and are discarded.

API sketch::

    mux = SolverMux(lanes=8)
    job = mux.submit("mmse_equalize", h, y, deadline=now + 2e-3)
    mux.submit("cholesky_solve", a, b)
    done = mux.run()            # every job.out filled
    snap = mux.metrics()        # per-pipeline p50/p99, utilization, ...

All timing runs on an injectable clock (``time.monotonic`` by default,
:class:`repro.serve.core.ManualClock` for deterministic tests and trace
replays).
"""
from __future__ import annotations

import math

import numpy as np

from repro.serve.core import EngineCore
from repro.serve.solver import (SolveJob, VariantDispatcher,
                                resolve_pipeline_spec)


def _bucket_priority(jobs: list[SolveJob]) -> tuple:
    """Oldest deadline first; FIFO (arrival seq) among deadline ties and
    no-deadline buckets.  Derived from the queued jobs each time, so a
    bucket whose oldest jobs were chunked away re-ranks correctly."""
    deadline = min((j.deadline for j in jobs if j.deadline is not None),
                   default=math.inf)
    return (deadline, min(j.seq for j in jobs))


class _LanePool:
    """Per-pipeline lane pool: variant dispatcher + shape buckets (lists
    of queued jobs keyed by per-arg shape/dtype).  Each bucket resolves
    through ``KernelSpec.dispatch`` — one compiled program per variant x
    shape bucket, so large / split-complex buckets transparently serve
    from the fast variant."""

    def __init__(self, spec, options: dict):
        self.spec = spec
        self.dispatcher = VariantDispatcher(spec, options)
        self.buckets: dict[tuple, list[SolveJob]] = {}

    def enqueue(self, job: SolveJob) -> None:
        self.buckets.setdefault(job.shape_key(), []).append(job)

    def queued(self) -> int:
        return sum(len(jobs) for jobs in self.buckets.values())


class SolverMux(EngineCore):
    """Mixed-job-type solver serving with shape-bucketed continuous
    batching and a deadline-aware flush policy.

    Parameters:
      lanes     lane-group width per grid launch (per-pipeline pools all
                share it; a launch never carries more than ``lanes`` jobs)
      max_wait  seconds a partial bucket may age before ``poll`` flushes
                it anyway (``None``: only deadlines/pressure flush
                partials)
      pressure  queued-job count in a pool above which ``poll`` flushes
                partial buckets (oldest deadline first) until relieved;
                defaults to ``4 * lanes``
      options   per-pipeline kwargs bound into the served kernel, e.g.
                ``{"mmse_equalize": {"sigma2": 0.05}}``
      clock     zero-arg time source (default ``time.monotonic``)
    """

    def __init__(self, lanes: int = 8, *, max_wait: float | None = None,
                 pressure: int | None = None, clock=None,
                 options: dict[str, dict] | None = None):
        super().__init__(lanes, clock=clock)
        self.max_wait = max_wait
        self.pressure = 4 * lanes if pressure is None else pressure
        self._options = dict(options or {})
        self._pools: dict[str, _LanePool] = {}
        self._seq = 0

    # ---------------- submission / routing ----------------

    def _pool(self, pipeline: str) -> _LanePool:
        pool = self._pools.get(pipeline)
        if pool is None:
            spec = resolve_pipeline_spec(pipeline)
            pool = _LanePool(spec, self._options.get(pipeline, {}))
            self._pools[pipeline] = pool
        return pool

    def submit(self, pipeline: str, *args,
               deadline: float | None = None) -> SolveJob:
        """Route one job to its pipeline's lane pool and shape bucket.

        ``args`` are per-problem arrays WITHOUT the batch dimension;
        ``deadline`` is an absolute clock time (None = best effort).
        Returns the queued :class:`SolveJob` (``out`` filled once a
        dispatch containing it runs).
        """
        pool = self._pool(pipeline)
        self._seq += 1
        job = SolveJob(args=tuple(np.asarray(a) for a in args),
                       pipeline=pipeline, deadline=deadline,
                       submitted_at=self.clock(), seq=self._seq)
        pool.enqueue(job)
        return job

    def pending(self) -> int:
        return sum(p.queued() for p in self._pools.values())

    # ---------------- dispatch ----------------

    def _sorted_buckets(self) -> list[tuple[_LanePool, tuple]]:
        """All non-empty buckets across pools, deadline-priority order."""
        items = [(pool, key) for pool in self._pools.values()
                 for key, jobs in pool.buckets.items() if jobs]
        items.sort(key=lambda pk: _bucket_priority(pk[0].buckets[pk[1]]))
        return items

    def _flush_bucket(self, pool: _LanePool, key: tuple, *,
                      full_only: bool) -> list[SolveJob]:
        """Dispatch a bucket in lane-group chunks.  ``full_only`` leaves
        the trailing partial chunk queued (continuous-batching path)."""
        jobs = pool.buckets[key]
        variant, fn = pool.dispatcher.resolve(key)
        done: list[SolveJob] = []
        while len(jobs) >= self.lanes:
            chunk, jobs = jobs[:self.lanes], jobs[self.lanes:]
            done.extend(self.dispatch_group(pool.spec, fn, key, chunk,
                                            variant=variant))
        if jobs and not full_only:
            chunk, jobs = jobs, []
            done.extend(self.dispatch_group(pool.spec, fn, key, chunk,
                                            variant=variant))
        if jobs:
            pool.buckets[key] = jobs
        else:
            del pool.buckets[key]
        return done

    def _expired(self, jobs: list[SolveJob], now: float) -> bool:
        deadline, _ = _bucket_priority(jobs)
        if deadline <= now:
            return True
        age = now - min(j.submitted_at for j in jobs)
        return self.max_wait is not None and age >= self.max_wait

    def poll(self, now: float | None = None) -> list[SolveJob]:
        """One continuous-batching round: full lane groups always
        dispatch; partial buckets dispatch only on expired deadline,
        ``max_wait`` age, or pool pressure.  Oldest deadline flushes
        first throughout."""
        now = self.clock() if now is None else now
        done: list[SolveJob] = []
        for pool, key in self._sorted_buckets():
            done.extend(self._flush_bucket(pool, key, full_only=True))
        for pool, key in self._sorted_buckets():
            jobs = pool.buckets[key]
            if self._expired(jobs, now) or pool.queued() >= self.pressure:
                done.extend(self._flush_bucket(pool, key, full_only=False))
        return done

    def run(self) -> list[SolveJob]:
        """Drain everything queued (deadline-priority bucket order) and
        return the completed jobs."""
        done: list[SolveJob] = []
        for pool, key in self._sorted_buckets():
            done.extend(self._flush_bucket(pool, key, full_only=False))
        return done
