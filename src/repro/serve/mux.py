"""Registry-driven multi-pipeline serving front-end: :class:`SolverMux`.

A real 5G PUSCH chain mixes Cholesky-, QR-, and MMSE-shaped traffic in
one pipeline rather than one kernel at a time.  ``SolverMux`` accepts
that interleaved stream and serves it with the paper's lane model:

  * **routing** — each submitted job names its pipeline; the kernel
    registry resolves it to a per-pipeline :class:`_LanePool` (created
    lazily), and each shape bucket resolves through
    ``KernelSpec.dispatch`` to a performance variant (one jit'd program
    per pipeline × variant × shape bucket) — n >= 128 buckets serve from
    the blocked kernels, 4-plane MMSE buckets from the split-complex
    fast path, without the caller choosing anything.
  * **shape buckets** — within a pool, jobs are bucketed by their
    per-arg (shape, dtype) key; only bucket-mates share a lane group
    (unless the overload policy coalesces — below).
  * **continuous batching** — ``poll(now)`` dispatches full lane groups
    immediately and flushes *partial* buckets only when a deadline has
    expired, the bucket has waited ``max_wait``, or pool pressure
    (queued jobs in THAT pool >= ``pressure``) demands draining;
    ``run()`` drains everything.  Bucket flush order is deadline-aware:
    the bucket with the oldest (earliest) deadline flushes first, ties
    broken by submission order.
  * **padding** — a short lane group is topped up from the pipeline's
    ``KernelSpec.filler`` (a declared benign problem, e.g. identity
    system / zero rhs) so padded lanes stay finite and are discarded.

Overload policy
---------------

With an :class:`OverloadPolicy` attached, ``poll`` becomes an
overload-aware scheduler.  Every decision is justified by one price:
``cost_model.launch_cost = overhead + lanes * model_flops * sec_per_flop``
(:mod:`repro.serve.cost`; calibratable from the committed
``BENCH_pipelines.json`` wall-clock baseline), evaluated through each
bucket's :class:`~repro.serve.solver.VariantDispatcher` so a blocked or
tiled bucket prices at its variant's real cost.  The rules:

  * **shedding (admission control)** — a best-effort job whose deadline
    has already expired can no longer meet it; it is dropped *before*
    lanes are committed (terminal ``state="dropped"``, ``out`` stays
    ``None``, a ``drop`` event and metrics counter).  Hard-priority jobs
    are NEVER shed — at worst they finish late.
  * **budgeted admission** — each poll admits launch candidates (full
    chunks always; due partials) in earliest-deadline order while their
    summed launch cost fits ``policy.budget`` (``None`` = unlimited).
    A candidate that does not fit is deferred with a ``defer`` event
    recording the price that did not fit.
  * **priority preemption** — when a hard-deadline candidate does not
    fit, already-admitted best-effort flushes are abandoned until it
    does, cheapest-to-abandon first (lowest launch cost, partials over
    full groups, fewest delayed jobs — all cost-model-ranked;
    ``preempt`` events).  The abandoned bucket stays queued, ages
    toward the starvation bypass, and is re-admitted later.
  * **no starvation** — every defer/preemption ages the bucket; once a
    due bucket has been pushed back ``policy.max_defer`` times it is
    admitted ahead of everything on the next poll, so best-effort
    traffic cannot be starved by a hard-deadline flood.
  * **cross-shape coalescing** — an admitted partial launch's free
    lanes would execute benign filler; under pool pressure (or when the
    donor bucket is itself due) the policy instead embeds small jobs
    from a compatible smaller bucket of the same pool into those lanes
    (``KernelSpec.coalesce`` — block-diagonal embedding, bit-exact
    extraction).  Applicability is checked at the padded shape:
    ``Coalescer.compatible`` on the (donor, host) keys, the host
    bucket's variant dispatched by its own predicate at exactly those
    shapes, and every embedded lane verified to conform to the host
    shapes/dtypes before launch.  The trade is scored by the cost
    model: ride iff k * lane_cost(big) < launch_cost(small, k) — i.e.
    the padded-lane waste is cheaper than the launch it avoids; a
    rejection is logged as a ``coalesce_reject`` event with both
    prices.  Absorbing a whole admitted smaller launch refunds its
    budget, which flows back to deferred candidates (``readmit``).

Every policy decision appends a JSON-able record to ``mux.events``
(``flush`` / ``drop`` / ``preempt`` / ``defer`` / ``coalesce`` /
``coalesce_reject`` / ``readmit``; plus ``shard_split`` /
``shard_reject`` on a mesh) — the audit trail golden-trace tests
replay.

DAG jobs (served pipelines)
---------------------------

``submit_dag(name, *args)`` serves a registered
:class:`repro.kernels.DagSpec` — e.g. ``pusch_receive``'s FFT ->
channel-estimate -> MMSE-equalize chain — as a set of stage jobs the
mux advances through the declared producer->consumer edges: root stages
are routed to their stage pipelines' lane pools immediately, and each
``poll``/``run`` round harvests completed stage outputs and submits the
newly-ready frontier (stage inputs assembled by ``StageSpec.bind`` from
the DAG args + upstream outputs — the cross-launch handoff buffers
described by the stages' stream descriptors).  Stage buckets price
through the same cost model as everything else; at equal deadline,
buckets carrying **critical-path** stages (``DagSpec.criticality`` —
``core/criticality.plan_split`` over the stages' declared FLOPs models)
flush and admit ahead of slack-stage and standalone buckets.
``chained=True`` serves the spec's fused stage list (adjacent stages
lane-resident in one ``pallas_call``, e.g. ``pusch_chain``) instead of
the stage-independent list.  Stage jobs inherit the DAG's deadline and
priority and run under the full overload/sharding/supervision machinery
unchanged: a failed mid-DAG stage retries / degrades / bisects through
the supervision ladder first, and only a *terminally* failed or dropped
stage ends the DAG (reason ``"stage:<name>:<reason>"``), cancelling
exactly the not-yet-submitted downstream stages — running siblings
finish normally, so every declared stage is accounted and none is
orphaned.  ``dag_submit`` / ``dag_stage`` / ``dag_done`` / ``dag_fail``
/ ``dag_drop`` events extend the audit trail, and
``MetricsSnapshot.dags`` reports end-to-end latency per DAG; muxes that
never see a DAG emit byte-identical events and metrics to the pre-DAG
stack.

Mesh-sharded lane pools
-----------------------

With ``mesh_size > 1`` (constructor argument, default from
``REPRO_SERVE_MESH_SIZE``) the mux spans a 1-D device mesh
(:class:`repro.serve.shard.LaneShards`): aggregate capacity is
``lanes * mesh_size`` and every scheduling rule above generalizes
per-shard —

  * **placement** — non-spanning launches are committed to the shard
    with the most remaining per-poll budget (then least accumulated
    load; deterministic index tiebreak), so flushes land on the
    least-loaded shard group.
  * **hot-bucket splitting (cross-shard work stealing)** — a bucket
    whose backlog reaches ``shard_split_pressure * lanes`` is offered
    as mesh-spanning flushes: one ``shard_map`` launch whose lane axis
    splits over the mesh's data axis (per-shard lane slabs, outputs
    gathered back), padded per shard so no shard sees a partial
    remainder.  The split is priced through the same cost model as
    everything else — ``overhead(mesh) + ceil(lanes/mesh) * lane_cost``
    vs the serial per-shard launches it replaces — and taken only when
    ``sharded_cost * steal_ratio < local_cost``, so stealing never
    beats a cheaper local partial (``shard_split`` / ``shard_reject``
    events record both prices).
  * **per-shard admission** — the policy budget becomes one budget per
    shard; a spanning flush must fit every shard's budget, a local
    flush only its placed shard's.  Preemption frees per-shard budget;
    coalescing refunds flow back per-shard.
  * **observability** — :meth:`SolverMux.metrics` adds per-shard
    utilization (:class:`repro.serve.metrics.ShardStats`) and the
    max/mean lane-load imbalance ratio, flagged against
    ``imbalance_alert``.

``mesh_size=1`` (the default) constructs no mesh at all: the mux is
bit-for-bit the single-device scheduler above — same launches, same
events, same metrics.

Launch supervision (fault tolerance)
------------------------------------

Every mux launch is *supervised*: the attempt is wrapped, exceptions
are caught, and the real (non-filler) output lanes are scanned for
non-finite values.  A failed group is retried up to ``max_retries``
times with bounded exponential backoff **charged against the admission
budget** (``retry_backoff * 2**k`` debited from the failing shard's
next-poll budget — the scheduling clock never blocks, so replays stay
deterministic); on a mesh each retry re-places onto a shard that has
not failed this supervision.  When retries exhaust, the failure is
contained instead of propagated:

  * a launch carrying coalesced **riders** detaches them first (they
    stay queued) and relaunches the host alone — a poisoned donor never
    sinks its host;
  * a **mesh-spanning** launch decomposes into per-shard local chunks,
    isolating a sick shard instead of failing the whole slab;
  * a multi-job local chunk **bisects** to isolate the poison lane —
    the single job left failing is marked terminal ``state="failed"``
    with a structured ``reason`` and the healthy remainder is served;
  * a persistently **non-finite output lane** fails only the jobs on
    the poisoned lanes; the rest of the launch's results are kept
    (lanes are independent, so the good lanes are exact).

Shard failures accumulate per-shard streaks
(:class:`repro.serve.shard.LaneShards`): ``quarantine_after``
consecutive failures quarantine the shard — placement stops,
mesh-spanning launches are disabled (aggregate capacity shrinks and
spanning work re-prices at the reduced mesh by falling back to local
launches) — and after ``probe_after`` clock seconds one real launch is
routed at it as a probe (success reinstates, failure re-arms).  Variant
failures feed the :class:`~repro.serve.solver.VariantDispatcher`
demotion ladder (``demote_after`` consecutive failures ban that variant
for that bucket; resolution falls tiled -> blocked -> base), and a
predicted-cost watchdog (``watchdog_ratio``; off by default — it
compares real wall-clock, which golden traces must not) flags launches
whose measured wall blows past the cost model's prediction.  All of it
is observable: ``retry`` / ``fail`` / ``quarantine`` / ``reinstate`` /
``demote`` / ``watchdog`` events plus the ``MetricsSnapshot.faults``
block.  Faults are *injected* only via
:class:`repro.serve.faults.FaultInjector` (``REPRO_SERVE_FAULT_TRACE``
or the ``injector`` constructor arg); with no injector the supervision
machinery is pure bookkeeping on the success path and the event/metric
streams are bit-identical to the pre-supervision stack.

API sketch::

    mux = SolverMux(lanes=8, policy=OverloadPolicy(budget=2e-4))
    job = mux.submit("mmse_equalize", h, y, deadline=now + 2e-3,
                     priority="hard")
    mux.submit("cholesky_solve", a, b)          # best-effort
    done = mux.poll(now)        # schedule one overload-aware round
    snap = mux.metrics()        # per-pipeline p50/p99, drops, ...

All timing runs on an injectable clock (``time.monotonic`` by default,
:class:`repro.serve.core.ManualClock` for deterministic tests and trace
replays).  Without a policy the mux behaves exactly as before: nothing
is ever dropped, preempted, or coalesced.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serve.config import global_config
from repro.serve.core import EngineCore, pad_group
from repro.serve.cost import CostModel
from repro.serve.faults import FaultInjector, InjectedLaunchError
from repro.serve.metrics import shard_stats
from repro.serve.shard import LaneShards
from repro.serve.solver import (SolveJob, VariantDispatcher,
                                resolve_pipeline_spec)
from repro.serve.tuning import BucketTuner


def _bucket_priority(jobs: list[SolveJob]) -> tuple:
    """Oldest deadline first; among deadline ties, critical-path DAG
    stages (``job.crit``, from ``DagSpec.criticality``) rank ahead of
    slack stages and standalone jobs; FIFO (arrival seq) last.  Derived
    from the queued jobs each time, so a bucket whose oldest jobs were
    chunked away re-ranks correctly.  Buckets with no DAG stages all get
    rank 1, so non-DAG traffic orders exactly as before."""
    deadline = min((j.deadline for j in jobs if j.deadline is not None),
                   default=math.inf)
    rank = 0 if any(j.crit for j in jobs) else 1
    return (deadline, rank, min(j.seq for j in jobs))


def _round(x: float) -> float:
    """Stable 6-significant-digit rounding for event-log costs, so the
    golden trace files stay platform-independent."""
    return float(f"{x:.6g}")


def _shape_label(key: tuple) -> list:
    """JSON-able form of a shape-bucket key for the event log."""
    return [list(shape) for shape, _ in key]


@dataclasses.dataclass
class OverloadPolicy:
    """Overload-management knobs for :class:`SolverMux` (see the module
    docstring for the scheduling rules each one enables).

    ``shed`` / ``preempt`` / ``coalesce`` gate the three mechanisms
    independently (all on by default); ``budget`` is the per-poll
    lane-time budget in cost-model seconds (``None`` = unlimited, so
    only shedding and coalescing act); ``max_defer`` is the starvation
    bound — a due bucket deferred or preempted this many times is
    admitted ahead of everything on the next poll.  ``cost_model``
    prices every decision; pass ``CostModel.from_bench_json()`` for
    wall-clock-calibrated rates."""

    shed: bool = True
    preempt: bool = True
    coalesce: bool = True
    budget: float | None = None
    max_defer: int = 3
    cost_model: CostModel = dataclasses.field(default_factory=CostModel)


@dataclasses.dataclass(eq=False)
class _Candidate:
    """One potential grid launch in a policy poll round.

    ``eq=False``: candidates are identity objects.  The generated
    field-wise ``__eq__`` would compare ``jobs`` lists of SolveJobs
    holding numpy arrays — ``admitted.remove(victim)`` in ``_admit``
    then raises "truth value of an array is ambiguous" the moment a
    preemption plan coexists with another candidate from the same
    bucket."""

    pool: "_LanePool"
    key: tuple
    jobs: list
    partial: bool
    hard: bool
    aged: bool
    price: float
    deadline: float
    seq: int
    riders: tuple = ()
    rank: int = 1                   # 0: carries critical-path DAG stages
    mesh: int = 1                   # > 1: mesh-spanning sharded flush
    shard: int | None = None        # admission-placed shard (mesh == 1)


@dataclasses.dataclass(eq=False)
class DagJob:
    """One submitted DAG (``SolverMux.submit_dag``): a set of stage
    :class:`SolveJob` s the mux advances through the declared
    producer->consumer edges.  (``eq=False``: identity object, like
    SolveJob — field-wise ``__eq__`` would compare numpy arrays.)

    ``stages`` maps stage name -> its submitted SolveJob, or
    ``"cancelled"`` for downstream stages never submitted because an
    upstream stage terminated the DAG — every declared stage is
    accounted in exactly one of: submitted (terminal SolveJob) or
    cancelled; no stage is ever orphaned.  ``outs`` holds completed
    stage outputs (the cross-launch handoff buffers); ``crit`` is the
    criticality plan's critical-stage set.  ``state`` mirrors SolveJob:
    ``queued`` -> ``running`` once a stage is in flight -> terminal
    ``done`` (``out`` = final stage's output) / ``failed`` / ``dropped``
    (``reason`` = ``"stage:<name>:<stage reason>"``)."""

    dag: str
    spec: object
    args: tuple
    deadline: float | None
    priority: str
    submitted_at: float
    seq: int
    chained: bool = False
    stages: dict = dataclasses.field(default_factory=dict)
    outs: dict = dataclasses.field(default_factory=dict)
    crit: frozenset = frozenset()
    state: str = "queued"
    out: np.ndarray | None = None
    reason: str | None = None
    finished_at: float | None = None


class _LanePool:
    """Per-pipeline lane pool: variant dispatcher + shape buckets (lists
    of queued jobs keyed by per-arg shape/dtype).  Each bucket resolves
    through ``KernelSpec.dispatch`` — one compiled program per variant x
    shape bucket, so large / split-complex buckets transparently serve
    from the fast variant.  ``age`` counts consecutive defer/preempt
    push-backs per bucket (the policy's starvation counter)."""

    def __init__(self, spec, options: dict, cost_model=None, shards=None):
        self.spec = spec
        self.dispatcher = VariantDispatcher(spec, options, cost_model,
                                            shards)
        self.buckets: dict[tuple, list[SolveJob]] = {}
        self.age: dict[tuple, int] = {}

    def enqueue(self, job: SolveJob) -> None:
        self.buckets.setdefault(job.shape_key(), []).append(job)

    def queued(self) -> int:
        return sum(len(jobs) for jobs in self.buckets.values())

    def remove(self, key: tuple, jobs: list) -> None:
        """Drop exactly ``jobs`` (by identity) from the ``key`` bucket,
        deleting the bucket (and its age counter) when emptied."""
        ids = {id(j) for j in jobs}
        left = [j for j in self.buckets.get(key, ()) if id(j) not in ids]
        if left:
            self.buckets[key] = left
        else:
            self.buckets.pop(key, None)
            self.age.pop(key, None)


class SolverMux(EngineCore):
    """Mixed-job-type solver serving with shape-bucketed continuous
    batching, a deadline-aware flush policy, and (optionally) the
    overload policy described in the module docstring.

    Parameters:
      lanes     lane-group width per grid launch (per-pipeline pools all
                share it; a launch never carries more than ``lanes`` jobs)
      max_wait  seconds a partial bucket may age before ``poll`` flushes
                it anyway (``None``: only deadlines/pressure flush
                partials)
      pressure  per-pool queued-job count at or above which ``poll``
                flushes that pool's partial buckets (oldest deadline
                first) until relieved; defaults to ``4 * lanes``.  The
                threshold is evaluated per pool — a backlog in one
                pipeline never flushes another pipeline's partials.
      policy    optional :class:`OverloadPolicy` enabling admission
                control, preemption, and cross-shape coalescing
      options   per-pipeline kwargs bound into the served kernel, e.g.
                ``{"mmse_equalize": {"sigma2": 0.05}}``
      clock     zero-arg time source (default ``time.monotonic``)
      wall      measurement clock for launch wall-clock (default
                ``time.perf_counter``) — feeds the cost model's
                calibration loop, independent of the scheduling clock
      cost_model  :class:`~repro.serve.cost.CostModel` used WITHOUT a
                policy (pricing + drift observability only); with a
                policy the policy's model wins and this must stay unset
      adapt     enable the :class:`~repro.serve.tuning.BucketTuner`
                (observed-traffic per-bucket ``max_wait`` + per-pool
                pressure); ``None`` defers to
                ``REPRO_SERVE_ADAPT_THRESHOLDS``
      mesh_size lane-shard count (``None`` defers to
                ``REPRO_SERVE_MESH_SIZE``, default 1).  > 1 spans the
                pools over the first ``mesh_size`` local devices —
                aggregate capacity ``lanes * mesh_size``, per-shard
                placement/budgets, hot-bucket splitting (see the module
                docstring); 1 builds no mesh and is bit-identical to
                the single-device scheduler
      injector  optional :class:`~repro.serve.faults.FaultInjector`
                driving seeded chaos runs; ``None`` defers to
                ``REPRO_SERVE_FAULT_TRACE`` (no trace configured — the
                default — leaves every launch path uninjected)

    Every launch is measured (``wall``) and fed back through
    :meth:`observe_launch` to whichever cost model is attached — the
    predict -> measure -> re-fit loop whose drift metrics
    :meth:`metrics` folds into the snapshot.
    """

    def __init__(self, lanes: int = 8, *, max_wait: float | None = None,
                 pressure: int | None = None, clock=None, wall=None,
                 policy: OverloadPolicy | None = None,
                 cost_model: CostModel | None = None,
                 adapt: bool | None = None,
                 mesh_size: int | None = None,
                 injector: FaultInjector | None = None,
                 options: dict[str, dict] | None = None):
        super().__init__(lanes, clock=clock, wall=wall)
        if policy is not None and cost_model is not None:
            raise ValueError("pass cost_model either directly (no "
                             "policy) or on the policy, not both")
        self.max_wait = max_wait
        self.pressure = 4 * lanes if pressure is None else pressure
        self.policy = policy
        self._cost_model = cost_model
        if adapt is None:
            adapt = global_config.adapt_thresholds
        self.tuner = BucketTuner(lanes, cost_model=self.cost_model) \
            if adapt else None
        if mesh_size is None:
            mesh_size = global_config.mesh_size
        if mesh_size < 1:
            raise ValueError(f"mesh_size must be >= 1, got {mesh_size}")
        self.mesh_size = int(mesh_size)
        # shards stay None at mesh_size=1: every sharded branch below is
        # guarded on them, so the single-device scheduler is untouched
        self.shards = LaneShards.build(self.mesh_size) \
            if self.mesh_size > 1 else None
        self._shard_split_pressure = global_config.shard_split_pressure
        self._steal_ratio = global_config.steal_ratio
        self._imbalance_alert = global_config.imbalance_alert
        self._options = dict(options or {})
        self._pools: dict[str, _LanePool] = {}
        self._seq = 0
        self._dags: list[DagJob] = []
        # token-decode front-end (attach_decode); None = solver-only mux
        self.decode = None
        self._decode_steps_per_poll = global_config.decode_steps_per_poll
        self.events: list[dict] = []
        # ---- launch supervision (module docstring) ----
        # injector stays None with no trace configured, keeping every
        # launch path bit-identical to the uninjected stack
        self.injector = injector if injector is not None \
            else FaultInjector.from_config()
        self.max_retries = global_config.max_retries
        self.retry_backoff = global_config.retry_backoff
        self.quarantine_after = global_config.quarantine_after
        self.probe_after = global_config.probe_after
        self.demote_after = global_config.demote_after
        self.watchdog_ratio = global_config.watchdog_ratio
        self._event_cap = global_config.event_cap
        self._fault_debt = [0.0] * (self.shards.size if self.shards
                                    else 1)
        self._probe_ready: list[int] = []
        self._watchdogs = 0
        self._events_dropped = 0

    @property
    def total_lanes(self) -> int:
        """Aggregate lane-pool capacity: ``lanes`` per *healthy* shard
        across the mesh (``lanes`` itself on a single device) — a
        quarantined shard's lanes are out of service until its probe
        reinstates it, so capacity visibly shrinks under degradation."""
        if self.shards is None:
            return self.lanes
        healthy = len(self.shards.healthy())
        return self.lanes * (healthy if healthy else self.shards.size)

    @property
    def cost_model(self) -> CostModel | None:
        """The one model pricing and observing this mux's launches: the
        policy's when a policy is attached, else the directly-passed
        one, else None."""
        if self.policy is not None:
            return self.policy.cost_model
        return self._cost_model

    # ---------------- submission / routing ----------------

    def _pool(self, pipeline: str) -> _LanePool:
        pool = self._pools.get(pipeline)
        if pool is None:
            spec = resolve_pipeline_spec(pipeline)
            pool = _LanePool(spec, self._options.get(pipeline, {}),
                             self.cost_model, self.shards)
            self._pools[pipeline] = pool
        return pool

    def submit(self, pipeline: str, *args, deadline: float | None = None,
               priority: str = "best_effort") -> SolveJob:
        """Route one job to its pipeline's lane pool and shape bucket.

        ``args`` are per-problem arrays WITHOUT the batch dimension;
        ``deadline`` is an absolute clock time (None = no deadline);
        ``priority`` is ``"hard"`` (never shed, may preempt) or
        ``"best_effort"`` (sheddable once expired, under a policy).
        Returns the queued :class:`SolveJob` (``out`` filled once a
        dispatch containing it runs; ``state`` becomes ``"done"`` or,
        under a shedding policy, possibly ``"dropped"``).

        Admission-time validation: a job whose float/complex args carry
        NaN/Inf is rejected here — terminal ``state="failed"`` with
        ``reason="nonfinite_input"`` — instead of being enqueued, so a
        poisoned input can never contaminate the lane group (and its
        coalesced riders) it would have been stacked into.
        """
        if priority not in SolveJob.PRIORITIES:
            raise ValueError(f"priority must be one of "
                             f"{SolveJob.PRIORITIES}, got {priority!r}")
        pool = self._pool(pipeline)
        self._seq += 1
        job = SolveJob(args=tuple(np.asarray(a) for a in args),
                       pipeline=pipeline, deadline=deadline,
                       submitted_at=self.clock(), seq=self._seq,
                       priority=priority)
        if any(a.dtype.kind in "fc" and not np.all(np.isfinite(a))
               for a in job.args):
            job.state = "failed"
            job.reason = "nonfinite_input"
            job.finished_at = job.submitted_at
            self.recorder.record_fail(pipeline, job.submitted_at,
                                      job.priority, "nonfinite_input")
            self._event("fail", t=job.submitted_at, pipeline=pipeline,
                        seq=job.seq, reason="nonfinite_input")
            return job
        pool.enqueue(job)
        if self.tuner is not None:
            self.tuner.note_arrival(pipeline, job.shape_key(),
                                    job.submitted_at)
        return job

    # ---------------- DAG jobs ----------------

    def submit_dag(self, name: str, *args, deadline: float | None = None,
                   priority: str = "best_effort",
                   chained: bool = False) -> DagJob:
        """Submit one DAG job (a registered :class:`repro.kernels.
        DagSpec`): its root stages (empty ``consumes``) are routed to
        their stage pipelines' lane pools immediately; downstream stages
        are submitted by :meth:`poll` / :meth:`run` as their producers'
        outputs land (``DagJob.outs`` — the cross-launch stage handoff
        buffers).  ``chained`` serves the spec's declared fused stage
        list (e.g. the one-``pallas_call`` channel-estimate->equalize
        chain) instead of the stage-independent list.

        Stage jobs inherit ``deadline`` and ``priority`` (``"hard"``
        stages are never shed, so a hard DAG either completes or fails
        through the supervision ladder — never silently dropped), and
        carry the criticality plan's per-stage flag: critical-path
        stages admit ahead of slack stages at equal deadline."""
        if priority not in SolveJob.PRIORITIES:
            raise ValueError(f"priority must be one of "
                             f"{SolveJob.PRIORITIES}, got {priority!r}")
        from repro import kernels as K
        spec = K.get_dag(name)
        stages = spec.stage_list(chained=chained)
        shapes = tuple(np.shape(a) for a in args)
        critical, _slack = spec.criticality(shapes, chained=chained)
        now = self.clock()
        self._seq += 1
        dj = DagJob(dag=name, spec=spec,
                    args=tuple(np.asarray(a) for a in args),
                    deadline=deadline, priority=priority,
                    submitted_at=now, seq=self._seq, chained=chained,
                    crit=frozenset(critical))
        self._dags.append(dj)
        self.recorder.record_dag_submit(name)
        self._event("dag_submit", t=now, dag=name, seq=dj.seq,
                    stages=[s.name for s in stages],
                    critical=sorted(dj.crit), chained=chained)
        for stage in stages:
            if not stage.consumes:
                self._submit_stage(dj, stage, now)
        return dj

    def _submit_stage(self, dj: DagJob, stage, now: float) -> None:
        """Route one ready DAG stage to its pipeline's lane pool: the
        stage's ``bind`` assembles its inputs from the DAG args and the
        produced upstream outputs, and the resulting SolveJob is tagged
        back to the DAG (+ its criticality rank) for advancement."""
        bound = stage.bind(dj.args, dj.outs)
        job = self.submit(stage.pipeline, *bound, deadline=dj.deadline,
                          priority=dj.priority)
        job.dag = dj
        job.stage = stage.name
        job.crit = stage.name in dj.crit
        dj.stages[stage.name] = job
        if dj.state == "queued":
            dj.state = "running"
        self._event("dag_stage", t=now, dag=dj.dag, seq=dj.seq,
                    stage=stage.name, pipeline=stage.pipeline,
                    job=job.seq, critical=job.crit)

    def _advance_dags(self, now: float) -> bool:
        """Advance every in-flight DAG: harvest completed stage outputs,
        submit newly-ready stages (all ``consumes`` produced), finish
        DAGs whose stages are all done, and cascade a terminal stage
        failure — the failed/dropped stage ends the DAG with reason
        ``"stage:<name>:<reason>"`` and every not-yet-submitted
        downstream stage is marked ``"cancelled"`` (running sibling
        stages finish normally through their own launches), so no stage
        is ever orphaned.  Loops to a fixed point within one call (a
        stage rejected at submit, e.g. non-finite input, is cascaded in
        the same round).  Returns True when anything progressed."""
        progressed = False
        while True:
            round_progress = False
            for dj in self._dags:
                if dj.state in ("done", "failed", "dropped"):
                    continue
                stages = dj.spec.stage_list(chained=dj.chained)
                failed_stage = None
                for stage in stages:
                    sj = dj.stages.get(stage.name)
                    if not isinstance(sj, SolveJob):
                        continue
                    if sj.state == "done" and stage.name not in dj.outs:
                        dj.outs[stage.name] = sj.out
                        round_progress = True
                    elif sj.state in ("failed", "dropped") \
                            and failed_stage is None:
                        failed_stage = (stage.name, sj)
                if failed_stage is not None:
                    sname, sj = failed_stage
                    dj.state = sj.state
                    dj.reason = f"stage:{sname}:{sj.reason or sj.state}"
                    dj.finished_at = now
                    cancelled = [s.name for s in stages
                                 if s.name not in dj.stages]
                    for cname in cancelled:
                        dj.stages[cname] = "cancelled"
                    self.recorder.record_dag(dj.dag, dj.submitted_at,
                                             now, dj.state, dj.priority)
                    self._event(
                        "dag_fail" if dj.state == "failed" else
                        "dag_drop", t=now, dag=dj.dag, seq=dj.seq,
                        stage=sname, reason=dj.reason,
                        cancelled=cancelled)
                    round_progress = True
                    continue
                if all(s.name in dj.outs for s in stages):
                    dj.state = "done"
                    dj.out = dj.outs[stages[-1].name]
                    dj.finished_at = now
                    self.recorder.record_dag(dj.dag, dj.submitted_at,
                                             now, "done", dj.priority)
                    self._event("dag_done", t=now, dag=dj.dag,
                                seq=dj.seq,
                                latency=_round(now - dj.submitted_at))
                    round_progress = True
                    continue
                for stage in stages:
                    if stage.name in dj.stages:
                        continue
                    if all(c in dj.outs for c in stage.consumes):
                        self._submit_stage(dj, stage, now)
                        round_progress = True
            if not round_progress:
                return progressed
            progressed = True

    def observe_launch(self, spec, variant, key: tuple, lanes: int,
                       measured: float, mesh: int = 1) -> None:
        """Close the calibration loop: every measured launch feeds the
        attached cost model (drift tracking always; rate/overhead
        re-fitting when the model is adaptive) and the threshold tuner
        when one is enabled.  ``mesh > 1`` marks a mesh-spanning launch
        so drift/overhead attribution stays per (pipeline, variant,
        mesh_size)."""
        cm = self.cost_model
        if cm is not None:
            shapes = tuple(shape for shape, _ in key)
            cm.observe(spec.name,
                       variant if variant is not None else spec.base,
                       shapes, lanes, measured, mesh=mesh)
        if self.tuner is not None:
            self.tuner.note_launch(spec.name, lanes, measured)

    # ---------------- token decode traffic ----------------

    def attach_decode(self, engine) -> None:
        """Register a :class:`repro.serve.decode.DecodeEngine` as this
        mux's token-traffic front-end, so ONE scheduler owns both
        solver and decode traffic (the hierarchical-scheduler shape of
        the wireless-modem related work):

        * the engine adopts the mux's recorder and both clocks — decode
          launches, per-request latencies and per-phase samples land in
          the same :meth:`metrics` snapshot (``snapshot.decode`` plus a
          ``"decode"`` entry in ``snapshot.pipelines``);
        * engine lifecycle events (``decode_insert`` / ``decode_done``)
          are folded into the mux event log, so virtual-clock replays
          pin decode scheduling decisions byte-for-byte like solver
          flushes;
        * measured step wall-clock feeds
          :meth:`repro.serve.cost.CostModel.observe_decode`, pricing
          decode phases through the same drift/calibration machinery.

        :meth:`poll` then serves up to ``decode_steps_per_poll``
        continuous-batching steps per round under the attached
        :class:`OverloadPolicy` (budget-priced, expired best-effort
        shed, hard-deadline decode never shed or deferred), and
        :meth:`run` drains decode alongside solver buckets."""
        if self.decode is not None:
            raise ValueError("a decode engine is already attached")
        engine.recorder = self.recorder
        engine.clock = self.clock
        engine.wall = self.wall
        engine.event_cb = lambda kind, t, **f: self._event(kind, t=t, **f)
        cm = self.cost_model
        if cm is not None:
            engine.observe_cb = cm.observe_decode
        self.decode = engine
        self._event("decode_attach", t=self.clock(),
                    spec=engine.spec.name, slots=engine.lanes,
                    max_len=engine.max_len)

    def submit_decode(self, request, *, deadline: float | None = None,
                      priority: str = "best_effort"):
        """Submit one decode :class:`~repro.serve.decode.Request` to the
        attached engine under the mux's admission classes: ``priority``
        and ``deadline`` mean exactly what they mean for
        :meth:`submit` — a hard request is never shed; an expired
        best-effort request still queued at a policy poll is dropped.
        The request joins the mux's global ``seq`` numbering so decode
        and solver events interleave unambiguously in the event log."""
        if self.decode is None:
            raise RuntimeError("no decode engine attached; call "
                               "attach_decode() first")
        if priority not in SolveJob.PRIORITIES:
            raise ValueError(f"priority must be one of "
                             f"{SolveJob.PRIORITIES}, got {priority!r}")
        self._seq += 1
        request.seq = self._seq
        request.priority = priority
        request.deadline = deadline
        return self.decode.submit(request)

    def _poll_decode(self, now: float) -> list:
        """One decode service round: shed expired best-effort queue
        entries (hard never shed), then run up to
        ``decode_steps_per_poll`` continuous-batching steps, each priced
        through the cost model and admitted against the policy budget.
        Decode budget is accounted separately from the solver flush
        budget within a poll — the same per-poll figure, so a saturated
        solver round cannot silently starve token traffic to zero — and
        a pending hard-deadline request overrides budget exhaustion
        (deferring it would trade a hard SLO for best-effort lane time).
        """
        eng = self.decode
        pol = self.policy
        if pol is not None and pol.shed:
            for r in eng.shed_expired(now):
                self.recorder.record_drop("decode", now, r.priority,
                                          "expired")
                self.recorder.record_decode_shed()
                self._event("drop", t=now, pipeline="decode", seq=r.seq,
                            deadline=r.deadline, reason="expired")
        cm = self.cost_model
        budget = math.inf if pol is None or pol.budget is None \
            else pol.budget
        spent, steps = 0.0, 0
        done: list = []
        while eng.has_work() and steps < self._decode_steps_per_poll:
            active = eng.occupied() or min(eng.pending(), eng.lanes)
            price = cm.decode_cost("generate",
                                   active * eng.token_flops) \
                if cm is not None else 0.0
            if spent + price > budget and not eng.hard_waiting():
                self._event("decode_defer", t=now, queued=eng.pending(),
                            active=eng.occupied(), cost=_round(price))
                break
            done.extend(eng.step())
            spent += price
            steps += 1
        if steps:
            self._event("decode_step", t=now, steps=steps,
                        done=len(done), active=eng.occupied(),
                        queued=eng.pending(), cost=_round(spent))
        return done

    def metrics(self):
        """Recorder snapshot plus — when a cost model is attached — the
        per-(pipeline, variant) drift stats, worst offender, and
        calibration update counts (the SLO-side view of the online
        loop)."""
        snap = self.recorder.snapshot()
        cm = self.cost_model
        if cm is not None:
            snap = dataclasses.replace(
                snap, drift=cm.drift(), worst_drift=cm.worst_drift(),
                calibration_updates=cm.calibration_updates())
        if self.shards is not None:
            shards, imb = shard_stats(snap.launches, self.shards.size,
                                      self.shards.load)
            snap = dataclasses.replace(
                snap, shards=shards, shard_imbalance=imb,
                shard_imbalance_alert=(not math.isnan(imb)
                                       and imb >= self._imbalance_alert))
        demotions = [d for p in self._pools.values()
                     for d in p.dispatcher.demotions]
        quarantined: tuple = ()
        quarantines = reinstatements = 0
        recover = math.nan
        if self.shards is not None:
            quarantines = self.shards.quarantines
            reinstatements = self.shards.reinstatements
            quarantined = tuple(s for s in range(self.shards.size)
                                if self.shards.quarantined(s))
            if self.shards.recovery_times:
                recover = (sum(self.shards.recovery_times)
                           / len(self.shards.recovery_times))
        snap = dataclasses.replace(snap, faults=dataclasses.replace(
            snap.faults, quarantines=quarantines,
            reinstatements=reinstatements, demotions=len(demotions),
            watchdog_flags=self._watchdogs,
            quarantined_shards=quarantined, time_to_recover=recover,
            alerts=tuple(f"demote:{d['pipeline']}:"
                         f"{d['from']}->{d['to']}" for d in demotions)))
        return snap

    def pending(self) -> int:
        n = sum(p.queued() for p in self._pools.values())
        if self.decode is not None:
            # queued requests plus occupied slots: both are unfinished
            # work run() is on the hook to drain
            n += self.decode.pending() + self.decode.occupied()
        return n

    def drain_events(self) -> list[dict]:
        """Return and clear the scheduling-decision event log.  When the
        bounded buffer (``REPRO_SERVE_EVENT_CAP``) overflowed since the
        last drain, the batch is prefixed with one ``events_dropped``
        record counting the discarded oldest records — overflow is
        reported, never silent."""
        events, self.events = self.events, []
        if self._events_dropped:
            events = [{"event": "events_dropped",
                       "count": self._events_dropped}] + events
            self._events_dropped = 0
        return events

    def _event(self, kind: str, t: float, **fields) -> None:
        self.events.append({"event": kind, "t": t, **fields})
        if self._event_cap and len(self.events) > self._event_cap:
            drop = len(self.events) - self._event_cap
            del self.events[:drop]
            self._events_dropped += drop

    # ---------------- dispatch ----------------

    def _sorted_buckets(self) -> list[tuple[_LanePool, tuple]]:
        """All non-empty buckets across pools, deadline-priority order."""
        items = [(pool, key) for pool in self._pools.values()
                 for key, jobs in pool.buckets.items() if jobs]
        items.sort(key=lambda pk: _bucket_priority(pk[0].buckets[pk[1]]))
        return items

    def _launch(self, pool: _LanePool, key: tuple, chunk: list,
                riders: tuple = (), now: float | None = None,
                mesh: int = 1, shard: int | None = None) -> list:
        """One supervised grid launch: ``chunk`` jobs of the (pool, key)
        bucket plus optional cross-shape ``riders`` embedded into
        otherwise-padded lanes.  Records the launch + per-job latencies
        and logs a ``flush`` event.

        On a mesh, ``mesh > 1`` runs the shard_map-wrapped spanning form
        (lane axis split over the mesh, padded to ``lanes * mesh`` so
        every shard gets a whole slab); ``mesh == 1`` places the launch
        on ``shard`` (least-loaded healthy when unspecified), committing
        inputs to that shard's device.  Without a mesh both default to
        the legacy single-device path.

        Preparation errors (coalesce-embed nonconformance, padding
        misdeclaration) propagate and leave the jobs queued — they are
        scheduler bugs, not launch faults; execution goes through
        :meth:`_supervise`, which contains failures instead (retry /
        bisect / terminal per-job ``failed``)."""
        spec = pool.spec
        t = self.clock() if now is None else now
        if mesh > 1:
            variant, _ = pool.dispatcher.resolve_sharded(key)
        else:
            variant, _ = pool.dispatcher.resolve(key)
        width = self.lanes * max(1, mesh)
        riders = tuple(riders)
        if riders:
            big_shapes = tuple(shape for shape, _ in key)
            embedded = [spec.coalesce.embed(j.args, big_shapes)
                        for j in riders]
            for lane in embedded:
                for arr, (shape, dt) in zip(lane, key):
                    arr = np.asarray(arr)
                    if arr.shape != tuple(shape) or str(arr.dtype) != dt:
                        raise ValueError(
                            f"{spec.name!r} coalesce.embed produced a "
                            f"{arr.shape}/{arr.dtype} lane; the host "
                            f"bucket expects {tuple(shape)}/{dt}")
            stacked = [np.stack([np.asarray(j.args[i]) for j in chunk]
                                + [np.asarray(e[i]) for e in embedded])
                       for i in range(len(key))]
        else:
            stacked = [np.stack([np.asarray(j.args[i]) for j in chunk])
                       for i in range(len(chunk[0].args))]
        padded, pad = pad_group(spec, stacked, width, variant=variant)
        return self._supervise(pool, key, list(chunk), riders, padded,
                               pad, t, mesh, shard)

    def _scatter(self, pool: _LanePool, chunk: list, riders: tuple,
                 res, t: float, bad: set | None = None) -> list:
        """Write per-lane results back onto the jobs.  Lanes in ``bad``
        (persistently non-finite output) fail their job terminally
        instead — lanes are independent, so the good lanes stay exact
        and are served."""
        spec = pool.spec
        done = []
        for i, job in enumerate(list(chunk) + list(riders)):
            if bad and i in bad:
                job.state = "failed"
                job.reason = "nonfinite_output"
                job.finished_at = t
                self.recorder.record_fail(spec.name, t, job.priority,
                                          "nonfinite_output")
                self._event("fail", t=t, pipeline=spec.name, seq=job.seq,
                            reason="nonfinite_output")
            else:
                if i < len(chunk):
                    job.out = res[i]
                else:
                    small = tuple(np.shape(a) for a in job.args)
                    job.out = spec.coalesce.extract(res[i], small)
                job.state = "done"
                self.record_job(spec.name, job)
            done.append(job)
        return done

    def _flush_event(self, pool: _LanePool, key: tuple, chunk: list,
                     riders: tuple, variant, t: float, mesh: int,
                     rec_shard: int, shard: int | None) -> None:
        """Shard load accounting + the ``flush`` event.  mesh/shard
        fields only appear on sharded muxes, so the single-device event
        stream (golden traces) is unchanged."""
        if self.shards is not None:
            cost = pool.dispatcher.price(key, self.lanes * max(1, mesh),
                                         mesh=mesh)
            if mesh > 1:
                self.shards.note_all(cost)
            else:
                self.shards.note(shard, cost)
            self._event("flush", t=t, pipeline=pool.spec.name,
                        variant=variant.name, shape=_shape_label(key),
                        jobs=[j.seq for j in chunk],
                        coalesced=[j.seq for j in riders],
                        mesh=mesh, shard=rec_shard)
        else:
            self._event("flush", t=t, pipeline=pool.spec.name,
                        variant=variant.name, shape=_shape_label(key),
                        jobs=[j.seq for j in chunk],
                        coalesced=[j.seq for j in riders])

    def _watchdog(self, pool: _LanePool, key: tuple, variant, width: int,
                  mesh: int, measured: float, t: float) -> None:
        """Predicted-cost watchdog: flag a launch whose measured wall
        exceeds ``watchdog_ratio`` times the cost model's prediction.
        Off at ratio 0.0 (the default) — it compares real wall-clock,
        which golden traces must never depend on."""
        if self.watchdog_ratio <= 0.0 or self.cost_model is None \
                or not math.isfinite(measured):
            return
        predicted = pool.dispatcher.price(key, width, mesh=mesh)
        if predicted > 0.0 and measured > self.watchdog_ratio * predicted:
            self._watchdogs += 1
            self._event("watchdog", t=t, pipeline=pool.spec.name,
                        variant=variant.name, measured=_round(measured),
                        predicted=_round(predicted))

    def _supervise(self, pool: _LanePool, key: tuple, chunk: list,
                   riders: tuple, padded: list, pad: int, t: float,
                   mesh: int, shard: int | None) -> list:
        """Supervised execution of one prepared launch: the attempt loop
        plus the containment ladder (module docstring).  Returns the
        terminal jobs — every ``chunk`` job comes back ``done`` or
        ``failed``; detached riders come back still ``queued`` (the
        policy dispatcher only dequeues terminal jobs)."""
        spec = pool.spec
        real = len(chunk) + len(riders)
        width = self.lanes * max(1, mesh)
        device = None
        probing = None
        if mesh == 1 and self.shards is not None:
            # a quarantined shard owed a probe gets this launch; else
            # place on the least-loaded healthy shard
            while self._probe_ready and probing is None:
                p = self._probe_ready.pop(0)
                if self.shards.quarantined(p):
                    shard = probing = p
            if probing is None and (shard is None
                                    or self.shards.quarantined(shard)):
                shard = self.shards.pick(among=self.shards.healthy())
            device = self.shards.devices[shard]
        rec_shard = -1 if mesh > 1 else (shard if shard is not None
                                         else 0)
        tried: set[int] = set()
        reason = "launch_failed"
        failed = False
        bad: list[int] = []
        res = measured = None
        for attempt in range(self.max_retries + 1):
            # re-resolve each attempt: a mid-supervision demotion swaps
            # the entry point (demotable variants share the spec's
            # calling convention, so the prepared group is reusable)
            if mesh > 1:
                variant, fn = pool.dispatcher.resolve_sharded(key)
            else:
                variant, fn = pool.dispatcher.resolve(key)
            ctx = {"pipeline": spec.name, "variant": variant.name,
                   "width": width, "mesh": mesh,
                   "shard": None if mesh > 1 else shard, "t": t}
            failed, bad = False, []
            try:
                res, measured = self._timed_call(fn, padded,
                                                 device=device,
                                                 fault_ctx=ctx)
            except InjectedLaunchError as e:
                failed, reason = True, str(e) or "launch_failed"
            except Exception as e:          # noqa: BLE001 — contained
                failed = True
                reason = f"launch_exception:{type(e).__name__}"
            if not failed:
                bad = [i for i in range(real)
                       if not np.all(np.isfinite(res[i]))]
                if not bad:
                    # ---- success ----
                    self.record_launch(spec.name, key, real, pad,
                                       variant.name,
                                       coalesced=len(riders),
                                       measured=measured, mesh=mesh,
                                       shard=rec_shard)
                    if mesh > 1:
                        self.observe_launch(spec, variant, key,
                                            real + pad, measured,
                                            mesh=mesh)
                    else:
                        self.observe_launch(spec, variant, key,
                                            real + pad, measured)
                    done = self._scatter(pool, chunk, riders, res, t)
                    pool.dispatcher.note_success(key, variant)
                    if mesh == 1 and self.shards is not None:
                        if probing is not None:
                            since = self.shards.quarantined_at[probing]
                            down = self.shards.reinstate(probing, t,
                                                         since)
                            self._event("reinstate", t=t, shard=probing,
                                        downtime=_round(down))
                        else:
                            self.shards.note_success(shard)
                    self._watchdog(pool, key, variant, width, mesh,
                                   measured, t)
                    self._flush_event(pool, key, chunk, riders, variant,
                                      t, mesh, rec_shard, shard)
                    return done
            # ---- failure accounting ----
            if not failed:
                reason = "nonfinite_output"
            fallback = pool.dispatcher.note_failure(key, variant,
                                                    self.demote_after)
            if fallback is not None:
                self._event("demote", t=t, pipeline=spec.name,
                            shape=_shape_label(key),
                            from_variant=variant.name,
                            to_variant=fallback.name)
            if mesh == 1 and self.shards is not None and failed:
                if self.shards.note_failure(shard, t,
                                            self.quarantine_after):
                    self._event("quarantine", t=t, shard=shard,
                                pipeline=spec.name, reason=reason)
            if attempt < self.max_retries:
                # backoff never blocks the scheduling clock: it is
                # charged as debt against the shard's next-poll budget
                backoff = self.retry_backoff * (2 ** attempt)
                if mesh > 1:
                    for s in range(len(self._fault_debt)):
                        self._fault_debt[s] += backoff
                else:
                    self._fault_debt[shard if shard is not None
                                     else 0] += backoff
                self.recorder.record_retry(spec.name, t, reason)
                self._event("retry", t=t, pipeline=spec.name,
                            shape=_shape_label(key),
                            jobs=[j.seq for j in chunk],
                            attempt=attempt + 1, reason=reason,
                            backoff=_round(backoff))
                if mesh == 1 and self.shards is not None and failed:
                    # re-place away from the shard that just failed
                    probing = None
                    tried.add(shard)
                    pickable = ([s for s in self.shards.healthy()
                                 if s not in tried]
                                or self.shards.healthy()
                                or list(range(self.shards.size)))
                    shard = self.shards.pick(among=pickable)
                    device = self.shards.devices[shard]
                    rec_shard = shard
        # ---- retries exhausted: contain, never propagate ----
        if not failed and bad:
            # executed fine but some real lanes are persistently
            # non-finite: fail exactly those jobs, serve the rest
            self.record_launch(spec.name, key, real, pad, variant.name,
                               coalesced=len(riders), measured=measured,
                               mesh=mesh, shard=rec_shard)
            done = self._scatter(pool, chunk, riders, res, t,
                                 bad=set(bad))
            self._flush_event(pool, key, chunk, riders, variant, t,
                              mesh, rec_shard, shard)
            return done
        if riders:
            # a poisoned donor must never sink its host: detach the
            # riders (they stay queued) and relaunch the host alone
            self._event("retry", t=t, pipeline=spec.name,
                        shape=_shape_label(key),
                        jobs=[j.seq for j in chunk],
                        action="detach_riders", reason=reason)
            return self._launch(pool, key, chunk, riders=(), now=t,
                                mesh=mesh, shard=None)
        if mesh > 1:
            # decompose the spanning slab into per-shard local chunks,
            # isolating a sick shard instead of failing the whole slab
            self._event("retry", t=t, pipeline=spec.name,
                        shape=_shape_label(key),
                        jobs=[j.seq for j in chunk],
                        action="decompose", reason=reason)
            done = []
            for i in range(0, len(chunk), self.lanes):
                done.extend(self._launch(pool, key,
                                         chunk[i:i + self.lanes],
                                         now=t, mesh=1))
            return done
        if len(chunk) > 1:
            # bisect to isolate the poison lane
            self._event("retry", t=t, pipeline=spec.name,
                        shape=_shape_label(key),
                        jobs=[j.seq for j in chunk],
                        action="bisect", reason=reason)
            mid = len(chunk) // 2
            return (self._launch(pool, key, chunk[:mid], now=t)
                    + self._launch(pool, key, chunk[mid:], now=t))
        job = chunk[0]
        job.state = "failed"
        job.reason = reason
        job.finished_at = t
        self.recorder.record_fail(spec.name, t, job.priority, reason)
        self._event("fail", t=t, pipeline=spec.name, seq=job.seq,
                    reason=reason)
        return [job]

    def _flush_bucket(self, pool: _LanePool, key: tuple, *,
                      full_only: bool,
                      now: float | None = None) -> list[SolveJob]:
        """Dispatch a bucket in lane-group chunks.  ``full_only`` leaves
        the trailing partial chunk queued (continuous-batching path).
        On a mesh, a backlog of at least ``lanes * mesh_size`` drains in
        mesh-spanning launches first; the remainder goes per-shard."""
        jobs = pool.buckets[key]
        done: list[SolveJob] = []
        if self.shards is not None and self.shards.all_healthy():
            # spanning launches execute on every shard, so any
            # quarantine degrades the mux to per-shard launches
            total = self.lanes * self.shards.size
            while len(jobs) >= total:
                chunk, jobs = jobs[:total], jobs[total:]
                done.extend(self._launch(pool, key, chunk, now=now,
                                         mesh=self.shards.size))
        while len(jobs) >= self.lanes:
            chunk, jobs = jobs[:self.lanes], jobs[self.lanes:]
            done.extend(self._launch(pool, key, chunk, now=now))
        if jobs and not full_only:
            done.extend(self._launch(pool, key, jobs, now=now))
            jobs = []
        if jobs:
            pool.buckets[key] = jobs
        else:
            del pool.buckets[key]
            pool.age.pop(key, None)
        return done

    def _bucket_max_wait(self, pool: "_LanePool | None", key: tuple,
                         queued: int) -> float | None:
        """Effective age threshold for one partial bucket: the tuner's
        observed-inter-arrival pick when enabled and warmed, else the
        constructor ``max_wait``."""
        if self.tuner is not None and pool is not None:
            return self.tuner.max_wait(pool.spec.name, key, queued,
                                       self.max_wait)
        return self.max_wait

    def _pool_pressure(self, pool: "_LanePool") -> int:
        """Effective pressure threshold for one pool: the tuner's
        launch-cost-amortizing pick when enabled and warmed, else the
        constructor ``pressure``."""
        if self.tuner is not None:
            return self.tuner.pressure(pool.spec.name, self.pressure)
        return self.pressure

    def _under_pressure(self, pool: "_LanePool") -> bool:
        return pool.queued() >= self._pool_pressure(pool)

    def _expired(self, jobs: list[SolveJob], now: float,
                 pool: "_LanePool | None" = None,
                 key: tuple | None = None) -> bool:
        deadline = _bucket_priority(jobs)[0]
        if deadline <= now:
            return True
        age = now - min(j.submitted_at for j in jobs)
        max_wait = self._bucket_max_wait(pool, key, len(jobs)) \
            if key is not None else self.max_wait
        return max_wait is not None and age >= max_wait

    def poll(self, now: float | None = None) -> list[SolveJob]:
        """One continuous-batching round: full lane groups always
        dispatch; partial buckets dispatch only on expired deadline,
        ``max_wait`` age, or per-pool pressure.  Oldest deadline flushes
        first throughout.  With an :class:`OverloadPolicy` attached the
        round additionally sheds expired best-effort jobs, admits
        launches against the lane-time budget (preempting best-effort
        partials for hard-deadline buckets), and coalesces small jobs
        into larger buckets' free lanes — see the module docstring."""
        now = self.clock() if now is None else now
        if self.shards is not None:
            # quarantined shards whose sit-out has elapsed are owed one
            # probe launch each this round (see _supervise)
            self._probe_ready = self.shards.probe_due(now,
                                                      self.probe_after)
        if self.policy is not None:
            done = self._poll_policy(now)
            if self._dags:
                self._advance_dags(now)
            if self.decode is not None:
                self._poll_decode(now)
            return done
        done: list[SolveJob] = []
        for pool, key in self._sorted_buckets():
            done.extend(self._flush_bucket(pool, key, full_only=True,
                                           now=now))
        for pool, key in self._sorted_buckets():
            jobs = pool.buckets[key]
            if self._expired(jobs, now, pool, key) \
                    or self._under_pressure(pool):
                done.extend(self._flush_bucket(pool, key, full_only=False,
                                               now=now))
        if self._dags:
            self._advance_dags(now)
        if self.decode is not None:
            self._poll_decode(now)
        return done

    def run(self) -> list[SolveJob]:
        """Drain everything queued (deadline-priority bucket order) and
        return the completed jobs.  Drain is unconditional: no budget,
        no shedding — every still-queued job is served.  With DAG jobs
        in flight the drain loops: each pass's completed stages unlock
        their consumers, which the next pass serves, until no bucket
        flushes and no DAG advances (DAG-free muxes take exactly one
        pass — identical to the pre-DAG drain).  An attached decode
        engine is drained the same way: unbudgeted continuous-batching
        steps interleave with the flush passes until its queue and
        every slot are empty."""
        done: list[SolveJob] = []
        while True:
            flushed = False
            for pool, key in self._sorted_buckets():
                served = self._flush_bucket(pool, key, full_only=False)
                done.extend(served)
                flushed = flushed or bool(served)
            advanced = self._advance_dags(self.clock()) \
                if self._dags else False
            stepped = False
            if self.decode is not None and self.decode.has_work():
                self.decode.step()
                stepped = True
            if not flushed and not advanced and not stepped:
                return done

    # ---------------- overload policy ----------------

    def _shed(self, now: float) -> None:
        """Admission control: drop queued best-effort jobs whose deadline
        has already expired (they can no longer meet it; serving them
        would burn budget hard-deadline traffic needs).  Hard jobs are
        never shed."""
        for pool in self._pools.values():
            for key in list(pool.buckets):
                keep = []
                for job in pool.buckets[key]:
                    if (job.priority != "hard" and job.deadline is not None
                            and job.deadline < now):
                        job.state = "dropped"
                        self.recorder.record_drop(pool.spec.name, now,
                                                  job.priority, "expired")
                        self._event("drop", t=now, pipeline=pool.spec.name,
                                    seq=job.seq, deadline=job.deadline,
                                    reason="expired")
                    else:
                        keep.append(job)
                if keep:
                    pool.buckets[key] = keep
                else:
                    del pool.buckets[key]
                    pool.age.pop(key, None)

    def _split_threshold(self) -> int:
        """Backlog (jobs in one bucket) at which a bucket counts as hot
        and is offered as mesh-spanning flushes: at least one full lane
        group plus one, scaled by ``shard_split_pressure``."""
        return max(self.lanes + 1,
                   int(round(self.lanes * self._shard_split_pressure)))

    def _candidates(self, now: float) -> list[_Candidate]:
        """Launch candidates this round: every full lane-group chunk,
        plus each due partial chunk (expired deadline / max_wait age /
        per-pool pressure / starvation-aged).  Priced at full pool width
        — padded lanes execute too — and sorted aged-first, then by
        (deadline, arrival).

        On a mesh, a hot bucket (backlog >= the split threshold) is
        first carved into mesh-spanning chunks of up to ``lanes * mesh``
        jobs — cross-shard work stealing — but only while the sharded
        price (times ``steal_ratio``) beats the serial per-shard
        launches it replaces, so stealing never wins over a cheaper
        local partial; the remainder falls through to the per-shard
        chunking below."""
        pol = self.policy
        cands: list[_Candidate] = []
        for pool in self._pools.values():
            under_pressure = self._under_pressure(pool)
            for key, jobs in pool.buckets.items():
                if not jobs:
                    continue
                price = pool.dispatcher.price(key, self.lanes)
                aged = pool.age.get(key, 0) >= pol.max_defer
                rest = jobs
                if self.shards is not None \
                        and self.shards.all_healthy() \
                        and len(rest) >= self._split_threshold():
                    total = self.lanes * self.shards.size
                    sh_price = pool.dispatcher.price(
                        key, total, mesh=self.shards.size)
                    while len(rest) >= self._split_threshold():
                        k = min(len(rest), total)
                        local = math.ceil(k / self.lanes) * price
                        if sh_price * self._steal_ratio >= local:
                            self._event(
                                "shard_reject", t=now,
                                pipeline=pool.spec.name,
                                shape=_shape_label(key), considered=k,
                                sharded_cost=_round(sh_price),
                                local_cost=_round(local))
                            break
                        chunk, rest = rest[:k], rest[k:]
                        cand = self._mk_cand(pool, key, chunk, k < total,
                                             aged, sh_price)
                        cand.mesh = self.shards.size
                        cands.append(cand)
                        self._event(
                            "shard_split", t=now,
                            pipeline=pool.spec.name,
                            shape=_shape_label(key),
                            jobs=[j.seq for j in chunk],
                            mesh=self.shards.size,
                            sharded_cost=_round(sh_price),
                            local_cost=_round(local))
                while len(rest) >= self.lanes:
                    chunk, rest = rest[:self.lanes], rest[self.lanes:]
                    cands.append(self._mk_cand(pool, key, chunk, False,
                                               aged, price))
                if rest and (aged or under_pressure
                             or self._expired(rest, now, pool, key)):
                    cands.append(self._mk_cand(pool, key, rest, True,
                                               aged, price))
        cands.sort(key=lambda c: (not c.aged, c.deadline, c.rank, c.seq))
        return cands

    @staticmethod
    def _mk_cand(pool, key, chunk, partial, aged, price) -> _Candidate:
        deadline, rank, seq = _bucket_priority(chunk)
        return _Candidate(pool=pool, key=key, jobs=list(chunk),
                          partial=partial,
                          hard=any(j.priority == "hard" for j in chunk),
                          aged=aged, price=price, deadline=deadline,
                          seq=seq, rank=rank)

    def _admit(self, cands: list[_Candidate],
               now: float) -> list[_Candidate]:
        """Budgeted admission with hard-deadline preemption.  Walks the
        candidates in priority order; a hard candidate that does not fit
        may abandon already-admitted best-effort launches (cheapest to
        abandon first; partials preferred) to free budget.  Deferred and
        preempted buckets
        age toward the starvation bypass: aged candidates sort first
        (budget priority), and ONE aged candidate per poll may borrow
        past the budget (the voucher drives the remaining budget
        negative, blocking this poll's later candidates; each poll
        starts afresh from ``policy.budget``) — bounded, so a backlog
        of aged buckets can never avalanche past admission control.

        On a mesh the budget generalizes to one ``policy.budget`` per
        shard: a local candidate is placed on (and charged to) the shard
        with the most remaining budget, then least load; a mesh-spanning
        candidate must fit EVERY shard's budget and is charged to all of
        them.  Preempted launches refund the shard(s) they were charged
        to.  With one shard this reduces exactly to the scalar logic
        above."""
        pol = self.policy
        n = 1 if self.shards is None else self.shards.size
        base = math.inf if pol.budget is None else pol.budget
        # retry backoff charged by launch supervision since the last
        # poll debits each shard's budget here (zero fault-free)
        budgets = [base - debt for debt in self._fault_debt]
        self._fault_debt = [0.0] * n
        admitted: list[_Candidate] = []
        voucher = True
        bumped: set[tuple] = set()

        def best(cand, extra=None):
            """Placement shard for a local candidate: most remaining
            budget (+ any budget a preemption plan would free), least
            load, lowest index."""
            if self.shards is None:
                return 0
            avail = budgets if extra is None else \
                [b + e for b, e in zip(budgets, extra)]
            return self.shards.pick(avail, among=self.shards.healthy())

        def fits(cand, extra=None):
            avail = budgets if extra is None else \
                [b + e for b, e in zip(budgets, extra)]
            if cand.mesh > 1:
                return min(avail) >= cand.price
            return avail[best(cand, extra)] >= cand.price

        def charge(cand, sign=-1.0):
            if cand.mesh > 1:
                for s in range(n):
                    budgets[s] += sign * cand.price
            else:
                budgets[cand.shard or 0] += sign * cand.price

        def place(cand):
            if cand.mesh <= 1 and self.shards is not None:
                cand.shard = best(cand)
            charge(cand)
            admitted.append(cand)

        def bump(cand):
            pool = cand.pool
            if (id(pool), cand.key) in bumped:
                return              # age once per bucket per poll
            bumped.add((id(pool), cand.key))
            pool.age[cand.key] = pool.age.get(cand.key, 0) + 1

        for cand in cands:
            ok = fits(cand)
            if ok or (cand.aged and voucher):
                if not ok:
                    voucher = False
                place(cand)
                continue
            if cand.hard and pol.preempt:
                victims = sorted(
                    (a for a in admitted if not a.hard and not a.aged),
                    key=lambda a: (a.price, not a.partial, len(a.jobs)))
                plan: list[_Candidate] = []
                freed = [0.0] * n
                for v in victims:
                    if fits(cand, freed):
                        break
                    plan.append(v)
                    if v.mesh > 1:
                        for s in range(n):
                            freed[s] += v.price
                    else:
                        freed[v.shard or 0] += v.price
                if plan and fits(cand, freed):
                    for v in plan:
                        admitted.remove(v)
                        bump(v)
                        charge(v, sign=1.0)
                        self.recorder.record_preempt(
                            v.pool.spec.name, len(v.jobs), now)
                        self._event(
                            "preempt", t=now,
                            pipeline=v.pool.spec.name,
                            shape=_shape_label(v.key),
                            jobs=[j.seq for j in v.jobs],
                            cost=_round(v.price),
                            for_pipeline=cand.pool.spec.name,
                            for_cost=_round(cand.price))
                    place(cand)
                    continue
            bump(cand)
            left = min(budgets) if cand.mesh > 1 else budgets[best(cand)]
            self._event("defer", t=now, pipeline=cand.pool.spec.name,
                        shape=_shape_label(cand.key),
                        jobs=[j.seq for j in cand.jobs],
                        price=_round(cand.price),
                        budget=_round(left))
        return admitted

    def _ride_score(self, cand: _Candidate, dkey: tuple, k: int,
                    host_variant) -> tuple[float, float]:
        """(ride, own) prices for embedding ``k`` jobs of donor bucket
        ``dkey`` into host ``cand``: ride = the padded-lane work the
        riders cost at the host shape; own = the launch they would need
        on their own.  Riding wins iff ride < own."""
        pool, spec = cand.pool, cand.pool.spec
        big_shapes = tuple(shape for shape, _ in cand.key)
        small_shapes = tuple(shape for shape, _ in dkey)
        donor_variant, _ = pool.dispatcher.resolve(dkey)
        cm = self.policy.cost_model
        ride = k * cm.lane_cost(spec.name, host_variant, big_shapes)
        own = cm.launch_cost(spec.name, donor_variant, small_shapes,
                             lanes=k)
        return ride, own

    def _plan_riders(self, admitted: list[_Candidate],
                     now: float) -> tuple[list[_Candidate], list[float]]:
        """Cross-shape coalescing: fill admitted partial launches' free
        lanes with compatible smaller jobs from the same pool instead of
        filler.  Two donor sources, in order: (1) a whole *admitted*
        smaller partial launch that fits entirely — its own launch is
        cancelled and its already-charged budget refunded (the saved
        launch is the point); (2) queued jobs of due-or-pressured
        smaller buckets that were not admitted this round.  A ride is
        validated at the padded shape (``Coalescer.compatible`` on the
        (donor, host) keys; the host bucket's variant was dispatched by
        its applicability predicate at exactly those shapes, and
        ``_launch`` verifies every embedded lane conforms to them) and
        scored by the cost model: ride iff the padded-lane work is
        cheaper than the launch it avoids.  Returns the admitted list
        with absorbed launches removed, plus the refunded budget
        (per-shard list; one entry on a single device).  Mesh-spanning
        launches are never absorbed as donors — their budget was
        charged to every shard — but a spanning partial can host
        riders in its padded lanes like any other partial."""
        pol = self.policy
        taken = {id(j) for c in admitted for j in c.jobs}
        absorbed: set[int] = set()
        refund = [0.0] * (1 if self.shards is None else self.shards.size)
        for cand in admitted:
            if not cand.partial or id(cand) in absorbed:
                continue
            free = self.lanes * max(1, cand.mesh) - len(cand.jobs)
            if free <= 0:
                continue
            pool, spec = cand.pool, cand.pool.spec
            if spec.coalesce is None:
                continue
            variant, _ = pool.dispatcher.resolve(cand.key)
            # (1) absorb whole admitted smaller partial launches
            for donor in admitted:
                if free <= 0:
                    break
                if (donor is cand or id(donor) in absorbed
                        or not donor.partial or donor.riders
                        or donor.mesh > 1
                        or donor.pool is not pool
                        or len(donor.jobs) > free
                        or not spec.coalesce.compatible(donor.key,
                                                        cand.key)):
                    continue
                k = len(donor.jobs)
                ride, own = self._ride_score(cand, donor.key, k, variant)
                if ride >= own:
                    self._event("coalesce_reject", t=now,
                                pipeline=spec.name,
                                from_shape=_shape_label(donor.key),
                                into_shape=_shape_label(cand.key),
                                ride_cost=_round(ride),
                                own_cost=_round(own))
                    continue
                cand.riders += tuple(donor.jobs)
                free -= k
                absorbed.add(id(donor))
                refund[donor.shard or 0] += donor.price
                self._event("coalesce", t=now, pipeline=spec.name,
                            from_shape=_shape_label(donor.key),
                            into_shape=_shape_label(cand.key),
                            jobs=[j.seq for j in donor.jobs],
                            ride_cost=_round(ride), own_cost=_round(own))
            # (2) queued donors that were not admitted this round
            under_pressure = self._under_pressure(pool)
            for dkey, djobs in list(pool.buckets.items()):
                if free <= 0:
                    break
                if dkey == cand.key or not djobs:
                    continue
                if not spec.coalesce.compatible(dkey, cand.key):
                    continue
                if not (under_pressure or self._expired(djobs, now,
                                                        pool, dkey)):
                    continue        # no pressure, donor can keep waiting
                avail = [j for j in djobs if id(j) not in taken]
                k = min(free, len(avail))
                if k <= 0:
                    continue
                ride, own = self._ride_score(cand, dkey, k, variant)
                if ride >= own:
                    self._event("coalesce_reject", t=now,
                                pipeline=spec.name,
                                from_shape=_shape_label(dkey),
                                into_shape=_shape_label(cand.key),
                                ride_cost=_round(ride),
                                own_cost=_round(own))
                    continue
                riders = avail[:k]
                cand.riders += tuple(riders)
                free -= k
                taken.update(id(j) for j in riders)
                self._event("coalesce", t=now, pipeline=spec.name,
                            from_shape=_shape_label(dkey),
                            into_shape=_shape_label(cand.key),
                            jobs=[j.seq for j in riders],
                            ride_cost=_round(ride), own_cost=_round(own))
        return [c for c in admitted if id(c) not in absorbed], refund

    def _readmit(self, cands: list[_Candidate],
                 admitted: list[_Candidate], refund: list[float],
                 now: float) -> list[_Candidate]:
        """Budget refunded by absorbed launches flows back to this
        round's deferred candidates, in the original priority order —
        without this, a poll that saved a launch by coalescing would
        still under-admit by that launch's cost.  Refunds are per-shard
        (a local candidate re-admits against the richest shard's refund
        and is placed there; a spanning one needs every shard's)."""
        have = {id(c) for c in admitted}
        extra: list[_Candidate] = []
        for cand in cands:
            if id(cand) in have or not cand.jobs:
                continue
            taken = {id(j) for c in admitted + extra
                     for j in (*c.jobs, *c.riders)}
            if any(id(j) in taken for j in cand.jobs):
                continue            # its jobs already ride elsewhere
            if cand.mesh > 1:
                if cand.price > min(refund):
                    continue
                for s in range(len(refund)):
                    refund[s] -= cand.price
            else:
                s = self.shards.pick(refund,
                                     among=self.shards.healthy()) \
                    if self.shards is not None else 0
                if cand.price > refund[s]:
                    continue
                refund[s] -= cand.price
                if self.shards is not None:
                    cand.shard = s
            extra.append(cand)
            self._event("readmit", t=now,
                        pipeline=cand.pool.spec.name,
                        shape=_shape_label(cand.key),
                        jobs=[j.seq for j in cand.jobs],
                        price=_round(cand.price))
        return extra

    def _poll_policy(self, now: float) -> list[SolveJob]:
        """One overload-aware scheduling round: shed -> build candidates
        -> budgeted admission (with preemption) -> coalesce (refunding
        absorbed launches' budget to deferred candidates) -> dispatch in
        admission priority order."""
        pol = self.policy
        if pol.shed:
            self._shed(now)
        cands = self._candidates(now)
        admitted = self._admit(cands, now)
        if pol.coalesce:
            admitted, refund = self._plan_riders(admitted, now)
            if any(r > 0.0 for r in refund):
                admitted.extend(self._readmit(cands, admitted, refund,
                                              now))
        done: list[SolveJob] = []
        order = {id(c): i for i, c in enumerate(cands)}
        for cand in sorted(admitted, key=lambda c: order[id(c)]):
            pool = cand.pool
            # launch BEFORE dequeuing: a launch that raises (e.g. a
            # nonconforming coalesce embedding) must leave its jobs
            # queued, exactly like the legacy flush path
            served = self._launch(pool, cand.key, cand.jobs,
                                  riders=cand.riders, now=now,
                                  mesh=cand.mesh, shard=cand.shard)
            # dequeue only terminal jobs: supervision may have detached
            # riders back to the queue for a later round
            pool.remove(cand.key,
                        [j for j in cand.jobs if j.state != "queued"])
            by_key: dict[tuple, list] = {}
            for rider in cand.riders:
                if rider.state == "queued":
                    continue
                by_key.setdefault(rider.shape_key(), []).append(rider)
            for dkey, riders in by_key.items():
                pool.remove(dkey, riders)
            pool.age.pop(cand.key, None)
            done.extend(served)
        return done
