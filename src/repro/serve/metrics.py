"""SLO metrics for the serving stack — plain dataclasses, no deps.

Every engine built on :class:`repro.serve.core.EngineCore` owns a
:class:`Recorder` that accumulates two event kinds:

  * **launches** — one per dispatched grid (a ``pallas_call`` over a
    lane group): pipeline name, shape key, how many lanes carried real
    jobs vs. benign padding.
  * **jobs** — one per completed job: submit and finish timestamps on
    the engine's clock (injectable — tests and trace replays use
    :class:`repro.serve.core.ManualClock`).

``Recorder.snapshot()`` folds the events into a :class:`MetricsSnapshot`
with per-pipeline p50/p99/mean/max latency, throughput over the active
window, lane utilization (real lanes / dispatched lanes) and padded-lane
waste (the complement) — the SLO surface the ROADMAP asks
``benchmarks/bench_pipelines.py`` to report for mixed traffic.
"""
from __future__ import annotations

import collections
import dataclasses
import math


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted list."""
    if not sorted_vals:
        return math.nan
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = (q / 100.0) * (len(sorted_vals) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Submit-to-finish latency distribution, in clock seconds."""

    count: int
    p50: float
    p99: float
    mean: float
    max: float

    @staticmethod
    def of(samples: list[float]) -> "LatencyStats":
        if not samples:
            return LatencyStats(0, math.nan, math.nan, math.nan, math.nan)
        s = sorted(samples)
        return LatencyStats(
            count=len(s),
            p50=_percentile(s, 50.0),
            p99=_percentile(s, 99.0),
            mean=sum(s) / len(s),
            max=s[-1])


@dataclasses.dataclass(frozen=True)
class LaunchRecord:
    """One dispatched grid: ``real + padded`` lanes went to the device.

    ``variant`` is the registry variant the dispatcher routed the lane
    group to (``"base"`` for the spec's own entry point) — the per-launch
    record behind :attr:`PipelineStats.dispatch_counts`."""

    pipeline: str
    shape: tuple
    real: int
    padded: int
    t: float
    variant: str = "base"


@dataclasses.dataclass(frozen=True)
class PipelineStats:
    """Aggregate SLO view of one pipeline's traffic."""

    pipeline: str
    jobs: int
    launches: int
    lanes_dispatched: int
    lanes_padded: int
    lane_utilization: float      # real lanes / dispatched lanes
    padded_lane_waste: float     # padded lanes / dispatched lanes
    latency: LatencyStats
    throughput: float            # jobs/s over [first submit, last finish]
    dispatch_counts: dict = dataclasses.field(default_factory=dict)
    """Launches per registry variant name — the observable proof that a
    bucket of large / split-complex jobs landed on the fast path."""


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """Point-in-time fold of everything a Recorder has seen."""

    pipelines: dict[str, PipelineStats]
    launches: tuple[LaunchRecord, ...]
    total_jobs: int
    total_launches: int

    def __getitem__(self, pipeline: str) -> PipelineStats:
        return self.pipelines[pipeline]


class Recorder:
    """Accumulates launch/job events; ``snapshot()`` builds the stats."""

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self._launches: list[LaunchRecord] = []
        self._jobs: dict[str, list[tuple[float, float]]] = \
            collections.defaultdict(list)

    def record_launch(self, pipeline: str, shape: tuple, real: int,
                      padded: int, t: float,
                      variant: str = "base") -> None:
        self._launches.append(
            LaunchRecord(pipeline, shape, int(real), int(padded), t,
                         variant))

    def record_job(self, pipeline: str, submitted_at: float,
                   finished_at: float) -> None:
        self._jobs[pipeline].append((submitted_at, finished_at))

    def snapshot(self) -> MetricsSnapshot:
        per: dict[str, PipelineStats] = {}
        names = set(self._jobs) | {l.pipeline for l in self._launches}
        for name in sorted(names):
            jobs = self._jobs.get(name, [])
            launches = [l for l in self._launches if l.pipeline == name]
            real = sum(l.real for l in launches)
            padded = sum(l.padded for l in launches)
            dispatched = real + padded
            lat = LatencyStats.of([f - s for s, f in jobs])
            if jobs:
                window = max(f for _, f in jobs) - min(s for s, _ in jobs)
                thr = len(jobs) / window if window > 0 else 0.0
            else:
                thr = 0.0
            per[name] = PipelineStats(
                pipeline=name,
                jobs=len(jobs),
                launches=len(launches),
                lanes_dispatched=dispatched,
                lanes_padded=padded,
                lane_utilization=(real / dispatched) if dispatched else 0.0,
                padded_lane_waste=(padded / dispatched) if dispatched
                else 0.0,
                latency=lat,
                throughput=thr,
                dispatch_counts=dict(collections.Counter(
                    l.variant for l in launches)))
        return MetricsSnapshot(
            pipelines=per,
            launches=tuple(self._launches),
            total_jobs=sum(len(v) for v in self._jobs.values()),
            total_launches=len(self._launches))
