"""SLO metrics for the serving stack — plain dataclasses, no deps.

Every engine built on :class:`repro.serve.core.EngineCore` owns a
:class:`Recorder` that accumulates four event kinds:

  * **launches** — one per dispatched grid (a ``pallas_call`` over a
    lane group): pipeline name, shape key, how many lanes carried real
    jobs vs. benign padding, how many of the real lanes were
    cross-shape *coalesced* riders (small jobs embedded into a larger
    bucket's free lanes by the overload policy), and the launch's
    **measured wall-clock** — the feedback signal the self-tuning cost
    model (:mod:`repro.serve.cost`) re-fits from.
  * **jobs** — one per completed job: submit and finish timestamps on
    the engine's clock (injectable — tests and trace replays use
    :class:`repro.serve.core.ManualClock`) plus the job's priority
    class, so latency distributions split per priority.
  * **drops** — one per job shed by the overload policy (expired
    best-effort work under admission control).
  * **preemptions** — one per bucket flush abandoned so a pending
    hard-deadline bucket could take its lane-time budget.
  * **retries / failures** — launch supervision's trail: one retry per
    supervised relaunch of a failed group, one failure per job marked
    terminal ``state="failed"`` with a structured reason (exhausted
    retries, persistent non-finite lane, rejected non-finite input).
    Folded into :class:`FaultStats` (``MetricsSnapshot.faults``)
    together with the shard-quarantine and variant-demotion counters
    the mux attaches.

``Recorder.snapshot()`` folds the events into a :class:`MetricsSnapshot`
with per-pipeline p50/p99/mean/max latency (overall AND per priority
class), throughput over the active window, lane utilization (real lanes
/ dispatched lanes), padded-lane waste (the complement), and the
dropped / preempted / coalesced counters the overload policy exposes —
the SLO surface the ROADMAP asks ``benchmarks/bench_pipelines.py`` to
report for mixed traffic.
"""
from __future__ import annotations

import collections
import dataclasses
import math


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted list."""
    if not sorted_vals:
        return math.nan
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = (q / 100.0) * (len(sorted_vals) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Submit-to-finish latency distribution, in clock seconds."""

    count: int
    p50: float
    p99: float
    mean: float
    max: float

    @staticmethod
    def of(samples: list[float]) -> "LatencyStats":
        if not samples:
            return LatencyStats(0, math.nan, math.nan, math.nan, math.nan)
        s = sorted(samples)
        return LatencyStats(
            count=len(s),
            p50=_percentile(s, 50.0),
            p99=_percentile(s, 99.0),
            mean=sum(s) / len(s),
            max=s[-1])


@dataclasses.dataclass(frozen=True)
class LaunchRecord:
    """One dispatched grid: ``real + padded`` lanes went to the device.

    ``variant`` is the registry variant the dispatcher routed the lane
    group to (``"base"`` for the spec's own entry point) — the per-launch
    record behind :attr:`PipelineStats.dispatch_counts`.  ``coalesced``
    counts how many of the ``real`` lanes carried cross-shape riders
    (small jobs embedded at this launch's shape instead of filler)."""

    pipeline: str
    shape: tuple
    real: int
    padded: int
    t: float
    variant: str = "base"
    coalesced: int = 0
    measured: float = math.nan
    """Measured wall-clock seconds of the launch (stack + pad + execute
    + scatter), NaN when the engine did not time it — the per-launch
    truth the cost model's predictions are checked against."""
    mesh: int = 1
    """Shard count the launch spanned: 1 for a single-device launch,
    N > 1 when the lane axis was shard_map'd over an N-shard mesh."""
    shard: int = 0
    """Shard the launch was placed on (``-1`` for mesh-spanning
    launches, which occupy every shard)."""


@dataclasses.dataclass(frozen=True)
class ShardStats:
    """Aggregate view of one mesh shard's lane traffic.

    Lane counts are floats: a mesh-spanning launch splits its lanes
    evenly across the shards that executed it (the padded width is a
    multiple of the shard count, so dispatched lanes divide exactly;
    real lanes may not).  ``load`` is the accumulated priced cost
    (cost-model seconds) the scheduler charged this shard — the
    balancing signal :meth:`repro.serve.shard.LaneShards.pick` uses."""

    shard: int
    launches: int
    lanes_dispatched: float
    lanes_real: float
    utilization: float           # real lanes / dispatched lanes
    load: float = 0.0


def shard_stats(launches, n_shards: int,
                load=None) -> tuple[dict, float]:
    """Fold launch records into per-shard stats + the imbalance ratio
    (max/mean dispatched lanes; NaN before any lanes).  A spanning
    launch (``mesh > 1``) counts on every shard it occupied; a placed
    launch on its ``shard`` alone."""
    lanes = [0.0] * n_shards
    real = [0.0] * n_shards
    count = [0] * n_shards
    for rec in launches:
        width = rec.real + rec.padded
        if rec.mesh > 1:
            for s in range(n_shards):
                lanes[s] += width / rec.mesh
                real[s] += rec.real / rec.mesh
                count[s] += 1
        elif 0 <= rec.shard < n_shards:
            lanes[rec.shard] += width
            real[rec.shard] += rec.real
            count[rec.shard] += 1
    total = sum(lanes)
    imbalance = (max(lanes) / (total / n_shards)) if total > 0 \
        else math.nan
    stats = {
        s: ShardStats(
            shard=s, launches=count[s],
            lanes_dispatched=lanes[s], lanes_real=real[s],
            utilization=(real[s] / lanes[s]) if lanes[s] else 0.0,
            load=(load[s] if load is not None else 0.0))
        for s in range(n_shards)}
    return stats, imbalance


@dataclasses.dataclass(frozen=True)
class DropRecord:
    """One job shed by the overload policy (terminal, never served)."""

    pipeline: str
    t: float
    priority: str = "best_effort"
    reason: str = "expired"


@dataclasses.dataclass(frozen=True)
class FailRecord:
    """One job launch supervision gave up on (terminal ``"failed"``)."""

    pipeline: str
    t: float
    priority: str = "best_effort"
    reason: str = "launch_failed"


@dataclasses.dataclass(frozen=True)
class FaultStats:
    """Fault-handling observables (``MetricsSnapshot.faults``): the
    supervision layer's health summary.  All zeros / empty on a
    fault-free run — the block exists unconditionally so dashboards can
    rely on its shape."""

    retries: int = 0
    """Supervised group relaunches (each charged backoff debt)."""
    failed_jobs: int = 0
    """Jobs marked terminal ``state="failed"`` with a reason."""
    quarantines: int = 0
    """Lifetime shard quarantine transitions."""
    reinstatements: int = 0
    """Quarantined shards returned to service by a surviving probe."""
    demotions: int = 0
    """Variant demotions (per-bucket fallback down the ladder)."""
    watchdog_flags: int = 0
    """Launches whose measured wall exceeded the predicted-cost
    watchdog ratio."""
    quarantined_shards: tuple = ()
    """Shard indices currently quarantined (empty when healthy)."""
    time_to_recover: float = math.nan
    """Mean quarantine downtime (scheduling-clock seconds) across
    reinstated shards; NaN before any reinstatement."""
    alerts: tuple = ()
    """Drift-style alert strings (e.g. ``"demote:cholesky_solve:
    blocked->base"``) — the degradations an operator should see."""


@dataclasses.dataclass(frozen=True)
class DagStats:
    """Aggregate view of one served DAG's end-to-end traffic
    (``MetricsSnapshot.dags``): terminal counts per state plus the
    submit-to-last-stage-done latency distribution — the per-*stage*
    latencies live in the stage pipelines' own :class:`PipelineStats`."""

    dag: str
    submitted: int
    done: int
    failed: int = 0
    dropped: int = 0
    latency: LatencyStats = dataclasses.field(
        default_factory=lambda: LatencyStats.of([]))
    """End-to-end (DAG submit -> final stage done) latency over the
    completed DAGs, in clock seconds."""
    latency_by_priority: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class DecodeStats:
    """Aggregate view of continuous-batching decode traffic
    (``MetricsSnapshot.decode``): per-phase latency in the
    maxtext-microbenchmark shape — **insert** (submit -> slot assigned,
    scheduling-clock queue wait), **prefill** (slot assigned -> final
    prompt token consumed, wall seconds) and **generate** (first output
    token -> request done, wall seconds) — plus the step/token counters
    the continuous-vs-lockstep throughput comparison is judged by.
    All-empty (the default) when no decode engine is attached, so the
    block's shape is always present."""

    requests: int = 0
    """Requests that reached ``done`` (EOS or ``max_new``)."""
    tokens: int = 0
    """Output tokens generated across all requests."""
    steps: int = 0
    """Pool-wide SPMD decode steps executed."""
    slot_reuses: int = 0
    """Inserts into a slot that previously held another request — the
    paged-KV reuse counter (no cache rebuild happened on these)."""
    shed: int = 0
    """Queued best-effort requests dropped past their deadline."""
    insert: LatencyStats = dataclasses.field(
        default_factory=lambda: LatencyStats.of([]))
    prefill: LatencyStats = dataclasses.field(
        default_factory=lambda: LatencyStats.of([]))
    generate: LatencyStats = dataclasses.field(
        default_factory=lambda: LatencyStats.of([]))
    tokens_per_step: float = math.nan
    """Continuous-batching throughput: generated tokens per SPMD step
    (the pool width is its ceiling; lockstep burns steps on idle lanes
    and trailing drain, pulling it down)."""


@dataclasses.dataclass(frozen=True)
class PipelineStats:
    """Aggregate SLO view of one pipeline's traffic."""

    pipeline: str
    jobs: int
    launches: int
    lanes_dispatched: int
    lanes_padded: int
    lane_utilization: float      # real lanes / dispatched lanes
    padded_lane_waste: float     # padded lanes / dispatched lanes
    latency: LatencyStats
    throughput: float
    """Jobs/s over [first submit, last finish].  ``0.0`` only for a
    genuinely empty pipeline (no completed jobs); a zero-width window
    (jobs that all completed at the same clock instant, e.g. one
    same-tick batch on a virtual clock) reports NaN — unknown, not
    dead."""
    dispatch_counts: dict = dataclasses.field(default_factory=dict)
    """Launches per registry variant name — the observable proof that a
    bucket of large / split-complex jobs landed on the fast path."""
    dropped: int = 0
    """Jobs shed by the overload policy (expired best-effort)."""
    failed: int = 0
    """Jobs launch supervision marked terminal ``"failed"`` (with a
    structured reason) — distinct from ``dropped``: these were admitted
    but could not be served."""
    retries: int = 0
    """Supervised launch retries attributed to this pipeline."""
    preempted: int = 0
    """Jobs whose bucket flush was abandoned for a hard-deadline bucket
    (they stay queued and are re-admitted later — not terminal)."""
    lanes_coalesced: int = 0
    """Real lanes that carried cross-shape riders."""
    latency_by_priority: dict = dataclasses.field(default_factory=dict)
    """Priority class -> LatencyStats — the per-priority p50/p99 view the
    overload policy is judged by."""


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """Point-in-time fold of everything a Recorder has seen."""

    pipelines: dict[str, PipelineStats]
    launches: tuple[LaunchRecord, ...]
    total_jobs: int
    total_launches: int
    total_dropped: int = 0
    total_preempted: int = 0
    total_coalesced: int = 0
    total_failed: int = 0
    total_retries: int = 0
    faults: FaultStats = dataclasses.field(default_factory=FaultStats)
    """Fault-handling health block (see :class:`FaultStats`).  The
    Recorder fills retries/failed_jobs; ``SolverMux.metrics()`` attaches
    the shard-quarantine / demotion / watchdog side it owns."""
    drift: dict = dataclasses.field(default_factory=dict)
    """``"pipeline/variant" -> repro.serve.cost.DriftStat`` — the cost
    model's predicted/measured health per pair (EWMA ratio, update
    count, calibration source).  Empty when the serving engine carries
    no cost model.  Attached by ``SolverMux.metrics()``; the Recorder
    itself never sees the cost model."""
    worst_drift: object | None = None
    """The DriftStat furthest from ratio 1.0 in log space, or None."""
    calibration_updates: dict = dataclasses.field(default_factory=dict)
    """Applied window-median update counts per estimator (``"overhead"``
    plus one ``"pipeline/variant"`` key per re-fit rate)."""
    shards: dict = dataclasses.field(default_factory=dict)
    """``shard index -> ShardStats`` for mesh-sharded muxes (empty on
    the single-device path).  Attached by ``SolverMux.metrics()`` —
    like ``drift``, the Recorder itself never sees the mesh."""
    shard_imbalance: float = math.nan
    """max/mean dispatched lanes across shards (1.0 = balanced; NaN
    when unsharded or before any launch)."""
    shard_imbalance_alert: bool = False
    """True when ``shard_imbalance`` exceeds the configured
    ``imbalance_alert`` ratio — the skew observability hook."""
    dags: dict = dataclasses.field(default_factory=dict)
    """``dag name -> DagStats`` for DAG jobs served via
    ``SolverMux.submit_dag`` (empty when no DAGs were submitted)."""
    decode: DecodeStats = dataclasses.field(default_factory=DecodeStats)
    """Continuous-batching decode traffic (see :class:`DecodeStats`).
    All-zero when no decode engine shares this recorder."""

    def __getitem__(self, pipeline: str) -> PipelineStats:
        return self.pipelines[pipeline]


class Recorder:
    """Accumulates launch/job/drop/preempt events; ``snapshot()`` builds
    the stats."""

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self._launches: list[LaunchRecord] = []
        self._jobs: dict[str, list[tuple[float, float, str]]] = \
            collections.defaultdict(list)
        self._drops: list[DropRecord] = []
        self._preempts: dict[str, int] = collections.defaultdict(int)
        self._fails: list[FailRecord] = []
        self._retries: dict[str, int] = collections.defaultdict(int)
        self._dag_submits: dict[str, int] = collections.defaultdict(int)
        self._dag_records: list[tuple[str, float, float, str, str]] = []
        self._decode_phases: dict[str, list[float]] = \
            collections.defaultdict(list)
        self._decode_steps = 0
        self._decode_tokens = 0
        self._decode_requests = 0
        self._decode_reuses = 0
        self._decode_shed = 0

    def record_launch(self, pipeline: str, shape: tuple, real: int,
                      padded: int, t: float, variant: str = "base",
                      coalesced: int = 0,
                      measured: float = math.nan,
                      mesh: int = 1, shard: int = 0) -> None:
        self._launches.append(
            LaunchRecord(pipeline, shape, int(real), int(padded), t,
                         variant, int(coalesced), float(measured),
                         int(mesh), int(shard)))

    def record_job(self, pipeline: str, submitted_at: float,
                   finished_at: float,
                   priority: str = "best_effort") -> None:
        self._jobs[pipeline].append((submitted_at, finished_at, priority))

    def record_drop(self, pipeline: str, t: float,
                    priority: str = "best_effort",
                    reason: str = "expired") -> None:
        self._drops.append(DropRecord(pipeline, t, priority, reason))

    def record_preempt(self, pipeline: str, jobs: int, t: float) -> None:
        self._preempts[pipeline] += int(jobs)

    def record_retry(self, pipeline: str, t: float,
                     reason: str = "launch_failed") -> None:
        self._retries[pipeline] += 1

    def record_fail(self, pipeline: str, t: float,
                    priority: str = "best_effort",
                    reason: str = "launch_failed") -> None:
        self._fails.append(FailRecord(pipeline, t, priority, reason))

    def record_dag_submit(self, dag: str) -> None:
        self._dag_submits[dag] += 1

    def record_dag(self, dag: str, submitted_at: float,
                   finished_at: float, state: str,
                   priority: str = "best_effort") -> None:
        """One DAG job reaching a terminal state (``done`` / ``failed``
        / ``dropped``); latency folds only over ``done``."""
        self._dag_records.append((dag, submitted_at, finished_at, state,
                                  priority))

    def record_decode_phase(self, phase: str, seconds: float) -> None:
        """One per-request phase latency sample: ``insert`` /
        ``prefill`` / ``generate`` (see :class:`DecodeStats`)."""
        self._decode_phases[phase].append(float(seconds))

    def record_decode_step(self, tokens: int) -> None:
        """One pool-wide SPMD decode step that generated ``tokens``."""
        self._decode_steps += 1
        self._decode_tokens += int(tokens)

    def record_decode_insert(self, reused: bool) -> None:
        self._decode_reuses += bool(reused)

    def record_decode_request(self) -> None:
        self._decode_requests += 1

    def record_decode_shed(self) -> None:
        self._decode_shed += 1

    def snapshot(self) -> MetricsSnapshot:
        per: dict[str, PipelineStats] = {}
        names = (set(self._jobs) | {l.pipeline for l in self._launches}
                 | {d.pipeline for d in self._drops}
                 | {d.pipeline for d in self._fails}
                 | set(self._preempts) | set(self._retries))
        for name in sorted(names):
            jobs = self._jobs.get(name, [])
            launches = [l for l in self._launches if l.pipeline == name]
            real = sum(l.real for l in launches)
            padded = sum(l.padded for l in launches)
            dispatched = real + padded
            lat = LatencyStats.of([f - s for s, f, _ in jobs])
            by_prio: dict[str, list[float]] = collections.defaultdict(list)
            for s, f, prio in jobs:
                by_prio[prio].append(f - s)
            if jobs:
                window = (max(f for _, f, _ in jobs)
                          - min(s for s, _, _ in jobs))
                # zero-width window with jobs completed: throughput is
                # UNKNOWN (one instantaneous batch), not zero — 0.0
                # would read as a dead pipeline in SLO reports
                thr = len(jobs) / window if window > 0 else math.nan
            else:
                thr = 0.0
            per[name] = PipelineStats(
                pipeline=name,
                jobs=len(jobs),
                launches=len(launches),
                lanes_dispatched=dispatched,
                lanes_padded=padded,
                lane_utilization=(real / dispatched) if dispatched else 0.0,
                padded_lane_waste=(padded / dispatched) if dispatched
                else 0.0,
                latency=lat,
                throughput=thr,
                dispatch_counts=dict(collections.Counter(
                    l.variant for l in launches)),
                dropped=sum(1 for d in self._drops if d.pipeline == name),
                failed=sum(1 for d in self._fails if d.pipeline == name),
                retries=self._retries.get(name, 0),
                preempted=self._preempts.get(name, 0),
                lanes_coalesced=sum(l.coalesced for l in launches),
                latency_by_priority={p: LatencyStats.of(v)
                                     for p, v in sorted(by_prio.items())})
        dags: dict[str, DagStats] = {}
        dag_names = set(self._dag_submits) | {r[0]
                                              for r in self._dag_records}
        for dname in sorted(dag_names):
            recs = [r for r in self._dag_records if r[0] == dname]
            lat = [f - s for _, s, f, st, _ in recs if st == "done"]
            by_prio: dict[str, list[float]] = collections.defaultdict(list)
            for _, s, f, st, prio in recs:
                if st == "done":
                    by_prio[prio].append(f - s)
            dags[dname] = DagStats(
                dag=dname,
                submitted=self._dag_submits.get(dname, len(recs)),
                done=sum(1 for r in recs if r[3] == "done"),
                failed=sum(1 for r in recs if r[3] == "failed"),
                dropped=sum(1 for r in recs if r[3] == "dropped"),
                latency=LatencyStats.of(lat),
                latency_by_priority={p: LatencyStats.of(v)
                                     for p, v in sorted(by_prio.items())})
        decode = DecodeStats(
            requests=self._decode_requests,
            tokens=self._decode_tokens,
            steps=self._decode_steps,
            slot_reuses=self._decode_reuses,
            shed=self._decode_shed,
            insert=LatencyStats.of(self._decode_phases.get("insert", [])),
            prefill=LatencyStats.of(self._decode_phases.get("prefill", [])),
            generate=LatencyStats.of(
                self._decode_phases.get("generate", [])),
            tokens_per_step=(self._decode_tokens / self._decode_steps)
            if self._decode_steps else math.nan)
        return MetricsSnapshot(
            pipelines=per,
            dags=dags,
            decode=decode,
            launches=tuple(self._launches),
            total_jobs=sum(len(v) for v in self._jobs.values()),
            total_launches=len(self._launches),
            total_dropped=len(self._drops),
            total_preempted=sum(self._preempts.values()),
            total_coalesced=sum(l.coalesced for l in self._launches),
            total_failed=len(self._fails),
            total_retries=sum(self._retries.values()),
            faults=FaultStats(retries=sum(self._retries.values()),
                              failed_jobs=len(self._fails)))
