"""Batched decode engine: prefill + step-wise generation with slot reuse.

Continuous-batching-lite: a fixed pool of B slots; finished sequences
free their slot and the next queued request is prefilled into it.  The
decode step is one jit'd SPMD program over the whole pool (padded slots
masked — implicit vector masking over the request dimension).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg: ArchConfig, params, batch: int = 8,
                 max_len: int = 512, eos_id: int = 1, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.eos = eos_id
        self.cache = D.init_cache(cfg, batch, max_len)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t, pos: D.decode_step(p, cfg, c, t, pos))
        self._queue: list[Request] = []
        self._slots: list[Request | None] = [None] * batch

    def submit(self, req: Request):
        self._queue.append(req)

    def _prefill_slot(self, slot: int, req: Request, tokens, pos):
        """Feed the prompt token-by-token through decode_step (correct,
        simple; a fused prefill kernel is the TPU fast path)."""
        for t in req.prompt[:-1]:
            tokens[slot] = t
            logits, self.cache = self._step(
                self.params, self.cache,
                jnp.asarray(tokens)[:, None],
                jnp.full((self.batch,), pos, jnp.int32))
            pos += 1
        tokens[slot] = req.prompt[-1]
        return pos

    def run(self) -> list[Request]:
        """Lockstep pool decode (uniform positions). Simplification: all
        pool members share a position counter; real deployments use
        per-slot positions + paged caches."""
        done: list[Request] = []
        while self._queue:
            active = self._queue[: self.batch]
            self._queue = self._queue[self.batch:]
            # pad the pool
            while len(active) < self.batch:
                active.append(Request(prompt=[self.eos], max_new=0))
            tokens = np.zeros((self.batch,), np.int64)
            plen = max(len(r.prompt) for r in active)
            # right-align prompts into the shared position stream
            toks = np.full((self.batch, plen), self.eos, np.int64)
            for i, r in enumerate(active):
                toks[i, plen - len(r.prompt):] = r.prompt
            pos = 0
            for j in range(plen - 1):
                _, self.cache = self._step(
                    self.params, self.cache, jnp.asarray(toks[:, j:j + 1]),
                    jnp.full((self.batch,), pos, jnp.int32))
                pos += 1
            cur = jnp.asarray(toks[:, -1:])
            max_new = max(r.max_new for r in active)
            for _ in range(max_new):
                logits, self.cache = self._step(
                    self.params, self.cache, cur,
                    jnp.full((self.batch,), pos, jnp.int32))
                pos += 1
                if any(r.temperature > 0 for r in active):
                    self.key, sub = jax.random.split(self.key)
                    nxt = jax.random.categorical(sub, logits)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt_np = np.asarray(nxt)
                for i, r in enumerate(active):
                    if not r.done and len(r.out) < r.max_new:
                        tok = int(nxt_np[i])
                        r.out.append(tok)
                        if tok == self.eos:
                            r.done = True
                cur = nxt[:, None]
                if all(r.done or len(r.out) >= r.max_new for r in active):
                    break
            done.extend(r for r in active if r.max_new > 0)
            # fresh cache per pool generation (slot-level reuse is the
            # paged-cache extension)
            self.cache = D.init_cache(self.cfg, self.batch, self.max_len)
        return done
