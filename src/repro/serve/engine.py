"""Back-compat shim: the serving stack now lives in the ``repro.serve``
package (core / decode / solver / mux / metrics).  Import from
``repro.serve`` directly in new code; this module keeps the original
``repro.serve.engine`` import path working (with a DeprecationWarning)."""
import warnings

warnings.warn(
    "repro.serve.engine is deprecated; import from repro.serve instead "
    "(e.g. `from repro.serve import PipelineEngine, SolverMux`)",
    DeprecationWarning, stacklevel=2)

from repro.serve.core import EngineCore, ManualClock  # noqa: F401,E402
from repro.serve.mux import SolverMux  # noqa: F401,E402
from repro.serve.solver import PipelineEngine, SolveJob  # noqa: F401,E402

__all__ = ["EngineCore", "ManualClock", "DecodeEngine", "Request",
           "SolverMux", "PipelineEngine", "SolveJob"]


def __getattr__(name):
    # lazy like repro.serve.__init__: decode drags in repro.models
    if name in ("DecodeEngine", "Request"):
        from repro.serve import decode
        return getattr(decode, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
